"""Device-aware dispatch: the cluster behind the single-device interface.

``ClusterScheduler`` exposes exactly the :class:`ExpertScheduler`
surface that ``core.pipeline`` and ``serving.controller`` already drive
(``advance`` / ``enqueue_prefetch`` / ``reconcile`` / ``demand_async`` /
``demand_union`` / ``wait_for`` / ``staged_payload`` / telemetry), and
routes each call to one of ``n_devices`` per-device schedulers:

  * **Routing** — a key that some device already *tracks* (staged, in
    flight, queued, or awaiting a top-up) goes back to that device —
    residency is sticky, so hits stay hits.  Otherwise the key's home
    device takes it; replicated experts go to the least-loaded replica
    link (:class:`~repro.cluster.links.LinkSelector`).
  * **Shared clock** — ``advance`` moves every device's scheduler in
    lockstep.  A demand stall measured on one device stalls the whole
    decode step, so ``wait_for`` re-advances the OTHER devices by the
    stalled seconds: all clocks stay equal (asserted), and transfers on
    other links keep overlapping the stall.
  * **Split unions** — a layer's union demands are per-expert calls, so
    they land on each expert's own device and the DMAs overlap across
    links; within a device the usual demand-preemption rules apply.
  * **No device→device path** — a miss is a host-tier fetch on the
    owning device's link, never a peer copy: the host record is the one
    shared source of truth (FluxMoE's residency decoupling).

With ``n_devices=1`` every call forwards to the single device-0
scheduler unchanged, which makes cluster decode bitwise- AND
timeline-identical to the plain runtime path (pinned by tests).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Hashable, List, Optional, Sequence

import numpy as np

from repro.cluster.links import ClusterEngine, LinkSelector
from repro.cluster.placement import ClusterPlan
from repro.core.offload import ExpertStore
from repro.obs.stall import StallAttribution
from repro.runtime.residency import ResidencyManager
from repro.runtime.scheduler import (ExpertScheduler, SchedulerStats,
                                     recall_from_stats)


class ClusterScheduler:
    """Route the scheduler interface across per-device schedulers."""

    def __init__(self, plan: ClusterPlan,
                 stores: Sequence[Optional[ExpertStore]],
                 residency: Sequence[Sequence[Optional[ResidencyManager]]],
                 engines: ClusterEngine, *,
                 lookahead: int = 2,
                 depth_discount: float = 0.5,
                 cancel_stale: bool = True,
                 progressive: bool = True,
                 calibrate: Optional[Callable[[float], float]] = None):
        assert len(residency) == plan.n_devices == engines.n_devices
        self.plan = plan
        self.engines = engines
        self.selector = LinkSelector(engines)
        self.devs: List[ExpertScheduler] = [
            ExpertScheduler(stores, residency[d], engines[d],
                            lookahead=lookahead,
                            depth_discount=depth_discount,
                            cancel_stale=cancel_stale,
                            progressive=progressive,
                            calibrate=calibrate)
            for d in range(plan.n_devices)]

    # -------------------------------------------------- shared attributes --
    key = staticmethod(ExpertScheduler.key)

    @property
    def clock(self) -> float:
        return self.devs[0].clock

    @property
    def lookahead(self) -> int:
        return self.devs[0].lookahead

    @property
    def progressive(self) -> bool:
        return self.devs[0].progressive

    @property
    def calibrate(self):
        return self.devs[0].calibrate

    @calibrate.setter
    def calibrate(self, fn) -> None:
        for s in self.devs:
            s.calibrate = fn

    @property
    def stats(self) -> SchedulerStats:
        """Merged per-device stats (summed field-wise, fresh object)."""
        merged = SchedulerStats()
        for s in self.devs:
            for f in dataclasses.fields(SchedulerStats):
                setattr(merged, f.name,
                        getattr(merged, f.name) + getattr(s.stats, f.name))
        return merged

    @property
    def attribution(self) -> StallAttribution:
        """Merged per-device stall attribution (fresh object).

        Conservation carries over: each device's attributor is bitwise
        lockstep with its own ``stats.stall_s``, and both merges sum the
        per-device values in the same device order."""
        merged = StallAttribution()
        for s in self.devs:
            merged = merged.merge(s.attribution)
        return merged

    @property
    def activation_freqs(self) -> dict:
        """Merged per-(layer, expert) demand counts across devices."""
        out: dict = {}
        for s in self.devs:
            for k, v in s.activation_freqs.items():
                out[k] = out.get(k, 0) + v
        return out

    # ------------------------------------------------------------ routing --
    def _locate(self, layer: int, expert: int) -> Optional[int]:
        """Device already tracking (layer, expert), else None."""
        for d in self.plan.devices_of(layer, expert):
            if self.devs[d].tracks(layer, expert):
                return d
        return None

    def _route(self, layer: int, expert: int) -> int:
        d = self._locate(layer, expert)
        if d is not None:
            return d
        homes = self.plan.devices_of(layer, expert)
        if len(homes) == 1:
            return homes[0]
        return self.selector.pick(homes, self.clock)

    def _sticky(self, layer: int, expert: int) -> int:
        """For follow-up calls (wait/payload): the tracking device, else
        the primary home (its scheduler resolves the no-op path)."""
        d = self._locate(layer, expert)
        return self.plan.devices_of(layer, expert)[0] if d is None else d

    # -------------------------------------------------------------- clock --
    def advance(self, dt: float) -> None:
        for s in self.devs:
            s.advance(dt)

    def _sync_clocks(self, leader: int) -> None:
        """After a stall moved one device's clock, bring every other
        device forward to it (their transfers kept moving meanwhile)."""
        t = self.devs[leader].clock
        for d, s in enumerate(self.devs):
            if d != leader and s.clock < t:
                s.advance(t - s.clock)
        assert all(abs(s.clock - t) < 1e-9 for s in self.devs)

    # ----------------------------------------------------------- prefetch --
    def enqueue_prefetch(self, layer: int, expert: int,
                         channel_idx: np.ndarray, confidence: float,
                         depth: int = 1) -> None:
        self.devs[self._route(layer, expert)].enqueue_prefetch(
            layer, expert, channel_idx, confidence, depth)

    def pump(self) -> None:
        for s in self.devs:
            s.pump()

    def reconcile(self, layer: int, true_experts: Sequence[int]) -> int:
        return sum(s.reconcile(layer, true_experts) for s in self.devs)

    # ------------------------------------------------------------- demand --
    def demand_async(self, layer: int, expert: int,
                     channel_idx_fn: Callable[[], np.ndarray]) -> tuple:
        return self.devs[self._route(layer, expert)].demand_async(
            layer, expert, channel_idx_fn)

    def demand_union(self, layer: int, expert: int,
                     need_idx: np.ndarray) -> tuple:
        return self.devs[self._route(layer, expert)].demand_union(
            layer, expert, need_idx)

    def wait_for(self, layer: int, expert: int, *,
                 was_miss: bool = False) -> float:
        d = self._sticky(layer, expert)
        stall = self.devs[d].wait_for(layer, expert, was_miss=was_miss)
        if stall > 0.0:
            self._sync_clocks(d)
        return stall

    def demand(self, layer: int, expert: int,
               channel_idx_fn: Callable[[], np.ndarray]) -> tuple:
        payload, was_miss = self.demand_async(layer, expert, channel_idx_fn)
        stall = self.wait_for(layer, expert, was_miss=was_miss)
        return payload, stall

    def staged_payload(self, layer: int, expert: int) -> Optional[tuple]:
        return self.devs[self._sticky(layer, expert)].staged_payload(
            layer, expert)

    def stall_estimate(self, layer: int, expert: int) -> float:
        return self.devs[self._sticky(layer, expert)].stall_estimate(
            layer, expert)

    def hint_cause(self, layer: int, expert: int, cause: str) -> None:
        self.devs[self._sticky(layer, expert)].hint_cause(
            layer, expert, cause)

    def bump_stat(self, name: str, layer: int = 0, expert: int = 0) -> None:
        """Counter increments must land on a DEVICE scheduler: the merged
        ``stats`` property returns a fresh summed object every read, so
        ``sched.stats.x += 1`` through this interface would be dropped."""
        self.devs[self._sticky(layer, expert)].bump_stat(name)

    # ---------------------------------------------------------- telemetry --
    def overlap_efficiency(self) -> float:
        busy = self.engines.busy_seconds()
        if busy <= 0.0:
            return 1.0
        return max(0.0, 1.0 - self.stats.stall_s / busy)

    def prefetch_precision(self) -> float:
        issued = sum(s.stats.prefetch_issued for s in self.devs)
        if issued == 0:
            return 1.0
        consumed = sum(r.stats.prefetch_hits for s in self.devs
                       for r in s.residency if r is not None)
        return min(1.0, consumed / issued)

    def prefetch_recall(self) -> float:
        return recall_from_stats(self.stats)

    def reset_stats(self) -> None:
        for s in self.devs:
            s.reset_stats()
