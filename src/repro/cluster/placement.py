"""Multi-GPU expert placement: partition, replicate, and budget per device.

FloE (§3.4) treats a single PCIe link as THE bottleneck; with several
memory-constrained GPUs the system gains one host→device link per device
plus aggregate VRAM.  The win comes from *placement* (FluxMoE's
residency/compute decoupling; predictive-replication work shows the
hottest experts need more than one copy):

  * **Partition** — each MoE layer's experts are split across devices by
    a frequency-balanced deterministic greedy (hottest-first,
    least-loaded device wins, ties break to the lowest device id), so no
    single link serves all of a layer's hot traffic.
  * **Replicate** — the ``replicate`` hottest experts of every layer get
    a home on EVERY device; demand/prefetch traffic for them routes to
    the least-loaded replica link (``cluster.links.LinkSelector``),
    removing the routing hot-spots a single copy cannot.
  * **Budget** — ``plan_cluster`` re-runs the ``store.planner`` greedy
    spend per device: non-expert weights are replicated on every device,
    but each device only holds ITS experts' resident up projections, so
    at fixed per-device VRAM more devices buy more pinned experts,
    richer formats, and more residency slots per device.  For
    ``n_devices=1`` the plan is identical to ``plan_store``'s (pinned
    by a property test).

Experts never move device→device: a miss on the owning device is a
host-tier fetch over THAT device's link (the host record is shared).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.config import ModelConfig
from repro.store import formats as F
from repro.store.planner import (PlanError, StorePlan, _moe_layers,
                                 default_slab_bytes, non_expert_bytes)

Key = Tuple[int, int]  # (layer, expert)


@dataclasses.dataclass
class ClusterPlan:
    """Expert→device placement plus the per-device budget decisions.

    ``store_plan`` carries the GLOBAL per-expert format map / host budget
    (one shared host+disk tier under all devices) and is what
    ``store.build_layer_stores`` consumes; everything device-shaped
    (pins, arena slabs, replica homes) lives here.  ``store_plan=None``
    is the placement-only case: flat in-host stores, no tiering — the
    degenerate configuration the ``n_devices=1`` parity test pins
    against the single-device runtime path.
    """

    n_devices: int
    device_of: Dict[Key, Tuple[int, ...]]  # home devices, len >= 1
    pinned_per_device: List[List[Key]]
    slots_per_layer: int  # residency slots per MoE layer PER DEVICE
    slab_bytes: int
    num_slabs: List[int]  # arena slabs per device
    replicate: int = 0  # hottest experts per layer homed everywhere
    store_plan: Optional[StorePlan] = None
    vram_budget_per_device: int = 0  # bytes (0 = placement-only plan)
    breakdown_per_device: List[Dict[str, int]] = \
        dataclasses.field(default_factory=list)

    def devices_of(self, layer: int, expert: int) -> Tuple[int, ...]:
        homes = self.device_of.get((layer, expert))
        if homes is None:  # unplanned key (dense layer etc.): deterministic
            return (expert % self.n_devices,)
        return homes

    def home_experts(self, d: int) -> List[Key]:
        return [k for k, homes in sorted(self.device_of.items())
                if d in homes]

    def footprint_bytes(self, d: int) -> int:
        return sum(self.breakdown_per_device[d].values()) \
            if self.breakdown_per_device else 0

    def device_summary(self, d: int) -> str:
        n_home = len(self.home_experts(d))
        s = (f"dev{d}: experts={n_home} "
             f"pinned={len(self.pinned_per_device[d])} "
             f"slots/layer={self.slots_per_layer} slabs={self.num_slabs[d]}")
        if self.vram_budget_per_device:
            s += (f" footprint={self.footprint_bytes(d) / 2 ** 30:.3f}GiB/"
                  f"{self.vram_budget_per_device / 2 ** 30:.3f}GiB")
        return s

    def summary(self) -> str:
        pins = sum(len(p) for p in self.pinned_per_device)
        s = (f"devices={self.n_devices} replicate={self.replicate} "
             f"pinned_total={pins} slots/layer/dev={self.slots_per_layer}")
        if self.store_plan is not None:
            counts: Dict[str, int] = {}
            for name in self.store_plan.formats.values():
                counts[name] = counts.get(name, 0) + 1
            parts = " ".join(f"{k}={v}" for k, v in sorted(counts.items()))
            s += f" formats[{parts}]"
        return s


def partition_layer(freq_row: np.ndarray, n_devices: int
                    ) -> List[Tuple[int, ...]]:
    """Frequency-balanced greedy partition of one layer's experts.

    Hottest expert first, each assigned to the device with the least
    accumulated frequency (ties: fewest experts, then lowest device id;
    equal-frequency experts order by id) — the classic LPT bound keeps
    the load spread within one expert's frequency of optimal, and the
    count tie-break keeps zero/uniform-frequency rows round-robin
    instead of piling onto device 0.  Deterministic.
    """
    E = len(freq_row)
    homes: List[Tuple[int, ...]] = [()] * E
    load = [0.0] * n_devices
    count = [0] * n_devices
    for e in sorted(range(E), key=lambda e: (-float(freq_row[e]), e)):
        d = min(range(n_devices), key=lambda i: (load[i], count[i], i))
        homes[e] = (d,)
        load[d] += float(freq_row[e])
        count[d] += 1
    return homes


def uniform_cluster_plan(cfg: ModelConfig, n_devices: int, *,
                         freqs: Optional[np.ndarray] = None,
                         replicate: int = 0) -> ClusterPlan:
    """Placement-only plan (no tiered store / budget spend): partition
    every MoE layer's experts across ``n_devices`` — frequency-balanced
    when ``freqs`` is given, round-robin by expert id otherwise."""
    assert n_devices >= 1
    moe = _moe_layers(cfg)
    E = cfg.num_experts
    device_of: Dict[Key, Tuple[int, ...]] = {}
    for li in moe:
        row = (np.asarray(freqs[li], np.float64) if freqs is not None
               else np.zeros(E))
        homes = partition_layer(row, n_devices)
        for e in range(E):
            device_of[(li, e)] = homes[e]
        if replicate > 0:
            hot = sorted(range(E), key=lambda e: (-float(row[e]), e))
            for e in hot[:replicate]:
                device_of[(li, e)] = tuple(range(n_devices))
    return ClusterPlan(
        n_devices=n_devices, device_of=device_of,
        pinned_per_device=[[] for _ in range(n_devices)],
        slots_per_layer=0, slab_bytes=0, num_slabs=[0] * n_devices,
        replicate=replicate)


def plan_cluster(cfg: ModelConfig, freqs: np.ndarray, *,
                 n_devices: int, vram_gb_per_device: float,
                 host_gb: float = 8.0,
                 replicate: int = 0,
                 max_slots: Optional[int] = None,
                 max_pinned_per_device: Optional[int] = None,
                 ladder: Optional[Tuple[str, ...]] = None,
                 progressive: bool = True,
                 shadows: Optional[str] = None) -> ClusterPlan:
    """Solve placement + per-device store configuration for a cluster.

    The same deterministic greedy spend as ``store.plan_store``, run
    against per-device footprints: every device replicates the
    non-expert weights, holds resident up projections only for ITS
    experts, and carves its own slab arena.  Stages (stall-first order,
    identical to the single-device planner): residency slots to k+1 →
    pin hottest experts on their home devices → little shadows for
    speculation when ``shadows`` names a shadow format → format upgrades
    hottest first (an upgrade must fit on EVERY home device) →
    remaining slots.  Raises
    :class:`~repro.store.planner.PlanError` if any device cannot
    hold the leanest configuration.
    """
    assert n_devices >= 1
    budget = int(vram_gb_per_device * 2 ** 30)
    host_budget = int(host_gb * 2 ** 30)
    d_model, d_ff = cfg.d_model, cfg.moe_d_ff
    group = cfg.floe.quant_group
    moe = _moe_layers(cfg)
    E = cfg.num_experts
    assert moe and E, "plan_cluster needs an MoE model"
    freqs = np.asarray(freqs, np.float64)
    assert freqs.shape == (cfg.num_layers, E), freqs.shape
    if ladder is None:
        ladder = F.LADDER

    # ---- placement: balanced partition + replicate the hottest -----------
    device_of: Dict[Key, Tuple[int, ...]] = {}
    for li in moe:
        homes = partition_layer(freqs[li], n_devices)
        for e in range(E):
            device_of[(li, e)] = homes[e]
        hot = sorted(range(E), key=lambda e: (-float(freqs[li, e]), e))
        for e in hot[:replicate]:
            device_of[(li, e)] = tuple(range(n_devices))

    # ---- budget machinery (per device) -----------------------------------
    slab = default_slab_bytes(cfg)
    pin_fmt = F.get_format(ladder[-1])
    pin_span = -(-F.slice_bytes(
        d_model, F.kept_channels(d_ff, pin_fmt.keep_ratio)) // slab)
    base = non_expert_bytes(cfg)
    if max_slots is None:
        max_slots = E

    fmt: Dict[Key, str] = {(li, e): ladder[0] for li in moe
                           for e in range(E)}
    pinned: List[List[Key]] = [[] for _ in range(n_devices)]
    home_keys: List[List[Key]] = [
        [k for k in sorted(device_of) if d in device_of[k]]
        for d in range(n_devices)]
    slots = 1
    shadow_fmt = F.get_shadow_format(shadows) if shadows else None
    shadow_cost = (F.shadow_bytes(shadow_fmt, d_model, d_ff)
                   if shadow_fmt is not None else 0)
    shadow_map: Dict[Key, str] = {}

    def up_cost(d: int) -> int:
        return sum(F.expert_vram_bytes(F.get_format(fmt[k]), d_model, d_ff,
                                       group) for k in home_keys[d])

    def shadow_bytes_on(d: int) -> int:
        return sum(shadow_cost for k in home_keys[d] if k in shadow_map)

    def arena_slabs(d: int, n_slots: int) -> int:
        return len(moe) * n_slots + len(pinned[d]) * pin_span

    def total(d: int, n_slots: int) -> int:
        return (base + up_cost(d) + shadow_bytes_on(d)
                + arena_slabs(d, n_slots) * slab)

    for d in range(n_devices):
        if total(d, 1) > budget:
            raise PlanError(
                f"per-device vram budget {budget / 2 ** 30:.2f}GiB cannot "
                f"hold device {d}'s leanest configuration "
                f"({total(d, 1) / 2 ** 30:.2f}GiB: non-expert "
                f"{base / 2 ** 30:.2f} + {ladder[0]} up "
                f"{up_cost(d) / 2 ** 30:.2f} + 1-slot arena)")

    # hottest experts first, across all layers (planner's global order)
    order = sorted(((li, e) for li in moe for e in range(E)),
                   key=lambda k: (-freqs[k[0], k[1]], k[0], k[1]))

    # 2. slots to cover one decode step's routed experts (+1 lookahead);
    # uniform across devices, constrained by the tightest device
    target = min(max(2, cfg.num_experts_per_tok + 1), max_slots)
    while slots < target and all(total(d, slots + 1) <= budget
                                 for d in range(n_devices)):
        slots += 1

    # 3. pin hottest experts on their home devices (richest format).  A
    # replicated expert pins everywhere or nowhere; a device that can no
    # longer fit a pin is full — colder experts cost the same or more.
    per_dev_cap = len(moe) * max(1, -(-E // n_devices) // 2)
    if max_pinned_per_device is not None:
        per_dev_cap = min(per_dev_cap, max_pinned_per_device)
    full: set = set()
    for k in order:
        if len(full) == n_devices:
            break
        homes = device_of[k]
        if any(d in full for d in homes):
            continue
        if any(len(pinned[d]) >= per_dev_cap for d in homes):
            continue
        prev = fmt[k]
        fmt[k] = pin_fmt.name
        for d in homes:
            pinned[d].append(k)
        failed = [d for d in homes if total(d, slots) > budget]
        if failed:
            for d in homes:
                pinned[d].pop()
            fmt[k] = prev
            full.update(failed)  # only the devices that ran out: a
            # replicated pin failing on one tight device must not stop
            # single-home pinning on devices that still have headroom

    # 3b. little shadows for speculative execution — hottest first,
    # skipping pinned experts (they never miss); a shadow lands on every
    # home device or none (mirrors the single-device stage order, so
    # ``n_devices=1`` stays plan_store-identical)
    if shadow_fmt is not None:
        sh_full: set = set()
        for k in order:
            if len(sh_full) == n_devices:
                break
            homes = device_of[k]
            if any(k in pinned[d] for d in homes):
                continue
            if any(d in sh_full for d in homes):
                continue
            shadow_map[k] = shadow_fmt.name
            failed = [d for d in homes if total(d, slots) > budget]
            if failed:
                del shadow_map[k]
                sh_full.update(failed)

    # 4. per-expert format upgrades (quality/coverage), one rung per pass,
    # hottest first; an upgrade must fit on every home device
    for rung in range(1, len(ladder)):
        saturated: set = set()
        for k in order:
            if len(saturated) == n_devices:
                break
            homes = device_of[k]
            if fmt[k] != ladder[rung - 1] or any(k in pinned[d]
                                                 for d in homes):
                continue
            if any(d in saturated for d in homes):
                continue
            fmt[k] = ladder[rung]
            failed = [d for d in homes if total(d, slots) > budget]
            if failed:
                fmt[k] = ladder[rung - 1]
                saturated.update(failed)

    # 5. remainder -> more residency slots (uniform)
    while slots < max_slots and all(total(d, slots + 1) <= budget
                                    for d in range(n_devices)):
        slots += 1

    num_slabs = [arena_slabs(d, slots) for d in range(n_devices)]
    breakdown = [{"non_expert": base, "resident_up": up_cost(d),
                  "residency_arena": num_slabs[d] * slab}
                 for d in range(n_devices)]
    if shadow_fmt is not None:
        for d in range(n_devices):
            breakdown[d]["shadows"] = shadow_bytes_on(d)
    # global store plan: formats + shared host budget; ``pinned`` is the
    # de-duplicated union (replicated pins appear once) for telemetry
    seen: set = set()
    pinned_union: List[Key] = []
    for d in range(n_devices):
        for k in pinned[d]:
            if k not in seen:
                seen.add(k)
                pinned_union.append(k)
    global_breakdown = {
        "non_expert": base * n_devices,
        "resident_up": sum(up_cost(d) for d in range(n_devices)),
        "residency_arena": sum(num_slabs) * slab}
    if shadow_fmt is not None:
        global_breakdown["shadows"] = sum(shadow_bytes_on(d)
                                          for d in range(n_devices))
    store_plan = StorePlan(
        vram_budget=budget * n_devices, host_budget=host_budget,
        formats=fmt, pinned=pinned_union, slots_per_layer=slots,
        slab_bytes=slab, num_slabs=sum(num_slabs),
        breakdown=global_breakdown,
        progressive=progressive, shadows=shadow_map)
    plan = ClusterPlan(
        n_devices=n_devices, device_of=device_of, pinned_per_device=pinned,
        slots_per_layer=slots, slab_bytes=slab, num_slabs=num_slabs,
        replicate=replicate, store_plan=store_plan,
        vram_budget_per_device=budget, breakdown_per_device=breakdown)
    for d in range(n_devices):
        assert plan.footprint_bytes(d) <= budget, (d, plan.device_summary(d))
    return plan
