"""Per-device transfer links on one shared simulated clock.

Each device owns a full :class:`~repro.runtime.transfer.TransferEngine`
— its own host→device link timeline and staging buffers — so transfers
to different devices genuinely overlap: the cluster's aggregate
bandwidth is ``n_devices`` links, not one.  All engines append to ONE
shared chronological record log (the pipeline's per-token telemetry
slices it exactly as in the single-device case), and every record is
tagged with its destination device for per-link accounting.

``LinkSelector`` is the routing policy for keys with more than one home
(replicated experts) or none staged yet: pick the device whose link
frees earliest at ``now`` (``TransferEngine.link_free_at``), ties to the
lowest device id — deterministic least-loaded-link routing.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

from repro.core.offload import LinkModel
from repro.runtime.transfer import (RecordLog, TransferAggregates,
                                    TransferEngine)


class ClusterEngine:
    """``n_devices`` transfer engines sharing one record log."""

    def __init__(self, link: Optional[LinkModel] = None, *,
                 n_devices: int = 1, num_buffers: int = 2,
                 chunk_channels: int = 50):
        assert n_devices >= 1
        self.n_devices = n_devices
        self.records = RecordLog()  # shared ring, in issue order
        self.engines: List[TransferEngine] = []
        for d in range(n_devices):
            eng = TransferEngine(link, num_buffers=num_buffers,
                                 chunk_channels=chunk_channels, device_id=d)
            eng.records = self.records  # one chronological log for all
            self.engines.append(eng)

    def __getitem__(self, d: int) -> TransferEngine:
        return self.engines[d]

    # ---------------------------------------------------------- telemetry -
    # Cluster telemetry is the sum of per-engine rolling aggregates —
    # no pass over the shared (and bounded) log.
    def _agg(self) -> TransferAggregates:
        return functools.reduce(TransferAggregates.merged,
                                (e.agg for e in self.engines))

    def busy_seconds(self) -> float:
        """Aggregate link-busy seconds across every device."""
        return sum(e.agg.busy_s for e in self.engines)

    def device_busy_seconds(self, d: int) -> float:
        return self.engines[d].busy_seconds()

    def wasted_bytes(self) -> int:
        return sum(e.agg.wasted_bytes for e in self.engines)

    def aggregate_utilization(self, now: float) -> float:
        """Busy fraction of the cluster's total link-time capacity
        (``n_devices`` links × elapsed clock)."""
        cap = self.n_devices * max(now, 1e-12)
        return min(1.0, self.busy_seconds() / cap)

    def drain_events(self) -> None:
        """Retire all in-flight transfers so the tracer sees final spans."""
        for e in self.engines:
            e.drain_events()

    def summary(self) -> dict:
        agg = self._agg()
        per_dev = [self.device_busy_seconds(d)
                   for d in range(self.n_devices)]
        return {
            "devices": self.n_devices,
            "transfers": agg.transfers,
            "bytes": agg.bytes,
            "busy_s": self.busy_seconds(),
            "busy_s_per_device": per_dev,
            "demoted": agg.demoted,
            "wasted_bytes": agg.wasted_bytes,
            "disk_s": agg.disk_s,
        }


class LinkSelector:
    """Deterministic least-loaded-link routing across replica homes."""

    def __init__(self, engines: ClusterEngine):
        self.engines = engines
        self.routed: Dict[int, int] = {d: 0
                                       for d in range(engines.n_devices)}
        self.replica_choices = 0  # picks that had > 1 candidate

    def pick(self, candidates: Sequence[int], now: float) -> int:
        """The candidate device whose link can start a new transfer
        earliest; ties break to the lowest device id."""
        assert candidates, "LinkSelector.pick needs at least one candidate"
        if len(candidates) > 1:
            self.replica_choices += 1
        d = min(candidates,
                key=lambda i: (self.engines[i].link_free_at(now), i))
        self.routed[d] += 1
        return d
