"""repro.cluster — multi-GPU expert placement, replication, dispatch.

Scale-out of the single-device runtime: ``n_devices`` simulated GPUs,
each with its own host→device link and residency arena, behind the
SAME scheduler interface the pipeline and serving controller already
use.

    plan_cluster(freqs, n_devices, vram_gb_per_device)
        │ partition (freq-balanced) · replicate hottest · budget/device
        ▼
    ClusterScheduler ──route(layer, expert)──▶ per-device ExpertScheduler
        │ shared lockstep clock                   │ own TransferEngine
        ▼                                         ▼ own link timeline
    LinkSelector (least-loaded replica link)  per-device ResidencyManager

See ROADMAP.md §cluster for the architecture notes.
"""
from repro.cluster.dispatch import ClusterScheduler
from repro.cluster.links import ClusterEngine, LinkSelector
from repro.cluster.placement import (ClusterPlan, partition_layer,
                                     plan_cluster, uniform_cluster_plan)

__all__ = [
    "ClusterPlan", "plan_cluster", "uniform_cluster_plan",
    "partition_layer", "ClusterEngine", "LinkSelector", "ClusterScheduler",
]
