"""repro.store — tiered compressed expert parameter store.

The memory hierarchy the runtime schedules against (FloE's footprint
results made structural):

    DiskTier (sharded ckpt, lazy index)
        │ disk→host prefill (pipelined with staging)
    HostTier (byte-budget LRU, pinned-memory records + INT8 drafts)
        │ host→device link (TransferEngine timeline)
    DevicePool (fixed slab arena) ◀── ResidencyManager slots

``formats`` assigns each expert a storage format (up-projection precision ×
gate/down keep-ratio × progressive draft), ``planner.plan_store`` solves
formats / pinned set / pool size for a ``--vram-gb`` budget from measured
activation frequencies, and ``tiered.TieredExpertStore`` serves the runtime
through the same interface as the flat in-host store.

See ROADMAP.md §store for the architecture notes.
"""
from repro.store.formats import (FORMATS, LADDER, SHADOW_FORMATS,
                                 ExpertFormat, ShadowFormat, get_format,
                                 get_shadow_format, register_format,
                                 shadow_bytes)
from repro.store.planner import (PlanError, StorePlan, dense_residency_bytes,
                                 floor_bytes, measure_frequencies,
                                 non_expert_bytes, plan_store)
from repro.store.tiered import (TieredExpertStore, build_layer_stores,
                                warm_host_tier)
from repro.store.tiers import (DevicePool, DiskModel, DiskTier, HostTier,
                               SlabSpan, tier_key)

__all__ = [
    "ExpertFormat", "FORMATS", "LADDER", "get_format", "register_format",
    "ShadowFormat", "SHADOW_FORMATS", "get_shadow_format", "shadow_bytes",
    "StorePlan", "PlanError", "plan_store", "measure_frequencies",
    "non_expert_bytes", "dense_residency_bytes", "floor_bytes",
    "DiskTier", "DiskModel", "HostTier", "DevicePool", "SlabSpan",
    "tier_key", "TieredExpertStore", "build_layer_stores",
    "warm_host_tier",
]
