"""The three tiers of the expert parameter store.

    DiskTier   — sharded checkpoint (checkpoint.io.ShardReader): one record
                 per expert, lazy offset index, modeled NVMe latency.
    HostTier   — capacity-bounded LRU of per-expert host records (compact
                 fp16 gate/down + INT8 draft) in pinned memory; misses
                 refill from disk.
    DevicePool — slab/arena allocator for the VRAM residency pool: staged
                 slices borrow fixed-size slabs and return them on
                 eviction, so the arena NEVER grows (zero external
                 fragmentation by construction; internal slack is
                 telemetry).

The residency-decoupling direction of FluxMoE (arXiv:2604.02715): where an
expert's bytes live (disk / host / device) is decided by capacity planning,
not by the checkpoint layout.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Hashable, List, Optional, Tuple

from repro import obs
from repro.checkpoint.io import ShardReader, ShardWriter


def tier_key(layer: int, expert: int, prefix: str = "") -> str:
    """Key of one expert's record in the shared host/disk tiers.  A
    fleet deployment scopes each model's records with a ``prefix`` so
    several models can share ONE HostTier/DiskTier without key
    collisions; single-model stores keep the historical unprefixed
    layout (shards stay readable across versions)."""
    return f"{prefix}L{layer}.E{expert}"


def record_nbytes(record: dict) -> int:
    """Decoded (in-host) bytes of one expert record — what the pinned
    host budget actually holds, as opposed to the compressed on-disk
    size."""
    return int(sum(getattr(v, "nbytes", 0) for v in record.values()))


# ------------------------------------------------------------------- disk --
@dataclasses.dataclass(frozen=True)
class DiskModel:
    """NVMe-like read model (paper setup: consumer SSD under PCIe 4.0)."""

    read_bw: float = 3.5e9  # bytes/s sequential
    seek_us: float = 80.0  # per-read latency (queue + firmware)

    def read_time(self, nbytes: int, reads: int = 1) -> float:
        if nbytes == 0:
            return 0.0
        return max(reads, 1) * self.seek_us * 1e-6 + nbytes / self.read_bw


@dataclasses.dataclass
class DiskStats:
    reads: int = 0
    bytes_read: int = 0
    modeled_seconds: float = 0.0
    index_builds: int = 0  # shard-header scans; stays 1 per reader


class DiskTier:
    """Per-expert sharded checkpoint + modeled read latency."""

    def __init__(self, dirpath, *, model: Optional[DiskModel] = None):
        self.reader = ShardReader(dirpath)
        self.model = model or DiskModel()
        self.stats = DiskStats()

    @classmethod
    def build(cls, dirpath, records: Dict[str, dict], *,
              model: Optional[DiskModel] = None, level: int = 3
              ) -> "DiskTier":
        with ShardWriter(dirpath, level=level) as w:
            for k, tree in records.items():
                w.add(k, tree)
        return cls(dirpath, model=model)

    def __contains__(self, key: str) -> bool:
        return key in self.reader

    def nbytes(self, key: str) -> int:
        return self.reader.nbytes(key)

    def load(self, key: str) -> Tuple[dict, float]:
        """One expert record + its modeled read seconds (lazy: only this
        record's bytes are read and decoded; the offset index is built
        once per reader and reused across fetches — per-expert loads in
        a cluster prefill loop never re-scan the shard header)."""
        rec = self.reader.load(key)
        n = self.reader.nbytes(key)
        t = self.model.read_time(n)
        self.stats.reads += 1
        self.stats.bytes_read += n
        self.stats.modeled_seconds += t
        self.stats.index_builds = self.reader.index_builds
        return rec, t


# ------------------------------------------------------------------- host --
@dataclasses.dataclass
class HostStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class HostTier:
    """Byte-capacity-bounded LRU of host expert records (pinned memory).

    A miss pulls the record from the disk tier (returning the modeled disk
    seconds so the transfer engine can pipeline disk→host with host→device
    staging) and admits it, evicting least-recently-used records until the
    byte budget holds again."""

    def __init__(self, capacity_bytes: int, disk: Optional[DiskTier] = None):
        assert capacity_bytes > 0
        self.capacity_bytes = capacity_bytes
        self.disk = disk
        self._records: "collections.OrderedDict[str, dict]" = \
            collections.OrderedDict()
        self._nbytes: Dict[str, int] = {}
        self.bytes_in_use = 0
        self.stats = HostStats()
        # simulated clock for event stamps, bound by the owning pipeline
        # (a bare host tier without a runtime emits at t=0)
        self._clock_fn = None

    def bind_clock(self, clock_fn) -> None:
        self._clock_fn = clock_fn

    def _now(self) -> float:
        return self._clock_fn() if self._clock_fn is not None else 0.0

    def __contains__(self, key: str) -> bool:
        return key in self._records

    def __len__(self) -> int:
        return len(self._records)

    def _evict_to_fit(self, incoming: int) -> None:
        while (self._records
               and self.bytes_in_use + incoming > self.capacity_bytes):
            k, _ = self._records.popitem(last=False)
            self.bytes_in_use -= self._nbytes.pop(k)
            self.stats.evictions += 1

    def admit(self, key: str, record: dict, nbytes: int) -> None:
        if key in self._records:
            self._records.move_to_end(key)
            return
        self._evict_to_fit(nbytes)
        self._records[key] = record
        self._nbytes[key] = nbytes
        self.bytes_in_use += nbytes

    def bytes_for_prefix(self, prefix: str) -> int:
        """Resident bytes whose keys carry ``prefix`` — per-model host
        share telemetry for fleet deployments (LRU itself stays global:
        shares are an admission-time promise, not a partition)."""
        return sum(n for k, n in self._nbytes.items()
                   if k.startswith(prefix))

    def fetch(self, key: str) -> Tuple[dict, float]:
        """(record, modeled disk seconds) — 0.0 on a host hit."""
        rec = self._records.get(key)
        if rec is not None:
            self._records.move_to_end(key)
            self.stats.hits += 1
            return rec, 0.0
        self.stats.misses += 1
        assert self.disk is not None and key in self.disk, \
            f"{key} in neither host nor disk tier"
        rec, disk_s = self.disk.load(key)
        self.admit(key, rec, record_nbytes(rec))
        if obs.enabled():
            obs.emit("host.miss", self._now(), cat="tier",
                     args={"key": key, "disk_s": disk_s})
        return rec, disk_s


# ----------------------------------------------------------------- device --
@dataclasses.dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    failures: int = 0  # alloc requests the arena could not satisfy
    overflow_allocs: int = 0  # emergency slabs outside the arena
    high_water_slabs: int = 0


@dataclasses.dataclass(frozen=True)
class SlabSpan:
    """A staged slice's claim on the pool: one or more whole slabs."""

    slabs: Tuple[int, ...]
    nbytes: int  # payload bytes actually used


class DevicePool:
    """Fixed-arena slab allocator for the VRAM residency pool.

    The arena is ``num_slabs`` slabs of ``slab_bytes`` each, carved once at
    plan time.  Every allocation takes whole slabs from the free list and
    every free returns them, so external fragmentation cannot accumulate:
    ``arena_bytes`` is constant for the lifetime of the pool and
    ``free + used == num_slabs`` is a class invariant.  Oversized slices
    take a *span* of (interchangeable, not necessarily adjacent) slabs.

    If the arena is exhausted the caller is expected to evict; emergency
    overflow slabs (ids >= num_slabs) are handed out as a last resort and
    DISCARDED on free — they never join the arena, so the steady-state
    footprint still cannot grow.
    """

    def __init__(self, slab_bytes: int, num_slabs: int):
        assert slab_bytes > 0 and num_slabs >= 1
        self.slab_bytes = slab_bytes
        self.num_slabs = num_slabs
        self._free: List[int] = list(range(num_slabs))
        self._used: Dict[int, Hashable] = {}  # slab id -> owner tag
        self._overflow_next = num_slabs
        self.stats = PoolStats()

    # ---------------------------------------------------------- accounting -
    @property
    def arena_bytes(self) -> int:
        return self.slab_bytes * self.num_slabs

    @property
    def free_slabs(self) -> int:
        return len(self._free)

    @property
    def used_slabs(self) -> int:
        return len([s for s in self._used if s < self.num_slabs])

    def slabs_needed(self, nbytes: int) -> int:
        return max(1, -(-nbytes // self.slab_bytes))

    def fragmentation_bytes(self, spans) -> int:
        """Internal slack across live spans (telemetry only)."""
        return sum(len(s.slabs) * self.slab_bytes - s.nbytes for s in spans)

    # ------------------------------------------------------------- alloc ---
    def try_alloc(self, nbytes: int, owner: Hashable = None
                  ) -> Optional[SlabSpan]:
        """A span of whole slabs, or None if the arena can't satisfy it
        (caller should evict and retry)."""
        k = self.slabs_needed(nbytes)
        if k > len(self._free):
            self.stats.failures += 1
            return None
        ids = tuple(self._free[:k])
        del self._free[:k]
        for s in ids:
            self._used[s] = owner
        self.stats.allocs += 1
        self.stats.high_water_slabs = max(self.stats.high_water_slabs,
                                          len(self._used))
        return SlabSpan(ids, nbytes)

    def alloc_overflow(self, nbytes: int, owner: Hashable = None) -> SlabSpan:
        """Emergency allocation outside the arena (e.g. everything pinned).
        Overflow slabs are discarded on free — the arena never inherits
        them."""
        k = self.slabs_needed(nbytes)
        ids = tuple(range(self._overflow_next, self._overflow_next + k))
        self._overflow_next += k
        for s in ids:
            self._used[s] = owner
        self.stats.allocs += 1
        self.stats.overflow_allocs += 1
        return SlabSpan(ids, nbytes)

    def free(self, span: Optional[SlabSpan]) -> None:
        if span is None:
            return
        for s in span.slabs:
            assert s in self._used, f"double free of slab {s}"
            del self._used[s]
            if s < self.num_slabs:  # arena slab: recycle
                self._free.append(s)
            # overflow slab: discarded — the arena does not grow
        self.stats.frees += 1

    def check_invariants(self) -> None:
        arena_used = [s for s in self._used if s < self.num_slabs]
        assert len(self._free) + len(arena_used) == self.num_slabs, \
            (len(self._free), len(arena_used), self.num_slabs)
        assert len(set(self._free)) == len(self._free), "free-list dup"
        assert not (set(self._free) & set(arena_used)), "slab both states"
