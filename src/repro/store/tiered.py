"""``TieredExpertStore`` — the flat host store rewired over the tier stack.

Per MoE layer the store holds, per expert and per its *planned format*
(``repro.store.planner.StorePlan``):

  * device-resident up projection at the format's precision (fp16 dense or
    HQQ-packed INT4/INT2) — the intra-predictor input, never offloaded;
  * a host record of the kept gate/down channels (compact fp16 layout,
    ranked by ‖W_up[:, c]‖) plus, for progressive formats, an INT8 draft
    copy — living in the capacity-bounded ``HostTier``;
  * the authoritative copy of every host record in the ``DiskTier``
    (per-expert sharded checkpoint, lazy index).

``fetch_slice`` is the runtime's entry point: it intersects the request
with the format's kept set, pulls the record through host (possibly
paying a modeled disk→host read that the transfer engine pipelines with
host→device staging), and stages either the full fp16 slice or the INT8
draft.  The flat ``core.offload.ExpertStore`` remains the degenerate
one-tier case behind the same interface.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hqq
from repro.core.offload import ExpertStore, FetchInfo, LinkModel, TransferLog
from repro.store import formats as F
from repro.store.planner import StorePlan
from repro.store.tiers import (DiskModel, DiskTier, HostTier, record_nbytes,
                               tier_key)


def _draft_encode(rec: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-record symmetric INT8: (codes int8 (n, 2D), scale f16 (n, 1))."""
    rec32 = rec.astype(np.float32)
    scale = np.maximum(np.abs(rec32).max(axis=1, keepdims=True), 1e-8) / 127.0
    codes = np.clip(np.round(rec32 / scale), -127, 127).astype(np.int8)
    return codes, scale.astype(np.float16)


def _draft_decode(codes: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return (codes.astype(np.float32) *
            scale.astype(np.float32)).astype(np.float16)


class TieredExpertStore(ExpertStore):
    """One MoE layer's experts behind the disk/host/device tier stack."""

    def __init__(self, moe_params: dict, thresholds: np.ndarray, *,
                 plan: StorePlan, layer: int, host: HostTier,
                 link: Optional[LinkModel] = None,
                 quant_group: int = 64,
                 shard_writer=None,
                 key_prefix: str = ""):
        we_gate = np.asarray(moe_params["we_gate"], np.float16)
        we_down = np.asarray(moe_params["we_down"], np.float16)
        e, d, f = we_gate.shape
        self.num_experts, self.d_model, self.d_ff = e, d, f
        self.layer = layer
        self.plan = plan
        self.host = host
        self.key_prefix = key_prefix  # scopes records in SHARED tiers
        self.thresholds = np.asarray(thresholds)
        self.link = link or LinkModel()
        self.log = TransferLog()

        # ---- per-expert format, kept channel set, disk records -----------
        self.fmts: List[F.ExpertFormat] = [plan.format_for(layer, i)
                                           for i in range(e)]
        self._kept: List[np.ndarray] = []
        for i in range(e):
            fmt = self.fmts[i]
            rank = F.rank_channels_by_upnorm(moe_params["we_up"][i])
            kept = np.sort(rank[:F.kept_channels(f, fmt.keep_ratio)])
            self._kept.append(kept.astype(np.int32))
            rec = np.concatenate([we_gate[i].T[kept], we_down[i][kept]],
                                 axis=-1)  # (n_keep, 2D) compact layout
            record = {"chan_idx": self._kept[i],
                      "records": np.ascontiguousarray(rec)}
            if fmt.progressive:
                codes, scale = _draft_encode(rec)
                record["draft"] = codes
                record["draft_scale"] = scale
            if shard_writer is not None:
                shard_writer.add(self.key(i), record)
            else:  # no disk tier: records live host-side unconditionally
                self.host.admit(self.key(i), record,
                                record_nbytes(record))

        # ---- device-resident up projections at per-expert precision ------
        up = np.asarray(moe_params["we_up"], np.float32)
        self._up: List = [None] * e
        by_bits: Dict[int, List[int]] = {}
        for i, fmt in enumerate(self.fmts):
            by_bits.setdefault(fmt.up_bits, []).append(i)
        for bits, idxs in by_bits.items():
            if bits == 16:
                for i in idxs:
                    self._up[i] = jnp.asarray(up[i], jnp.float16)
            else:
                qt = hqq.quantize_per_expert(jnp.asarray(up[idxs]),
                                             bits=bits, group=quant_group)
                for j, i in enumerate(idxs):
                    self._up[i] = hqq.QTensor(
                        qt.packed[j], qt.scale[j], qt.zero[j], qt.bits,
                        qt.group, qt.shape)

    # -------------------------------------------------------------- sizes --
    @property
    def records(self):  # the flat array does not exist in the tiered store
        raise AttributeError(
            "TieredExpertStore holds no flat records array; use "
            "fetch_slice/slice_nbytes")

    def slice_nbytes(self, channel_idx, precision: str = "full") -> int:
        return F.slice_bytes(self.d_model, len(channel_idx), precision)

    def up_nbytes(self, e: int) -> int:
        u = self._up[e]
        if isinstance(u, hqq.QTensor):
            return u.nbytes
        return int(u.size * u.dtype.itemsize)

    def host_bytes(self, e: int) -> int:
        return F.host_bytes(self.fmts[e], self.d_model, self.d_ff)

    def compressed_expert_bytes(self, keep_ratio: float) -> int:
        rec = F.record_bytes(self.d_model, self.d_ff, keep_ratio)
        return rec + self.up_nbytes(0)

    # -------------------------------------------------------------- tiers --
    def key(self, e: int) -> str:
        return tier_key(self.layer, e, self.key_prefix)

    def available_channels(self, e: int) -> Optional[np.ndarray]:
        if self.fmts[e].keep_ratio >= 1.0:
            return None
        return self._kept[e]

    def progressive_available(self, e: int) -> bool:
        return self.plan.progressive and self.fmts[e].progressive

    # ------------------------------------------------------------ fetches --
    def fetch_slice(self, e: int, channel_idx: np.ndarray, *,
                    chunk_channels: int = 50, precision: str = "full"
                    ) -> tuple[np.ndarray, jax.Array, jax.Array, FetchInfo]:
        import time

        idx = np.asarray(channel_idx)
        kept = self._kept[e]
        served = idx if self.fmts[e].keep_ratio >= 1.0 else \
            np.intersect1d(idx, kept)
        record, disk_s = self.host.fetch(self.key(e))
        pos = np.searchsorted(record["chan_idx"], served)
        if precision == "draft" and "draft" in record:
            rec = _draft_decode(record["draft"][pos],
                                record["draft_scale"][pos])
        else:
            precision = "full"
            rec = record["records"][pos]
        nbytes = self.slice_nbytes(served, precision)
        chunks = max(1, -(-len(served) // max(chunk_channels, 1)))
        t0 = time.perf_counter()
        dev = jax.device_put(np.ascontiguousarray(rec))
        jax.block_until_ready(dev)
        self._account(nbytes, chunks, time.perf_counter() - t0)
        gate_cols = dev[:, :self.d_model]
        down_rows = dev[:, self.d_model:]
        return served, gate_cols, down_rows, FetchInfo(nbytes, disk_s,
                                                       precision)

    def fetch_sparse(self, e: int, channel_idx: np.ndarray,
                     chunk_channels: int = 50) -> tuple[jax.Array, jax.Array]:
        _, gate_cols, down_rows, _ = self.fetch_slice(
            e, channel_idx, chunk_channels=chunk_channels)
        return gate_cols, down_rows

    def fetch_up(self, e: int) -> hqq.QTensor:
        u = self._up[e]
        assert isinstance(u, hqq.QTensor), \
            "fetch_up on an fp16-format expert; use true_mask"
        return u

    def fetch_dense(self, e: int):
        raise NotImplementedError(
            "the tiered store has no dense-offload baseline path")

    # -------------------------------------------------- intra-mask compute -
    def true_mask(self, h: jax.Array, e: int
                  ) -> tuple[jax.Array, np.ndarray]:
        """v = h W_up at the expert's resident precision; per-row mask
        |v| >= threshold.  Returns (v (B, F) f32, mask (B, F) bool)."""
        u = self._up[e]
        if isinstance(u, hqq.QTensor):
            wu = hqq.dequantize(u, jnp.float32)
        else:
            wu = u.astype(jnp.float32)
        v = h.astype(jnp.float32) @ wu
        return v, np.asarray(jnp.abs(v) >= self.thresholds[e])


def warm_host_tier(host: HostTier,
                   entries: Sequence[Tuple[float, TieredExpertStore, int]]
                   ) -> None:
    """Prefill the host tier hottest-first under its byte budget from
    ``(freq, store, expert)`` entries — shared by the single-model build
    below and the fleet builder (which ranks across ALL models so one
    global temperature order decides residency in the shared tier)."""
    for _, store, e in sorted(entries, key=lambda t: (-t[0],
                                                      t[1].key_prefix,
                                                      t[1].layer, t[2])):
        key = store.key(e)
        if key in host:
            continue
        if host.bytes_in_use + store.host_bytes(e) > host.capacity_bytes:
            break
        rec, _ = host.disk.load(key)
        host.admit(key, rec, record_nbytes(rec))


def build_layer_stores(layers: Sequence[dict], thresholds: np.ndarray,
                       plan: StorePlan, store_dir, *,
                       link: Optional[LinkModel] = None,
                       disk_model: Optional[DiskModel] = None,
                       quant_group: int = 64,
                       freqs: Optional[np.ndarray] = None,
                       host: Optional[HostTier] = None,
                       writer=None,
                       key_prefix: str = ""
                       ) -> Tuple[List[Optional[TieredExpertStore]], HostTier]:
    """Build every MoE layer's tiered store over ONE shared disk shard +
    host tier, then warm the host tier hottest-first under its budget.

    A fleet passes its SHARED ``host`` and ``writer`` (plus a per-model
    ``key_prefix``); it then owns finalization — closing the writer,
    attaching the DiskTier, and warming globally across models — so
    those steps only run here when the writer is locally owned."""
    from repro.checkpoint.io import ShardWriter

    if host is None:
        host = HostTier(plan.host_budget)
    own_writer = writer is None
    if own_writer:
        writer = ShardWriter(store_dir)
    stores: List[Optional[TieredExpertStore]] = []
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            stores.append(None)
            continue
        stores.append(TieredExpertStore(
            layer["moe"], thresholds[li], plan=plan, layer=li, host=host,
            link=link, quant_group=quant_group, shard_writer=writer,
            key_prefix=key_prefix))
    if not own_writer:
        return stores, host
    writer.close()
    host.disk = DiskTier(store_dir, model=disk_model)

    # hottest experts become host-resident first
    entries: List[Tuple[float, TieredExpertStore, int]] = []
    for li, store in enumerate(stores):
        if store is None:
            continue
        for e in range(store.num_experts):
            f = float(freqs[li, e]) if freqs is not None else 0.0
            entries.append((f, store, e))
    warm_host_tier(host, entries)
    return stores, host
