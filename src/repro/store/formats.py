"""Per-expert storage formats and their byte accounting.

FloE stores every expert the same way (INT2 up + one keep-ratio for
gate/down).  The tiered store generalizes this into a *format registry*
(MoBiLE's big/little experts, arXiv:2510.12357): hot experts ride a richer
format than cold ones, chosen by the VRAM planner from measured activation
frequencies.

A format fixes, per expert:

  * ``up_bits``   — the device-RESIDENT up projection precision (the intra
    predictor input).  16 = dense fp16; 4/2 = HQQ-packed.
  * ``keep_ratio``— the fraction of gate/down channel records materialized
    in the host tier (ranked by ‖W_up[:, c]‖, the same statistic the
    contextual mask thresholds).  Channels outside the kept set can never
    be staged — the footprint/quality knob (coverage is logged).
  * ``progressive`` — demand fetches are served from an INT8 *draft* copy
    of the records immediately (≈half the bytes on the demand-critical
    path) and refined to full fp16 by a background transfer.

Draft records are symmetric per-channel INT8: codes (n, 2D) int8 plus one
f16 scale per channel record.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ExpertFormat:
    name: str
    up_bits: int  # 16 (dense fp16) | 8 | 4 | 2 (HQQ-packed)
    keep_ratio: float  # fraction of gate/down records in the host tier
    progressive: bool = False  # draft-then-refine demand fetches

    def __post_init__(self):
        assert self.up_bits in (16, 8, 4, 2), self.up_bits
        assert 0.0 < self.keep_ratio <= 1.0, self.keep_ratio


# Richest to leanest.  fp16 is the pinned/hot format (full records, dense
# up); int2 is the paper's cold default (FloE §3.2 with sparsity 0.8).
FORMATS: Dict[str, ExpertFormat] = {
    "fp16": ExpertFormat("fp16", 16, 1.0, progressive=True),
    "int4": ExpertFormat("int4", 4, 0.5, progressive=True),
    "int2": ExpertFormat("int2", 2, 0.3, progressive=True),
}
#: upgrade path the planner walks with spare VRAM (lean -> rich)
LADDER: Tuple[str, ...] = ("int2", "int4", "fp16")


def get_format(name: str) -> ExpertFormat:
    try:
        return FORMATS[name]
    except KeyError:
        raise KeyError(f"unknown expert format {name!r}; "
                       f"registered: {sorted(FORMATS)}") from None


def register_format(fmt: ExpertFormat) -> None:
    FORMATS[fmt.name] = fmt


@dataclasses.dataclass(frozen=True)
class ShadowFormat:
    """Always-resident "little" copy of an expert for speculative
    execution (MoBiLE's big-little experts, MELINOE's proxies): a
    low-bit snapshot of the kept gate/down channel records that lives
    permanently in device memory, so a demand miss can compute the
    token NOW from the shadow and verify-or-rollback when the big
    expert arrives.  Shadows are priced explicitly by the planner
    (``plan_store(shadows=...)``) against pins and ladder upgrades."""

    name: str
    bits: int  # record precision: 8 (the INT8 draft codes) | 2
    keep_ratio: float  # fraction of channel records in the shadow

    def __post_init__(self):
        assert self.bits in (8, 2), self.bits
        assert 0.0 < self.keep_ratio <= 1.0, self.keep_ratio


#: shadow registry: the INT8 draft records the host tier already builds
#: for progressive formats (richest little), or a leaner int2 snapshot.
SHADOW_FORMATS: Dict[str, ShadowFormat] = {
    "draft-int8": ShadowFormat("draft-int8", 8, 0.3),
    "shadow-int2": ShadowFormat("shadow-int2", 2, 0.3),
}


def get_shadow_format(name: str) -> ShadowFormat:
    try:
        return SHADOW_FORMATS[name]
    except KeyError:
        raise KeyError(f"unknown shadow format {name!r}; "
                       f"registered: {sorted(SHADOW_FORMATS)}") from None


# ------------------------------------------------------------- accounting --
def up_bytes(d_model: int, d_ff: int, bits: int, group: int = 64,
             meta_bytes: int = 2) -> int:
    """Device-resident up-projection bytes at a given precision."""
    if bits == 16:
        return d_model * d_ff * 2
    packed = d_model * d_ff * bits // 8
    meta = 2 * (d_model // group) * d_ff * meta_bytes  # f16 scale + zero
    return packed + meta


def kept_channels(d_ff: int, keep_ratio: float) -> int:
    return max(1, int(round(d_ff * keep_ratio)))


def record_bytes(d_model: int, d_ff: int, keep_ratio: float) -> int:
    """Host fp16 compact records (gate col ‖ down row) for the kept set."""
    return kept_channels(d_ff, keep_ratio) * 2 * d_model * 2


def draft_bytes(d_model: int, d_ff: int, keep_ratio: float) -> int:
    """INT8 draft copy: codes + one f16 scale per kept channel record."""
    n = kept_channels(d_ff, keep_ratio)
    return n * 2 * d_model + n * 2


def slice_bytes(d_model: int, n_channels: int, precision: str = "full") -> int:
    """Bytes moved for a staged slice of ``n_channels`` records."""
    if precision == "draft":
        return n_channels * 2 * d_model + n_channels * 2
    return n_channels * 2 * d_model * 2


def shadow_bytes(shadow: ShadowFormat, d_model: int, d_ff: int) -> int:
    """Device-resident bytes for one expert's always-on shadow copy:
    low-bit codes for the kept (gate col ‖ down row) records plus one
    f16 scale per record."""
    n = kept_channels(d_ff, shadow.keep_ratio)
    return n * 2 * d_model * shadow.bits // 8 + n * 2


def host_bytes(fmt: ExpertFormat, d_model: int, d_ff: int) -> int:
    """Host-tier bytes for one expert in this format."""
    n = record_bytes(d_model, d_ff, fmt.keep_ratio)
    if fmt.progressive:
        n += draft_bytes(d_model, d_ff, fmt.keep_ratio)
    return n


def expert_vram_bytes(fmt: ExpertFormat, d_model: int, d_ff: int,
                      group: int = 64) -> int:
    """Device-resident bytes for one expert in this format (its up proj)."""
    return up_bytes(d_model, d_ff, fmt.up_bits, group)


def rank_channels_by_upnorm(we_up: np.ndarray) -> np.ndarray:
    """Channel importance for the static keep set: ‖W_up[:, c]‖₂.

    The contextual mask keeps channels with large |x·W_up[:, c]|, so the
    column norm is the input-independent upper-bound proxy — the same
    statistic FloE's calibration thresholds."""
    return np.argsort(-np.linalg.norm(np.asarray(we_up, np.float32),
                                      axis=0), kind="stable")
