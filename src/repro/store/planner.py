"""VRAM-budget planner: solve formats / pinned set / pool size for a budget.

Given ``--vram-gb`` (and host GB) plus measured per-(layer, expert)
activation frequencies, decide:

  * the per-expert storage format (rich formats for hot experts),
  * the pinned always-resident set (hottest experts, staged full-format at
    t=0 and never evicted),
  * the residency-pool size (slots per MoE layer and the slab arena that
    backs them),

such that the modeled device footprint — non-expert weights + per-expert
resident up projections + the slab arena — fits the budget.  This is the
paper's footprint/quality knob made end-to-end: every GiB the budget grants
is spent, in priority order, on the resources that cut demand stall the
most.

The solver is deterministic and greedy, spending in stall-first order
(pinning removes a hot expert's transfers entirely; format upgrades buy
*quality* — coverage — at slightly higher per-fetch bytes):

  1. feasibility floor: every expert in the leanest format, one residency
     slot per MoE layer, nothing pinned.  Below this, raise ``PlanError``.
  2. grow residency slots to k+1 (every routed expert of a step plus one).
  3. pin the hottest experts (their staged slices live permanently in
     arena slabs; the richest ladder format).
  4. upgrade experts one format-ladder rung at a time, hottest first.
  5. spend any remainder on more residency slots.

``ladder`` restricts the format choices (e.g. ``("int2",)`` holds quality
constant so a budget sweep isolates the footprint↔stall curve).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.config import ModelConfig
from repro.store import formats as F


class PlanError(ValueError):
    """The budget cannot hold even the leanest feasible configuration."""


@dataclasses.dataclass
class StorePlan:
    """The planner's decision, consumed by the tiered store + pipeline."""

    vram_budget: int  # bytes
    host_budget: int  # bytes
    formats: Dict[Tuple[int, int], str]  # (layer, expert) -> format name
    pinned: List[Tuple[int, int]]
    slots_per_layer: int
    slab_bytes: int
    num_slabs: int  # total arena (shared across layers)
    breakdown: Dict[str, int]  # bytes per component
    progressive: bool = True
    #: (layer, expert) -> shadow format name: always-resident little
    #: copies for speculative execution (empty when speculation is off)
    shadows: Dict[Tuple[int, int], str] = \
        dataclasses.field(default_factory=dict)

    def format_for(self, layer: int, expert: int) -> F.ExpertFormat:
        return F.get_format(self.formats[(layer, expert)])

    def footprint_bytes(self) -> int:
        return sum(self.breakdown.values())

    def summary(self) -> str:
        counts: Dict[str, int] = {}
        for name in self.formats.values():
            counts[name] = counts.get(name, 0) + 1
        parts = " ".join(f"{k}={v}" for k, v in sorted(counts.items()))
        gib = self.footprint_bytes() / 2 ** 30
        return (f"footprint={gib:.3f}GiB/"
                f"{self.vram_budget / 2 ** 30:.3f}GiB "
                f"slots/layer={self.slots_per_layer} "
                f"pinned={len(self.pinned)} slabs={self.num_slabs} "
                f"formats[{parts}]")


def measure_frequencies(layers: Sequence[dict], cfg: ModelConfig, *,
                        samples: int = 128, seed: int = 9,
                        scale: float = 0.5) -> np.ndarray:
    """(L, E) expert activation frequencies from routing calibration states
    through each MoE layer's router (the same proxy distribution the
    threshold calibration uses)."""
    import jax
    from repro.models.moe import router_topk

    freqs = np.zeros((len(layers), cfg.num_experts), np.float64)
    h = jax.random.normal(jax.random.PRNGKey(seed),
                          (samples, cfg.d_model)) * scale
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        _, eids, _ = router_topk(h, layer["moe"]["router"],
                                 cfg.num_experts_per_tok)
        ids, counts = np.unique(np.asarray(eids).reshape(-1),
                                return_counts=True)
        freqs[li, ids] = counts
        freqs[li] /= max(freqs[li].sum(), 1.0)
    return freqs


def _moe_layers(cfg: ModelConfig) -> List[int]:
    out, li = [], 0
    for pattern, reps in cfg.segments():
        for _ in range(reps):
            for kind in pattern:
                if kind == "moe":
                    out.append(li)
                li += 1
    return out


def non_expert_bytes(cfg: ModelConfig, dense_bytes: int = 2) -> int:
    """Device-resident non-expert weights (attention, norms, router,
    embeddings, head) at fp16."""
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    n_moe = len(_moe_layers(cfg))
    return (cfg.param_count() - n_moe * cfg.num_experts * per_expert) \
        * dense_bytes


def dense_residency_bytes(cfg: ModelConfig, dense_bytes: int = 2) -> int:
    """Footprint of keeping EVERY weight resident at fp16 — the budget
    ceiling the planner exists to undercut."""
    return cfg.param_count() * dense_bytes


def floor_bytes(cfg: ModelConfig,
                ladder: Optional[Tuple[str, ...]] = None) -> int:
    """Footprint of the leanest feasible plan (everything in the leanest
    ladder format, one residency slot per MoE layer, no pins) — budgets
    below this raise :class:`PlanError`."""
    ladder = ladder or F.LADDER
    moe = _moe_layers(cfg)
    lean = F.get_format(ladder[0])
    up = len(moe) * cfg.num_experts * F.expert_vram_bytes(
        lean, cfg.d_model, cfg.moe_d_ff, cfg.floe.quant_group)
    return non_expert_bytes(cfg) + up + len(moe) * default_slab_bytes(cfg)


def default_slab_bytes(cfg: ModelConfig) -> int:
    """One slab holds a typical staged slice: a union channel mask at the
    calibrated sparsity (~(1-sparsity)·1.75 of d_ff) of fp16 records.
    Bigger slices take a span of slabs."""
    keep = min(1.0, (1.0 - cfg.floe.sparsity) * 1.75)
    return F.slice_bytes(cfg.d_model, F.kept_channels(cfg.moe_d_ff, keep))


def plan_store(cfg: ModelConfig, freqs: np.ndarray, *,
               vram_gb: float, host_gb: float = 8.0,
               max_slots: Optional[int] = None,
               max_pinned: Optional[int] = None,
               ladder: Optional[Tuple[str, ...]] = None,
               progressive: bool = True,
               shadows: Optional[str] = None) -> StorePlan:
    """Solve the tiered-store configuration for a VRAM budget (GiB).

    ``shadows`` names a :data:`repro.store.formats.SHADOW_FORMATS` entry
    to price always-resident little copies of every affordable expert
    into the spend (speculative execution); ``None`` (the default)
    leaves the plan bitwise identical to the shadow-free planner."""
    budget = int(vram_gb * 2 ** 30)
    host_budget = int(host_gb * 2 ** 30)
    d, f = cfg.d_model, cfg.moe_d_ff
    group = cfg.floe.quant_group
    moe = _moe_layers(cfg)
    E = cfg.num_experts
    assert moe and E, "plan_store needs an MoE model"
    freqs = np.asarray(freqs)
    assert freqs.shape == (cfg.num_layers, E), freqs.shape
    if ladder is None:
        ladder = F.LADDER

    slab = default_slab_bytes(cfg)
    # slabs a pinned expert's permanently-staged slice occupies
    pin_fmt = F.get_format(ladder[-1])
    pin_span = -(-F.slice_bytes(
        d, F.kept_channels(f, pin_fmt.keep_ratio)) // slab)
    base = non_expert_bytes(cfg)
    if max_slots is None:
        max_slots = E

    fmt: Dict[Tuple[int, int], str] = {(li, e): ladder[0]
                                       for li in moe for e in range(E)}
    pinned: List[Tuple[int, int]] = []
    slots = 1
    shadow_fmt = F.get_shadow_format(shadows) if shadows else None
    shadow_cost = (F.shadow_bytes(shadow_fmt, d, f)
                   if shadow_fmt is not None else 0)
    shadow_map: Dict[Tuple[int, int], str] = {}

    def up_cost() -> int:
        return sum(F.expert_vram_bytes(F.get_format(n), d, f, group)
                   for n in fmt.values())

    def arena_slabs(n_slots: int) -> int:
        return len(moe) * n_slots + len(pinned) * pin_span

    def total(n_slots: int) -> int:
        return (base + up_cost() + len(shadow_map) * shadow_cost
                + arena_slabs(n_slots) * slab)

    if total(1) > budget:
        raise PlanError(
            f"vram budget {budget / 2 ** 30:.2f}GiB cannot hold the leanest "
            f"store configuration ({total(1) / 2 ** 30:.2f}GiB: "
            f"non-expert {base / 2 ** 30:.2f} + {ladder[0]} up "
            f"{up_cost() / 2 ** 30:.2f} + 1-slot arena)")

    # hottest experts first, across all layers
    order = sorted(((li, e) for li in moe for e in range(E)),
                   key=lambda k: (-freqs[k[0], k[1]], k[0], k[1]))

    # 2. slots to cover one decode step's routed experts (+1 lookahead)
    target = min(max(2, cfg.num_experts_per_tok + 1), max_slots)
    while slots < target and total(slots + 1) <= budget:
        slots += 1

    # 3. pin the hottest experts: the strongest stall reducer (a pinned
    # expert never transfers again), bounded so cold-expert capacity
    # remains for the quality upgrades below
    pin_cap = len(moe) * max(1, E // 2)
    if max_pinned is not None:
        pin_cap = min(pin_cap, max_pinned)
    for k in order:
        if len(pinned) >= pin_cap:
            break
        prev = fmt[k]
        fmt[k] = pin_fmt.name  # pinned experts ride the richest format
        pinned.append(k)
        if total(slots) > budget:
            pinned.pop()
            fmt[k] = prev
            break

    # 3b. little shadows: an always-resident low-bit copy per expert so
    # a demand miss can speculate instead of stalling — hottest first
    # (hot experts miss most often), skipping pinned experts (they never
    # miss), priced against the same budget as pins and the upgrades
    # below: a shadow the pin stage already spent for simply never lands
    if shadow_fmt is not None:
        for k in order:
            if k in pinned:
                continue
            shadow_map[k] = shadow_fmt.name
            if total(slots) > budget:
                del shadow_map[k]
                break  # colder experts cost the same: stop the pass

    # 4. per-expert upgrades (quality/coverage), one rung per pass,
    # hottest first
    for rung in range(1, len(ladder)):
        for k in order:
            if fmt[k] != ladder[rung - 1] or k in pinned:
                continue
            fmt[k] = ladder[rung]
            if total(slots) > budget:
                fmt[k] = ladder[rung - 1]
                break  # colder experts cost the same or more: stop the pass

    # 5. remainder -> more residency slots
    while slots < max_slots and total(slots + 1) <= budget:
        slots += 1

    breakdown = {"non_expert": base, "resident_up": up_cost(),
                 "residency_arena": arena_slabs(slots) * slab}
    if shadow_fmt is not None:
        breakdown["shadows"] = len(shadow_map) * shadow_cost
    plan = StorePlan(
        vram_budget=budget, host_budget=host_budget, formats=fmt,
        pinned=pinned, slots_per_layer=slots, slab_bytes=slab,
        num_slabs=arena_slabs(slots), breakdown=breakdown,
        progressive=progressive, shadows=shadow_map)
    assert plan.footprint_bytes() <= budget
    return plan
