from repro.common.config import (
    FloEConfig,
    MeshConfig,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    MULTI_POD,
    SHAPES,
    SINGLE_POD,
    reduced,
)
from repro.common.sharding import logical_to_physical, shard_params_spec

__all__ = [
    "FloEConfig",
    "MeshConfig",
    "ModelConfig",
    "ShapeConfig",
    "TrainConfig",
    "MULTI_POD",
    "SHAPES",
    "SINGLE_POD",
    "reduced",
    "logical_to_physical",
    "shard_params_spec",
]
