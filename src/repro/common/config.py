"""Configuration schema for the repro framework.

Every architecture in ``repro.configs`` produces a :class:`ModelConfig`;
input shapes are :class:`ShapeConfig`; distribution is :class:`MeshConfig`.
Configs are plain frozen dataclasses so they hash, compare, and print well,
and stay jit-static when closed over.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Tuple

ArchKind = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
BlockKind = Literal["attn", "mamba2", "zamba_shared"]


@dataclass(frozen=True)
class FloEConfig:
    """Paper-technique knobs (FloE §3.2-3.4)."""

    enabled: bool = False
    # contextual sparsification of gate/down (S_t on |x W_up|), target ratio.
    sparsity: float = 0.8
    # ultra-low-bit quantization of the up projection.
    up_bits: int = 2
    # group size for HQQ quantization groups.
    quant_group: int = 64
    # sparsity mask granularity in channels (TPU lane-block adaptation).
    block_size: int = 128
    # predictors
    inter_predictor_hidden: int = 1024  # 0 => linear predictor
    # expert cache: number of resident expert slots per layer (serving).
    cache_slots: int = 2


@dataclass(frozen=True)
class ModelConfig:
    """A complete architecture description."""

    name: str
    kind: ArchKind
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads

    # --- attention ---
    rope_theta: float = 10000.0
    use_rope: bool = True
    sliding_window: int = 0  # 0 => full attention
    causal: bool = True  # False for encoder-only

    # --- MoE ---
    num_experts: int = 0  # 0 => dense MLP
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0  # expert hidden dim; 0 => d_ff
    router_aux_coef: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0  # N (state dim per head); 0 => no ssm
    ssm_heads: int = 0  # number of SSD heads; 0 => derived
    ssm_head_dim: int = 64  # P (channels per head)
    ssm_expand: int = 2  # d_inner = expand * d_model
    ssm_chunk: int = 128  # SSD chunk length
    ssm_conv_width: int = 4

    # --- hybrid layout (zamba2-style) ---
    # pattern of block kinds, tiled over num_layers. () => derived from kind.
    block_pattern: Tuple[BlockKind, ...] = ()
    # zamba: one *shared* transformer block applied every k mamba blocks.
    shared_attn_every: int = 0
    # llama4-style: every `moe_every`-th block uses MoE, others dense MLP.
    moe_every: int = 1

    # --- frontends (stub carve-out) ---
    # "none" | "audio" (frame embeddings) | "vision" (patch embeddings)
    frontend: str = "none"
    frontend_tokens: int = 0  # prepended embedding tokens for vlm

    # --- activations / norm ---
    mlp_activation: str = "swiglu"  # "swiglu" | "gelu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- paper technique ---
    floe: FloEConfig = field(default_factory=FloEConfig)

    # --- citation ---
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.moe_d_ff == 0 and self.num_experts:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.kind in ("ssm", "hybrid") and self.ssm_heads == 0 and self.ssm_state:
            d_inner = self.ssm_expand * self.d_model
            object.__setattr__(self, "ssm_heads", d_inner // self.ssm_head_dim)

    # ---- derived quantities -------------------------------------------------
    @property
    def d_head(self) -> int:
        return self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    def segments(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Layer stack as (pattern, repeats) segments for scan-over-layers.

        Block kinds: "dense" (attn+MLP), "moe" (attn+MoE), "mamba" (Mamba2
        mixer), "shared" (zamba2 shared transformer block with per-invocation
        input projection).
        """
        L = self.num_layers
        if self.kind == "ssm":
            return ((("mamba",), L),)
        if self.kind == "hybrid" and self.shared_attn_every > 1:
            k = self.shared_attn_every
            per = (("mamba",) * (k - 1)) + ("shared",)
            reps, rem = divmod(L, k)
            segs: list = []
            if reps:
                segs.append((per, reps))
            if rem:
                segs.append((("mamba",), rem))
            return tuple(segs)
        if self.is_moe:
            if self.moe_every > 1:
                per = (("dense",) * (self.moe_every - 1)) + ("moe",)
                reps, rem = divmod(L, self.moe_every)
                segs = []
                if reps:
                    segs.append((per, reps))
                if rem:
                    segs.append((("dense",), rem))
                return tuple(segs)
            return ((("moe",), L),)
        return ((("dense",), L),)

    def pattern(self) -> Tuple[BlockKind, ...]:
        """Resolved per-layer block kinds of length num_layers."""
        if self.block_pattern:
            pat = self.block_pattern
            reps = -(-self.num_layers // len(pat))
            return tuple((pat * reps)[: self.num_layers])
        if self.kind == "ssm":
            return ("mamba2",) * self.num_layers
        return ("attn",) * self.num_layers

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings and self.causal:
            n += self.vocab_size * self.d_model  # lm head
        for kind in self.pattern():
            n += self.block_param_count(kind)
        n += self.d_model  # final norm
        return n

    def block_param_count(self, kind: BlockKind) -> int:
        d = self.d_model
        if kind == "mamba2":
            d_in = self.d_inner
            conv_dim = d_in + 2 * self.ssm_state  # x, B, C (n_groups=1)
            n = d * (d_in + conv_dim + self.ssm_heads)  # in_proj
            n += self.ssm_conv_width * conv_dim + conv_dim  # conv w + b
            n += 3 * self.ssm_heads  # A_log, D, dt_bias
            n += d_in  # gated rmsnorm
            n += d_in * d  # out proj
            n += d  # pre-norm
            return n
        # attention part
        hd = self.head_dim
        n = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (self.num_heads * hd) * d
        n += 2 * d  # norms
        if kind == "zamba_shared":
            n += d * d  # input concat-projection for shared block
        # mlp part
        if self.is_moe:
            n += self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
        else:
            if self.mlp_activation == "swiglu":
                n += 3 * d * self.d_ff
            else:
                n += 2 * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        n = self.param_count()
        per_expert = 3 * self.d_model * self.moe_d_ff
        n -= len([k for k in self.pattern() if k != "mamba2"]) * (
            (self.num_experts - self.num_experts_per_tok) * per_expert
        )
        return n


@dataclass(frozen=True)
class ShapeConfig:
    """An input-shape workload."""

    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class TrainConfig:
    """Training-run hyperparameters."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    max_grad_norm: float = 1.0
    remat: bool = True
    seed: int = 0


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 256,
            max_experts: int = 4, vocab: int = 512) -> ModelConfig:
    """A smoke-test-sized variant of the same architecture family."""
    heads = max(2, min(cfg.num_heads, d_model // 64))
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    num_experts = min(cfg.num_experts, max_experts)
    updates = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=d_model * 3,
        moe_d_ff=d_model * 2 if num_experts else 0,
        vocab_size=vocab,
        num_experts=num_experts,
        num_experts_per_tok=min(cfg.num_experts_per_tok, num_experts) if num_experts else 0,
        ssm_heads=0,  # re-derived in __post_init__
        ssm_head_dim=32,
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_chunk=32,
        sliding_window=min(cfg.sliding_window, 128) if cfg.sliding_window else 0,
        frontend_tokens=min(cfg.frontend_tokens, 16) if cfg.frontend_tokens else 0,
        block_pattern=cfg.block_pattern,
        name=cfg.name + "-reduced",
    )
    return dataclasses.replace(cfg, **updates)
