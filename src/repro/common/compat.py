"""Version compatibility shims for the jax APIs this repo leans on.

The codebase targets current jax (``jax.shard_map``, ``jax.sharding.
AxisType``); older runtimes (0.4.x) ship the same functionality under
``jax.experimental.shard_map`` and without explicit axis types.  Routing
every use through this module keeps model code on the modern spelling.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.5: experimental home, and check_vma was named check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(*args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(*args, **kwargs)


def mesh_kwargs(n_axes: int) -> dict:
    """kwargs for ``jax.make_mesh``: explicit Auto axis types when the
    installed jax supports them, nothing otherwise."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}
