"""Logical→physical sharding rules.

Parameters are annotated by *path naming convention*: the trailing dict key of
each leaf determines its logical axes, and a config-aware rules table maps
logical axes to mesh axes.  This mirrors the MaxText-style logical-axis-rules
approach while keeping model code free of sharding concerns.

Default physical mapping (single pod, mesh ("data", "model")):

  vocab / ffn / experts / inner / ssm_heads -> "model"   (tensor parallel)
  heads or head_dim (see below)             -> "model"
  embed                                     -> "data"    (FSDP)
  layers / scalars / norms                  -> replicated
  batch                                     -> ("pod","data")

Attention sharding mode is chosen per architecture:
  * "head":     q/k/v sharded over the head axis.   Requires BOTH
                num_heads % model == 0 and num_kv_heads % model == 0.
  * "head_dim": q/k/v sharded over head_dim (Megatron-style contraction
                with psum on QK^T and WO).  Used for GQA archs whose kv
                head count is smaller than the model axis (glm4 kv=2,
                mistral-large kv=8, ...) and for non-divisible head counts
                (starcoder2 36H, llama4 40H, smollm 9H).
  * "replicated": fallback when neither divides.

Non-divisible vocab (hubert 504, mamba2 50280) falls back to replicated
embedding/head — recorded by ``check_divisibility``.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import MeshConfig, ModelConfig

# Leaf-name -> logical axes. A leading "layers" axis (from scan-over-layers
# stacking) is padded automatically when the leaf has extra dims.
_LOGICAL_RULES: dict[str, Tuple[Optional[str], ...]] = {
    # embedding / head
    "embedding": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "pos_embedding": (None, "embed"),
    "frontend_proj": (None, "embed"),
    # attention, head-structured
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv", "head_dim"),
    "wv": ("embed", "kv", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "shared_in": ("embed2", "embed"),
    # dense swiglu / gelu mlp
    "w_gate": ("embed", "ffn"),
    "w_up": ("embed", "ffn"),
    "w_down": ("ffn", "embed"),
    "w_in": ("embed", "ffn"),
    "w_out": ("ffn", "embed"),
    # moe
    "router": ("embed", "experts"),
    "we_gate": ("experts", "embed", "ffn"),
    "we_up": ("experts", "embed", "ffn"),
    "we_down": ("experts", "ffn", "embed"),
    # floe compressed buffers (packed ints + scales share the expert layout)
    "we_up_q": ("experts", "embed", "ffn"),
    "we_up_scale": ("experts", "groups", "ffn"),
    "we_up_zero": ("experts", "groups", "ffn"),
    "thresholds": ("experts",),
    # mamba2 / ssd
    "in_proj": ("embed", "inner"),
    "out_proj": ("inner", "embed"),
    "conv_w": (None, "inner"),
    "conv_b": ("inner",),
    "A_log": ("ssm_heads",),
    "D": ("ssm_heads",),
    "dt_bias": ("ssm_heads",),
    "ssm_norm": ("inner",),
    # norms / scalars
    "scale": ("embed",),
    "bias": ("embed",),
    # inter-expert predictor (FloE §3.3.1)
    "p_w1": ("embed", "pffn"),
    "p_w2": ("pffn", "experts"),
    "p_b1": ("pffn",),
    "p_b2": ("experts",),
}


def attn_mode(cfg: ModelConfig, model_size: int) -> str:
    """"head": Q heads shard over model (KV too when divisible);
    "seq": context parallelism (query-sequence sharding) for head counts
    that don't divide; "replicated" on trivial meshes."""
    if model_size <= 1:
        return "replicated"
    if cfg.num_heads % model_size == 0:
        return "head"
    return "seq"


def _physical_rules(cfg: Optional[ModelConfig],
                    mesh_axes: Sequence[str],
                    mesh_shape: Sequence[int]) -> dict[Any, Any]:
    sizes = dict(zip(mesh_axes, mesh_shape))
    model = sizes.get("model", 1)
    data = sizes.get("data", 1)
    multi_pod = "pod" in mesh_axes

    def div(n: int, axis: str, by: int) -> Optional[str]:
        return axis if (by > 0 and n % by == 0) else None

    rules: dict[Any, Any] = {
        "batch": ("pod", "data") if multi_pod else "data",
        "groups": None,
        "embed2": None,
        "pffn": None,
        None: None,
    }
    if cfg is None:
        # generic fallback: shard nothing we cannot verify.
        rules.update({k: None for k in
                      ("vocab", "ffn", "experts", "inner", "ssm_heads",
                       "heads", "head_dim", "kv", "embed")})
        return rules

    mode = attn_mode(cfg, model)
    rules["heads"] = "model" if mode == "head" else None
    rules["kv"] = "model" if (mode == "head" and
                              cfg.num_kv_heads % model == 0) else None
    rules["head_dim"] = None
    rules["vocab"] = div(cfg.vocab_size, "model", model)
    rules["ffn"] = div(cfg.moe_d_ff if cfg.is_moe else cfg.d_ff, "model", model)
    if cfg.is_moe:
        rules["experts"] = div(cfg.num_experts, "model", model)
        # if experts shard over model, expert-ffn stays unsharded (EP not TP)
        if rules["experts"] is not None:
            rules["ffn"] = None
    else:
        rules["experts"] = None
    rules["inner"] = div(cfg.d_inner, "model", model) if cfg.ssm_state else None
    rules["ssm_heads"] = div(cfg.ssm_heads, "model", model) if cfg.ssm_state else None
    rules["embed"] = div(cfg.d_model, "data", data)
    return rules


def logical_to_physical(logical: Sequence[Optional[str]],
                        mesh_axes: Sequence[str],
                        mesh_shape: Sequence[int],
                        cfg: Optional[ModelConfig] = None) -> P:
    rules = _physical_rules(cfg, mesh_axes, mesh_shape)
    return P(*(rules.get(ax, None) for ax in logical))


def _leaf_logical(path: Tuple[Any, ...], ndim: int) -> Tuple[Optional[str], ...]:
    name = None
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = str(entry.key)
            break
        if isinstance(entry, jax.tree_util.GetAttrKey):
            name = str(entry.name)
            break
    rule = _LOGICAL_RULES.get(name or "")
    if rule is None:
        return (None,) * ndim
    if len(rule) == ndim:
        return rule
    if len(rule) < ndim:  # stacked by scan-over-layers (1-2 leading dims)
        return (None,) * (ndim - len(rule)) + tuple(rule)
    return (None,) * ndim


def shard_params_spec(params: Any, mesh_axes: Sequence[str],
                      mesh_shape: Sequence[int],
                      cfg: Optional[ModelConfig] = None) -> Any:
    """PartitionSpec tree matching ``params`` (arrays or ShapeDtypeStructs)."""

    def spec(path, leaf):
        ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
        return logical_to_physical(_leaf_logical(path, ndim),
                                   mesh_axes, mesh_shape, cfg)

    return jax.tree_util.tree_map_with_path(spec, params)


def named_sharding_tree(params: Any, mesh: Mesh,
                        cfg: Optional[ModelConfig] = None) -> Any:
    specs = shard_params_spec(params, mesh.axis_names, mesh.devices.shape, cfg)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh_axes: Sequence[str], extra_dims: int = 1) -> P:
    """PartitionSpec for (batch, ...) activations."""
    batch = ("pod", "data") if "pod" in mesh_axes else "data"
    return P(batch, *([None] * extra_dims))


def kv_cache_spec(cfg: ModelConfig, mesh_axes: Sequence[str],
                  mesh_shape: Sequence[int], *, seq_sharded: bool = False) -> P:
    """KV cache (batch, seq, kv_heads, head_dim)."""
    sizes = dict(zip(mesh_axes, mesh_shape))
    model = sizes.get("model", 1)
    mode = attn_mode(cfg, model)
    kv_ax = "model" if (mode == "head" and
                        cfg.num_kv_heads % max(model, 1) == 0) else None
    batch = ("pod", "data") if "pod" in mesh_axes else "data"
    if seq_sharded:
        # batch=1 long-context decode: shard the KV sequence over data.
        return P(None, batch, kv_ax, None)
    return P(batch, None, kv_ax, None)


def check_divisibility(cfg: ModelConfig, mesh_cfg: MeshConfig) -> list[str]:
    """Human-readable report of replication fallbacks (empty = fully sharded)."""
    sizes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    model = sizes.get("model", 1)
    issues = []
    mode = attn_mode(cfg, model)
    if mode != "head":
        issues.append(
            f"attention uses {mode} sharding "
            f"(heads={cfg.num_heads}, kv={cfg.num_kv_heads} vs model={model})")
    elif cfg.num_kv_heads % model:
        issues.append(
            f"kv heads {cfg.num_kv_heads} replicated over model={model} "
            "(GQA head sharding keeps Q sharded)")
    if cfg.vocab_size % model:
        issues.append(f"vocab {cfg.vocab_size} replicated (not divisible by {model})")
    ffn = cfg.moe_d_ff if cfg.is_moe else cfg.d_ff
    if ffn and ffn % model:
        issues.append(f"d_ff {ffn} replicated")
    if cfg.is_moe and cfg.num_experts % model:
        issues.append(f"experts {cfg.num_experts} not divisible by {model}")
    return issues
