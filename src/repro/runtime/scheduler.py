"""Event-driven expert-transfer scheduler (FloE Fig. 1(c), §3.4).

The scheduler is the runtime's control plane: it owns a simulated clock,
a confidence-ordered prefetch queue, the transfer engine's staging
buffers, and per-layer residency.  Both the single-stream FloE decode
pipeline (``repro.core.pipeline``) and the batched serving engine
(``repro.serving.engine``) drive their expert movement through it.

Event model — overlap is *computed*, never hand-wired:

  * ``advance(dt)`` — compute progressed by ``dt`` modeled seconds; the
    clock moves and completed transfers retire.  Transfer time that
    elapses under ``advance`` is hidden (overlapped) by construction.
  * ``enqueue_prefetch`` / ``pump`` — speculative requests enter a
    priority queue (predictor confidence, demoted geometrically per
    lookahead layer) and are issued to the transfer engine whenever a
    staging buffer is free.
  * ``demand(...)`` — the true router needs an expert NOW.  Resident and
    ready: free.  Resident but still in flight: stall for the residual
    ``complete_t - clock``.  Queued but never issued: promoted to the
    head of the link with its *predicted* channels.  Absent: a
    synchronous demand fetch with the true channels.  Every stalled
    second is accounted against the token being decoded.
  * ``reconcile(layer, true_experts)`` — the true router has spoken:
    queued prefetches for that layer it disagrees with are cancelled
    (they never touch the link), in-flight ones are demoted in telemetry
    (their bytes were already committed to the DMA queue).

Cross-layer speculation: ``lookahead`` ≥ 2 layers are predicted each step;
deeper layers enter the queue at ``confidence × depth_discount^(depth-1)``
so near-term transfers win the link when bandwidth is scarce.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, Hashable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.offload import ExpertStore
from repro.obs.stall import StallAttribution
from repro.runtime.residency import Entry, ResidencyManager
from repro.runtime.transfer import TransferEngine, TransferRecord


@dataclasses.dataclass
class SchedulerStats:
    prefetch_enqueued: int = 0
    prefetch_issued: int = 0
    prefetch_cancelled: int = 0  # dropped from the queue before the link
    prefetch_demoted: int = 0  # stale but already on the link
    prefetch_promoted: int = 0  # demanded while still queued
    demand_fetches: int = 0
    demand_hits: int = 0  # demanded; a PREFETCH had staged it, zero wait
    residual_waits: int = 0  # demanded; a prefetch staged it, still in flight
    demand_reuse: int = 0  # demanded; an earlier DEMAND had staged it
    demand_topups: int = 0  # staged slice lacked channels; delta fetched
    topup_channels: int = 0  # channels moved by top-up fetches
    draft_fetches: int = 0  # progressive demands served from the INT8 draft
    draft_served: int = 0  # consumptions that computed on a draft payload
    refines_applied: int = 0  # background full-precision upgrades landed
    refines_dropped: int = 0  # refine stale (slice changed under it)
    spec_served: int = 0  # shadow results served in place of a wait
    spec_accepts: int = 0  # verifications that kept the shadow output
    spec_rollbacks: int = 0  # verifications that forced a recompute
    spec_declined: int = 0  # divergence gate said wait instead
    stall_s: float = 0.0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, type(getattr(self, f.name))())


def recall_from_stats(stats: SchedulerStats) -> float:
    """Prefetch recall over a stats block: demand events a prediction
    covered, over all demand events (unpredicted reuse counts against).
    One definition shared by the single-device scheduler and the
    cluster dispatcher's merged view — the semantics have shifted once
    already (``Entry.predicted`` credit) and must not diverge."""
    served = stats.demand_hits + stats.residual_waits
    total = served + stats.demand_fetches + stats.demand_reuse
    return served / total if total else 1.0


@dataclasses.dataclass
class PrefetchRequest:
    layer: int
    expert: int
    channel_idx: np.ndarray
    priority: float  # calibrated confidence x depth discount
    depth: int  # 1 = next layer, 2 = layer after, ...
    raw_priority: float = 0.0  # pre-calibration confidence x discount


class ExpertScheduler:
    """Priority prefetch + demand servicing over a simulated clock."""

    def __init__(self, stores: Sequence[Optional[ExpertStore]],
                 residency: Sequence[Optional[ResidencyManager]],
                 engine: TransferEngine, *,
                 lookahead: int = 2,
                 depth_discount: float = 0.5,
                 cancel_stale: bool = True,
                 progressive: bool = True,
                 calibrate: Optional[Callable[[float], float]] = None):
        assert lookahead >= 1
        self.stores = list(stores)
        self.residency = list(residency)
        self.engine = engine
        self.lookahead = lookahead
        self.depth_discount = depth_discount
        self.cancel_stale = cancel_stale
        # progressive precision: a demand miss on a progressive-format
        # expert stages the INT8 draft first (half the critical-path
        # bytes) and refines to full fp16 in the background.  Only takes
        # effect for stores whose format opts in (tiered store).
        self.progressive = progressive
        # Optional confidence calibration (trained-predictor control plane):
        # maps a raw predictor confidence to a calibrated one before it is
        # used as a prefetch priority / residency score.  The serving
        # controller installs a running precision-based calibrator here.
        self.calibrate = calibrate
        self.clock = 0.0
        self.stats = SchedulerStats()
        # stall attribution is stats-level bookkeeping (always on, like
        # stall_s itself): every residual wait is classified at wait_for
        # time, and attribution.total_s accumulates in lockstep with
        # stats.stall_s so the conservation invariant holds bitwise
        self.attribution = StallAttribution()
        # root-cause context for the next wait on a key, set by the
        # demand paths where the cause is known (eviction re-fetch,
        # progressive draft, cold predictor miss)
        self._attr_ctx: Dict[Hashable, str] = {}
        # per-(layer, expert) demand counts — the activation-frequency
        # telemetry placement/replication planners consume
        self.activation_freqs: Dict[Hashable, int] = {}
        self._queue: List[tuple] = []  # (-priority, seq, PrefetchRequest)
        self._queued: Dict[Hashable, PrefetchRequest] = {}
        # pending top-up completion per key: consulted by wait_for even if
        # the residency entry was evicted between demand_union and the wait
        # (the top-up's inflight record is under its own compound key)
        self._topup_ready: Dict[Hashable, float] = {}
        self._topup_rec: Dict[Hashable, TransferRecord] = {}
        self._seq = itertools.count()
        for r in self.residency:
            if r is not None:
                r.bind_clock(lambda: self.clock, engine.device_id)

    # ------------------------------------------------------------ helpers --
    @staticmethod
    def key(layer: int, expert: int) -> Hashable:
        return (layer, expert)

    def _res(self, layer: int) -> ResidencyManager:
        r = self.residency[layer]
        assert r is not None, f"layer {layer} has no residency manager"
        return r

    def tracks(self, layer: int, expert: int) -> bool:
        """This scheduler currently owns state for (layer, expert):
        staged, in flight, queued, or awaiting a top-up completion.  The
        multi-device dispatcher routes follow-up calls (demand, wait,
        payload reads) to the scheduler that tracks the key."""
        k = self.key(layer, expert)
        r = self.residency[layer]
        return ((r is not None and k in r) or k in self.engine.inflight
                or k in self._queued or k in self._topup_ready)

    # -------------------------------------------------------------- clock --
    def advance(self, dt: float) -> None:
        """Compute ran for ``dt`` modeled seconds; transfers overlap it."""
        self.clock += dt
        self.engine.poll(self.clock)
        self.pump()

    # ----------------------------------------------------------- prefetch --
    def enqueue_prefetch(self, layer: int, expert: int,
                         channel_idx: np.ndarray, confidence: float,
                         depth: int = 1) -> None:
        k = self.key(layer, expert)
        if (k in self.engine.inflight or
                (self.residency[layer] is not None and k in self._res(layer))):
            # already staged / in flight: no new transfer, but the live
            # prediction still covers an upcoming demand — mark the entry
            # so a hit credits prediction recall, not cache locality
            ent = (self._res(layer).peek(k)
                   if self.residency[layer] is not None else None)
            if ent is not None:
                ent.predicted = True
            return
        discount = self.depth_discount ** max(depth - 1, 0)
        raw_prio = float(confidence) * discount
        if self.calibrate is not None:
            confidence = self.calibrate(float(confidence))
        prio = float(confidence) * discount
        if k in self._queued:
            # fresher prediction for a still-queued request: promote its
            # priority (stale heap entry is lazily invalidated); a weaker
            # re-prediction leaves the earlier one in place
            if prio <= self._queued[k].priority:
                return
            req = PrefetchRequest(layer, expert, np.asarray(channel_idx),
                                  prio, depth, raw_prio)
            heapq.heappush(self._queue, (-prio, next(self._seq), req))
            self._queued[k] = req
            return
        req = PrefetchRequest(layer, expert, np.asarray(channel_idx),
                              prio, depth, raw_prio)
        heapq.heappush(self._queue, (-prio, next(self._seq), req))
        self._queued[k] = req
        self.stats.prefetch_enqueued += 1

    def pump(self) -> None:
        """Issue queued prefetches while a staging buffer is free."""
        while self._queue and self.engine.has_capacity(self.clock):
            _, _, req = heapq.heappop(self._queue)
            k = self.key(req.layer, req.expert)
            if self._queued.get(k) is not req:  # cancelled or promoted
                continue
            del self._queued[k]
            self._issue(req)

    def _issue(self, req: PrefetchRequest) -> Entry:
        k = self.key(req.layer, req.expert)
        payload, rec = self.engine.issue(
            self.stores[req.layer], k, req.expert, req.channel_idx,
            self.clock, kind="prefetch")
        res = self._res(req.layer)
        res.put(k, payload, ready_t=rec.complete_t, score=req.priority,
                raw_score=req.raw_priority, prefetch=True)
        self.stats.prefetch_issued += 1
        return res.peek(k)

    def reconcile(self, layer: int, true_experts: Sequence[int]) -> int:
        """True router decided: drop stale speculation for this layer.

        Returns the number of cancelled (never-issued) prefetches."""
        if not self.cancel_stale:
            return 0
        truth = set(int(e) for e in true_experts)
        cancelled = 0
        for k, req in list(self._queued.items()):
            if req.layer == layer and req.expert not in truth:
                del self._queued[k]  # heap entry lazily invalidated
                cancelled += 1
                self.stats.prefetch_cancelled += 1
        for k, rec in self.engine.inflight.items():
            if rec.kind != "prefetch":
                continue  # demand / top-up traffic (compound keys) is
            lay, e = k  # never speculative, so never demoted
            if lay == layer and e not in truth:
                if self.engine.demote(k):
                    self.stats.prefetch_demoted += 1
        self.pump()
        return cancelled

    # ------------------------------------------------------------- demand --
    def _promote_queued(self, layer: int, k: Hashable,
                        extra_idx: Optional[np.ndarray] = None) -> None:
        """A queued prediction is demanded NOW — issue its predicted
        channels (plus ``extra_idx`` true channels, if given) at demand
        priority: head of the link, preempting speculative traffic, not
        at the backlog's tail."""
        req = self._queued.pop(k)
        idx = (req.channel_idx if extra_idx is None
               else np.union1d(req.channel_idx, extra_idx))
        payload, rec = self.engine.issue(
            self.stores[layer], k, req.expert, idx, self.clock,
            kind="demand")
        self._res(layer).put(k, payload, ready_t=rec.complete_t,
                             score=req.priority,
                             raw_score=req.raw_priority, prefetch=True)
        self.stats.prefetch_issued += 1
        self.stats.prefetch_promoted += 1

    def _demand_fetch(self, layer: int, k: Hashable, expert: int,
                      idx: np.ndarray) -> tuple:
        """Cold miss: synchronous demand fetch of the true channels.

        Progressive-format experts stage the INT8 draft on the demand
        path (half the bytes → half the stall) and a background refine
        transfer upgrades the entry to full precision; ``wait_for``
        applies the upgrade once its modeled completion has passed."""
        store = self.stores[layer]
        prog = self.progressive and store.progressive_available(expert)
        res = self._res(layer)
        # classify the cold miss while the evidence is still visible:
        # residency remembers keys it evicted, so a re-fetch of one is an
        # eviction-of-future-hit, not a predictor miss
        if res.was_evicted(k):
            self._attr_ctx[k] = "eviction"
        elif prog:
            self._attr_ctx[k] = "draft_residual"
        else:
            self._attr_ctx[k] = "predictor_miss"
        payload, rec = self.engine.issue(
            store, k, expert, np.asarray(idx), self.clock, kind="demand",
            precision="draft" if prog else "full")
        res.put(k, payload, ready_t=rec.complete_t)
        ent = res.peek(k)
        ent.uses += 1  # consumed on arrival (miss already counted)
        self.stats.demand_fetches += 1
        if prog and len(payload[0]):
            full, frec = self.engine.issue(
                store, (k, "refine", next(self._seq)), expert,
                np.asarray(payload[0]), self.clock, kind="refine")
            ent.refine = (full, frec.complete_t)
            self.stats.draft_fetches += 1
        return payload

    def demand_async(self, layer: int, expert: int,
                     channel_idx_fn: Callable[[], np.ndarray]) -> tuple:
        """Locate or issue the transfer for a demanded expert WITHOUT
        waiting — the caller overlaps other experts' compute with the
        in-flight DMA and calls ``wait_for`` when the payload is needed.

        ``channel_idx_fn`` lazily produces the true channel index set —
        only evaluated on a miss (hits reuse the staged slice).  Returns
        (payload, was_miss)."""
        k = self.key(layer, expert)
        res = self._res(layer)
        if k not in res and k in self._queued:
            self._promote_queued(layer, k)
        ent = res.get(k)
        if ent is not None:
            return ent.payload, False
        return self._demand_fetch(layer, k, expert,
                                  channel_idx_fn()), True

    def wait_for(self, layer: int, expert: int, *,
                 was_miss: bool = False) -> float:
        """Block (on the modeled clock) until the expert's transfer has
        completed; returns the stalled seconds."""
        k = self.key(layer, expert)
        ent = self._res(layer).peek(k)
        rec = self.engine.inflight.get(k)
        if rec is not None:  # live record: demand preemption may have
            ready = rec.complete_t  # pushed its start back
            if ent is not None:  # a top-up may complete even later
                ready = max(ready, ent.ready_t)
        else:
            ready = ent.ready_t if ent is not None else self.clock
        topup = self._topup_ready.pop(k, None)
        if topup is not None:  # survives eviction of the entry itself
            ready = max(ready, topup)
        stall = max(0.0, ready - self.clock)
        self.activation_freqs[k] = self.activation_freqs.get(k, 0) + 1
        # ---- stall attribution: classify BEFORE the clock moves, while
        # `now` still means "when the demand arrived".  The governing
        # record is whichever transfer gates the wait (base key vs top-up).
        cause = self._attr_ctx.pop(k, None)
        trec = self._topup_rec.pop(k, None)
        gov = rec
        if trec is not None and (gov is None
                                 or trec.complete_t >= gov.complete_t):
            gov = trec
        segs = self.attribution.attribute(
            stall, self.clock, record=gov, cause=cause,
            origin_prefetch=(ent is not None and ent.origin_prefetch))
        if stall > 0.0 and obs.enabled():
            obs.emit("demand.stall", self.clock, cat="stall",
                     dur=stall, device=self.engine.device_id,
                     args={"key": repr(k), "stall_s": stall,
                           "causes": segs, "was_miss": was_miss})
        if not was_miss:
            # prediction-covered demands count toward prefetch recall:
            # either a prediction STAGED the entry (origin_prefetch) or a
            # live prediction re-named an already-staged one (predicted).
            # A repeat demand nothing predicted is plain cache reuse.
            if ent is not None and (ent.origin_prefetch or ent.predicted):
                if stall > 0.0:
                    self.stats.residual_waits += 1
                else:
                    self.stats.demand_hits += 1
            else:
                self.stats.demand_reuse += 1
        if ent is not None:
            ent.predicted = False  # consume the prediction mark
        if stall > 0.0:
            self.clock = ready
            self.engine.poll(self.clock)
        self.stats.stall_s += stall
        self._apply_refine(layer, k)
        self.pump()
        return stall

    def _apply_refine(self, layer: int, k: Hashable) -> None:
        """Land a completed background precision upgrade; a refine whose
        slice no longer matches the entry (top-up grew it) is stale and
        dropped.  Serving from the draft is counted while the refine is
        still in flight."""
        ent = self._res(layer).peek(k)
        if ent is None or ent.refine is None:
            return
        full, ready_t = ent.refine
        if not np.array_equal(np.asarray(full[0]),
                              np.asarray(ent.payload[0])):
            ent.refine = None
            self.stats.refines_dropped += 1
            if obs.enabled():
                obs.emit("refine.drop", self.clock, cat="refine",
                         device=self.engine.device_id,
                         args={"key": repr(k)})
            return
        if ready_t <= self.clock + 1e-12:
            self._res(layer).update_payload(k, full)
            ent.refine = None
            self.stats.refines_applied += 1
            if obs.enabled():
                obs.emit("refine.apply", self.clock, cat="refine",
                         device=self.engine.device_id,
                         args={"key": repr(k)})
        else:
            self.stats.draft_served += 1

    def stall_estimate(self, layer: int, expert: int) -> float:
        """The stall ``wait_for`` WOULD charge right now, with no side
        effects — the same ready-time fold (inflight record, entry
        ``ready_t``, pending top-ups) without moving the clock, popping
        context, or touching stats.  The speculative executor consults
        this to decide shadow-compute vs wait."""
        k = self.key(layer, expert)
        ent = self._res(layer).peek(k)
        rec = self.engine.inflight.get(k)
        if rec is not None:
            ready = rec.complete_t
            if ent is not None:
                ready = max(ready, ent.ready_t)
        else:
            ready = ent.ready_t if ent is not None else self.clock
        topup = self._topup_ready.get(k)
        if topup is not None:
            ready = max(ready, topup)
        return max(0.0, ready - self.clock)

    def hint_cause(self, layer: int, expert: int, cause: str) -> None:
        """Override the root-cause context for the next ``wait_for`` on
        this key (the speculative executor marks fallback waits so their
        stall lands under ``speculative_fallback``)."""
        self._attr_ctx[self.key(layer, expert)] = cause

    def bump_stat(self, name: str, layer: int = 0, expert: int = 0) -> None:
        """Increment a stats counter through the scheduler interface.

        On a single device this is ``stats.<name> += 1``; the cluster
        dispatcher overrides it to land the count on the device that
        owns (layer, expert) — its merged ``stats`` property returns a
        FRESH summed object, so mutating that directly would silently
        drop the count."""
        setattr(self.stats, name, getattr(self.stats, name) + 1)

    def staged_payload(self, layer: int, expert: int) -> Optional[tuple]:
        """The CURRENT staged payload (post-refine / post-top-up); callers
        re-read it after ``wait_for`` so compute uses the freshest slice."""
        ent = self._res(layer).peek(self.key(layer, expert))
        return None if ent is None else ent.payload

    def demand(self, layer: int, expert: int,
               channel_idx_fn: Callable[[], np.ndarray]) -> tuple:
        """Blocking demand: (payload, stall_s).  Equivalent to
        ``demand_async`` immediately followed by ``wait_for``."""
        payload, was_miss = self.demand_async(layer, expert, channel_idx_fn)
        stall = self.wait_for(layer, expert, was_miss=was_miss)
        return payload, stall

    def demand_union(self, layer: int, expert: int,
                     need_idx: np.ndarray) -> tuple:
        """Coverage-guaranteeing demand for a *union* channel set.

        The serving controller demands each routed expert once per layer
        with the union of its tokens' true channel masks.  Unlike
        ``demand_async`` — which reuses whatever slice happens to be staged
        and silently drops channels the stale slice lacks — this path
        compares the staged channel set against ``need_idx`` and issues a
        *delta* top-up fetch for only the missing channels, merging the
        payloads.  The returned slice therefore always covers ``need_idx``:
        per-request outputs become independent of cache history and batch
        composition (the bitwise swap-in conformance guarantee), and
        coverage loss can only come from prediction, never staleness.

        Returns (payload, was_miss) like ``demand_async``; call
        ``wait_for`` afterwards (top-up completion times are folded into
        the entry's ``ready_t``).

        With a tiered store the coverage guarantee is relative to the
        expert's SERVABLE channels (its format's kept set): channels
        outside it are a format/quality decision, not staleness, so the
        union is clipped before the delta is computed (otherwise every
        step would re-issue an unservable top-up).
        """
        k = self.key(layer, expert)
        res = self._res(layer)
        need_idx = np.asarray(need_idx)
        avail = self.stores[layer].available_channels(expert)
        if avail is not None:
            need_idx = np.intersect1d(need_idx, avail)
        if k not in res and k in self._queued:
            # queued prediction demanded NOW: fetch the union of its
            # predicted channels and the truth at demand priority
            self._promote_queued(layer, k, extra_idx=need_idx)
        ent = res.get(k)
        if ent is None:
            return self._demand_fetch(layer, k, expert, need_idx), True
        staged_idx = ent.payload[0]
        missing = np.setdiff1d(need_idx, staged_idx)
        if missing.size == 0:
            return ent.payload, False
        # partial hit: top up the staged slice with the missing channels
        (m_idx, m_gate, m_down), rec = self.engine.issue(
            self.stores[layer], (k, "topup", next(self._seq)), expert,
            missing, self.clock, kind="demand")
        merged_idx = np.concatenate([staged_idx, m_idx])
        order = np.argsort(merged_idx, kind="stable")
        _, s_gate, s_down = ent.payload
        merged_gate = jnp.concatenate([s_gate, m_gate], axis=0)[order]
        merged_down = jnp.concatenate([s_down, m_down], axis=0)[order]
        res.update_payload(k, (merged_idx[order], merged_gate, merged_down))
        if ent.refine is not None:  # slice grew: the in-flight refine no
            ent.refine = None  # longer matches it
            self.stats.refines_dropped += 1
        ent.ready_t = max(ent.ready_t, rec.complete_t)
        self._topup_ready[k] = max(self._topup_ready.get(k, 0.0),
                                   rec.complete_t)
        prev = self._topup_rec.get(k)
        if prev is None or rec.complete_t >= prev.complete_t:
            self._topup_rec[k] = rec
        # a top-up stall means the predictor staged the expert but got
        # its channel set wrong — a predictor miss unless a stronger
        # cause (eviction re-fetch) is already pending on this key
        self._attr_ctx.setdefault(k, "predictor_miss")
        self.stats.demand_topups += 1
        self.stats.topup_channels += int(missing.size)
        return ent.payload, False

    # ---------------------------------------------------------- telemetry --
    def overlap_efficiency(self) -> float:
        """Fraction of link busy time hidden under compute."""
        busy = self.engine.busy_seconds()
        if busy <= 0.0:
            return 1.0
        return max(0.0, 1.0 - self.stats.stall_s / busy)

    def prefetch_precision(self) -> float:
        """Issued prefetches that were actually consumed."""
        issued = self.stats.prefetch_issued
        if issued == 0:
            return 1.0
        consumed = sum(r.stats.prefetch_hits for r in self.residency
                       if r is not None)
        return min(1.0, consumed / issued)

    def prefetch_recall(self) -> float:
        """Demand events a prediction covered (staged by prediction, or
        already staged AND re-named by a live prediction), over all demand
        events.  Unpredicted demand-fetch reuse is cache locality — it
        counts against recall, not for it."""
        return recall_from_stats(self.stats)

    def reset_stats(self) -> None:
        self.stats.reset()
        # attribution accumulates in lockstep with stats.stall_s, so a
        # stats reset must clear it too or conservation breaks
        self.attribution.reset()
        self.activation_freqs.clear()
        for r in self.residency:
            if r is not None:
                r.reset_stats()
