"""repro.runtime — asynchronous expert-transfer runtime.

The layer between compression (``repro.core``) and serving
(``repro.serving``): an event-driven scheduler that owns all host→device
expert movement and residency, so that transfer genuinely overlaps
compute (FloE Fig. 1(c)) instead of being an accounting afterthought.

    predictor ──confidence──▶ ExpertScheduler ──issue──▶ TransferEngine
                                    │                        │
                              reconcile/demand          double-buffered
                                    ▼                     link timeline
                             ResidencyManager ◀──staged payloads──┘

See ROADMAP.md §runtime for the architecture notes.
"""
from repro.runtime.residency import (Entry, ResidencyManager, ResidencyStats,
                                     POLICIES)
from repro.runtime.scheduler import (ExpertScheduler, PrefetchRequest,
                                     SchedulerStats)
from repro.runtime.transfer import (RecordLog, TransferAggregates,
                                    TransferEngine, TransferRecord,
                                    coalesce_runs)

__all__ = [
    "Entry", "ResidencyManager", "ResidencyStats", "POLICIES",
    "ExpertScheduler", "PrefetchRequest", "SchedulerStats",
    "RecordLog", "TransferAggregates", "TransferEngine", "TransferRecord",
    "coalesce_runs",
]
