"""Expert residency manager — which compressed experts live in HBM.

Generalizes ``repro.core.cache.ExpertCache`` (FloE Fig. 1(b/c) ③) into the
runtime's device-memory authority: a fixed number of slots per MoE layer
holds staged expert slices, a pluggable eviction policy decides victims,
and *pinned* keys (e.g. a shared expert, or the layer-0 working set that is
demanded before any prefetch window exists) are never evicted.

Entries carry a ``ready_t`` timestamp from the transfer engine: the payload
is functionally staged at insertion (the jax arrays exist), but on the
modeled timeline it only becomes usable at ``ready_t`` — a demand arriving
earlier pays the residual wait as stall (scheduler's job, see
``runtime.scheduler``).

Policies:

* ``lru``  — least-recently-used, byte-for-byte the ``ExpertCache`` order
             (the equivalence is pinned by a test).
* ``lfu``  — least-frequently-used with LRU tie-break; favors hot experts
             under skewed routing (Zipfian expert popularity).
* ``weighted`` — predictor-weighted: victim minimizes
             ``score + use_count``, where score is the prefetch confidence
             the scheduler attached at insertion; low-confidence
             speculation is evicted before confirmed-hot experts.

Backing memory: with a ``repro.store.DevicePool`` attached, every staged
payload borrows a span of fixed-size slabs from the shared VRAM arena on
insertion and returns it on eviction/drop — the arena never grows, so
residency churn cannot fragment device memory.  Inserting into a full
arena evicts (policy order) until the span fits.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Dict, Hashable, Iterable, Optional

from repro import obs

POLICIES = ("lru", "lfu", "weighted")


def payload_nbytes(payload: Any) -> int:
    """Device bytes of a staged payload (tuple/list of arrays)."""
    if isinstance(payload, (tuple, list)):
        return int(sum(int(getattr(a, "nbytes", 0)) for a in payload))
    return int(getattr(payload, "nbytes", 0))


@dataclasses.dataclass
class ResidencyStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    insertions: int = 0
    prefetch_hits: int = 0  # first consumption of a prefetched entry
    arena_overcommit: int = 0  # inserts that grew past capacity/arena
    #                            because every resident key was pinned

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset(self) -> None:
        self.hits = self.misses = self.evictions = 0
        self.insertions = self.prefetch_hits = 0
        self.arena_overcommit = 0


@dataclasses.dataclass
class Entry:
    payload: Any
    ready_t: float = 0.0  # modeled time the transfer completes
    score: float = 0.0  # predictor confidence at insertion (calibrated)
    raw_score: float = 0.0  # pre-calibration confidence: rescoring under a
    #                         NEW calibration scale starts from this, so
    #                         scales never compound across rescore calls
    prefetch: bool = False  # True until first consumption
    origin_prefetch: bool = False  # staged by prediction (never cleared)
    predicted: bool = False  # a LIVE prediction re-named this entry since
    #                          its last consumption (recall credit even
    #                          when the bytes never had to move again)
    uses: int = 0
    slab: Any = None  # SlabSpan backing this payload (DevicePool attached)
    refine: Any = None  # (full payload, ready_t) of an in-flight
    #                     progressive-precision upgrade, else None


class ResidencyManager:
    """Fixed-capacity map of (layer, expert) -> staged payload."""

    def __init__(self, capacity: int, *, policy: str = "lru",
                 pinned: Iterable[Hashable] = (), pool=None):
        assert capacity >= 1
        if policy not in POLICIES:
            raise ValueError(f"unknown residency policy {policy!r}; "
                             f"choose from {POLICIES}")
        self.capacity = capacity
        self.policy = policy
        self.pinned = set(pinned)
        self.pool = pool  # optional repro.store.DevicePool (shared arena)
        # insertion/recency order is tracked by the OrderedDict itself
        self._slots: "collections.OrderedDict[Hashable, Entry]" = \
            collections.OrderedDict()
        self.stats = ResidencyStats()
        # keys this manager has evicted and not re-admitted since: lets
        # the scheduler classify a demand re-fetch of one as an
        # eviction-of-future-hit rather than a predictor miss
        self._evicted_keys: set = set()
        # observability context (simulated clock + device id), bound by
        # the owning scheduler so evictions can be emitted at sim time
        self._clock_fn: Optional[Callable[[], float]] = None
        self._obs_device = 0

    def bind_clock(self, clock_fn: Callable[[], float],
                   device: int = 0) -> None:
        """Attach the owning scheduler's simulated clock (event stamps)."""
        self._clock_fn = clock_fn
        self._obs_device = device

    def was_evicted(self, key: Hashable) -> bool:
        return key in self._evicted_keys

    # ------------------------------------------------------------- lookup --
    def __contains__(self, key: Hashable) -> bool:
        return key in self._slots

    def __len__(self) -> int:
        return len(self._slots)

    def keys(self):
        return list(self._slots.keys())

    def peek(self, key: Hashable) -> Optional[Entry]:
        """Entry without touching stats or recency (scheduler internals)."""
        return self._slots.get(key)

    def get(self, key: Hashable) -> Optional[Entry]:
        """Consume-path lookup: updates recency, use counts, and stats."""
        ent = self._slots.get(key)
        if ent is None:
            self.stats.misses += 1
            return None
        self._slots.move_to_end(key)
        ent.uses += 1
        self.stats.hits += 1
        if ent.prefetch:
            self.stats.prefetch_hits += 1
            ent.prefetch = False  # count once per distinct prefetch
        return ent

    # -------------------------------------------------------------- arena --
    def _evict(self, victim: Hashable) -> None:
        ent = self._slots.pop(victim)
        if self.pool is not None:
            self.pool.free(ent.slab)
        self.stats.evictions += 1
        self._evicted_keys.add(victim)
        if obs.enabled():
            t = self._clock_fn() if self._clock_fn is not None else 0.0
            obs.emit("residency.evict", t, cat="residency",
                     device=self._obs_device,
                     args={"key": repr(victim), "uses": ent.uses,
                           "score": ent.score})

    def _pool_alloc(self, key: Hashable, nbytes: int):
        """A slab span for this payload, evicting (policy order) while the
        arena is full.  Falls back to an overflow span when everything
        left is pinned — the arena itself never grows."""
        span = self.pool.try_alloc(nbytes, owner=key)
        while span is None:
            victim = self._victim(exclude=key)
            if victim is None:
                self._note_overcommit(key, nbytes)
                return self.pool.alloc_overflow(nbytes, owner=key)
            self._evict(victim)
            span = self.pool.try_alloc(nbytes, owner=key)
        return span

    def _note_overcommit(self, key: Hashable, nbytes: int) -> None:
        """An insert is about to grow past the arena/slot budget because
        everything resident is pinned.  Migration's pin/unpin churn must
        never hit this silently: count it and emit an obs event so the
        trace shows which key forced the overflow."""
        self.stats.arena_overcommit += 1
        if obs.enabled():
            t = self._clock_fn() if self._clock_fn is not None else 0.0
            obs.emit("residency.overcommit", t, cat="residency",
                     device=self._obs_device,
                     args={"key": repr(key), "nbytes": int(nbytes),
                           "resident": len(self._slots),
                           "pinned": len(self.pinned),
                           "capacity": self.capacity})

    def update_payload(self, key: Hashable, payload: Any) -> bool:
        """Swap an entry's payload in place (top-up merge / progressive
        refine), resizing its slab span to the new byte count."""
        ent = self._slots.get(key)
        if ent is None:
            return False
        ent.payload = payload
        if self.pool is not None:
            self.pool.free(ent.slab)
            ent.slab = self._pool_alloc(key, payload_nbytes(payload))
        return True

    # ------------------------------------------------------------- insert --
    def put(self, key: Hashable, payload: Any, *, ready_t: float = 0.0,
            score: float = 0.0, prefetch: bool = False,
            raw_score: Optional[float] = None) -> None:
        if raw_score is None:
            raw_score = score
        if key in self._slots:
            ent = self._slots[key]
            ent.payload = payload
            ent.ready_t = min(ent.ready_t, ready_t)
            ent.score = max(ent.score, score)
            ent.raw_score = max(ent.raw_score, raw_score)
            ent.origin_prefetch = ent.origin_prefetch or prefetch
            if self.pool is not None:
                self.pool.free(ent.slab)
                ent.slab = self._pool_alloc(key, payload_nbytes(payload))
            self._slots.move_to_end(key)
            return
        while len(self._slots) >= self.capacity:
            victim = self._victim()
            if victim is None:  # everything pinned: grow past capacity
                self._note_overcommit(key, payload_nbytes(payload))
                break
            self._evict(victim)
        ent = Entry(payload, ready_t=ready_t, score=score,
                    raw_score=raw_score, prefetch=prefetch,
                    origin_prefetch=prefetch)
        self._slots[key] = ent
        self._evicted_keys.discard(key)  # re-admitted: no longer a victim
        if self.pool is not None:
            ent.slab = self._pool_alloc(key, payload_nbytes(payload))
        self.stats.insertions += 1

    def drop(self, key: Hashable) -> bool:
        """Remove without counting an eviction (prefetch cancellation)."""
        if key in self._slots:
            ent = self._slots.pop(key)
            if self.pool is not None:
                self.pool.free(ent.slab)
            return True
        return False

    def rescore(self, key: Hashable, score: float) -> bool:
        """Replace an entry's predictor score in place (no recency touch).

        The serving controller calls this when its confidence calibration
        shifts, so the ``weighted`` eviction policy ranks already-staged
        speculation by the *current* calibrated confidence rather than the
        confidence at insertion time."""
        ent = self._slots.get(key)
        if ent is None:
            return False
        ent.score = float(score)
        return True

    def pin(self, key: Hashable) -> None:
        self.pinned.add(key)

    def unpin(self, key: Hashable) -> None:
        self.pinned.discard(key)

    # ------------------------------------------------------------ policy ---
    def _victim(self, exclude: Optional[Hashable] = None
                ) -> Optional[Hashable]:
        evictable = [k for k in self._slots
                     if k not in self.pinned and k != exclude]
        if not evictable:
            return None
        if self.policy == "lru":
            return evictable[0]  # OrderedDict front = least recent
        if self.policy == "lfu":
            # min uses; ties broken by recency order (front = older)
            return min(evictable, key=lambda k: (self._slots[k].uses,
                                                 list(self._slots).index(k)))
        # weighted: confirmed-hot (uses) and confident prefetches survive
        return min(evictable,
                   key=lambda k: (self._slots[k].score + self._slots[k].uses,
                                  list(self._slots).index(k)))

    def reset_stats(self) -> None:
        self.stats.reset()
