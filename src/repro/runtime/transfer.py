"""Double-buffered expert-transfer staging engine (FloE §3.4.2, Fig. 5/7).

Owns every host→device movement the runtime performs.  Functionally each
``issue`` gathers the requested compact records through
``ExpertStore.fetch_sparse`` (real ``jax.device_put``); on the *modeled*
timeline the transfer occupies

  * one of ``num_buffers`` pinned staging buffers (double buffering: while
    buffer A is on the link, buffer B is being packed for the next
    transfer; a third concurrent request must wait for a buffer), and
  * the single host→device link, serially (one PCIe/DMA engine).

so ``start = max(enqueue, link_free, earliest_buffer_free)`` and
``complete = start + LinkModel.transfer_time(bytes, chunks)``.  Overlap
with compute falls out of these event times — the scheduler advances a
simulated clock during compute and only waits (stalls) when a demanded
transfer has not completed yet.

Chunk coalescing: the compact layout (gate column i ‖ down row i as one
record) makes *adjacent* masked channels contiguous in host memory, so a
run of adjacent records needs one DMA descriptor and no packing.  For each
transfer we compare the pack-then-send chunking (``ceil(n/chunk)`` chunks
+ packing pass) against direct per-run descriptors and model whichever is
cheaper — scattered masks pack, clustered masks go direct (Fig. 5's
chunk-doubling generalized).

Tier awareness: a store fetch may include a disk→host stage
(``FetchInfo.disk_s`` from the tiered store).  The disk read prefills the
pinned host record chunk by chunk WHILE earlier chunks stage host→device,
so the modeled duration is the classic two-stage pipeline

    t = disk/c + (c-1)·max(disk, h2d)/c + h2d/c

rather than the serial sum — distinct bandwidths, one clock.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.core.offload import ExpertStore, LinkModel


def coalesce_runs(channel_idx: np.ndarray) -> List[Tuple[int, int]]:
    """Sorted channel indices -> maximal (start, length) adjacent runs."""
    idx = np.asarray(channel_idx)
    if idx.size == 0:
        return []
    splits = np.nonzero(np.diff(idx) != 1)[0] + 1
    runs = []
    for part in np.split(idx, splits):
        runs.append((int(part[0]), int(part.size)))
    return runs


@dataclasses.dataclass
class TransferRecord:
    """Per-transfer telemetry (modeled timeline + strategy)."""

    key: Hashable
    kind: str  # "prefetch" | "demand" | "refine"
    nbytes: int
    chunks: int
    strategy: str  # "packed" | "direct"
    enqueue_t: float
    start_t: float
    complete_t: float
    demoted: bool = False  # stale prefetch the router disagreed with
    disk_s: float = 0.0  # disk→host stage pipelined into the duration
    precision: str = "full"  # "full" | "draft" (progressive first pass)
    device: int = 0  # destination device (multi-GPU cluster; 0 otherwise)

    @property
    def duration(self) -> float:
        return self.complete_t - self.start_t


class TransferEngine:
    """Staging-buffer + link timeline over one or more ``ExpertStore``s."""

    def __init__(self, link: Optional[LinkModel] = None, *,
                 num_buffers: int = 2, chunk_channels: int = 50,
                 device_id: int = 0):
        assert num_buffers >= 1
        self.link = link or LinkModel()
        self.num_buffers = num_buffers
        self.chunk_channels = max(1, chunk_channels)
        self.device_id = device_id  # which GPU this engine's link feeds
        self._buffer_free = [0.0] * num_buffers
        self._link_free = 0.0
        self.inflight: Dict[Hashable, TransferRecord] = {}
        self.records: List[TransferRecord] = []

    # ------------------------------------------------------------ timeline -
    def active_count(self, now: float) -> int:
        """Transfers whose modeled completion is still in the future."""
        return sum(1 for r in self.inflight.values() if r.complete_t > now)

    def has_capacity(self, now: float) -> bool:
        return self.active_count(now) < self.num_buffers

    def link_free_at(self, now: float) -> float:
        """Earliest time this link can start a NEW transfer — the load
        signal a multi-device ``LinkSelector`` ranks replicas by."""
        return max(self._link_free, now)

    def poll(self, now: float) -> List[TransferRecord]:
        """Retire transfers completed by ``now`` (frees their buffers)."""
        done = [k for k, r in self.inflight.items() if r.complete_t <= now]
        out = [self.inflight.pop(k) for k in done]
        return out

    def _chunking(self, channel_idx: np.ndarray, nbytes: int
                  ) -> Tuple[int, str, float]:
        """(chunks, strategy, duration) minimizing modeled transfer time."""
        n = len(channel_idx)
        packed_chunks = max(1, -(-n // self.chunk_channels))
        t_packed = self.link.transfer_time(nbytes, packed_chunks, pinned=True)
        runs = coalesce_runs(channel_idx)
        direct_chunks = sum(max(1, -(-ln // self.chunk_channels))
                            for _, ln in runs) or 1
        t_direct = (direct_chunks * self.link.launch_us * 1e-6 +
                    nbytes / self.link.peak_bw)  # no packing pass
        if t_direct <= t_packed:
            return direct_chunks, "direct", t_direct
        return packed_chunks, "packed", t_packed

    @staticmethod
    def _pipelined(disk_s: float, h2d_s: float, chunks: int) -> float:
        """Two-stage pipeline at chunk granularity: disk→host prefill of
        chunk i overlaps host→device staging of chunk i-1."""
        c = max(chunks, 1)
        return disk_s / c + (c - 1) * max(disk_s, h2d_s) / c + h2d_s / c

    # --------------------------------------------------------------- issue -
    def issue(self, store: ExpertStore, key: Hashable, expert: int,
              channel_idx: np.ndarray, now: float, *,
              kind: str = "prefetch", precision: str = "full"
              ) -> Tuple[tuple, TransferRecord]:
        """Stage a sparse expert slice; returns (payload, record).

        payload matches the synchronous pipeline's cache payload exactly:
        ``(channel_idx, gate_cols, down_rows)`` with device-resident
        arrays, so scheduler-driven decode is bitwise-identical to the
        synchronous path.  A tiered store may serve a SUBSET of the
        requested channels (its format's kept set) and report a disk→host
        stage; a ``precision="draft"`` fetch stages the INT8 draft copy
        (about half the link bytes) for progressive refinement.
        """
        idx = np.asarray(channel_idx)
        # real movement (host gather + device_put) happens here
        served, gate_cols, down_rows, info = store.fetch_slice(
            expert, idx, chunk_channels=self.chunk_channels,
            precision=precision)
        nbytes = info.nbytes
        chunks, strategy, duration = self._chunking(served, nbytes)
        if info.disk_s > 0.0:
            duration = self._pipelined(info.disk_s, duration, chunks)
        payload = (served, gate_cols, down_rows)
        if kind == "demand":
            # demand preempts speculative traffic: it enters the link right
            # after the chunk currently in transit; queued prefetches are
            # pushed back behind it (they keep their buffers)
            start, complete = self._preempt_schedule(now, duration)
        else:
            b = int(np.argmin(self._buffer_free))
            start = max(now, self._link_free, self._buffer_free[b])
            complete = start + duration
            self._link_free = complete
            self._buffer_free[b] = complete
        rec = TransferRecord(key=key, kind=kind, nbytes=nbytes, chunks=chunks,
                             strategy=strategy, enqueue_t=now, start_t=start,
                             complete_t=complete, disk_s=info.disk_s,
                             precision=info.precision, device=self.device_id)
        self.inflight[key] = rec
        self.records.append(rec)
        return payload, rec

    def _preempt_schedule(self, now: float, duration: float
                          ) -> Tuple[float, float]:
        """Link slot for a demand transfer.  Demands are FIFO among
        themselves (non-preemptible); speculative traffic is preemptible
        at *chunk* granularity: the demand waits for any in-flight
        demands, then only for the chunk of the prefetch currently in
        transit — that prefetch's remaining chunks resume after the
        demand, and every not-yet-started prefetch queues behind it.
        The demand path stages through its own bounce buffer, so
        staging-buffer occupancy does not gate it."""
        active = [r for r in self.inflight.values() if r.complete_t > now]
        # serial link, demands first: enter after every in-flight demand
        start = max([now] + [r.complete_t for r in active
                             if r.kind == "demand"])
        # at most one prefetch physically occupies the link at `start`
        on_link = [r for r in active if r.kind != "demand"
                   and r.start_t <= start < r.complete_t]
        if on_link:
            r = min(on_link, key=lambda r: r.start_t)
            chunk_len = r.duration / max(r.chunks, 1)
            remaining = r.complete_t - start
            wait = min(remaining, chunk_len)
            start += wait
            if wait < remaining:  # preempted: its tail resumes after us
                r.complete_t += duration
        complete = start + duration
        pending = sorted((r for r in active
                          if r.start_t > now and r.kind != "demand"),
                         key=lambda r: r.start_t)
        t = max([complete] + [r.complete_t for r in on_link])
        for r in pending:
            d = r.duration
            r.start_t = max(t, r.enqueue_t)
            r.complete_t = r.start_t + d
            t = r.complete_t
        self._link_free = max(t, complete)
        comps = sorted((r.complete_t for r in active), reverse=True)
        comps = comps[: self.num_buffers]
        self._buffer_free = sorted(comps) + \
            [now] * (self.num_buffers - len(comps))
        return start, complete

    def demote(self, key: Hashable) -> bool:
        """Mark an in-flight prefetch stale (router disagreed).  The bytes
        still move (the DMA was already scheduled); telemetry records the
        waste so prefetch precision reflects it."""
        rec = self.inflight.get(key)
        if rec is not None and not rec.demoted:
            rec.demoted = True
            return True
        return False

    # ----------------------------------------------------------- telemetry -
    def _own_records(self) -> List[TransferRecord]:
        """This engine's transfers.  A cluster aliases every engine's
        ``records`` to ONE shared chronological log, so per-engine
        telemetry must filter by device (single-device engines only
        ever hold their own records — the filter is a no-op there)."""
        return [r for r in self.records if r.device == self.device_id]

    def busy_seconds(self) -> float:
        return sum(r.duration for r in self._own_records())

    def wasted_bytes(self) -> int:
        return sum(r.nbytes for r in self._own_records() if r.demoted)

    def summary(self) -> dict:
        recs = self._own_records()
        n = len(recs)
        return {
            "transfers": n,
            "bytes": sum(r.nbytes for r in recs),
            "busy_s": self.busy_seconds(),
            "demoted": sum(1 for r in recs if r.demoted),
            "wasted_bytes": self.wasted_bytes(),
            "disk_s": sum(r.disk_s for r in recs),
            "draft_transfers":
                sum(1 for r in recs if r.precision == "draft"),
            "refines": sum(1 for r in recs if r.kind == "refine"),
            "direct_fraction":
                (sum(1 for r in recs if r.strategy == "direct") / n)
                if n else 0.0,
        }
