"""Double-buffered expert-transfer staging engine (FloE §3.4.2, Fig. 5/7).

Owns every host→device movement the runtime performs.  Functionally each
``issue`` gathers the requested compact records through
``ExpertStore.fetch_sparse`` (real ``jax.device_put``); on the *modeled*
timeline the transfer occupies

  * one of ``num_buffers`` pinned staging buffers (double buffering: while
    buffer A is on the link, buffer B is being packed for the next
    transfer; a third concurrent request must wait for a buffer), and
  * the single host→device link, serially (one PCIe/DMA engine).

so ``start = max(enqueue, link_free, earliest_buffer_free)`` and
``complete = start + LinkModel.transfer_time(bytes, chunks)``.  Overlap
with compute falls out of these event times — the scheduler advances a
simulated clock during compute and only waits (stalls) when a demanded
transfer has not completed yet.

Chunk coalescing: the compact layout (gate column i ‖ down row i as one
record) makes *adjacent* masked channels contiguous in host memory, so a
run of adjacent records needs one DMA descriptor and no packing.  For each
transfer we compare the pack-then-send chunking (``ceil(n/chunk)`` chunks
+ packing pass) against direct per-run descriptors and model whichever is
cheaper — scattered masks pack, clustered masks go direct (Fig. 5's
chunk-doubling generalized).

Tier awareness: a store fetch may include a disk→host stage
(``FetchInfo.disk_s`` from the tiered store).  The disk read prefills the
pinned host record chunk by chunk WHILE earlier chunks stage host→device,
so the modeled duration is the classic two-stage pipeline

    t = disk/c + (c-1)·max(disk, h2d)/c + h2d/c

rather than the serial sum — distinct bandwidths, one clock.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Hashable, Iterator, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.offload import ExpertStore, LinkModel


def coalesce_runs(channel_idx: np.ndarray) -> List[Tuple[int, int]]:
    """Sorted channel indices -> maximal (start, length) adjacent runs."""
    idx = np.asarray(channel_idx)
    if idx.size == 0:
        return []
    splits = np.nonzero(np.diff(idx) != 1)[0] + 1
    runs = []
    for part in np.split(idx, splits):
        runs.append((int(part[0]), int(part.size)))
    return runs


@dataclasses.dataclass
class TransferRecord:
    """Per-transfer telemetry (modeled timeline + strategy)."""

    key: Hashable
    kind: str  # "prefetch" | "demand" | "refine"
    nbytes: int
    chunks: int
    strategy: str  # "packed" | "direct"
    enqueue_t: float
    start_t: float
    complete_t: float
    demoted: bool = False  # stale prefetch the router disagreed with
    disk_s: float = 0.0  # disk→host stage pipelined into the duration
    precision: str = "full"  # "full" | "draft" (progressive first pass)
    device: int = 0  # destination device (multi-GPU cluster; 0 otherwise)
    h2d_s: float = 0.0  # pure host→device time before disk pipelining
    seq: int = -1  # position in the append-order log (monotonic)

    @property
    def duration(self) -> float:
        return self.complete_t - self.start_t


class RecordLog:
    """Bounded ring of recent transfer records.

    The full history used to live in an ever-growing list that cluster
    engines aliased and telemetry re-filtered on every stats call.
    Aggregates are now maintained incrementally (:class:`TransferAggregates`)
    so the log only has to serve the tracer and tests: a ``deque`` keeps
    the most recent ``maxlen`` records, ``total`` counts every append
    ever, and ``since(seq)`` replaces ``records[i:]`` slicing (pipeline
    per-token prefetch accounting) without assuming the log is unbounded.
    """

    def __init__(self, maxlen: int = 4096):
        self._ring: collections.deque = collections.deque(maxlen=maxlen)
        self.total = 0

    def append(self, rec: TransferRecord) -> None:
        rec.seq = self.total
        self.total += 1
        self._ring.append(rec)

    def since(self, seq: int) -> List[TransferRecord]:
        """Records appended at or after ``seq`` (still in the ring)."""
        return [r for r in self._ring if r.seq >= seq]

    @property
    def dropped(self) -> int:
        """Appends that have aged out of the ring."""
        return self.total - len(self._ring)

    def __iter__(self) -> Iterator[TransferRecord]:
        return iter(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    def __getitem__(self, i: int) -> TransferRecord:
        return self._ring[i]


@dataclasses.dataclass
class TransferAggregates:
    """Per-engine rolling telemetry, updated at append/mutation time.

    Replaces whole-log re-filtering: every ``issue`` adds its record
    here, ``demote`` and demand preemption apply deltas, so stats are
    O(1) regardless of run length and survive the ring dropping old
    records.  ``tests/test_obs.py`` pins these equal to a full-log
    recomputation.
    """

    transfers: int = 0
    bytes: int = 0
    busy_s: float = 0.0
    demoted: int = 0
    wasted_bytes: int = 0
    disk_s: float = 0.0
    draft_transfers: int = 0
    refines: int = 0
    direct: int = 0

    def add(self, rec: TransferRecord) -> None:
        self.transfers += 1
        self.bytes += rec.nbytes
        self.busy_s += rec.duration
        self.disk_s += rec.disk_s
        if rec.precision == "draft":
            self.draft_transfers += 1
        if rec.kind == "refine":
            self.refines += 1
        if rec.strategy == "direct":
            self.direct += 1

    def mark_demoted(self, rec: TransferRecord) -> None:
        self.demoted += 1
        self.wasted_bytes += rec.nbytes

    def summary(self) -> dict:
        n = self.transfers
        return {
            "transfers": n,
            "bytes": self.bytes,
            "busy_s": self.busy_s,
            "demoted": self.demoted,
            "wasted_bytes": self.wasted_bytes,
            "disk_s": self.disk_s,
            "draft_transfers": self.draft_transfers,
            "refines": self.refines,
            "direct_fraction": (self.direct / n) if n else 0.0,
        }

    def merged(self, other: "TransferAggregates") -> "TransferAggregates":
        out = TransferAggregates()
        for f in dataclasses.fields(TransferAggregates):
            setattr(out, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return out


class TransferEngine:
    """Staging-buffer + link timeline over one or more ``ExpertStore``s."""

    def __init__(self, link: Optional[LinkModel] = None, *,
                 num_buffers: int = 2, chunk_channels: int = 50,
                 device_id: int = 0):
        assert num_buffers >= 1
        self.link = link or LinkModel()
        self.num_buffers = num_buffers
        self.chunk_channels = max(1, chunk_channels)
        self.device_id = device_id  # which GPU this engine's link feeds
        self._buffer_free = [0.0] * num_buffers
        self._link_free = 0.0
        self.inflight: Dict[Hashable, TransferRecord] = {}
        self.records = RecordLog()
        self.agg = TransferAggregates()

    # ------------------------------------------------------------ timeline -
    def active_count(self, now: float) -> int:
        """Transfers whose modeled completion is still in the future."""
        return sum(1 for r in self.inflight.values() if r.complete_t > now)

    def has_capacity(self, now: float) -> bool:
        return self.active_count(now) < self.num_buffers

    def link_free_at(self, now: float) -> float:
        """Earliest time this link can start a NEW transfer — the load
        signal a multi-device ``LinkSelector`` ranks replicas by."""
        return max(self._link_free, now)

    def poll(self, now: float) -> List[TransferRecord]:
        """Retire transfers completed by ``now`` (frees their buffers)."""
        done = [k for k, r in self.inflight.items() if r.complete_t <= now]
        out = [self.inflight.pop(k) for k in done]
        if out and obs.enabled():
            # emit at retire time: a retired record can no longer be
            # mutated by demand preemption, so its span is final
            for r in out:
                obs.emit("transfer.complete", r.start_t, cat="transfer",
                         dur=r.duration, device=r.device,
                         args={"key": repr(r.key), "kind": r.kind,
                               "nbytes": r.nbytes, "chunks": r.chunks,
                               "strategy": r.strategy,
                               "precision": r.precision,
                               "demoted": r.demoted, "disk_s": r.disk_s})
        return out

    def drain_events(self) -> List[TransferRecord]:
        """Retire EVERYTHING still in flight (end of run) so the tracer
        sees every transfer as a finalized span."""
        return self.poll(float("inf"))

    def _chunking(self, channel_idx: np.ndarray, nbytes: int
                  ) -> Tuple[int, str, float]:
        """(chunks, strategy, duration) minimizing modeled transfer time."""
        n = len(channel_idx)
        packed_chunks = max(1, -(-n // self.chunk_channels))
        t_packed = self.link.transfer_time(nbytes, packed_chunks, pinned=True)
        runs = coalesce_runs(channel_idx)
        direct_chunks = sum(max(1, -(-ln // self.chunk_channels))
                            for _, ln in runs) or 1
        t_direct = (direct_chunks * self.link.launch_us * 1e-6 +
                    nbytes / self.link.peak_bw)  # no packing pass
        if t_direct <= t_packed:
            return direct_chunks, "direct", t_direct
        return packed_chunks, "packed", t_packed

    @staticmethod
    def _pipelined(disk_s: float, h2d_s: float, chunks: int) -> float:
        """Two-stage pipeline at chunk granularity: disk→host prefill of
        chunk i overlaps host→device staging of chunk i-1."""
        c = max(chunks, 1)
        return disk_s / c + (c - 1) * max(disk_s, h2d_s) / c + h2d_s / c

    # --------------------------------------------------------------- issue -
    def issue(self, store: ExpertStore, key: Hashable, expert: int,
              channel_idx: np.ndarray, now: float, *,
              kind: str = "prefetch", precision: str = "full"
              ) -> Tuple[tuple, TransferRecord]:
        """Stage a sparse expert slice; returns (payload, record).

        payload matches the synchronous pipeline's cache payload exactly:
        ``(channel_idx, gate_cols, down_rows)`` with device-resident
        arrays, so scheduler-driven decode is bitwise-identical to the
        synchronous path.  A tiered store may serve a SUBSET of the
        requested channels (its format's kept set) and report a disk→host
        stage; a ``precision="draft"`` fetch stages the INT8 draft copy
        (about half the link bytes) for progressive refinement.
        """
        idx = np.asarray(channel_idx)
        # real movement (host gather + device_put) happens here
        served, gate_cols, down_rows, info = store.fetch_slice(
            expert, idx, chunk_channels=self.chunk_channels,
            precision=precision)
        nbytes = info.nbytes
        chunks, strategy, duration = self._chunking(served, nbytes)
        h2d_s = duration  # pure host→device time, pre disk pipelining
        if info.disk_s > 0.0:
            duration = self._pipelined(info.disk_s, duration, chunks)
        payload = (served, gate_cols, down_rows)
        if kind == "demand":
            # demand preempts speculative traffic: it enters the link right
            # after the chunk currently in transit; queued prefetches are
            # pushed back behind it (they keep their buffers)
            start, complete = self._preempt_schedule(now, duration)
        else:
            b = int(np.argmin(self._buffer_free))
            start = max(now, self._link_free, self._buffer_free[b])
            complete = start + duration
            self._link_free = complete
            self._buffer_free[b] = complete
        rec = TransferRecord(key=key, kind=kind, nbytes=nbytes, chunks=chunks,
                             strategy=strategy, enqueue_t=now, start_t=start,
                             complete_t=complete, disk_s=info.disk_s,
                             precision=info.precision, device=self.device_id,
                             h2d_s=h2d_s)
        self.inflight[key] = rec
        self.records.append(rec)
        self.agg.add(rec)
        if obs.enabled():
            obs.emit("transfer.start", now, cat="transfer",
                     device=self.device_id,
                     args={"key": repr(key), "kind": kind, "nbytes": nbytes,
                           "chunks": chunks, "strategy": strategy,
                           "precision": info.precision,
                           "start_t": start, "complete_t": complete})
        return payload, rec

    def _preempt_schedule(self, now: float, duration: float
                          ) -> Tuple[float, float]:
        """Link slot for a demand transfer.  Demands are FIFO among
        themselves (non-preemptible); speculative traffic is preemptible
        at *chunk* granularity: the demand waits for any in-flight
        demands, then only for the chunk of the prefetch currently in
        transit — that prefetch's remaining chunks resume after the
        demand, and every not-yet-started prefetch queues behind it.
        The demand path stages through its own bounce buffer, so
        staging-buffer occupancy does not gate it."""
        active = [r for r in self.inflight.values() if r.complete_t > now]
        # serial link, demands first: enter after every in-flight demand
        start = max([now] + [r.complete_t for r in active
                             if r.kind == "demand"])
        # at most one prefetch physically occupies the link at `start`
        on_link = [r for r in active if r.kind != "demand"
                   and r.start_t <= start < r.complete_t]
        if on_link:
            r = min(on_link, key=lambda r: r.start_t)
            chunk_len = r.duration / max(r.chunks, 1)
            remaining = r.complete_t - start
            wait = min(remaining, chunk_len)
            start += wait
            if wait < remaining:  # preempted: its tail resumes after us
                old_dur = r.duration
                r.complete_t += duration
                self.agg.busy_s += r.duration - old_dur
        complete = start + duration
        pending = sorted((r for r in active
                          if r.start_t > now and r.kind != "demand"),
                         key=lambda r: r.start_t)
        t = max([complete] + [r.complete_t for r in on_link])
        for r in pending:
            d = r.duration
            r.start_t = max(t, r.enqueue_t)
            r.complete_t = r.start_t + d
            if r.duration != d:  # float re-lay drift: keep agg log-exact
                self.agg.busy_s += r.duration - d
            t = r.complete_t
        self._link_free = max(t, complete)
        comps = sorted((r.complete_t for r in active), reverse=True)
        comps = comps[: self.num_buffers]
        self._buffer_free = sorted(comps) + \
            [now] * (self.num_buffers - len(comps))
        return start, complete

    def demote(self, key: Hashable) -> bool:
        """Mark an in-flight prefetch stale (router disagreed).  The bytes
        still move (the DMA was already scheduled); telemetry records the
        waste so prefetch precision reflects it."""
        rec = self.inflight.get(key)
        if rec is not None and not rec.demoted:
            rec.demoted = True
            self.agg.mark_demoted(rec)
            if obs.enabled():
                obs.emit("transfer.demote", rec.enqueue_t, cat="transfer",
                         device=rec.device,
                         args={"key": repr(key), "nbytes": rec.nbytes})
            return True
        return False

    # ----------------------------------------------------------- telemetry -
    # Rolling aggregates (updated at append/mutation time) replace the
    # old whole-log re-filtering: O(1) per stats call, and correct even
    # after the bounded RecordLog drops old records.  A cluster aliases
    # every engine's ``records`` to ONE shared log, but ``agg`` stays
    # per-engine, so device telemetry needs no filtering at all.
    def busy_seconds(self) -> float:
        return self.agg.busy_s

    def wasted_bytes(self) -> int:
        return self.agg.wasted_bytes

    def summary(self) -> dict:
        return self.agg.summary()
