"""Plan diffing: old placement -> new placement as a typed migration.

``diff(old, new)`` turns two planner solutions (:class:`StorePlan` or
:class:`ClusterPlan`) into a :class:`MigrationDelta` — the exact, typed
list of steps that takes the serving state from the old plan to the new
one.  Ops, in the fixed order they appear in a delta (capacity is freed
before it is refilled):

  * ``unpin`` / ``replica_drop`` / ``downgrade`` — release VRAM,
  * ``upgrade`` / ``pin`` / ``replica_add`` / ``rehome`` — claim it.

Within an op group steps are sorted by ``(key, device)``, so the delta
is a pure deterministic function of its two inputs: equal plans diff to
the empty delta (idempotence, pinned by tests) and equal plan pairs
always diff to byte-identical deltas (determinism, property-tested).

Format changes compare ladder richness ``(keep_ratio, bits)``: a step is
an ``upgrade`` when the new format materializes more of the expert.  The
executor treats format steps as advisory — the host-tier records are
immutable after build — but the delta records them so telemetry shows
what a rebuild would change.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple, Union

from repro.cluster.placement import ClusterPlan
from repro.store import formats as F
from repro.store.planner import StorePlan

Key = Tuple[int, int]
Plan = Union[StorePlan, ClusterPlan]

#: fixed op emission order: free capacity first, then claim it
OPS: Tuple[str, ...] = ("unpin", "replica_drop", "downgrade",
                        "upgrade", "pin", "replica_add", "rehome")


@dataclasses.dataclass(frozen=True)
class MigrationStep:
    """One typed placement change for ``(layer, expert)``."""

    op: str  # one of OPS
    key: Key
    device: int = 0  # device the step applies to (target for rehome)
    fmt_from: str = ""
    fmt_to: str = ""
    src_device: int = -1  # rehome only: a device losing the expert

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown migration op {self.op!r}")


@dataclasses.dataclass(frozen=True)
class MigrationDelta:
    """Deterministically-ordered tuple of migration steps."""

    steps: Tuple[MigrationStep, ...] = ()

    @property
    def empty(self) -> bool:
        return not self.steps

    def __bool__(self) -> bool:
        return bool(self.steps)

    def __len__(self) -> int:
        return len(self.steps)

    def count(self, op: str) -> int:
        return sum(1 for s in self.steps if s.op == op)

    def summary(self) -> str:
        parts = [f"{op}={n}" for op in OPS if (n := self.count(op))]
        return " ".join(parts) if parts else "empty"


def _richness(fmt: str) -> Tuple[float, int]:
    f = F.get_format(fmt)
    return (f.keep_ratio, f.bits)


def _format_steps(old_formats, new_formats) -> List[MigrationStep]:
    steps = []
    for k in sorted(set(old_formats) | set(new_formats)):
        a, b = old_formats.get(k), new_formats.get(k)
        if a is None or b is None or a == b:
            continue  # coverage changes surface as pin/slot steps instead
        op = "upgrade" if _richness(b) > _richness(a) else "downgrade"
        steps.append(MigrationStep(op=op, key=k, fmt_from=a, fmt_to=b))
    return steps


def _diff_store(old: StorePlan, new: StorePlan) -> List[MigrationStep]:
    steps: List[MigrationStep] = []
    old_p, new_p = set(old.pinned), set(new.pinned)
    steps += [MigrationStep(op="unpin", key=k)
              for k in sorted(old_p - new_p)]
    steps += _format_steps(old.formats, new.formats)
    steps += [MigrationStep(op="pin", key=k)
              for k in sorted(new_p - old_p)]
    return steps


def _diff_cluster(old: ClusterPlan, new: ClusterPlan) -> List[MigrationStep]:
    if old.n_devices != new.n_devices:
        raise ValueError(f"cannot diff cluster plans across device counts "
                         f"({old.n_devices} vs {new.n_devices})")
    steps: List[MigrationStep] = []
    for d in range(old.n_devices):
        old_p = set(old.pinned_per_device[d])
        new_p = set(new.pinned_per_device[d])
        steps += [MigrationStep(op="unpin", key=k, device=d)
                  for k in sorted(old_p - new_p)]
        steps += [MigrationStep(op="pin", key=k, device=d)
                  for k in sorted(new_p - old_p)]
    steps += _format_steps(old.store_plan.formats, new.store_plan.formats)
    for k in sorted(set(old.device_of) | set(new.device_of)):
        homes_a = set(old.devices_of(*k))
        homes_b = set(new.devices_of(*k))
        if homes_a == homes_b:
            continue
        if homes_a.isdisjoint(homes_b):
            src = min(homes_a)
            steps += [MigrationStep(op="rehome", key=k, device=d,
                                    src_device=src)
                      for d in sorted(homes_b)]
        else:  # replica-set change around a surviving home
            steps += [MigrationStep(op="replica_drop", key=k, device=d)
                      for d in sorted(homes_a - homes_b)]
            steps += [MigrationStep(op="replica_add", key=k, device=d)
                      for d in sorted(homes_b - homes_a)]
    return steps


def diff(old: Plan, new: Plan) -> MigrationDelta:
    """Typed, deterministically-ordered migration taking ``old`` to
    ``new``.  ``diff(plan, plan)`` is always empty."""
    if isinstance(old, ClusterPlan) and isinstance(new, ClusterPlan):
        steps = _diff_cluster(old, new)
    elif isinstance(old, StorePlan) and isinstance(new, StorePlan):
        steps = _diff_store(old, new)
    else:
        raise TypeError(f"cannot diff {type(old).__name__} against "
                        f"{type(new).__name__}")
    order = {op: i for i, op in enumerate(OPS)}
    steps.sort(key=lambda s: (order[s.op], s.key, s.device))
    return MigrationDelta(steps=tuple(steps))
