"""Migration execution and the closed re-planning loop.

:class:`MigrationExecutor` applies a :class:`~repro.replan.diff.
MigrationDelta` to the live runtime.  Bookkeeping steps (pin / unpin /
replica and home flips on the live :class:`ClusterPlan`) apply
immediately — they are set mutations with no bytes attached — while the
byte movement (warming newly-pinned / re-homed experts into device
residency) is queued and issued as ``kind="migrate"`` transfers on the
existing :class:`~repro.runtime.transfer.TransferEngine` timeline.
Migrate transfers ride the *speculative* scheduling path: a demand
fetch preempts them at chunk granularity exactly like a prefetch, so an
in-progress migration can never pause decode.  Decode outputs stay
bitwise identical with migration on vs off at fixed routing because a
migrated payload is the expert's full available slice and the MoE apply
path selects exactly the channels it needs from any staged superset.

Issue pacing: at most ``bandwidth_share`` of the wall the migration has
existed may be spent on migrate traffic (modeled link seconds), and a
transfer is only issued while the engine has a free staging buffer —
prefetches and migrations share the same buffers, so the cap bounds how
much speculation the migration can displace.  ``begin`` on an executor
with work still in flight *supersedes* it: the queue is dropped and
in-flight migrate transfers are demoted (bytes already scheduled still
move, telemetry records the waste), so a newer re-plan always wins.

:class:`Replanner` closes the loop: every ``check_every`` controller
steps it feeds the scheduler's live ``activation_freqs`` to a
:class:`~repro.replan.drift.DriftDetector`; on a trigger it re-runs the
planner on the live window (via an injected ``plan_fn``), diffs the
current plan against the new one, debits the fleet admission ledger
when one is attached (a denial aborts that re-plan), hands the delta to
the executor, and re-arms the detector with the live window as the new
reference.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.replan.diff import MigrationDelta, diff
from repro.replan.drift import DriftDetector, freqs_to_array
from repro.store.planner import PlanError

Key = Tuple[int, int]


@dataclasses.dataclass
class MigrationStats:
    """Rolling telemetry across every migration this executor ran."""

    begun: int = 0
    superseded: int = 0
    pins: int = 0
    unpins: int = 0
    rehomes: int = 0
    replica_adds: int = 0
    replica_drops: int = 0
    format_changes: int = 0  # advisory: host records immutable post-build
    transfers: int = 0
    bytes: int = 0
    busy_s: float = 0.0  # modeled link seconds spent on migrate traffic
    deferred: int = 0  # polls that hit the bandwidth/buffer cap

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class MigrationExecutor:
    """Apply migration deltas as background transfers on the live runtime."""

    def __init__(self, sched, *, bandwidth_share: float = 0.5,
                 live_plan=None):
        assert 0.0 < bandwidth_share <= 1.0
        self.sched = sched  # ExpertScheduler or ClusterScheduler
        self.bandwidth_share = float(bandwidth_share)
        # the ClusterPlan the live dispatcher routes by (home flips must
        # mutate THIS object, not the planner's fresh solution)
        self.live_plan = live_plan
        self._queue: collections.deque = collections.deque()  # (key, dev)
        self._recs: List[tuple] = []  # (dev_idx, engine_key, record)
        self._seq = itertools.count()
        self._t0: Optional[float] = None
        self._rehomed: set = set()  # keys re-homed within current begin()
        self.stats = MigrationStats()

    # ------------------------------------------------------------ helpers --
    def _devs(self) -> list:
        return list(self.sched.devs) if hasattr(self.sched, "devs") \
            else [self.sched]

    @property
    def active(self) -> bool:
        """Work queued or still in flight on the modeled timeline."""
        return bool(self._queue) or bool(self._recs)

    def _set_homes(self, key: Key, *, add: Optional[int] = None,
                   remove: Optional[int] = None) -> None:
        if self.live_plan is None:
            return
        cur = set(self.live_plan.devices_of(*key))
        if add is not None:
            cur.add(add)
        if remove is not None:
            cur.discard(remove)
        if cur:
            self.live_plan.device_of[key] = tuple(sorted(cur))

    def _rehome(self, key: Key, dst: int) -> None:
        if self.live_plan is None:
            return
        if key in self._rehomed:  # second target device of the same move
            self._set_homes(key, add=dst)
        else:
            self.live_plan.device_of[key] = (dst,)
            self._rehomed.add(key)

    # -------------------------------------------------------------- begin --
    def begin(self, delta: MigrationDelta, now: float) -> None:
        """Start (or supersede into) executing ``delta`` at time ``now``."""
        devs = self._devs()
        if self.active:
            self._supersede(now, devs)
        self.stats.begun += 1
        if self._t0 is None:
            self._t0 = now
        self._rehomed = set()
        for s in delta.steps:
            li, _ = s.key
            d = s.device if 0 <= s.device < len(devs) else 0
            res = devs[d].residency[li] \
                if 0 <= li < len(devs[d].residency) else None
            if s.op == "unpin":
                if res is not None:
                    res.unpin(s.key)
                    self.stats.unpins += 1
            elif s.op in ("upgrade", "downgrade"):
                self.stats.format_changes += 1
            elif s.op == "replica_drop":
                self._set_homes(s.key, remove=d)
                if res is not None:
                    res.unpin(s.key)
                    self.stats.replica_drops += 1
            elif s.op in ("pin", "replica_add", "rehome"):
                if s.op == "replica_add":
                    self._set_homes(s.key, add=d)
                    self.stats.replica_adds += 1
                elif s.op == "rehome":
                    self._rehome(s.key, d)
                    self.stats.rehomes += 1
                if res is None:
                    continue
                if s.op != "rehome":  # re-homing moves, it does not pin
                    res.pin(s.key)
                    if s.op == "pin":
                        self.stats.pins += 1
                if s.key not in res:
                    self._queue.append((s.key, d))
        self.poll(now)

    def _supersede(self, now: float, devs: list) -> None:
        self._queue.clear()
        for d, ekey, rec in self._recs:
            if rec.complete_t > now:
                devs[d].engine.demote(ekey)
        self.stats.superseded += 1
        if obs.enabled():
            obs.emit("replan.supersede", now, cat="replan",
                     args={"dropped_inflight": len(self._recs)})

    # --------------------------------------------------------------- poll --
    def poll(self, now: float) -> None:
        """Issue queued warm-ups within the bandwidth/buffer budget."""
        if self._t0 is None:
            return
        self._recs = [t for t in self._recs if t[2].complete_t > now]
        devs = self._devs()
        while self._queue:
            elapsed = max(now - self._t0, 1e-9)
            if self.stats.busy_s > self.bandwidth_share * elapsed:
                self.stats.deferred += 1
                break
            key, d = self._queue[0]
            dev = devs[d]
            if not dev.engine.has_capacity(now):
                self.stats.deferred += 1
                break
            self._queue.popleft()
            self._stage(dev, d, key, now)

    def _stage(self, dev, d: int, key: Key, now: float) -> None:
        li, e = key
        store = dev.stores[li]
        res = dev.residency[li]
        if store is None or res is None or key in res:
            return  # dense layer, or a prefetch/demand beat us to it
        idx = store.available_channels(e)
        if idx is None:
            idx = np.arange(store.d_ff)
        ekey = (key, "migrate", next(self._seq))
        payload, rec = dev.engine.issue(store, ekey, e, idx, now,
                                        kind="migrate")
        res.put(key, payload, ready_t=rec.complete_t)
        self._recs.append((d, ekey, rec))
        self.stats.transfers += 1
        self.stats.bytes += rec.nbytes
        self.stats.busy_s += rec.duration


class Replanner:
    """Drift detector + planner re-run + migration, one object.

    The serving controller calls :meth:`on_step` once per decode step;
    everything else is wiring handed in by the deploy builder:
    ``plan_fn`` re-runs ``plan_store``/``plan_cluster`` with the
    deployment's own resource knobs, ``ledger`` (optional) is the fleet
    admission hook — it either re-commits the member's budget to the new
    plan or raises, which aborts that re-plan as *denied*.
    """

    def __init__(self, sched, plan, reference: np.ndarray,
                 plan_fn: Callable[[np.ndarray], object], *,
                 window: int = 64, threshold: float = 0.25,
                 hysteresis: float = 0.5, cooldown_s: float = 0.25,
                 check_every: int = 8, bandwidth_share: float = 0.5,
                 ledger: Optional[Callable[[object], None]] = None,
                 device: int = 0, trigger: str = "drift", health=None):
        assert check_every >= 1
        assert trigger in ("drift", "health"), trigger
        assert trigger != "health" or health is not None, \
            "trigger='health' needs a HealthMonitor"
        self.sched = sched
        self.plan = plan
        self.plan_fn = plan_fn
        self.trigger = trigger
        self.health = health  # HealthMonitor (consume_replan_trigger)
        self.detector = DriftDetector(reference, window=window,
                                      threshold=threshold,
                                      cooldown_s=cooldown_s,
                                      hysteresis=hysteresis, device=device)
        has_devices = hasattr(sched, "devs")
        self.executor = MigrationExecutor(
            sched, bandwidth_share=bandwidth_share,
            live_plan=plan if has_devices else None)
        self.check_every = int(check_every)
        self.ledger = ledger
        self._device = device
        self._step_i = 0
        self.checks = 0
        self.replans = 0
        self.denied = 0
        self.plan_errors = 0
        self.empty_deltas = 0
        self.health_triggers = 0

    def on_step(self, now: float) -> None:
        """Controller hook: pump migrations, periodically check drift."""
        self.executor.poll(now)
        self._step_i += 1
        if self._step_i % self.check_every:
            return
        self.checks += 1
        freqs = self.sched.activation_freqs
        # the detector always observes: its window IS the live evidence a
        # health-triggered re-plan feeds the planner, and its readings
        # stay comparable across trigger modes
        reading = self.detector.observe(freqs, now)
        if self.trigger == "health":
            pending = self.health.consume_replan_trigger()
            # no live routing evidence yet -> nothing to re-plan FROM
            if not pending or reading.n_events < 1:
                return
            self.health_triggers += 1
        elif not reading.triggered:
            return
        live = self._live_freqs(freqs)
        try:
            new_plan = self.plan_fn(live)
        except PlanError:
            self.plan_errors += 1
            return
        delta = diff(self.plan, new_plan)
        if delta.empty:
            self.empty_deltas += 1
            self.detector.rearm(reference=live, freqs=freqs)
            return
        if self.ledger is not None:
            try:
                self.ledger(new_plan)
            except Exception:  # AdmissionError: budget denies this re-plan
                self.denied += 1
                return
        if obs.enabled():
            obs.emit("replan.plan", now, cat="replan", device=self._device,
                     args={"steps": len(delta), "summary": delta.summary(),
                           "distance": round(reading.distance, 4),
                           "n_events": reading.n_events})
        self.executor.begin(delta, now)
        self.plan = new_plan
        self.replans += 1
        self.detector.rearm(reference=live, freqs=freqs)

    def _live_freqs(self, freqs) -> np.ndarray:
        """Live window as a planner-ready array; layers with no live
        evidence keep the reference row so the planner never starves an
        unobserved layer."""
        counts = self.detector.window_counts(freqs)
        arr = freqs_to_array(counts, *self.detector.reference.shape)
        for li in range(arr.shape[0]):
            if arr[li].sum() <= 0.0:
                arr[li] = self.detector.reference[li]
        return arr

    def report(self) -> dict:
        out = {
            "checks": self.checks,
            "trigger": self.trigger,
            "health_triggers": self.health_triggers,
            "drift_readings": self.detector.readings,
            "drift_triggers": self.detector.triggers,
            "replans": self.replans,
            "denied": self.denied,
            "plan_errors": self.plan_errors,
            "empty_deltas": self.empty_deltas,
            "migration_active": self.executor.active,
        }
        out.update({f"migrate_{k}": v
                    for k, v in self.executor.stats.as_dict().items()})
        return out
