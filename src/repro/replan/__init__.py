"""Live re-planning: drift detection -> plan diff -> expert migration.

The closed loop from measured routing statistics back into placement
while serving (FluxMoE's continuously-redistributed residency, EPLB):

  * :mod:`repro.replan.drift`   — windowed TV-distance drift detector
    with hysteresis + cooldown over live ``activation_freqs``.
  * :mod:`repro.replan.diff`    — re-planned ``StorePlan``/``ClusterPlan``
    diffed into a typed, deterministic :class:`MigrationDelta`.
  * :mod:`repro.replan.migrate` — :class:`MigrationExecutor` issuing the
    delta as demand-preemptible ``kind="migrate"`` transfers, and
    :class:`Replanner`, the controller-facing loop.
"""
from repro.replan.diff import MigrationDelta, MigrationStep, diff
from repro.replan.drift import DriftDetector, DriftReading, freqs_to_array
from repro.replan.migrate import (MigrationExecutor, MigrationStats,
                                  Replanner)

__all__ = [
    "DriftDetector",
    "DriftReading",
    "MigrationDelta",
    "MigrationExecutor",
    "MigrationStats",
    "MigrationStep",
    "Replanner",
    "diff",
    "freqs_to_array",
]
