"""Windowed drift detection over live routing statistics.

The planner solves placement once from offline activation frequencies;
this module watches the frequencies the runtime *actually* accumulates
(``ExpertScheduler.activation_freqs`` / the merged ``ClusterScheduler``
view) and decides when the plan has gone stale.  The signal is the mean
per-layer total-variation distance between the normalized live window
and the plan's reference distribution:

    TV(layer) = 0.5 * sum_e | live[layer, e] - ref[layer, e] |

averaged over layers that have live observations.  A trigger needs all
of: the detector armed, at least ``window`` demand events in the live
window, ``cooldown_s`` of modeled time since the last trigger, and
distance above ``threshold``.  Triggering disarms the detector; it
re-arms when the distance falls back under ``hysteresis * threshold``
(burst decayed, no re-plan needed) or when :meth:`rearm` is called after
a re-plan lands (the live window becomes the new reference).  Hysteresis
plus cooldown is what keeps a flash crowd from thrashing the planner.

Every observation emits a ``replan.drift`` obs event, so the trace shows
the distance series alongside the transfers it eventually causes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

from repro import obs

Key = Tuple[int, int]


def freqs_to_array(freqs: Mapping[Key, int], num_layers: int,
                   num_experts: int) -> np.ndarray:
    """``{(layer, expert): count}`` -> row-normalized ``(L, E)`` array.

    Rows with no observations stay all-zero (callers treat them as
    "no evidence", not "uniform")."""
    out = np.zeros((num_layers, num_experts), dtype=np.float64)
    for (li, e), c in freqs.items():
        if 0 <= li < num_layers and 0 <= e < num_experts:
            out[li, e] += float(c)
    sums = out.sum(axis=1, keepdims=True)
    np.divide(out, np.where(sums > 0, sums, 1.0), out=out)
    return out


@dataclasses.dataclass(frozen=True)
class DriftReading:
    """One detector observation on the modeled timeline."""

    t: float
    distance: float  # mean per-layer TV distance, live window vs reference
    n_events: int  # demand events inside the live window
    triggered: bool
    armed: bool  # state AFTER this observation


class DriftDetector:
    """Hysteresis + cooldown drift detector over windowed demand counts."""

    def __init__(self, reference: np.ndarray, *, window: int = 64,
                 threshold: float = 0.25, cooldown_s: float = 0.25,
                 hysteresis: float = 0.5, device: int = 0):
        assert window >= 1 and 0.0 < threshold <= 1.0
        assert cooldown_s >= 0.0 and 0.0 <= hysteresis <= 1.0
        self.reference = self._normalize(reference)
        self.window = int(window)
        self.threshold = float(threshold)
        self.cooldown_s = float(cooldown_s)
        self.hysteresis = float(hysteresis)
        self._device = device
        self._base: Dict[Key, int] = {}  # counts snapshot; window = live-base
        self._armed = True
        self._last_trigger = -math.inf
        self.readings = 0
        self.triggers = 0

    @staticmethod
    def _normalize(reference: np.ndarray) -> np.ndarray:
        ref = np.asarray(reference, dtype=np.float64).copy()
        sums = ref.sum(axis=1, keepdims=True)
        np.divide(ref, np.where(sums > 0, sums, 1.0), out=ref)
        return ref

    @property
    def armed(self) -> bool:
        return self._armed

    def snapshot(self, freqs: Mapping[Key, int]) -> None:
        """Start a fresh window at the current cumulative counts."""
        self._base = dict(freqs)

    def window_counts(self, freqs: Mapping[Key, int]) -> Dict[Key, int]:
        """Demand counts accumulated since the last snapshot."""
        out: Dict[Key, int] = {}
        for k, v in freqs.items():
            d = int(v) - int(self._base.get(k, 0))
            if d > 0:
                out[k] = d
        return out

    def distance(self, freqs: Mapping[Key, int]) -> Tuple[float, int]:
        """(mean per-layer TV distance, events in window)."""
        counts = self.window_counts(freqs)
        n = sum(counts.values())
        if n == 0:
            return 0.0, 0
        live = freqs_to_array(counts, *self.reference.shape)
        tvs = []
        for li in range(self.reference.shape[0]):
            if live[li].sum() <= 0.0 or self.reference[li].sum() <= 0.0:
                continue  # dense layer or no live evidence: no opinion
            tvs.append(0.5 * float(np.abs(live[li]
                                          - self.reference[li]).sum()))
        return (float(np.mean(tvs)) if tvs else 0.0), n

    def observe(self, freqs: Mapping[Key, int], now: float) -> DriftReading:
        """Evaluate the live window at modeled time ``now``."""
        dist, n = self.distance(freqs)
        triggered = (self._armed and n >= self.window
                     and now - self._last_trigger >= self.cooldown_s
                     and dist > self.threshold)
        if triggered:
            self._armed = False
            self._last_trigger = now
            self.triggers += 1
        elif not self._armed and dist <= self.hysteresis * self.threshold:
            self._armed = True  # burst decayed on its own
        self.readings += 1
        if obs.enabled():
            obs.emit("replan.drift", now, cat="replan", device=self._device,
                     args={"distance": round(dist, 4), "n_events": n,
                           "triggered": triggered, "armed": self._armed})
        return DriftReading(t=now, distance=dist, n_events=n,
                            triggered=triggered, armed=self._armed)

    def rearm(self, *, reference: Optional[np.ndarray] = None,
              freqs: Optional[Mapping[Key, int]] = None) -> None:
        """Re-arm after a re-plan landed: the live window becomes the new
        reference and the count window restarts."""
        if reference is not None:
            self.reference = self._normalize(reference)
        if freqs is not None:
            self.snapshot(freqs)
        self._armed = True
