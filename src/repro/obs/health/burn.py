"""Multi-window SLO burn-rate alerting on the simulated clock.

The SRE-style multiwindow rule, deterministic because every timestamp is
modeled time: each request outcome is an SLI sample (error = SLO missed
or rejected), the error budget is ``1 - slo_target``, and the burn rate
of a window is ``error_fraction / budget`` — burn 1.0 spends the budget
exactly at the sustainable rate, burn N spends it N times too fast.

Two windows per tenant gate two severities:

``page``
    ``burn > page_burn`` in BOTH the fast and the slow window — the
    fast window gives low detection latency, the slow window keeps a
    momentary blip from paging.
``ticket``
    ``burn > ticket_burn`` in the slow window — sustained but slower
    budget spend.

Each (tenant, severity) channel carries its own
:class:`~repro.obs.health.alerts.TriggerState`, so one sustained burn
raises one page per cooldown instead of one per finished request.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Tuple

from repro.obs.health.alerts import Alert, TriggerState


class _Window:
    """Sliding window of (t, error) outcomes over ``span_s`` modeled s."""

    __slots__ = ("span_s", "_q", "errors")

    def __init__(self, span_s: float):
        self.span_s = float(span_s)
        self._q = collections.deque()
        self.errors = 0

    def add(self, t: float, is_error: bool) -> None:
        self._q.append((t, is_error))
        if is_error:
            self.errors += 1

    def roll(self, now: float) -> None:
        horizon = now - self.span_s
        q = self._q
        while q and q[0][0] < horizon:
            _, err = q.popleft()
            if err:
                self.errors -= 1

    @property
    def n(self) -> int:
        return len(self._q)

    @property
    def error_fraction(self) -> float:
        return self.errors / len(self._q) if self._q else 0.0


class BurnRateAlerter:
    """Per-tenant fast/slow burn-rate windows over one SLI signal."""

    def __init__(self, *, signal: str = "attainment", slo_target: float = 0.9,
                 fast_window_s: float = 5.0, slow_window_s: float = 30.0,
                 page_burn: float = 4.0, ticket_burn: float = 2.0,
                 min_events: int = 4, hysteresis: float = 0.5,
                 cooldown_s: float = 10.0):
        self.signal = signal
        self.budget = max(1.0 - slo_target, 1e-9)
        self.fast_window_s = fast_window_s
        self.slow_window_s = slow_window_s
        self.page_burn = page_burn
        self.ticket_burn = ticket_burn
        self.min_events = min_events
        self.hysteresis = hysteresis
        self.cooldown_s = cooldown_s
        self._windows: Dict[str, Tuple[_Window, _Window]] = {}
        self._states: Dict[Tuple[str, str], TriggerState] = {}
        self.outcomes = 0
        self.errors = 0

    # ------------------------------------------------------------ recording --
    def _tenant(self, tenant: str) -> Tuple[_Window, _Window]:
        w = self._windows.get(tenant)
        if w is None:
            w = (_Window(self.fast_window_s), _Window(self.slow_window_s))
            self._windows[tenant] = w
        return w

    def record(self, t: float, tenant: str, is_error: bool) -> None:
        fast, slow = self._tenant(tenant)
        fast.add(t, is_error)
        slow.add(t, is_error)
        self.outcomes += 1
        if is_error:
            self.errors += 1

    # ----------------------------------------------------------- evaluation --
    def burn_rates(self, now: float, tenant: str) -> Tuple[float, float]:
        """(fast, slow) burn rates for ``tenant`` at ``now``."""
        fast, slow = self._tenant(tenant)
        fast.roll(now)
        slow.roll(now)
        return (fast.error_fraction / self.budget,
                slow.error_fraction / self.budget)

    def evaluate(self, now: float) -> List[Alert]:
        """Roll every tenant's windows and fire due page/ticket alerts."""
        fired: List[Alert] = []
        for tenant in sorted(self._windows):
            fast, slow = self._windows[tenant]
            fast.roll(now)
            slow.roll(now)
            burn_fast = fast.error_fraction / self.budget
            burn_slow = slow.error_fraction / self.budget
            detail = {"burn_fast": burn_fast, "burn_slow": burn_slow,
                      "n_fast": fast.n, "n_slow": slow.n,
                      "window_fast_s": self.fast_window_s,
                      "window_slow_s": self.slow_window_s}
            # page: BOTH windows over page_burn -> the condition value is
            # the min of the two, which also drives hysteresis re-arm
            page = self._states.setdefault((tenant, "page"), TriggerState())
            if page.update(now, min(burn_fast, burn_slow), self.page_burn,
                           hysteresis=self.hysteresis,
                           cooldown_s=self.cooldown_s,
                           eligible=fast.n >= self.min_events):
                fired.append(Alert(t=now, signal=self.signal,
                                   severity="page", key=tenant,
                                   value=min(burn_fast, burn_slow),
                                   threshold=self.page_burn, detail=detail))
            ticket = self._states.setdefault((tenant, "ticket"),
                                             TriggerState())
            if ticket.update(now, burn_slow, self.ticket_burn,
                             hysteresis=self.hysteresis,
                             cooldown_s=self.cooldown_s,
                             eligible=slow.n >= self.min_events):
                fired.append(Alert(t=now, signal=self.signal,
                                   severity="ticket", key=tenant,
                                   value=burn_slow,
                                   threshold=self.ticket_burn,
                                   detail=detail))
        return fired

    def report(self) -> dict:
        return {
            "signal": self.signal,
            "outcomes": self.outcomes,
            "errors": self.errors,
            "tenants": sorted(self._windows),
        }
