"""repro.obs.health — live health layer on the observability bus.

PR 6's ``repro.obs`` explains every stalled second after the fact; this
package watches the serving stack WHILE it serves.  Everything is a pure
:class:`~repro.obs.events.EventBus` consumer — nothing here touches the
modeled timeline, so a run with the monitor attached is bitwise
identical to one without (the zero-overhead invariant of
``obs.enabled()`` extends to health unchanged).

    BurnRateAlerter (burn.py)      multi-window SLO burn-rate alerting:
                                   fast/slow window pairs over per-tenant
                                   attainment (and optionally TPOT),
                                   page/ticket severities, deterministic
                                   on the simulated clock
    CompositionDetector,           anomaly detection: windowed TV
    LinkHealthDetector             distance over stall-cause shares
    (anomaly.py)                   (DriftDetector's arming discipline)
                                   and link utilization / queue delay
    FlightRecorder (recorder.py)   bounded ring of recent events per
                                   model scope; on any alert, a
                                   byte-deterministic INCIDENT BUNDLE:
                                   Perfetto slice of the alert window,
                                   metrics snapshot, per-cause stall
                                   attribution, offending-request
                                   waterfalls, replayable scenario slice
    HealthMonitor (monitor.py)     the bus consumer wiring it together;
                                   ``Deployment.report()["health"]``,
                                   ``launch/serve.py --health``, and the
                                   Replanner's ``trigger="health"`` path
"""
from repro.obs.health.alerts import Alert, TriggerState
from repro.obs.health.anomaly import CompositionDetector, LinkHealthDetector
from repro.obs.health.burn import BurnRateAlerter
from repro.obs.health.monitor import HealthMonitor
from repro.obs.health.recorder import FlightRecorder, build_bundle

__all__ = [
    "Alert", "BurnRateAlerter", "CompositionDetector", "FlightRecorder",
    "HealthMonitor", "LinkHealthDetector", "TriggerState", "build_bundle",
]
