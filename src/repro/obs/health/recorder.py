"""Flight recorder + byte-deterministic incident bundles.

:class:`FlightRecorder` keeps a bounded ring of recent bus events per
model scope — cheap enough to run for the whole serve.  When an alert
fires, :func:`build_bundle` freezes the alert window into one
self-contained JSON document:

* ``trace`` — a Perfetto/Chrome trace-event slice of the window
  (rendered by a fresh :class:`~repro.obs.trace.Tracer`, loadable in
  ui.perfetto.dev as-is),
* ``metrics`` — the monitor's registry snapshot at alert time,
* ``stall_attribution`` — per-cause stalled seconds inside the window
  (every cause, zeros included),
* ``requests`` — the window's finished requests with their
  queue/stall/compute waterfalls, offenders (SLO-missed) called out,
* ``scenario`` — when the serve was scenario-driven, the spec plus the
  request slice needed to replay the window
  (``repro.workload.trace`` format, so ``load_trace`` reads it back).

Serialization is ``json.dumps(..., indent=1, sort_keys=True)`` over
values that are themselves deterministic on the simulated clock, so two
identical runs produce byte-identical bundles (a bench acceptance row).
"""
from __future__ import annotations

import collections
import json
from typing import Dict, List, Optional

from repro.obs.events import Event
from repro.obs.health.alerts import Alert
from repro.obs.stall import CAUSES
from repro.obs.trace import Tracer

BUNDLE_SCHEMA = "repro.obs.health/incident-v1"


class FlightRecorder:
    """Bounded ring of recent events, one ring per model scope."""

    def __init__(self, maxlen: int = 4096):
        self.maxlen = int(maxlen)
        self._rings: Dict[str, collections.deque] = {}
        self.recorded = 0
        self.dropped = 0

    def record(self, ev: Event) -> None:
        ring = self._rings.get(ev.model)
        if ring is None:
            ring = collections.deque(maxlen=self.maxlen)
            self._rings[ev.model] = ring
        if len(ring) == self.maxlen:
            self.dropped += 1
        ring.append(ev)
        self.recorded += 1

    def window(self, t0: float, t1: float,
               model: Optional[str] = None) -> List[Event]:
        """Events overlapping ``[t0, t1]`` (span-aware), in emission
        order, merged across rings unless ``model`` pins one scope."""
        rings = ([self._rings[model]] if model is not None
                 and model in self._rings else self._rings.values())
        out = [ev for ring in rings for ev in ring
               if ev.t <= t1 and ev.t + max(ev.dur, 0.0) >= t0]
        out.sort(key=lambda ev: ev.seq)
        return out

    def __len__(self) -> int:
        return sum(len(r) for r in self._rings.values())


def _waterfalls(events: List[Event]) -> dict:
    """Finished requests in the window as queue/stall/compute waterfalls."""
    rows = []
    offenders = []
    for ev in events:
        if ev.name != "request.finish":
            continue
        a = ev.args or {}
        row = {"uid": a.get("uid"), "t": ev.t,
               "attained": bool(a.get("attained", True))}
        for field in ("tenant", "tokens", "queue_s", "stall_s",
                      "compute_s", "ttft_s", "tpot_s"):
            if field in a:
                row[field] = a[field]
        rows.append(row)
        if not row["attained"]:
            offenders.append(row["uid"])
    rows.sort(key=lambda r: (r["uid"] is None, r["uid"]))
    return {"finished": rows, "offenders": sorted(
        (u for u in offenders if u is not None))}


def _stall_shares(events: List[Event]) -> dict:
    totals = {c: 0.0 for c in CAUSES}
    stall_s = 0.0
    n = 0
    for ev in events:
        if ev.name != "demand.stall":
            continue
        a = ev.args or {}
        stall_s += a.get("stall_s", ev.dur)
        n += 1
        for cause, v in (a.get("causes") or {}).items():
            if cause in totals:
                totals[cause] += v
    return {"events": n, "stall_s": stall_s, "causes": totals}


def _scenario_slice(scenario, requests, t1: float) -> Optional[dict]:
    """The replayable slice: scenario spec + every request whose arrival
    precedes the window's end (in-flight work included by construction).
    ``repro.workload.trace`` format so ``load_trace`` reads it back."""
    if scenario is None or requests is None:
        return None
    from repro.workload.trace import _request_dict  # lazy: avoids a cycle
    spec_dict = scenario.to_dict() if hasattr(scenario, "to_dict") \
        else dict(scenario)
    return {"scenario": spec_dict,
            "requests": [_request_dict(r) for r in requests
                         if r.arrival_t <= t1]}


def build_bundle(*, alert: Alert, events: List[Event], metrics: dict,
                 window: float, seq: int, scenario=None,
                 requests=None) -> str:
    """Serialize one incident window as a byte-deterministic JSON doc."""
    t1 = alert.t
    t0 = max(t1 - window, 0.0)
    tracer = Tracer()
    for ev in events:
        tracer.on_event(ev)
    doc = {
        "schema": BUNDLE_SCHEMA,
        "incident": seq,
        "alert": alert.to_dict(),
        "window": {"t0": t0, "t1": t1, "events": len(events)},
        "trace": tracer.to_chrome(),
        "metrics": dict(metrics),
        "stall_attribution": _stall_shares(events),
        "requests": _waterfalls(events),
        "scenario": _scenario_slice(scenario, requests, t1),
    }
    return json.dumps(doc, indent=1, sort_keys=True) + "\n"
