"""Alert record + the shared arming discipline for every health detector.

:class:`TriggerState` is :class:`~repro.replan.drift.DriftDetector`'s
trigger/hysteresis/cooldown state machine factored out so the burn-rate
alerter and both anomaly detectors behave identically: a trigger
requires ARMED + value over threshold + cooldown elapsed; triggering
disarms the channel; the channel re-arms only once the signal recedes
to ``hysteresis * threshold`` (or on explicit :meth:`rearm`).  One
sustained excursion therefore raises ONE alert per cooldown, not one
per event.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Alert:
    """One health alert, fully described by value/threshold at trigger.

    ``signal`` names the detector (``attainment`` / ``tpot`` /
    ``stall_composition`` / ``link_util`` / ``queue_delay``);
    ``severity`` is ``page`` or ``ticket`` for burn-rate alerts and
    ``anomaly`` for the composition/link detectors; ``key`` scopes the
    alert (tenant, ``device:<d>``, or the dominant stall cause).
    """

    t: float
    signal: str
    severity: str
    key: str
    value: float
    threshold: float
    detail: Optional[dict] = None

    def to_dict(self) -> dict:
        d = {
            "t": self.t,
            "signal": self.signal,
            "severity": self.severity,
            "key": self.key,
            "value": self.value,
            "threshold": self.threshold,
        }
        if self.detail:
            d["detail"] = {k: self.detail[k] for k in sorted(self.detail)}
        return d


class TriggerState:
    """Armed/cooldown/hysteresis state for one alert channel."""

    __slots__ = ("armed", "last_trigger_t")

    def __init__(self):
        self.armed = True
        self.last_trigger_t = -math.inf

    def update(self, now: float, value: float, threshold: float, *,
               hysteresis: float, cooldown_s: float,
               eligible: bool = True) -> bool:
        """Advance the channel; True iff an alert fires at ``now``.

        ``eligible`` gates triggering only (window fill, min events) —
        re-arming still happens while ineligible so a drained window
        re-arms the channel.
        """
        if (self.armed and eligible and value > threshold
                and now - self.last_trigger_t >= cooldown_s):
            self.armed = False
            self.last_trigger_t = now
            return True
        if not self.armed and value <= hysteresis * threshold:
            self.armed = True
        return False

    def rearm(self) -> None:
        self.armed = True
