"""Anomaly detectors: stall-cause composition shift and link health.

:class:`CompositionDetector` watches WHAT the stack stalls on, not how
much: the live window is the last ``window`` ``demand.stall`` events'
cause segments normalized to shares, the reference is everything that
has aged OUT of the live window (so the detector self-calibrates to the
run's own steady state and needs no prior), and the statistic is total
variation distance between the two — the same statistic, and the same
arming discipline, as ``replan.DriftDetector``.  A burst that merely
scales every cause up stays silent; a composition FLIP (e.g. prefetch
misses giving way to link contention when a hot link saturates) fires.

:class:`LinkHealthDetector` watches each device's transfer link from
``transfer.start`` events: windowed utilization (link-seconds laid down
per wall-second — sustained > 1 means the schedule is being pushed into
the future, i.e. the queue grows) and per-transfer queue delay
(``start_t`` minus enqueue time).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional

from repro.obs.health.alerts import Alert, TriggerState
from repro.obs.stall import CAUSES


class CompositionDetector:
    """Windowed TV distance of stall-cause shares vs the aged reference."""

    def __init__(self, *, window: int = 16, threshold: float = 0.3,
                 hysteresis: float = 0.5, cooldown_s: float = 10.0,
                 causes=CAUSES):
        self.window = int(window)
        self.threshold = threshold
        self.hysteresis = hysteresis
        self.cooldown_s = cooldown_s
        self.causes = tuple(causes)
        self._live = collections.deque()  # (t, {cause: seconds})
        self._ref: Dict[str, float] = {c: 0.0 for c in self.causes}
        self._ref_total = 0.0
        self._ref_n = 0  # stalls aged into the reference
        self._state = TriggerState()
        self.observations = 0
        self.last_distance = 0.0

    def _shares(self, totals: Dict[str, float], total: float):
        return {c: totals.get(c, 0.0) / total for c in self.causes}

    def observe(self, t: float, segs: Dict[str, float]) -> Optional[Alert]:
        """Fold one stall's cause segments; an Alert when composition
        shifted past the threshold (None otherwise)."""
        self.observations += 1
        self._live.append((t, dict(segs)))
        while len(self._live) > self.window:  # age into the reference
            _, old = self._live.popleft()
            self._ref_n += 1
            for c, v in old.items():
                self._ref[c] = self._ref.get(c, 0.0) + v
                self._ref_total += v
        live_totals: Dict[str, float] = {}
        live_total = 0.0
        for _, s in self._live:
            for c, v in s.items():
                live_totals[c] = live_totals.get(c, 0.0) + v
                live_total += v
        if (len(self._live) < self.window or self._ref_n < self.window
                or self._ref_total <= 0.0 or live_total <= 0.0):
            # warming up: judge only against a FULL reference window —
            # a handful of just-aged cold-start stalls is not a steady
            # state to deviate from (cold caches are eviction/miss heavy
            # by nature and would page every fresh deployment)
            return None
        live = self._shares(live_totals, live_total)
        ref = self._shares(self._ref, self._ref_total)
        dist = 0.5 * sum(abs(live[c] - ref[c]) for c in self.causes)
        self.last_distance = dist
        if not self._state.update(t, dist, self.threshold,
                                  hysteresis=self.hysteresis,
                                  cooldown_s=self.cooldown_s):
            return None
        top = max(self.causes, key=lambda c: live[c] - ref[c])
        return Alert(t=t, signal="stall_composition", severity="anomaly",
                     key=f"cause:{top}", value=dist,
                     threshold=self.threshold,
                     detail={"live_shares": live, "ref_shares": ref,
                             "window": self.window})

    @property
    def armed(self) -> bool:
        return self._state.armed

    def report(self) -> dict:
        return {"observations": self.observations,
                "last_distance": self.last_distance,
                "armed": self._state.armed}


class LinkHealthDetector:
    """Per-device windowed link utilization and transfer queue delay."""

    def __init__(self, *, window_s: float = 5.0, util_threshold: float = 1.5,
                 queue_delay_s: float = 0.5, hysteresis: float = 0.5,
                 cooldown_s: float = 10.0):
        self.window_s = float(window_s)
        self.util_threshold = util_threshold
        self.queue_delay_s = queue_delay_s
        self.hysteresis = hysteresis
        self.cooldown_s = cooldown_s
        self._windows: Dict[int, collections.deque] = {}
        self._util: Dict[int, TriggerState] = {}
        self._queue: Dict[int, TriggerState] = {}
        self.observations = 0
        self.last_util: Dict[int, float] = {}

    def observe(self, t: float, device: int, dur: float,
                queue_delay: float) -> List[Alert]:
        """Fold one ``transfer.start``; fire due utilization/queue alerts."""
        self.observations += 1
        q = self._windows.setdefault(device, collections.deque())
        q.append((t, max(dur, 0.0), max(queue_delay, 0.0)))
        horizon = t - self.window_s
        while q and q[0][0] < horizon:
            q.popleft()
        util = sum(d for _, d, _ in q) / self.window_s
        qmax = max(qd for _, _, qd in q)
        self.last_util[device] = util
        fired: List[Alert] = []
        st = self._util.setdefault(device, TriggerState())
        if st.update(t, util, self.util_threshold,
                     hysteresis=self.hysteresis, cooldown_s=self.cooldown_s):
            fired.append(Alert(t=t, signal="link_util", severity="anomaly",
                               key=f"device:{device}", value=util,
                               threshold=self.util_threshold,
                               detail={"transfers": len(q),
                                       "window_s": self.window_s}))
        if self.queue_delay_s > 0.0:
            st = self._queue.setdefault(device, TriggerState())
            if st.update(t, qmax, self.queue_delay_s,
                         hysteresis=self.hysteresis,
                         cooldown_s=self.cooldown_s):
                fired.append(Alert(t=t, signal="queue_delay",
                                   severity="anomaly",
                                   key=f"device:{device}", value=qmax,
                                   threshold=self.queue_delay_s,
                                   detail={"transfers": len(q),
                                           "window_s": self.window_s}))
        return fired

    def report(self) -> dict:
        return {"observations": self.observations,
                "last_util": {str(d): v
                              for d, v in sorted(self.last_util.items())}}
