"""HealthMonitor — the bus consumer wiring burn-rate alerting, anomaly
detection, and the flight recorder into one live health layer.

Attach it like any other consumer (``obs.attach(monitor)``); it never
emits into the modeled timeline, only observes it.  Routing:

* ``request.finish`` / ``request.reject`` feed the per-tenant
  attainment burn windows (and the TPOT windows when a budget is set),
* ``demand.stall`` feeds the stall-composition detector,
* ``transfer.start`` feeds the link utilization / queue-delay detector,
* everything (post model-scope filter) lands in the flight recorder and
  the monitor's own metrics registry.

On any alert the monitor appends an :class:`Alert`, bumps a
``health.alerts.<severity>`` counter, emits a ``health.alert`` bus
event (so tracers see it; the monitor ignores the ``health`` category
to avoid consuming its own output), and — up to ``max_incidents`` —
freezes a byte-deterministic incident bundle of the alert window,
written to ``incident_dir`` when one is configured.

Fleet scoping: a monitor constructed with ``model="llama-a"`` folds
only events stamped with that model label (plus unscoped fleet-level
events), so per-member monitors coexist on the shared bus.

``consume_replan_trigger()`` is the Replanner's ``trigger="health"``
hook: it drains the count of page/anomaly alerts raised since the last
call.
"""
from __future__ import annotations

import os
from typing import List, Optional

from repro.deploy.spec import HealthSpec
from repro.obs.events import Event, emit, enabled
from repro.obs.health.alerts import Alert
from repro.obs.health.anomaly import CompositionDetector, LinkHealthDetector
from repro.obs.health.burn import BurnRateAlerter
from repro.obs.health.recorder import FlightRecorder, build_bundle


class HealthMonitor:
    """Live SLO/anomaly watchdog + incident forensics over the bus."""

    def __init__(self, spec: Optional[HealthSpec] = None, *,
                 model: str = "", incident_dir: Optional[str] = None):
        s = spec if spec is not None else HealthSpec()
        self.spec = s
        self.model = model
        self.incident_dir = (incident_dir if incident_dir is not None
                             else (s.incident_dir or None))
        burn_kw = dict(slo_target=s.slo_target,
                       fast_window_s=s.fast_window_s,
                       slow_window_s=s.slow_window_s,
                       page_burn=s.page_burn, ticket_burn=s.ticket_burn,
                       min_events=s.min_events, hysteresis=s.hysteresis,
                       cooldown_s=s.cooldown_s)
        self.attainment = BurnRateAlerter(signal="attainment", **burn_kw)
        self.tpot = (BurnRateAlerter(signal="tpot", **burn_kw)
                     if s.tpot_budget_ms > 0 else None)
        self.composition = CompositionDetector(
            window=s.anomaly_window, threshold=s.anomaly_threshold,
            hysteresis=s.hysteresis, cooldown_s=s.cooldown_s)
        self.link = LinkHealthDetector(
            window_s=s.link_window_s, util_threshold=s.link_util_threshold,
            queue_delay_s=s.queue_delay_s, hysteresis=s.hysteresis,
            cooldown_s=s.cooldown_s)
        self.recorder = FlightRecorder(maxlen=s.ring_events)
        from repro.obs.metrics import MetricsRegistry
        self.registry = MetricsRegistry()
        self.alerts: List[Alert] = []
        self.incidents: List[dict] = []  # {"name", "bytes", "path"|None}
        self._bundles: List[str] = []  # serialized docs, capped
        self._unconsumed = 0  # page/anomaly alerts the Replanner can drain
        self._scenario = None
        self._requests = None
        self.events_seen = 0
        self.last_t = 0.0

    # -------------------------------------------------------------- wiring --
    def bind_scenario(self, scenario, requests) -> None:
        """Attach the driving scenario so incident bundles can carry the
        replayable slice (spec + requests preceding the window)."""
        self._scenario = scenario
        self._requests = list(requests) if requests is not None else None

    # ------------------------------------------------------------- consume --
    def on_event(self, ev: Event) -> None:
        if ev.cat == "health":  # never consume our own alerts
            return
        if self.model and ev.model not in ("", self.model):
            return  # another fleet member's scope
        self.events_seen += 1
        now = ev.t + max(ev.dur, 0.0)
        self.last_t = max(self.last_t, now)
        self.recorder.record(ev)
        if ev.name == "request.finish":
            a = ev.args or {}
            tenant = a.get("tenant", "")
            self.attainment.record(now, tenant,
                                   not bool(a.get("attained", True)))
            if self.tpot is not None and a.get("tpot_s") is not None:
                self.tpot.record(
                    now, tenant,
                    a["tpot_s"] * 1e3 > self.spec.tpot_budget_ms)
            self._evaluate(now)
        elif ev.name == "request.reject":
            a = ev.args or {}
            self.attainment.record(now, a.get("tenant", ""), True)
            self._evaluate(now)
        elif ev.name == "demand.stall":
            a = ev.args or {}
            alert = self.composition.observe(ev.t, a.get("causes") or {})
            if alert is not None:
                self._fire(alert)
        elif ev.name == "transfer.start":
            a = ev.args or {}
            start_t = a.get("start_t", ev.t)
            complete_t = a.get("complete_t", start_t)
            for alert in self.link.observe(ev.t, ev.device,
                                           complete_t - start_t,
                                           start_t - ev.t):
                self._fire(alert)
        elif ev.name == "serving.step":
            self._evaluate(now)

    def _evaluate(self, now: float) -> None:
        for alert in self.attainment.evaluate(now):
            self._fire(alert)
        if self.tpot is not None:
            for alert in self.tpot.evaluate(now):
                self._fire(alert)

    # --------------------------------------------------------------- alerts --
    def _fire(self, alert: Alert) -> None:
        self.alerts.append(alert)
        self.registry.counter(f"health.alerts.{alert.severity}").inc()
        self.registry.counter(f"health.signal.{alert.signal}").inc()
        if alert.severity in ("page", "anomaly"):
            self._unconsumed += 1
        if enabled():
            emit("health.alert", alert.t, cat="health",
                 args={"signal": alert.signal, "severity": alert.severity,
                       "key": alert.key, "value": alert.value,
                       "threshold": alert.threshold})
        if len(self._bundles) < self.spec.max_incidents:
            self._capture(alert)

    def _capture(self, alert: Alert) -> None:
        window = self.spec.slow_window_s
        events = self.recorder.window(max(alert.t - window, 0.0), alert.t,
                                      model=self.model or None)
        seq = len(self._bundles)
        text = build_bundle(alert=alert, events=events,
                            metrics=self.registry.snapshot(),
                            window=window, seq=seq,
                            scenario=self._scenario,
                            requests=self._requests)
        self._bundles.append(text)
        self.registry.counter("health.incidents").inc()
        name = f"incident_{seq:03d}_{alert.signal}.json"
        path = None
        if self.incident_dir:
            os.makedirs(self.incident_dir, exist_ok=True)
            path = os.path.join(self.incident_dir, name)
            with open(path, "w") as f:
                f.write(text)
        self.incidents.append({"name": name, "bytes": len(text),
                               "path": path})

    # ------------------------------------------------------------ replanner --
    def consume_replan_trigger(self) -> int:
        """Drain page/anomaly alerts raised since the last call — the
        Replanner's ``trigger='health'`` condition."""
        n, self._unconsumed = self._unconsumed, 0
        return n

    # ------------------------------------------------------------ reporting --
    @property
    def bundles(self) -> List[str]:
        return list(self._bundles)

    def count(self, severity: str) -> int:
        return sum(1 for a in self.alerts if a.severity == severity)

    def first_alert_t(self) -> Optional[float]:
        return self.alerts[0].t if self.alerts else None

    def report(self) -> dict:
        by_signal: dict = {}
        for a in self.alerts:
            by_signal[a.signal] = by_signal.get(a.signal, 0) + 1
        return {
            "model": self.model,
            "events": self.events_seen,
            "alerts": len(self.alerts),
            "pages": self.count("page"),
            "tickets": self.count("ticket"),
            "anomalies": self.count("anomaly"),
            "by_signal": dict(sorted(by_signal.items())),
            "first_alert_t": self.first_alert_t(),
            "last_alert_t": self.alerts[-1].t if self.alerts else None,
            "alerts_detail": [a.to_dict() for a in self.alerts[:32]],
            "attainment": self.attainment.report(),
            "tpot": self.tpot.report() if self.tpot is not None else None,
            "composition": self.composition.report(),
            "link": self.link.report(),
            "recorder": {"recorded": self.recorder.recorded,
                         "dropped": self.recorder.dropped,
                         "ring": len(self.recorder)},
            "incidents": [dict(i) for i in self.incidents],
            "metrics": self.registry.snapshot(),
        }
