"""Tracer — collect bus events and export Chrome/Perfetto trace JSON.

The exported file is the standard trace-event format (the JSON flavour
both ``chrome://tracing`` and https://ui.perfetto.dev load directly):

* ``ph: "X"`` complete events for spans (transfers on a link, decode
  steps, request lifetimes) with ``ts``/``dur`` in microseconds,
* ``ph: "i"`` instant events for point observations (stalls, evictions,
  admission decisions),
* ``ph: "M"`` metadata records naming processes (one per model) and
  threads (one per device, plus one lane per request uid).

``pid`` is the model's first-seen index (single-model runs collapse to
pid 0); ``tid`` is the device index, or ``1000 + uid`` for per-request
lanes so request timelines render as their own rows under the same
process.  Export is byte-deterministic: events are sorted by emission
sequence, timestamps are rounded to sub-ns, and ``json.dumps`` runs
with ``sort_keys=True`` — two identical simulated runs produce
byte-identical files (pinned by ``tests/test_obs.py``).
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

from repro.obs.events import Event

_REQ_LANE = 1000  # tid offset for per-request rows


def _us(t: float) -> float:
    """Modeled seconds → trace microseconds, rounded for repr stability."""
    return round(t * 1e6, 3)


class Tracer:
    """Bus consumer that buffers events and renders trace-event JSON.

    ``max_export`` bounds how many events one export renders (the
    MOST RECENT ones win — the tail is where an investigation starts).
    When the cap drops events the trace gains a ``metadata`` block with
    the dropped/total counts and :meth:`export` warns on stderr, so a
    truncated artifact is never mistaken for a complete one.  Unbounded
    by default: existing exports stay byte-identical.
    """

    def __init__(self, max_export: int | None = None):
        if max_export is not None and max_export < 1:
            raise ValueError(f"max_export must be >= 1, got {max_export}")
        self.events: List[Event] = []
        self._models: Dict[str, int] = {}
        self.max_export = max_export
        self.dropped_last_export = 0

    # ------------------------------------------------------------ consume --
    def on_event(self, ev: Event) -> None:
        self.events.append(ev)
        if ev.model not in self._models:
            self._models[ev.model] = len(self._models)

    def clear(self) -> None:
        self.events.clear()
        self._models.clear()

    def __len__(self) -> int:
        return len(self.events)

    # ------------------------------------------------------------- export --
    def _pid(self, model: str) -> int:
        return self._models.get(model, 0)

    def to_chrome(self) -> dict:
        """Render the buffered events as a trace-event JSON object."""
        events = self.events
        dropped = 0
        if self.max_export is not None and len(events) > self.max_export:
            dropped = len(events) - self.max_export
            events = events[-self.max_export:]
        self.dropped_last_export = dropped
        out: List[dict] = []
        seen_threads = set()
        for model, pid in sorted(self._models.items(), key=lambda kv: kv[1]):
            out.append({"ph": "M", "pid": pid, "tid": 0,
                        "name": "process_name",
                        "args": {"name": model or "repro"}})
        for ev in events:
            pid = self._pid(ev.model)
            if ev.lane is not None:
                tid = _REQ_LANE + ev.lane
                label = f"request {ev.lane}"
            else:
                tid = ev.device
                label = f"device {ev.device}"
            if (pid, tid) not in seen_threads:
                seen_threads.add((pid, tid))
                out.append({"ph": "M", "pid": pid, "tid": tid,
                            "name": "thread_name",
                            "args": {"name": label}})
            rec = {"name": ev.name, "cat": ev.cat or "repro",
                   "pid": pid, "tid": tid, "ts": _us(ev.t)}
            if ev.dur > 0.0:
                rec["ph"] = "X"
                rec["dur"] = _us(ev.dur)
            else:
                rec["ph"] = "i"
                rec["s"] = "t"
            if ev.args:
                rec["args"] = dict(ev.args)
            out.append(rec)
        doc = {"traceEvents": out, "displayTimeUnit": "ms"}
        if dropped:  # only a truncated export carries the metadata block
            doc["metadata"] = {"dropped_events": dropped,
                               "total_events": len(self.events),
                               "max_export": self.max_export}
        return doc

    def export_str(self) -> str:
        return json.dumps(self.to_chrome(), sort_keys=True,
                          separators=(",", ":")) + "\n"

    def export(self, path) -> int:
        """Write the trace to ``path``; returns the exported event count."""
        text = self.export_str()
        with open(path, "w") as f:
            f.write(text)
        if self.dropped_last_export:
            print(f"[obs.trace] span cap {self.max_export}: dropped "
                  f"{self.dropped_last_export}/{len(self.events)} oldest "
                  f"events from {path}", file=sys.stderr)
        return len(self.events) - self.dropped_last_export
