"""The observability event bus — typed events on the simulated clock.

One process-wide :class:`EventBus` carries every subsystem's structured
events (``transfer.start/complete``, ``demand.stall``, ``residency.evict``,
``request.admit/reject/preempt/finish``, ``refine.apply/drop``, ...) to
whichever consumers are attached: a :class:`~repro.obs.trace.Tracer`
(Chrome/Perfetto export), a :class:`~repro.obs.metrics.MetricsCollector`
(counters / histograms), or a test harness.

Zero overhead when disabled: with no consumer attached ``enabled()`` is
False and every emit site skips even *building* its args dict::

    if obs.enabled():
        obs.emit("transfer.start", now, cat="transfer", device=d,
                 args={"key": str(key), "nbytes": rec.nbytes})

Emitting never touches the modeled timeline — events are observations of
event times the runtime already computed, so decode outputs and transfer
schedules are bitwise identical with the bus on or off (pinned by the
golden-trace and parity tests).

Scoping: ``with obs.scope(model="llama-a"):`` stamps every event emitted
inside the block with that model label (fleet members, deployments);
``device`` is stamped per event by the emitting engine/scheduler.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, List, Optional


@dataclasses.dataclass
class Event:
    """One structured observation on the simulated clock.

    ``t``/``dur`` are modeled seconds; ``dur > 0`` renders as a span
    (Perfetto ``X`` event), ``dur == 0`` as an instant.  ``lane`` (when
    set) overrides ``device`` as the display track — per-request
    timelines use ``lane = uid`` so requests get their own rows.
    """

    seq: int
    t: float
    name: str
    cat: str
    dur: float = 0.0
    device: int = 0
    model: str = ""
    lane: Optional[int] = None
    args: Optional[dict] = None


class EventBus:
    """Fan events out to attached consumers; a no-op with none attached."""

    def __init__(self):
        self._consumers: List[object] = []
        self._scope: List[str] = []
        self._seq = 0

    # ---------------------------------------------------------- consumers --
    @property
    def consumers(self) -> List[object]:
        return list(self._consumers)

    def attach(self, consumer) -> None:
        """Attach a consumer (anything with ``on_event(event)``)."""
        assert hasattr(consumer, "on_event"), consumer
        if consumer not in self._consumers:
            self._consumers.append(consumer)

    def detach(self, consumer) -> None:
        if consumer in self._consumers:
            self._consumers.remove(consumer)

    def enabled(self) -> bool:
        return bool(self._consumers)

    # ------------------------------------------------------------ scoping --
    @contextlib.contextmanager
    def scope(self, model: str):
        """Stamp events emitted inside the block with ``model``."""
        self._scope.append(model)
        try:
            yield
        finally:
            self._scope.pop()

    @property
    def current_model(self) -> str:
        return self._scope[-1] if self._scope else ""

    # --------------------------------------------------------------- emit --
    def emit(self, name: str, t: float, *, cat: str = "", dur: float = 0.0,
             device: int = 0, lane: Optional[int] = None,
             args: Optional[dict] = None) -> None:
        if not self._consumers:
            return
        ev = Event(seq=self._seq, t=float(t), name=name, cat=cat,
                   dur=float(dur), device=int(device),
                   model=self.current_model, lane=lane, args=args)
        self._seq += 1
        for c in self._consumers:
            c.on_event(ev)


#: The process-wide bus every subsystem emits to.  Swappable for test
#: isolation via :func:`use_bus`.
BUS = EventBus()


def enabled() -> bool:
    """Guard for emit sites: skip building args when nobody listens."""
    return BUS.enabled()


def emit(name: str, t: float, **kw) -> None:
    BUS.emit(name, t, **kw)


def attach(consumer) -> None:
    BUS.attach(consumer)


def detach(consumer) -> None:
    BUS.detach(consumer)


def scope(model: str):
    return BUS.scope(model)


@contextlib.contextmanager
def use_bus(bus: EventBus):
    """Swap the process-wide bus (test isolation)."""
    global BUS
    prev, BUS = BUS, bus
    try:
        yield bus
    finally:
        BUS = prev


@contextlib.contextmanager
def consumer(*consumers):
    """Attach consumers for the duration of a block (always detached)."""
    for c in consumers:
        attach(c)
    try:
        yield consumers[0] if len(consumers) == 1 else consumers
    finally:
        for c in consumers:
            detach(c)


def subscribe(fn: Callable[[Event], None]):
    """Adapt a plain callable into a consumer object (returns it attached;
    caller detaches)."""
    class _Fn:
        def on_event(self, ev):  # noqa: D401 - tiny adapter
            fn(ev)
    c = _Fn()
    attach(c)
    return c
