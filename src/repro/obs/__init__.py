"""repro.obs — unified tracing, stall attribution, and metrics.

Three consumers over one typed event bus on the simulated clock:

* :class:`Tracer` — Chrome/Perfetto trace-event export
  (``launch/serve.py --trace out.json``),
* :class:`StallAttribution` — every stalled second classified into a
  root cause with a conservation invariant against
  ``SchedulerStats.stall_s``,
* :class:`MetricsRegistry` / :class:`MetricsCollector` — deterministic
  counter/gauge/histogram snapshots embedded in ``Deployment.report()``
  and ``BENCH_*.json``.

Emit sites live in the subsystems; they guard with :func:`enabled` so a
run with no consumer attached pays nothing and changes nothing.
"""
from repro.obs.events import (  # noqa: F401
    BUS,
    Event,
    EventBus,
    attach,
    consumer,
    detach,
    emit,
    enabled,
    scope,
    subscribe,
    use_bus,
)
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsCollector,
    MetricsRegistry,
    request_metrics,
    scheduler_metrics,
)
from repro.obs.stall import CAUSES, StallAttribution  # noqa: F401
from repro.obs.trace import Tracer  # noqa: F401

__all__ = [
    "BUS", "Event", "EventBus", "attach", "consumer", "detach", "emit",
    "enabled", "scope", "subscribe", "use_bus",
    "Counter", "Gauge", "Histogram", "MetricsCollector", "MetricsRegistry",
    "request_metrics", "scheduler_metrics",
    "CAUSES", "StallAttribution", "Tracer",
]
