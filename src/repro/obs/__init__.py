"""repro.obs — unified tracing, stall attribution, and metrics.

Three consumers over one typed event bus on the simulated clock:

* :class:`Tracer` — Chrome/Perfetto trace-event export
  (``launch/serve.py --trace out.json``),
* :class:`StallAttribution` — every stalled second classified into a
  root cause with a conservation invariant against
  ``SchedulerStats.stall_s``,
* :class:`MetricsRegistry` / :class:`MetricsCollector` — deterministic
  counter/gauge/histogram snapshots embedded in ``Deployment.report()``
  and ``BENCH_*.json``.

Emit sites live in the subsystems; they guard with :func:`enabled` so a
run with no consumer attached pays nothing and changes nothing.

The LIVE complement is :mod:`repro.obs.health` (PR 9): multi-window SLO
burn-rate alerting, stall-composition / link anomaly detection, and a
flight recorder emitting byte-deterministic incident bundles —
re-exported lazily here (``obs.HealthMonitor``) to keep ``import
repro.obs`` free of the deploy-spec dependency.
"""
from repro.obs.events import (  # noqa: F401
    BUS,
    Event,
    EventBus,
    attach,
    consumer,
    detach,
    emit,
    enabled,
    scope,
    subscribe,
    use_bus,
)
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsCollector,
    MetricsRegistry,
    request_metrics,
    scheduler_metrics,
)
from repro.obs.stall import CAUSES, StallAttribution  # noqa: F401
from repro.obs.trace import Tracer  # noqa: F401

_LAZY = {  # health pulls in repro.deploy.spec; resolve on first touch
    "Alert": "health", "BurnRateAlerter": "health",
    "CompositionDetector": "health", "FlightRecorder": "health",
    "HealthMonitor": "health", "LinkHealthDetector": "health",
}

__all__ = [
    "BUS", "Event", "EventBus", "attach", "consumer", "detach", "emit",
    "enabled", "scope", "subscribe", "use_bus",
    "Counter", "Gauge", "Histogram", "MetricsCollector", "MetricsRegistry",
    "request_metrics", "scheduler_metrics",
    "CAUSES", "StallAttribution", "Tracer", *sorted(_LAZY),
]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f"repro.obs.{mod}"), name)
