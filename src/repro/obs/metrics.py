"""Metrics — counters / gauges / histograms with deterministic snapshots.

A :class:`MetricsRegistry` is a flat namespace of instruments; its
:meth:`~MetricsRegistry.snapshot` renders one sorted ``{name: value}``
dict (histograms expand to ``.count/.sum/.mean/.p50/.p99/.max``) that is
stable across identical simulated runs — the representation embedded in
``Deployment.report()["metrics"]``, printed by ``launch/serve.py
--metrics``, and pinned inside ``BENCH_*.json`` for
``benchmarks/compare.py`` to diff.

:class:`MetricsCollector` is the bus consumer that folds the event
stream into a registry: transfer traffic by kind, stalled seconds by
attributed cause (with a ``stall.conservation_violations`` counter that
increments whenever an event's cause segments fail to sum back to its
stall — the per-event view of the conservation invariant), residency
churn, and the request lifecycle with TTFT/TPOT split into
queue-wait / stall / compute.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Dict, List, Optional

import numpy as np

from repro.obs.events import Event


class Counter:
    """Monotonic accumulator (ints or seconds)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """Last-written value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Sample histogram; percentiles by nearest-rank on the sorted sample.

    Exact (and therefore bit-identical to the historical behavior) while
    the sample count stays at or below ``bound``.  Past the bound the
    sample store becomes a fixed-size uniform reservoir (Vitter's
    algorithm R) driven by a histogram-local seeded RNG, so memory stays
    O(bound) on 10k+-request runs while quantiles remain stable across
    identical runs — deterministic bounded mode, not a randomized
    sketch.  ``count/sum/mean/max`` are maintained as running values and
    stay EXACT in both modes; only ``p50/p99`` switch to the reservoir
    estimate once the bound is exceeded.  ``bound=None`` (the default
    for directly constructed histograms) keeps every sample.
    """

    __slots__ = ("values", "bound", "_seen", "_sum", "_max", "_rng")

    def __init__(self, bound: Optional[int] = None, seed: int = 0):
        if bound is not None and bound < 1:
            raise ValueError(f"histogram bound must be >= 1, got {bound}")
        self.values: List[float] = []
        self.bound = bound
        self._seen = 0
        self._sum = 0.0
        self._max = 0.0
        # lazily created on first reservoir replacement so unbounded /
        # small-N histograms never pay for RNG state
        self._rng = None if bound is None else seed

    def observe(self, value: float) -> None:
        value = float(value)
        self._max = value if self._seen == 0 else max(self._max, value)
        self._seen += 1
        self._sum += value
        if self.bound is None or len(self.values) < self.bound:
            self.values.append(value)
            return
        if isinstance(self._rng, int):
            self._rng = np.random.default_rng(self._rng)
        # algorithm R: sample i (0-based) replaces a reservoir slot
        # with probability bound/(i+1)
        j = int(self._rng.integers(0, self._seen))
        if j < self.bound:
            self.values[j] = value

    @property
    def count(self) -> int:
        return self._seen

    def percentile(self, q: float) -> float:
        if not self.values:
            return 0.0
        s = sorted(self.values)
        k = min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))
        return s[k]

    def summary(self) -> Dict[str, float]:
        n = self._seen
        return {
            "count": n,
            "sum": self._sum,
            "mean": self._sum / n if n else 0.0,
            "p50": self.percentile(0.50),
            "p99": self.percentile(0.99),
            "max": self._max if n else 0.0,
        }


#: Default per-histogram sample bound for registry-created histograms.
#: Exact below this count (so small-N pins are unaffected), reservoir
#: above it (so fleet-scale runs stay O(bound) per instrument).
DEFAULT_HIST_BOUND = 4096


class MetricsRegistry:
    """Get-or-create namespace of instruments with one flat snapshot.

    Histograms created through :meth:`histogram` are bounded at
    ``hist_bound`` samples (see :class:`Histogram`); each instrument's
    reservoir RNG is seeded from ``crc32(name) ^ seed`` so snapshots
    are deterministic per (registry seed, instrument name) — never from
    ``hash()``, which is randomized per process.  Pass
    ``hist_bound=None`` for the historical keep-everything behavior.
    """

    def __init__(self, hist_bound: Optional[int] = DEFAULT_HIST_BOUND,
                 seed: int = 0):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._hist_bound = hist_bound
        self._seed = int(seed)

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = Histogram(
                bound=self._hist_bound,
                seed=zlib.crc32(name.encode()) ^ self._seed)
            self._histograms[name] = h
        return h

    def snapshot(self) -> Dict[str, float]:
        """Sorted flat ``{name: value}`` dict, deterministic run-to-run."""
        out: Dict[str, float] = {}
        for name, c in self._counters.items():
            v = c.value
            out[name] = int(v) if float(v).is_integer() else v
        for name, g in self._gauges.items():
            out[name] = g.value
        for name, h in self._histograms.items():
            for stat, v in h.summary().items():
                out[f"{name}.{stat}"] = v
            if h.bound is not None and h._seen > h.bound:
                # percentiles are reservoir estimates past the bound;
                # stamp it so compare.py exempts p50/p99 from the
                # regression rule (count/sum/mean/max stay exact+gated)
                out[f"{name}.reservoir"] = True
        return dict(sorted(out.items()))

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._histograms.clear()


def scheduler_metrics(reg: MetricsRegistry, sched) -> MetricsRegistry:
    """Fold a scheduler's telemetry into ``reg`` (report-time snapshot).

    Duck-typed over :class:`~repro.runtime.scheduler.ExpertScheduler`
    and the cluster dispatcher's merged view: stats counters, stall
    attribution by cause (plus the conservation check as a 0/1 gauge),
    prefetch precision/recall, and per-expert activation frequencies.
    """
    st = sched.stats
    for f in dataclasses.fields(st):
        reg.counter(f"sched.{f.name}").inc(getattr(st, f.name))
    attr = sched.attribution
    snap = attr.snapshot()
    for cause, v in snap["causes"].items():
        reg.counter(f"stall.cause.{cause}_s").inc(v)
    reg.counter("stall.attributed_s").inc(attr.attributed_s())
    reg.gauge("stall.conservation_ok").set(
        1.0 if attr.check_conservation(st.stall_s) else 0.0)
    reg.gauge("prefetch.precision").set(sched.prefetch_precision())
    reg.gauge("prefetch.recall").set(sched.prefetch_recall())
    reg.gauge("overlap.efficiency").set(sched.overlap_efficiency())
    for (li, e), n in sorted(sched.activation_freqs.items()):
        reg.counter(f"experts.freq.L{li}.E{e}").inc(n)
    return reg


def request_metrics(reg: MetricsRegistry, requests) -> MetricsRegistry:
    """Fold completed serving requests into ``reg``: TTFT/TPOT plus the
    breakdown of each request's life into queue-wait / stall / compute."""
    for r in requests:
        if r.ttft is not None:
            reg.histogram("request.ttft_s").observe(r.ttft)
        if r.tpot is not None:
            reg.histogram("request.tpot_s").observe(r.tpot)
        if r.admitted_t is not None:
            reg.histogram("request.queue_s").observe(
                max(r.admitted_t - r.arrival_t, 0.0))
        reg.histogram("request.stall_s").observe(
            getattr(r, "stall_share_s", 0.0))
        reg.histogram("request.compute_s").observe(
            getattr(r, "compute_share_s", 0.0))
    return reg


_SEG_TOL = 1e-9  # per-event conservation slack (float associativity)


class MetricsCollector:
    """Bus consumer folding the event stream into a registry."""

    def __init__(self, registry: MetricsRegistry | None = None):
        self.registry = registry if registry is not None else MetricsRegistry()

    # The event names handled here mirror the emit sites across the
    # runtime/serving stack; unknown events only bump a generic counter
    # so new instrumentation never breaks an old collector.
    def on_event(self, ev: Event) -> None:
        m = self.registry
        m.counter("events_total").inc()
        if ev.name == "transfer.complete":
            a = ev.args or {}
            kind = a.get("kind", "unknown")
            m.counter(f"transfer.{kind}.count").inc()
            m.counter(f"transfer.{kind}.bytes").inc(a.get("nbytes", 0))
            m.histogram(f"transfer.{kind}.duration_s").observe(ev.dur)
            if a.get("demoted"):
                m.counter("transfer.demoted.count").inc()
            if a.get("disk_s", 0.0) > 0.0:
                m.counter("transfer.disk.count").inc()
        elif ev.name == "demand.stall":
            a = ev.args or {}
            stall = a.get("stall_s", ev.dur)
            m.counter("stall.total_s").inc(stall)
            m.histogram("stall.per_wait_s").observe(stall)
            attributed = 0.0
            for cause, seconds in (a.get("causes") or {}).items():
                m.counter(f"stall.cause.{cause}_s").inc(seconds)
                attributed += seconds
            if abs(attributed - stall) > _SEG_TOL * max(1.0, stall):
                m.counter("stall.conservation_violations").inc()
        elif ev.name == "residency.evict":
            m.counter("residency.evictions").inc()
        elif ev.name == "refine.apply":
            m.counter("refine.applied").inc()
        elif ev.name == "refine.drop":
            m.counter("refine.dropped").inc()
        elif ev.name.startswith("request."):
            what = ev.name.partition(".")[2]
            m.counter(f"requests.{what}").inc()
            if what == "finish":
                a = ev.args or {}
                for field in ("ttft_s", "tpot_s", "queue_s",
                              "stall_s", "compute_s"):
                    if field in a:
                        m.histogram(f"request.{field}").observe(a[field])
        elif ev.name.startswith("swap."):
            m.counter(f"serving.{ev.name.partition('.')[2]}s").inc()
        elif ev.name == "spec.divergence":
            a = ev.args or {}
            m.histogram("spec.divergence").observe(
                a.get("divergence", 0.0))
        elif ev.name in ("spec.serve", "spec.accept", "spec.rollback"):
            a = ev.args or {}
            what = ev.name.partition(".")[2]
            m.counter(f"spec.{what}").inc()
            # per-expert acceptance bookkeeping for health surfacing
            if ev.name != "spec.serve":
                key = f"L{a.get('layer', '?')}.E{a.get('expert', '?')}"
                m.counter(f"spec.{what}.{key}").inc()
        elif ev.name == "health.alert":
            a = ev.args or {}
            m.counter(f"health.alerts.{a.get('severity', 'page')}").inc()
            m.counter(f"health.signal.{a.get('signal', 'unknown')}").inc()
