"""Stall attribution — classify every stalled second into a root cause.

FloE's headline is stall time removed from the decode critical path, so
a stall number without a *why* is unanswerable: was the predictor wrong,
did a speculative prefetch get demoted behind a demand, did residency
evict an expert the future needed, was the link simply busy, did the
fetch have to go to disk, or is the token waiting on an INT8 draft
residual?  :class:`StallAttribution` answers that at the only place the
truth is known — :meth:`ExpertScheduler.wait_for`, where the residual
wait is computed — by splitting each stall into segments:

``link_contention``
    The governing transfer sat queued behind other traffic before it
    reached the link: ``clip(record.start_t - now, 0, stall)``.
``disk_tier_miss``
    The transfer had to page through the disk tier; the slowdown beyond
    a pure host→device copy: ``clip(duration - h2d_s, 0, remaining)``.
``speculative_demotion``
    Waiting on a prefetch that demand preemption pushed back.
``eviction``
    A demand re-fetch of an expert residency had previously evicted.
``draft_residual``
    Progressive serving waited on the low-bit draft of a cold expert.
``prefetch_late``
    A healthy, undemoted prefetch simply had not finished in time.
``predictor_miss``
    Cold demand with no mitigating story — the predictor never asked.
``speculative_fallback``
    Speculative execution fell back to waiting on the big expert: the
    divergence predictor declined to speculate, a rollback replay
    re-waited, or a settle forced the wait at request finish.

Conservation is the invariant the whole design hangs on: the attributor
accumulates ``total_s += stall`` in lockstep with the scheduler's
``stats.stall_s += stall`` — same values, same order — so the two are
**bitwise** equal, and per-cause segments are constructed to sum back
to each stall (checked within float-associativity tolerance).  The
attributor is always on (it is stats-level bookkeeping, like
``stall_s`` itself), independent of whether the event bus has
consumers.
"""
from __future__ import annotations

from typing import Dict, Optional

#: Every cause class the attributor can emit, in reporting order.
CAUSES = (
    "predictor_miss",
    "speculative_demotion",
    "eviction",
    "link_contention",
    "disk_tier_miss",
    "draft_residual",
    "prefetch_late",
    "speculative_fallback",
)

_REL_TOL = 1e-9  # float associativity headroom for per-cause sums


class StallAttribution:
    """Per-scheduler ledger mapping stalled seconds to root causes."""

    def __init__(self):
        self.causes: Dict[str, float] = {}
        self.total_s: float = 0.0
        self.events: int = 0

    # ---------------------------------------------------------- recording --
    def attribute(self, stall: float, now: float, *, record=None,
                  cause: Optional[str] = None,
                  origin_prefetch: bool = False) -> Dict[str, float]:
        """Record one ``wait_for`` residual and split it into segments.

        ``stall`` must be the exact value added to ``stats.stall_s`` so
        the conservation invariant holds bitwise.  ``record`` is the
        governing transfer (the one whose ``complete_t`` gated the
        wait), if any; ``cause`` is an explicit primary cause from the
        demand path (eviction / draft_residual / predictor_miss);
        ``origin_prefetch`` marks waits satisfied by a live prefetch.
        """
        self.total_s += stall
        self.events += 1
        segs: Dict[str, float] = {}
        if stall <= 0.0:
            return segs
        remaining = stall
        if record is not None:
            # Queueing delay before the transfer reached the link.
            queued = min(max(record.start_t - now, 0.0), remaining)
            if queued > 0.0:
                segs["link_contention"] = queued
                remaining -= queued
            # Disk-tier overhead beyond the pure host->device copy.
            if remaining > 0.0 and getattr(record, "disk_s", 0.0) > 0.0:
                h2d = getattr(record, "h2d_s", 0.0)
                disk = min(max(record.duration - h2d, 0.0), remaining)
                if disk > 0.0:
                    segs["disk_tier_miss"] = disk
                    remaining -= disk
        if remaining > 0.0:
            primary = cause
            if primary is None:
                if record is not None and record.demoted:
                    primary = "speculative_demotion"
                elif origin_prefetch:
                    primary = "prefetch_late"
                else:
                    primary = "predictor_miss"
            segs[primary] = segs.get(primary, 0.0) + remaining
        for k, v in segs.items():
            self.causes[k] = self.causes.get(k, 0.0) + v
        return segs

    # ---------------------------------------------------------- reporting --
    def snapshot(self) -> dict:
        """Deterministic dict view: every cause (zeros included), totals."""
        return {
            "total_s": self.total_s,
            "events": self.events,
            "causes": {c: self.causes.get(c, 0.0) for c in CAUSES},
        }

    def attributed_s(self) -> float:
        return sum(self.causes.get(c, 0.0) for c in CAUSES)

    def check_conservation(self, stall_s: float) -> bool:
        """True iff attribution conserves the scheduler's stall total.

        ``total_s`` must equal ``stall_s`` *bitwise* (lockstep
        accumulation), and the per-cause segments must sum back to the
        total within float-associativity tolerance.
        """
        if self.total_s != stall_s:
            return False
        tol = _REL_TOL * max(1.0, abs(self.total_s))
        return abs(self.attributed_s() - self.total_s) <= tol

    def merge(self, other: "StallAttribution") -> "StallAttribution":
        """Field-wise sum (cluster view over per-device attributors)."""
        out = StallAttribution()
        for src in (self, other):
            out.total_s += src.total_s
            out.events += src.events
            for k, v in src.causes.items():
                out.causes[k] = out.causes.get(k, 0.0) + v
        return out

    def reset(self) -> None:
        self.causes.clear()
        self.total_s = 0.0
        self.events = 0
