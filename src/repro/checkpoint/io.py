"""Pytree checkpointing: msgpack + zstd, no orbax dependency.

Leaves are stored as (dtype, shape, raw bytes); the treedef is rebuilt from
the same nested-dict structure, so any params/opt-state pytree of arrays
round-trips.  bfloat16 is encoded via uint16 views (msgpack/numpy have no
native bf16).

Registered pytree *nodes* (dataclasses exposing ``tree_flatten`` /
``tree_unflatten``, e.g. ``repro.core.hqq.QTensor``) also round-trip: the
node is stored as its class path + packed aux data + packed children and
rebuilt via ``tree_unflatten`` on load, so sub-byte packed codes and frozen
static metadata survive a checkpoint.

`zstandard` is optional: when the wheel is absent checkpoints are written
with a raw codec behind a small magic header, and either codec is detected
on load (zstd frames carry their own 0xFD2FB528 magic).

Sharded layout (``ShardWriter`` / ``ShardReader``): one ``data.bin`` of
independently-encoded records plus a small ``index.msgpack`` of
``key -> (offset, length)``.  Opening a reader touches ONLY the index;
``load(key)`` seeks and decodes one record — a single expert's weights
load without deserializing the rest of the checkpoint (the disk tier of
``repro.store`` is built on this).
"""
from __future__ import annotations

import dataclasses
import importlib
from pathlib import Path
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # raw fallback codec below
    zstandard = None

_BF16 = "bfloat16"
_RAW_MAGIC = b"CKPTRAW0"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _encode_leaf(x) -> dict:
    arr = np.asarray(x)
    if str(arr.dtype) == _BF16:
        return {"d": _BF16, "s": list(arr.shape),
                "b": arr.view(np.uint16).tobytes()}
    return {"d": str(arr.dtype), "s": list(arr.shape), "b": arr.tobytes()}


def _decode_leaf(rec: dict) -> np.ndarray:
    if rec["d"] == _BF16:
        u = np.frombuffer(rec["b"], np.uint16).reshape(rec["s"])
        return u.view(jnp.bfloat16)
    return np.frombuffer(rec["b"], rec["d"]).reshape(rec["s"]).copy()


def _pack_aux(v: Any) -> Any:
    """Static (non-array) aux data of a pytree node: scalars + nested
    tuples/lists only — kept in native msgpack types so e.g. a QTensor's
    ``shape`` comes back as the same tuple of python ints."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return {"k": "s", "v": v}
    if isinstance(v, (list, tuple)):
        tag = "l" if isinstance(v, list) else "t"
        return {"k": tag, "v": [_pack_aux(x) for x in v]}
    raise TypeError(f"unsupported pytree-node aux value: {type(v)}")


def _unpack_aux(rec: Any) -> Any:
    if rec["k"] == "s":
        return rec["v"]
    vals = [_unpack_aux(x) for x in rec["v"]]
    return vals if rec["k"] == "l" else tuple(vals)


def _is_node(tree: Any) -> bool:
    """A registered-pytree dataclass node (QTensor-style)."""
    return (dataclasses.is_dataclass(tree) and hasattr(tree, "tree_flatten")
            and hasattr(type(tree), "tree_unflatten"))


def _pack(tree: Any) -> Any:
    if isinstance(tree, dict):
        return {"__t": "d", "v": {k: _pack(v) for k, v in tree.items()}}
    if _is_node(tree):
        children, aux = tree.tree_flatten()
        cls = type(tree)
        return {"__t": "n", "c": f"{cls.__module__}:{cls.__qualname__}",
                "x": _pack_aux(tuple(aux)),
                "v": [_pack(c) for c in children]}
    if isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        name = type(tree).__name__ if hasattr(tree, "_fields") else ""
        return {"__t": tag, "n": name, "v": [_pack(v) for v in tree]}
    return {"__t": "a", "v": _encode_leaf(tree)}


def _unpack(rec: Any) -> Any:
    t = rec["__t"]
    if t == "d":
        return {k: _unpack(v) for k, v in rec["v"].items()}
    if t == "n":
        mod, _, qual = rec["c"].partition(":")
        cls: Any = importlib.import_module(mod)
        for part in qual.split("."):
            cls = getattr(cls, part)
        children = [_unpack(v) for v in rec["v"]]
        return cls.tree_unflatten(_unpack_aux(rec["x"]), children)
    if t in ("l", "t"):
        vals = [_unpack(v) for v in rec["v"]]
        return vals if t == "l" else tuple(vals)
    return _decode_leaf(rec["v"])


def save_checkpoint(path: str | Path, tree: Any, *, level: int = 3) -> int:
    """Returns bytes written."""
    comp = _encode_record(tree, level)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_bytes(comp)
    return len(comp)


def _encode_record(tree: Any, level: int) -> bytes:
    tree = jax.tree.map(np.asarray, tree)
    raw = msgpack.packb(_pack(tree), use_bin_type=True)
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=level).compress(raw)
    return _RAW_MAGIC + raw


def _decode_record(blob: bytes, path) -> Any:
    if blob.startswith(_RAW_MAGIC):
        raw = blob[len(_RAW_MAGIC):]
    elif blob.startswith(_ZSTD_MAGIC):
        if zstandard is None:
            raise RuntimeError(
                f"{path} is zstd-compressed but zstandard is not installed")
        raw = zstandard.ZstdDecompressor().decompress(blob)
    else:
        raise ValueError(f"{path}: unrecognized checkpoint codec")
    return _unpack(msgpack.unpackb(raw, raw=False))


_INDEX_FILE = "index.msgpack"
_DATA_FILE = "data.bin"


class ShardWriter:
    """Append-only sharded checkpoint: per-key records + an offset index."""

    def __init__(self, dirpath: str | Path, *, level: int = 3):
        self.dir = Path(dirpath)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.level = level
        self._index: dict[str, list[int]] = {}
        self._data = open(self.dir / _DATA_FILE, "wb")
        self._offset = 0

    def add(self, key: str, tree: Any) -> int:
        """Encode one record; returns its stored byte size."""
        assert key not in self._index, f"duplicate shard key {key!r}"
        blob = _encode_record(tree, self.level)
        self._data.write(blob)
        self._index[key] = [self._offset, len(blob)]
        self._offset += len(blob)
        return len(blob)

    def close(self) -> int:
        """Flush data + index; returns total bytes on disk."""
        self._data.close()
        idx = msgpack.packb({"records": self._index}, use_bin_type=True)
        (self.dir / _INDEX_FILE).write_bytes(idx)
        return self._offset + len(idx)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class ShardReader:
    """Lazy sharded-checkpoint reader: the offset index is decoded at
    most ONCE per reader — on first use, not at open — and reused by
    every subsequent lookup; each ``load`` then seeks to one record and
    decodes it alone.  ``index_builds`` (telemetry) must stay at 1 for
    the lifetime of a reader: per-expert fetch loops (the store's
    disk-tier prefill path) never re-scan the shard header."""

    def __init__(self, dirpath: str | Path):
        self.dir = Path(dirpath)
        self._index: dict[str, list] | None = None  # built lazily, once
        # one long-lived handle: per-record loads seek, not reopen
        self._data = open(self.dir / _DATA_FILE, "rb")
        # telemetry: proves single-record loads don't touch the full file
        self.records_decoded = 0
        self.bytes_read = 0
        self.index_builds = 0

    def _ensure_index(self) -> dict[str, list]:
        if self._index is None:
            idx = msgpack.unpackb((self.dir / _INDEX_FILE).read_bytes(),
                                  raw=False)
            self._index = idx["records"]
            self.index_builds += 1
        return self._index

    def keys(self) -> Iterable[str]:
        return list(self._ensure_index().keys())

    def __contains__(self, key: str) -> bool:
        return key in self._ensure_index()

    def nbytes(self, key: str) -> int:
        """Stored (on-disk) size of one record."""
        return self._ensure_index()[key][1]

    def load(self, key: str) -> Any:
        off, length = self._ensure_index()[key]
        self._data.seek(off)
        blob = self._data.read(length)
        self.records_decoded += 1
        self.bytes_read += length
        return _decode_record(blob, self.dir / _DATA_FILE)

    def close(self) -> None:
        self._data.close()


def save_sharded(dirpath: str | Path, records: dict, *,
                 level: int = 3) -> int:
    """Write ``{key: tree}`` as a sharded checkpoint; returns total bytes
    on disk (data + index)."""
    w = ShardWriter(dirpath, level=level)
    for k, tree in records.items():
        w.add(k, tree)
    return w.close()


def load_checkpoint(path: str | Path) -> Any:
    return _decode_record(Path(path).read_bytes(), path)
