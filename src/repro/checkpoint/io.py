"""Pytree checkpointing: msgpack + zstd, no orbax dependency.

Leaves are stored as (dtype, shape, raw bytes); the treedef is rebuilt from
the same nested-dict structure, so any params/opt-state pytree of arrays
round-trips.  bfloat16 is encoded via uint16 views (msgpack/numpy have no
native bf16).

`zstandard` is optional: when the wheel is absent checkpoints are written
with a raw codec behind a small magic header, and either codec is detected
on load (zstd frames carry their own 0xFD2FB528 magic).
"""
from __future__ import annotations

from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # raw fallback codec below
    zstandard = None

_BF16 = "bfloat16"
_RAW_MAGIC = b"CKPTRAW0"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _encode_leaf(x) -> dict:
    arr = np.asarray(x)
    if str(arr.dtype) == _BF16:
        return {"d": _BF16, "s": list(arr.shape),
                "b": arr.view(np.uint16).tobytes()}
    return {"d": str(arr.dtype), "s": list(arr.shape), "b": arr.tobytes()}


def _decode_leaf(rec: dict) -> np.ndarray:
    if rec["d"] == _BF16:
        u = np.frombuffer(rec["b"], np.uint16).reshape(rec["s"])
        return u.view(jnp.bfloat16)
    return np.frombuffer(rec["b"], rec["d"]).reshape(rec["s"]).copy()


def _pack(tree: Any) -> Any:
    if isinstance(tree, dict):
        return {"__t": "d", "v": {k: _pack(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        name = type(tree).__name__ if hasattr(tree, "_fields") else ""
        return {"__t": tag, "n": name, "v": [_pack(v) for v in tree]}
    return {"__t": "a", "v": _encode_leaf(tree)}


def _unpack(rec: Any) -> Any:
    t = rec["__t"]
    if t == "d":
        return {k: _unpack(v) for k, v in rec["v"].items()}
    if t in ("l", "t"):
        vals = [_unpack(v) for v in rec["v"]]
        return vals if t == "l" else tuple(vals)
    return _decode_leaf(rec["v"])


def save_checkpoint(path: str | Path, tree: Any, *, level: int = 3) -> int:
    """Returns bytes written."""
    tree = jax.tree.map(np.asarray, tree)
    raw = msgpack.packb(_pack(tree), use_bin_type=True)
    if zstandard is not None:
        comp = zstandard.ZstdCompressor(level=level).compress(raw)
    else:
        comp = _RAW_MAGIC + raw
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_bytes(comp)
    return len(comp)


def load_checkpoint(path: str | Path) -> Any:
    blob = Path(path).read_bytes()
    if blob.startswith(_RAW_MAGIC):
        raw = blob[len(_RAW_MAGIC):]
    elif blob.startswith(_ZSTD_MAGIC):
        if zstandard is None:
            raise RuntimeError(
                f"{path} is zstd-compressed but zstandard is not installed")
        raw = zstandard.ZstdDecompressor().decompress(blob)
    else:
        raise ValueError(f"{path}: unrecognized checkpoint codec")
    return _unpack(msgpack.unpackb(raw, raw=False))
