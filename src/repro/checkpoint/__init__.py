from repro.checkpoint.io import (ShardReader, ShardWriter, load_checkpoint,
                                 save_checkpoint, save_sharded)

__all__ = ["save_checkpoint", "load_checkpoint", "ShardReader",
           "ShardWriter", "save_sharded"]
