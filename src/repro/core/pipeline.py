"""The FloE on-the-fly decode pipeline (paper Fig. 1(c)).

Host-driven layer loop for offloaded MoE decoding:

  while computing layer i:
    inter-predictor(h_i)  -> experts likely routed at layer i+1
    intra-predictor(h_i)  -> their active channels (reused W_up^(i+1,q))
    offload engine        -> prefetch compact sparse slices into the cache

  at layer i+1:
    true router + true mask (from resident quantized up) decide what is
    actually needed; cache hits cost nothing, mispredictions pay a
    synchronous reload; prefetched-but-missing channels are dropped
    (coverage is logged — the FloE approximation).

Timing: every step charges a modeled compute time (DeviceModel) and modeled
transfer time (LinkModel); prefetch overlaps with compute, sync reloads
stall.  Real jax ops still run, so outputs are functionally exact given the
prefetched weights.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.core import floe_layer, hqq, predictor, sparsify
from repro.core.cache import ExpertCache
from repro.core.offload import ExpertStore, LinkModel
from repro.models import nn


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """RTX-3090-like accelerator for the latency model (paper's testbed)."""

    peak_flops: float = 35.6e12  # fp16
    hbm_bw: float = 936e9  # bytes/s

    def matmul_time(self, flops: float, bytes_touched: float) -> float:
        return max(flops / self.peak_flops, bytes_touched / self.hbm_bw)


def paper_scaled_models(cfg: ModelConfig) -> tuple[DeviceModel, LinkModel]:
    """Latency-model constants that preserve the PAPER's ratios at reduced
    model scale: dense per-expert compute ≈ 5 ms, dense fp16 expert transfer
    ≈ 15 ms over the link (Mixtral-8x7B on RTX 3090 + PCIe 4.0, §3.1), HBM
    ~29× the link.  Without this, micro models make transfer unhidable (µs
    of compute vs ms of transfer) and every overlap experiment degenerates.
    """
    df = cfg.d_model * (cfg.moe_d_ff or cfg.d_ff)
    dense_bytes = 6.0 * df  # 3 fp16 matrices
    flops = 6.0 * df  # per-token GEMV flops
    device = DeviceModel(peak_flops=flops / 0.005,
                         hbm_bw=dense_bytes / 0.005)
    link = LinkModel(peak_bw=dense_bytes / 0.015, launch_us=10.0,
                     pack_bw=6.0 * dense_bytes / 0.015)
    return device, link


@dataclasses.dataclass
class StepMetrics:
    compute_s: float = 0.0
    stall_s: float = 0.0
    prefetch_s: float = 0.0  # issued (overlapped) transfer time
    coverage: float = 1.0  # fraction of needed channels that were resident
    expert_hits: int = 0
    expert_misses: int = 0


class FloEPipeline:
    """Offloaded decode for one MoE model (host loop over layers)."""

    def __init__(self, params: dict, cfg: ModelConfig, *,
                 thresholds: np.ndarray,  # (L, E)
                 inter_predictors: Optional[list] = None,
                 cache_slots: int = 4,
                 link: Optional[LinkModel] = None,
                 device: Optional[DeviceModel] = None,
                 prefetch: bool = True,
                 mode: str = "floe"):  # "floe" | "naive" | "resident"
        self.cfg = cfg
        self.mode = mode
        self.prefetch = prefetch and mode == "floe"
        self.link = link or LinkModel()
        self.device = device or DeviceModel()
        self.inter = inter_predictors
        self.layers = _unstack_layers(params, cfg)
        self.embedding = params["embedding"]
        self.final_norm = params["final_norm"]
        self.lm_head = params.get("lm_head")
        self.cfg = cfg

        # per-layer host stores + resident quantized up + caches
        self.stores: list[Optional[ExpertStore]] = []
        self.up_res: list = []
        self.caches: list = []
        for li, layer in enumerate(self.layers):
            if "moe" not in layer:
                self.stores.append(None)
                self.up_res.append(None)
                self.caches.append(None)
                continue
            moe_p = layer["moe"]
            thr = thresholds[li]
            if mode == "resident":
                self.stores.append(None)
            else:
                from repro.core.offload import build_expert_store
                self.stores.append(build_expert_store(
                    moe_p, thr, bits=cfg.floe.up_bits,
                    group=cfg.floe.quant_group, link=self.link))
            self.up_res.append(floe_layer.compress_moe_layer(
                moe_p, thr, bits=cfg.floe.up_bits, group=cfg.floe.quant_group))
            self.caches.append(ExpertCache(cache_slots))
        self.metrics: list[StepMetrics] = []

    # ------------------------------------------------------------ helpers --
    def _moe_layer_indices(self):
        return [i for i, l in enumerate(self.layers) if "moe" in l]

    def _route(self, h: jax.Array, li: int):
        from repro.models.moe import router_topk
        gates, eids, _ = router_topk(
            h, self.layers[li]["moe"]["router"], self.cfg.num_experts_per_tok)
        return np.asarray(gates), np.asarray(eids)

    def _true_mask(self, h: jax.Array, li: int, e: int):
        w = self.up_res[li]
        qt = hqq.QTensor(w.up_q.packed[e], w.up_q.scale[e], w.up_q.zero[e],
                         w.up_q.bits, w.up_q.group, w.up_q.shape)
        v, mask = floe_layer.up_and_mask(h, qt, w.thresholds[e])
        return v, np.asarray(mask.any(axis=0))

    def _predict_next(self, h: jax.Array, li_next: int):
        """(expert ids, per-expert predicted channel masks) for layer li_next."""
        if self.inter is not None and self.inter[li_next] is not None:
            eids = np.asarray(predictor.inter_predict_topk(
                self.inter[li_next], h, self.cfg.num_experts_per_tok))
        else:  # fallback: today's router reused (high hidden-state similarity)
            _, eids = self._route(h, li_next)
        eids = np.unique(eids.reshape(-1))
        masks = {}
        for e in eids.tolist():
            _, m = self._true_mask(h, li_next, e)  # reuse-based intra pred
            masks[e] = m
        return eids.tolist(), masks

    # --------------------------------------------------------- expert exec -
    def _run_expert(self, h, li, e, metrics: StepMetrics):
        cfg = self.cfg
        d, f = cfg.d_model, cfg.moe_d_ff
        w = self.up_res[li]
        qt = hqq.QTensor(w.up_q.packed[e], w.up_q.scale[e], w.up_q.zero[e],
                         w.up_q.bits, w.up_q.group, w.up_q.shape)
        v, need_mask = self._true_mask(h, li, e)

        if self.mode == "resident":
            y = sparsify.expert_forward_dense(
                h, w.we_gate[e], hqq.dequantize(qt, h.dtype), w.we_down[e])
            metrics.compute_s += self.device.matmul_time(
                6 * h.shape[0] * d * f, 6 * d * f)
            return y, 1.0

        store = self.stores[li]
        if self.mode == "naive":
            wg, wu, wd = store.fetch_dense(e)  # (D,F), (D,F), (F,D)
            metrics.stall_s += self.link.transfer_time(
                store.dense_expert_bytes(), 3)
            y = sparsify.expert_forward_dense(h, wg, wu, wd)
            metrics.compute_s += self.device.matmul_time(
                6 * h.shape[0] * d * f, 6 * d * f)
            return y, 1.0

        # --- floe mode ---
        cache = self.caches[li]
        payload = cache.get((li, e))
        if payload is None:
            idx = np.nonzero(need_mask)[0]
            t0_model = self.link.transfer_time(
                len(idx) * 2 * d * store.records.dtype.itemsize,
                max(1, len(idx) // 50))
            gate_cols, down_rows = store.fetch_sparse(e, idx)
            cache.put((li, e), (idx, gate_cols, down_rows))
            metrics.stall_s += t0_model
            metrics.expert_misses += 1
            payload = (idx, gate_cols, down_rows)
        else:
            metrics.expert_hits += 1
        idx, gate_cols, down_rows = payload

        avail = np.zeros(f, bool)
        avail[idx] = True
        usable = need_mask & avail
        cov = usable.sum() / max(need_mask.sum(), 1)
        sel = np.nonzero(usable[idx])[0]  # positions within the slice
        v_active = v[:, idx[sel]]
        y = floe_layer.sparse_expert_apply(
            h, gate_cols[sel], down_rows[sel], v_active)
        # compute model: dense up GEMV + sparse gate/down GEMVs
        n_act = int(len(sel))
        up_bytes = qt.packed.nbytes + qt.scale.nbytes + qt.zero.nbytes
        metrics.compute_s += self.device.matmul_time(
            2 * h.shape[0] * d * f, up_bytes)
        metrics.compute_s += self.device.matmul_time(
            4 * h.shape[0] * d * n_act, 4 * d * n_act)
        return y, float(cov)

    # --------------------------------------------------------- decode step -
    def decode_token(self, h: jax.Array) -> tuple[jax.Array, StepMetrics]:
        """h (B, D): post-embedding hidden state; returns final hidden."""
        cfg = self.cfg
        metrics = StepMetrics()
        covs = []
        moe_layers = set(self._moe_layer_indices())

        for li, layer in enumerate(self.layers):
            # prefetch for the NEXT MoE layer while "computing" this one
            nxt = li + 1
            if self.prefetch and nxt in moe_layers and self.caches[nxt] is not None:
                eids, masks = self._predict_next(h, nxt)
                for e in eids:
                    if (nxt, e) in self.caches[nxt]:
                        continue
                    idx = np.nonzero(masks[e])[0]
                    store = self.stores[nxt]
                    gate_cols, down_rows = store.fetch_sparse(e, idx)
                    self.caches[nxt].put((nxt, e), (idx, gate_cols, down_rows),
                                         prefetch=True)
                    metrics.prefetch_s += self.link.transfer_time(
                        len(idx) * 2 * cfg.d_model *
                        store.records.dtype.itemsize,
                        max(1, len(idx) // 50))

            # non-expert compute (attention + norms) — modeled only
            attn_flops = 2 * h.shape[0] * (
                4 * cfg.d_model * cfg.num_heads * cfg.head_dim)
            metrics.compute_s += self.device.matmul_time(
                attn_flops, 4 * cfg.d_model * cfg.num_heads * cfg.head_dim * 2)

            if li in moe_layers:
                hn = nn.rms_norm(h, layer["mlp_norm"]["scale"], cfg.norm_eps)
                gates, eids = self._route(hn, li)
                y = jnp.zeros_like(h, dtype=jnp.float32)
                for slot in range(eids.shape[1]):
                    for b in range(h.shape[0]):
                        e = int(eids[b, slot])
                        ye, cov = self._run_expert(hn[b:b + 1], li, e, metrics)
                        covs.append(cov)
                        y = y.at[b].add(ye[0].astype(jnp.float32)
                                        * gates[b, slot])
                h = h + y.astype(h.dtype)
            else:
                pass  # dense layers resident; compute time charged above

        # prefetch overlaps with compute: only the excess stalls
        metrics.stall_s += max(0.0, metrics.prefetch_s - metrics.compute_s)
        metrics.coverage = float(np.mean(covs)) if covs else 1.0
        self.metrics.append(metrics)
        return h, metrics

    def tokens_per_second(self) -> float:
        if not self.metrics:
            return 0.0
        total = sum(m.compute_s + m.stall_s for m in self.metrics)
        return len(self.metrics) / max(total, 1e-12)


def _unstack_layers(params: dict, cfg: ModelConfig) -> list[dict]:
    """Flatten scan-stacked params into a per-layer list of block params."""
    layers: list[dict] = []
    for si, (pattern, reps) in enumerate(cfg.segments()):
        seg = params[f"seg{si}"]
        for r in range(reps):
            for pi, kind in enumerate(pattern):
                sp = jax.tree.map(lambda a: a[r], seg[f"pos{pi}"])
                if kind == "shared":
                    block = dict(seg["shared_block"])
                    block["shared_in"] = sp["shared_in"]
                    layers.append(block)
                else:
                    layers.append(sp)
    return layers
