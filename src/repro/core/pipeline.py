"""The FloE on-the-fly decode pipeline (paper Fig. 1(c)).

Host-driven layer loop for offloaded MoE decoding:

  while computing layer i:
    inter-predictor(h_i)  -> experts likely routed at layer i+1
    intra-predictor(h_i)  -> their active channels (reused W_up^(i+1,q))
    offload engine        -> prefetch compact sparse slices into the cache

  at layer i+1:
    true router + true mask (from resident quantized up) decide what is
    actually needed; cache hits cost nothing, mispredictions pay a
    synchronous reload; prefetched-but-missing channels are dropped
    (coverage is logged — the FloE approximation).

Two timing backends:

* synchronous (historical): every step charges a modeled compute time
  (DeviceModel) and modeled transfer time (LinkModel); prefetch "overlap"
  is the end-of-token accounting identity
  ``stall += max(0, prefetch_s - compute_s)``.
* runtime (``use_runtime=True``): decode is driven through
  ``repro.runtime.ExpertScheduler`` — a simulated-clock event loop where
  prefetches occupy real (modeled) link/staging-buffer timelines, the
  true router cancels stale speculation, and stalls are the *measured*
  residual waits at demand time.  Cross-layer lookahead ≥ 2 and priority
  scheduling only exist on this path (FloE §3.4 made operational).

Both paths run the same jax ops on the same staged payloads, so with
matching residency configuration (lookahead=1, LRU, ample staging
buffers) their outputs are bitwise identical — pinned by a test.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.core import floe_layer, hqq, predictor, sparsify
from repro.core.cache import ExpertCache
from repro.core.offload import ExpertStore, LinkModel
from repro.models import nn
from repro.runtime import ExpertScheduler, ResidencyManager, TransferEngine


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """RTX-3090-like accelerator for the latency model (paper's testbed)."""

    peak_flops: float = 35.6e12  # fp16
    hbm_bw: float = 936e9  # bytes/s

    def matmul_time(self, flops: float, bytes_touched: float) -> float:
        return max(flops / self.peak_flops, bytes_touched / self.hbm_bw)


def paper_scaled_models(cfg: ModelConfig) -> tuple[DeviceModel, LinkModel]:
    """Latency-model constants that preserve the PAPER's ratios at reduced
    model scale: dense per-expert compute ≈ 5 ms, dense fp16 expert transfer
    ≈ 15 ms over the link (Mixtral-8x7B on RTX 3090 + PCIe 4.0, §3.1), HBM
    ~29× the link.  Without this, micro models make transfer unhidable (µs
    of compute vs ms of transfer) and every overlap experiment degenerates.
    """
    df = cfg.d_model * (cfg.moe_d_ff or cfg.d_ff)
    dense_bytes = 6.0 * df  # 3 fp16 matrices
    flops = 6.0 * df  # per-token GEMV flops
    device = DeviceModel(peak_flops=flops / 0.005,
                         hbm_bw=dense_bytes / 0.005)
    link = LinkModel(peak_bw=dense_bytes / 0.015, launch_us=10.0,
                     pack_bw=6.0 * dense_bytes / 0.015)
    return device, link


_UNSET = object()  # "use the pipeline's own predictor" sentinel


@dataclasses.dataclass
class StepMetrics:
    compute_s: float = 0.0
    stall_s: float = 0.0
    prefetch_s: float = 0.0  # issued (overlapped) transfer time
    coverage: float = 1.0  # fraction of needed channels that were resident
    expert_hits: int = 0
    expert_misses: int = 0


class FloEPipeline:
    """Offloaded decode for one MoE model (host loop over layers)."""

    def __init__(self, params: dict, cfg: ModelConfig, *,
                 thresholds: np.ndarray,  # (L, E)
                 inter_predictors: Optional[list] = None,
                 cache_slots: int = 4,
                 link: Optional[LinkModel] = None,
                 device: Optional[DeviceModel] = None,
                 prefetch: bool = True,
                 mode: str = "floe",  # "floe" | "naive" | "resident"
                 use_runtime: bool = False,
                 lookahead: int = 2,
                 residency_policy: str = "lru",
                 num_buffers: int = 2,
                 cancel_stale: bool = True,
                 cross_token: bool = True,
                 batched_demand: bool = False,
                 inter_residual: bool = False,
                 pinned_experts: tuple = (),  # ((layer, expert), ...)
                 store_plan=None,  # repro.store.StorePlan (tiered store)
                 store_dir=None,  # disk-tier shard dir (tmp dir if None)
                 store_freqs=None,  # (L, E) activation freqs (host warm)
                 cluster_plan=None,  # repro.cluster.ClusterPlan (multi-GPU)
                 runtime_spec=None,  # repro.deploy.RuntimeSpec (overrides
                 #                     the individual runtime kwargs above)
                 engine=None,  # pre-built Transfer/ClusterEngine (a fleet
                 #               shares one so models contend per link)
                 layer_stores=None):  # (stores, host_tier) built externally
        from repro.deploy.spec import RuntimeSpec, SpecError

        # The runtime kwargs are a thin shim over the typed spec: they are
        # normalized into ONE RuntimeSpec here and every knob below reads
        # from it, so a spec-built pipeline (repro.deploy.build) and a
        # kwargs-built one construct through the identical path — bitwise
        # parity by construction (pinned by test).
        if runtime_spec is None:
            runtime_spec = RuntimeSpec(
                mode=mode, use_runtime=use_runtime, prefetch=prefetch,
                lookahead=lookahead, residency_policy=residency_policy,
                num_buffers=num_buffers, cache_slots=cache_slots,
                cancel_stale=cancel_stale, cross_token=cross_token,
                batched_demand=batched_demand)
        rs = self.runtime_spec = runtime_spec
        mode, use_runtime, prefetch = rs.mode, rs.use_runtime, rs.prefetch
        lookahead, residency_policy = rs.lookahead, rs.residency_policy
        num_buffers, cancel_stale = rs.num_buffers, rs.cancel_stale
        cache_slots = rs.cache_slots

        self.cfg = cfg
        self.mode = mode
        self.prefetch = prefetch and mode == "floe"
        self.link = link or LinkModel()
        self.device = device or DeviceModel()
        self.inter = inter_predictors
        # inter_residual: trained predictors are residual corrections over
        # the reuse (router-on-proxy) logits — see predictor.py.  Either a
        # bool (all layers) or a SET of layer indices, so online-trained
        # residual probes can coexist with user-supplied standalone ones.
        self.inter_residual = inter_residual
        self.last_pred: dict = {}  # layer -> (eids, conf) of depth-1 preds
        self.layers = _unstack_layers(params, cfg)
        self.embedding = params["embedding"]
        self.final_norm = params["final_norm"]
        self.lm_head = params.get("lm_head")

        # ----------------------------------- tiered store (VRAM planner) --
        # A StorePlan routes every expert through repro.store: per-expert
        # formats, a disk/host tier stack behind the stores, and a slab
        # arena backing residency.  Requires the runtime scheduler (the
        # synchronous path has no tier-aware timeline).
        # ------------------------------------- multi-GPU cluster (plan) --
        # A ClusterPlan partitions experts over n_devices simulated GPUs
        # (per-device links, arenas, pins) behind the same scheduler
        # interface; its optional store_plan drives the tiered store
        # exactly like a single-device one (shared host/disk tiers).
        self.cluster_plan = cluster_plan
        if cluster_plan is not None:
            if not (use_runtime and mode == "floe"):
                raise SpecError(
                    "runtime.use_runtime",
                    "cluster_plan requires use_runtime=True and "
                    f"mode='floe' (got use_runtime={use_runtime}, "
                    f"mode={mode!r})")
            if cluster_plan.store_plan is not None:
                if store_plan is not None:
                    raise SpecError(
                        "resources.vram_gb",
                        "pass the cluster's store plan via the "
                        "ClusterPlan, not store_plan=")
                store_plan = cluster_plan.store_plan

        self.store_plan = store_plan
        self.host_tier = None
        self.device_pool = None
        self.device_pools: list = []
        if store_plan is not None:
            if not (use_runtime and mode == "floe"):
                raise SpecError(
                    "runtime.use_runtime",
                    "store_plan requires use_runtime=True and "
                    f"mode='floe' (got use_runtime={use_runtime}, "
                    f"mode={mode!r})")
            cache_slots = store_plan.slots_per_layer
            pinned_experts = tuple(store_plan.pinned)

        # per-layer host stores + resident quantized up + caches
        self.stores: list[Optional[ExpertStore]] = []
        self.up_res: list = []
        self.caches: list = []
        if store_plan is not None:
            from repro.store import DevicePool, build_layer_stores
            if layer_stores is not None:  # fleet-shared host/disk tiers
                self.stores, self.host_tier = layer_stores
            else:
                import tempfile
                if store_dir is None:
                    store_dir = tempfile.mkdtemp(prefix="floe-store-")
                self.stores, self.host_tier = build_layer_stores(
                    self.layers, thresholds, store_plan, store_dir,
                    link=self.link, quant_group=cfg.floe.quant_group,
                    freqs=store_freqs)
            if cluster_plan is not None:  # one slab arena PER device
                self.device_pools = [
                    DevicePool(store_plan.slab_bytes, max(n, 1))
                    for n in cluster_plan.num_slabs]
            else:
                self.device_pool = DevicePool(store_plan.slab_bytes,
                                              store_plan.num_slabs)
            for layer in self.layers:
                self.up_res.append(None)  # per-expert up lives in the store
                # the ExpertCache is the SYNC path's residency; a tiered
                # store mandates the runtime scheduler, so none is built
                self.caches.append(None)
        else:
            for li, layer in enumerate(self.layers):
                if "moe" not in layer:
                    self.stores.append(None)
                    self.up_res.append(None)
                    self.caches.append(None)
                    continue
                moe_p = layer["moe"]
                thr = thresholds[li]
                if mode == "resident":
                    self.stores.append(None)
                else:
                    from repro.core.offload import build_expert_store
                    self.stores.append(build_expert_store(
                        moe_p, thr, bits=cfg.floe.up_bits,
                        group=cfg.floe.quant_group, link=self.link))
                self.up_res.append(floe_layer.compress_moe_layer(
                    moe_p, thr, bits=cfg.floe.up_bits,
                    group=cfg.floe.quant_group))
                self.caches.append(ExpertCache(cache_slots))
        self.metrics: list[StepMetrics] = []

        # ------------------------------------------- runtime scheduler ----
        self.sched: Optional[ExpertScheduler] = None
        self.cross_token = rs.cross_token
        self.batched_demand = rs.batched_demand
        if use_runtime and mode == "floe" and cluster_plan is not None:
            self._init_cluster(cache_slots, residency_policy, num_buffers,
                               lookahead, cancel_stale, pinned_experts,
                               engine)
        elif use_runtime and mode == "floe":
            self.residency: list[Optional[ResidencyManager]] = []
            for li, layer in enumerate(self.layers):
                if "moe" not in layer:
                    self.residency.append(None)
                    continue
                pins = [(li, e) for (pl, e) in pinned_experts if pl == li]
                cap = cache_slots + (len(pins) if store_plan is not None
                                     else 0)
                self.residency.append(ResidencyManager(
                    cap, policy=residency_policy, pinned=pins,
                    pool=self.device_pool))
            self.engine = engine if engine is not None else \
                TransferEngine(self.link, num_buffers=num_buffers)
            self.sched = ExpertScheduler(
                self.stores, self.residency, self.engine,
                lookahead=lookahead, cancel_stale=cancel_stale,
                progressive=(store_plan.progressive
                             if store_plan is not None else True))
            if store_plan is not None:
                self._stage_pinned()
        if self.host_tier is not None and self.sched is not None:
            # host-tier events (host.miss instants) stamp sim time
            self.host_tier.bind_clock(lambda: self.sched.clock)

    # ------------------------------------------------------------ helpers --
    def _moe_layer_indices(self):
        return [i for i, l in enumerate(self.layers) if "moe" in l]

    def _stage_one_pinned(self, li: int, e: int, res) -> None:
        """Stage one pinned expert's full-format slice into ``res`` at
        t=0 — the single body behind single-device and per-device
        cluster pinned staging."""
        store = self.stores[li]
        avail = store.available_channels(e)
        served, gate, down, _ = store.fetch_slice(
            e, avail if avail is not None else np.arange(store.d_ff))
        res.put((li, e), (served, gate, down), ready_t=0.0)

    def _stage_pinned(self) -> None:
        """Stage every planner-pinned expert at t=0 in its full format.
        Their slab spans come out of the arena (the planner budgeted
        them) and the entries are never evicted; the staging traffic is
        planning-time, so the transfer logs are reset afterwards."""
        for (li, e) in self.store_plan.pinned:
            self._stage_one_pinned(li, e, self.residency[li])
        for s in self.stores:
            if s is not None:
                s.reset_log()

    # --------------------------------------------------- cluster wiring ---
    def _init_cluster(self, cache_slots: int, residency_policy: str,
                      num_buffers: int, lookahead: int, cancel_stale: bool,
                      pinned_experts: tuple, engine=None) -> None:
        """Per-device residency + links + the ClusterScheduler shim.

        Each device gets its own per-layer ResidencyManagers (capacity =
        planned slots + its pins, backed by its own slab arena when the
        plan is tiered) and its own TransferEngine; the dispatcher keeps
        their clocks in lockstep.  ``self.residency`` becomes the FLAT
        list of every device's managers — the controller's rescore loop
        and telemetry iterate it, they never index by layer."""
        from repro.cluster import ClusterEngine, ClusterScheduler
        plan = self.cluster_plan
        tiered = plan.store_plan is not None
        self.cluster_residency: list[list[Optional[ResidencyManager]]] = []
        for d in range(plan.n_devices):
            per_layer: list[Optional[ResidencyManager]] = []
            for li, layer in enumerate(self.layers):
                if "moe" not in layer:
                    per_layer.append(None)
                    continue
                if tiered:
                    pins = [(li, e) for (pl, e) in plan.pinned_per_device[d]
                            if pl == li]
                    cap = plan.slots_per_layer + len(pins)
                    pool = self.device_pools[d]
                else:
                    pins = [(li, e) for (pl, e) in pinned_experts
                            if pl == li and d in plan.devices_of(pl, e)]
                    cap = cache_slots
                    pool = None
                per_layer.append(ResidencyManager(
                    cap, policy=residency_policy, pinned=pins, pool=pool))
            self.cluster_residency.append(per_layer)
        self.residency = [r for dev in self.cluster_residency
                          for r in dev if r is not None]
        if engine is not None:
            if engine.n_devices != plan.n_devices:
                from repro.deploy.spec import SpecError
                raise SpecError(
                    "resources.devices",
                    f"shared engine has {engine.n_devices} device link(s) "
                    f"but the plan needs {plan.n_devices}")
            self.engine = engine
        else:
            self.engine = ClusterEngine(self.link, n_devices=plan.n_devices,
                                        num_buffers=num_buffers)
        self.sched = ClusterScheduler(
            plan, self.stores, self.cluster_residency, self.engine,
            lookahead=lookahead, cancel_stale=cancel_stale,
            progressive=(plan.store_plan.progressive if tiered else True))
        if tiered:
            self._stage_pinned_cluster()

    def _stage_pinned_cluster(self) -> None:
        """Stage each device's planner-pinned experts at t=0 (a
        replicated pinned expert gets a copy on EVERY home device; the
        per-device arenas budgeted the spans)."""
        for d, pins in enumerate(self.cluster_plan.pinned_per_device):
            for (li, e) in pins:
                self._stage_one_pinned(li, e, self.cluster_residency[d][li])
        for s in self.stores:
            if s is not None:
                s.reset_log()

    def _route(self, h: jax.Array, li: int):
        from repro.models.moe import router_topk
        gates, eids, probs = router_topk(
            h, self.layers[li]["moe"]["router"], self.cfg.num_experts_per_tok)
        return np.asarray(gates), np.asarray(eids), np.asarray(probs)

    def _up_mask_rows(self, h: jax.Array, li: int, e: int):
        """v = h W_up^(q) + PER-ROW activation mask (B, F) — from the
        tiered store's per-expert-format up projection when one backs
        this layer, else the layer-wide resident quantized up."""
        store = self.stores[li]
        if store is not None and hasattr(store, "true_mask"):
            v, mask = store.true_mask(h, e)
            return v, np.asarray(mask)
        w = self.up_res[li]
        qt = hqq.QTensor(w.up_q.packed[e], w.up_q.scale[e], w.up_q.zero[e],
                         w.up_q.bits, w.up_q.group, w.up_q.shape)
        v, mask = floe_layer.up_and_mask(h, qt, w.thresholds[e])
        return v, np.asarray(mask)

    def _true_mask(self, h: jax.Array, li: int, e: int):
        v, mask = self._up_mask_rows(h, li, e)
        return v, mask.any(axis=0)

    def _predict_next(self, h: jax.Array, li_next: int,
                      probe=_UNSET, residual: bool = False):
        """(expert ids, predicted channel masks, confidence) for li_next.

        Confidence is the prefetch priority signal: the predictor logits'
        softmax mass, or the reused router's softmax mass, averaged over
        the batch.  By default the pipeline's own per-layer predictor is
        used (residual per ``inter_residual``); an explicit ``probe``
        (possibly None → pure reuse fallback) lets callers with their own
        predictor banks — the serving controller's cross-token bank —
        share this exact code path."""
        if probe is _UNSET:
            probe = self.inter[li_next] if self.inter is not None else None
            ir = self.inter_residual
            residual = (li_next in ir if isinstance(ir, (set, frozenset))
                        else bool(ir))
        if probe is not None:
            if residual:
                base = (h.astype(jnp.float32) @
                        self.layers[li_next]["moe"]["router"].astype(
                            jnp.float32))
                logits = predictor.residual_inter_logits(probe, h, base)
            else:
                logits = predictor.inter_logits(probe, h)
            eids = np.asarray(jax.lax.top_k(
                logits, self.cfg.num_experts_per_tok)[1])
            # softmax mass, not per-expert sigmoid: the priority queue
            # needs DIVERSE relative confidences (saturated sigmoids make
            # every prefetch rank equal), and it matches the fallback's
            # semantics so calibration treats both sources alike
            conf_all = np.asarray(jax.nn.softmax(logits, axis=-1)).mean(
                axis=0)
        else:  # fallback: today's router reused (high hidden-state similarity)
            _, eids, probs = self._route(h, li_next)
            conf_all = probs.mean(axis=0)
        self._last_row_eids = eids  # (B, k) pre-union, for per-row grading
        eids = np.unique(eids.reshape(-1))
        masks, conf = {}, {}
        for e in eids.tolist():
            _, m = self._true_mask(h, li_next, e)  # reuse-based intra pred
            masks[e] = m
            conf[e] = float(conf_all[e])
        return eids.tolist(), masks, conf

    # --------------------------------------------------------- expert exec -
    def _run_expert(self, h, li, e, metrics: StepMetrics):
        cfg = self.cfg
        d, f = cfg.d_model, cfg.moe_d_ff
        w = self.up_res[li]
        qt = hqq.QTensor(w.up_q.packed[e], w.up_q.scale[e], w.up_q.zero[e],
                         w.up_q.bits, w.up_q.group, w.up_q.shape)
        v, need_mask = self._true_mask(h, li, e)

        if self.mode == "resident":
            y = sparsify.expert_forward_dense(
                h, w.we_gate[e], hqq.dequantize(qt, h.dtype), w.we_down[e])
            metrics.compute_s += self.device.matmul_time(
                6 * h.shape[0] * d * f, 6 * d * f)
            return y, 1.0

        store = self.stores[li]
        if self.mode == "naive":
            wg, wu, wd = store.fetch_dense(e)  # (D,F), (D,F), (F,D)
            metrics.stall_s += self.link.transfer_time(
                store.dense_expert_bytes(), 3)
            y = sparsify.expert_forward_dense(h, wg, wu, wd)
            metrics.compute_s += self.device.matmul_time(
                6 * h.shape[0] * d * f, 6 * d * f)
            return y, 1.0

        # --- floe mode ---
        cache = self.caches[li]
        payload = cache.get((li, e))
        if payload is None:
            idx = np.nonzero(need_mask)[0]
            t0_model = self.link.transfer_time(
                len(idx) * 2 * d * store.records.dtype.itemsize,
                max(1, len(idx) // 50))
            gate_cols, down_rows = store.fetch_sparse(e, idx)
            cache.put((li, e), (idx, gate_cols, down_rows))
            metrics.stall_s += t0_model
            metrics.expert_misses += 1
            payload = (idx, gate_cols, down_rows)
        else:
            metrics.expert_hits += 1
        y, cov, t_up, t_sparse = self._apply_payload(h, li, e, payload, v,
                                                     need_mask)
        metrics.compute_s += t_up + t_sparse
        return y, cov

    def _up_time(self, batch: int, li: int, e: int) -> float:
        """Modeled time of the resident up GEMV (the true-mask
        computation) — payload-independent, so it overlaps demand DMA.
        Bytes follow the expert's resident format (tiered store) or the
        layer-wide quantized up."""
        cfg = self.cfg
        store = self.stores[li]
        if store is not None and hasattr(store, "up_nbytes"):
            up_bytes = store.up_nbytes(e)
        else:
            w = self.up_res[li]
            up_bytes = (w.up_q.packed[e].nbytes + w.up_q.scale[e].nbytes +
                        w.up_q.zero[e].nbytes)
        return self.device.matmul_time(
            2 * batch * cfg.d_model * cfg.moe_d_ff, up_bytes)

    def _apply_payload(self, h, li: int, e: int, payload, v, need_mask
                       ) -> tuple[jax.Array, float, float, float]:
        """FloE expert compute over a staged payload — the single code path
        shared by the synchronous and scheduler-driven decoders (bitwise
        parity between them rests on this).  Returns (y, coverage,
        modeled up-GEMV seconds, modeled sparse gate/down seconds)."""
        d, f = self.cfg.d_model, self.cfg.moe_d_ff
        idx, gate_cols, down_rows = payload
        avail = np.zeros(f, bool)
        avail[idx] = True
        usable = need_mask & avail
        cov = usable.sum() / max(need_mask.sum(), 1)
        sel = np.nonzero(usable[idx])[0]  # positions within the slice
        v_active = v[:, idx[sel]]
        y = floe_layer.sparse_expert_apply(
            h, gate_cols[sel], down_rows[sel], v_active)
        # compute model: dense up GEMV + sparse gate/down GEMVs
        n_act = int(len(sel))
        t_up = self._up_time(h.shape[0], li, e)
        t_sparse = self.device.matmul_time(
            4 * h.shape[0] * d * n_act, 4 * d * n_act)
        return y, float(cov), t_up, t_sparse

    # --------------------------------------------------------- decode step -
    def decode_token(self, h: jax.Array) -> tuple[jax.Array, StepMetrics]:
        """h (B, D): post-embedding hidden state; returns final hidden."""
        if self.sched is not None:
            return self._decode_token_runtime(h)
        cfg = self.cfg
        metrics = StepMetrics()
        covs = []
        moe_layers = set(self._moe_layer_indices())

        for li, layer in enumerate(self.layers):
            # prefetch for the NEXT MoE layer while "computing" this one
            nxt = li + 1
            if self.prefetch and nxt in moe_layers and self.caches[nxt] is not None:
                eids, masks, _ = self._predict_next(h, nxt)
                for e in eids:
                    if (nxt, e) in self.caches[nxt]:
                        continue
                    idx = np.nonzero(masks[e])[0]
                    store = self.stores[nxt]
                    gate_cols, down_rows = store.fetch_sparse(e, idx)
                    self.caches[nxt].put((nxt, e), (idx, gate_cols, down_rows),
                                         prefetch=True)
                    metrics.prefetch_s += self.link.transfer_time(
                        len(idx) * 2 * cfg.d_model *
                        store.records.dtype.itemsize,
                        max(1, len(idx) // 50))

            # non-expert compute (attention + norms) — modeled only
            attn_flops = 2 * h.shape[0] * (
                4 * cfg.d_model * cfg.num_heads * cfg.head_dim)
            metrics.compute_s += self.device.matmul_time(
                attn_flops, 4 * cfg.d_model * cfg.num_heads * cfg.head_dim * 2)

            if li in moe_layers:
                hn = nn.rms_norm(h, layer["mlp_norm"]["scale"], cfg.norm_eps)
                gates, eids, _ = self._route(hn, li)
                y = jnp.zeros_like(h, dtype=jnp.float32)
                for slot in range(eids.shape[1]):
                    for b in range(h.shape[0]):
                        e = int(eids[b, slot])
                        ye, cov = self._run_expert(hn[b:b + 1], li, e, metrics)
                        covs.append(cov)
                        y = y.at[b].add(ye[0].astype(jnp.float32)
                                        * gates[b, slot])
                h = h + y.astype(h.dtype)
            else:
                pass  # dense layers resident; compute time charged above

        # final norm + LM head + sampling happen after the last layer
        metrics.compute_s += self._head_time(h.shape[0])

        # prefetch overlaps with compute: only the excess stalls
        metrics.stall_s += max(0.0, metrics.prefetch_s - metrics.compute_s)
        metrics.coverage = float(np.mean(covs)) if covs else 1.0
        self.metrics.append(metrics)
        return h, metrics

    def _head_time(self, batch: int) -> float:
        """Modeled final-norm + LM-head + sampling time per decode step."""
        cfg = self.cfg
        return self.device.matmul_time(
            2 * batch * cfg.d_model * cfg.vocab_size,
            cfg.d_model * cfg.vocab_size * 2)

    # ---------------------------------------- scheduler-driven MoE exec ----
    def speculate(self, h2d: jax.Array, li: int) -> None:
        """Enqueue cross-layer speculative prefetches for the next
        ``lookahead`` MoE layers from the live hidden state (B, D)."""
        sched = self.sched
        moe_layers = set(self._moe_layer_indices())
        for depth in range(1, sched.lookahead + 1):
            nxt = li + depth
            if nxt not in moe_layers:
                continue
            eids, masks, conf = self._predict_next(h2d, nxt)
            if depth == 1:  # graded against truth at reconcile time
                self.last_pred[nxt] = (list(eids), dict(conf),
                                       np.asarray(self._last_row_eids))
            for e in eids:
                sched.enqueue_prefetch(nxt, e, np.nonzero(masks[e])[0],
                                       conf[e], depth)
        sched.pump()

    def speculate_cross_token(self, h_in: jax.Array) -> None:
        """Prefetch the FIRST MoE layers for the NEXT token from this
        token's entry state (consecutive decode steps route similarly —
        temporal locality of expert activation); the synchronous path
        structurally cannot do this, so those layers' cold demand-misses
        become prefetch hits only on the runtime path."""
        if not (self.prefetch and self.cross_token):
            return
        sched = self.sched
        moe_list = self._moe_layer_indices()
        for depth, li0 in enumerate(moe_list[:sched.lookahead], start=1):
            eids, masks, conf = self._predict_next(h_in, li0)
            for e in eids:
                sched.enqueue_prefetch(li0, e, np.nonzero(masks[e])[0],
                                       conf[e], depth)
        sched.pump()

    def _demand_issue(self, hb: jax.Array, li: int, e: int,
                      metrics: StepMetrics) -> tuple:
        """Phase A of a demanded expert: run the resident up GEMV (its time
        advances the clock — the DMA it triggers overlaps later experts'
        phase A), then issue the transfer without waiting."""
        sched = self.sched
        v, need_mask = self._true_mask(hb, li, e)
        t_up = self._up_time(hb.shape[0], li, e)
        metrics.compute_s += t_up
        sched.advance(t_up)
        payload, was_miss = sched.demand_async(
            li, e, lambda m=need_mask: np.nonzero(m)[0])
        if was_miss:
            metrics.expert_misses += 1
        else:
            metrics.expert_hits += 1
        return (hb, v, need_mask, payload, was_miss)

    def _demand_finish(self, issued: tuple, li: int, e: int,
                       metrics: StepMetrics, covs: list) -> jax.Array:
        """Phase B: wait for the staged slice, then the sparse compute."""
        sched = self.sched
        hb, v, need_mask, payload, was_miss = issued
        metrics.stall_s += sched.wait_for(li, e, was_miss=was_miss)
        # the staged slice may have been upgraded (progressive refine) or
        # grown (top-up) while we waited — compute on the freshest copy
        # (same channel set only: an evicted-and-refetched entry keeps the
        # original payload, preserving sync-path parity)
        cur = sched.staged_payload(li, e)
        if cur is not None and cur is not payload and \
                np.array_equal(np.asarray(cur[0]), np.asarray(payload[0])):
            payload = cur
        ye, cov, _, t_sparse = self._apply_payload(hb, li, e, payload, v,
                                                   need_mask)
        metrics.compute_s += t_sparse
        sched.advance(t_sparse)
        covs.append(cov)
        return ye

    def moe_apply_batched(self, hn: jax.Array, li: int, gates: np.ndarray,
                          eids: np.ndarray, metrics: StepMetrics, covs: list
                          ) -> jax.Array:
        """Batched MoE through the scheduler: each distinct expert is
        demanded ONCE with the union of its tokens' channel masks and the
        staged slice is shared across the batch — the transfer count per
        layer is the number of distinct routed experts, not B×k, and no
        token silently loses channels another token fetched first.  All
        demands are issued up front (phase A) so each expert's DMA
        overlaps the others' compute.  This is the offloaded serving path
        (multi-request decode); the synchronous pipeline has no
        equivalent."""
        y = jnp.zeros((hn.shape[0], self.cfg.d_model), jnp.float32)
        experts = np.unique(eids.reshape(-1)).tolist()
        issued = {}
        for e in experts:
            rows = np.nonzero((eids == e).any(axis=1))[0]
            issued[e] = (rows, self._demand_issue(hn[rows], li, int(e),
                                                  metrics))
        for e in experts:
            rows, ent = issued[e]
            ye = self._demand_finish(ent, li, int(e), metrics, covs)
            w = (np.asarray(gates) * (eids == e)).sum(axis=1)[rows]
            y = y.at[rows].add(ye.astype(jnp.float32) * w[:, None])
        return y

    # ------------------------------------------- scheduler-driven decode ---
    def _decode_token_runtime(self, h: jax.Array
                              ) -> tuple[jax.Array, StepMetrics]:
        """Decode one token through the runtime scheduler (Fig. 1(c) as an
        event loop).  Same jax ops and staged payloads as the synchronous
        path; stall/overlap come from enqueue/complete event times."""
        cfg = self.cfg
        sched = self.sched
        metrics = StepMetrics()
        covs = []
        moe_layers = set(self._moe_layer_indices())
        rec_mark = self.engine.records.total  # monotonic, ring-safe
        h_in = h  # token-entry state: the cross-token routing proxy

        for li, layer in enumerate(self.layers):
            # cross-layer speculative prefetch (lookahead >= 1 MoE layers)
            if self.prefetch:
                self.speculate(h, li)

            # non-expert compute (attention + norms) overlaps transfers
            attn_flops = 2 * h.shape[0] * (
                4 * cfg.d_model * cfg.num_heads * cfg.head_dim)
            t_attn = self.device.matmul_time(
                attn_flops, 4 * cfg.d_model * cfg.num_heads * cfg.head_dim * 2)
            metrics.compute_s += t_attn
            sched.advance(t_attn)

            if li in moe_layers:
                hn = nn.rms_norm(h, layer["mlp_norm"]["scale"], cfg.norm_eps)
                gates, eids, _ = self._route(hn, li)
                sched.reconcile(li, np.unique(eids.reshape(-1)).tolist())
                if self.batched_demand:
                    y = self.moe_apply_batched(hn, li, gates, eids,
                                               metrics, covs)
                else:
                    # per-(slot, token) order mirrors the sync path (for
                    # bitwise parity), but demands are issued up front so
                    # each DMA overlaps the other experts' compute
                    y = jnp.zeros_like(h, dtype=jnp.float32)
                    order = [(slot, b) for slot in range(eids.shape[1])
                             for b in range(h.shape[0])]
                    issued = []
                    for slot, b in order:
                        e = int(eids[b, slot])
                        issued.append(self._demand_issue(
                            hn[b:b + 1], li, e, metrics))
                    for (slot, b), ent in zip(order, issued):
                        e = int(eids[b, slot])
                        ye = self._demand_finish(ent, li, e, metrics, covs)
                        y = y.at[b].add(ye[0].astype(jnp.float32)
                                        * gates[b, slot])
                h = h + y.astype(h.dtype)

        self.speculate_cross_token(h_in)

        # final norm + LM head + sampling: cross-token transfers overlap it
        t_head = self._head_time(h.shape[0])
        metrics.compute_s += t_head
        sched.advance(t_head)

        metrics.prefetch_s = sum(
            r.duration for r in self.engine.records.since(rec_mark)
            if r.kind == "prefetch")
        metrics.coverage = float(np.mean(covs)) if covs else 1.0
        self.metrics.append(metrics)
        return h, metrics

    def tokens_per_second(self) -> float:
        if not self.metrics:
            return 0.0
        total = sum(m.compute_s + m.stall_s for m in self.metrics)
        return len(self.metrics) / max(total, 1e-12)


def _unstack_layers(params: dict, cfg: ModelConfig) -> list[dict]:
    """Flatten scan-stacked params into a per-layer list of block params."""
    layers: list[dict] = []
    for si, (pattern, reps) in enumerate(cfg.segments()):
        seg = params[f"seg{si}"]
        for r in range(reps):
            for pi, kind in enumerate(pattern):
                sp = jax.tree.map(lambda a: a[r], seg[f"pos{pi}"])
                if kind == "shared":
                    block = dict(seg["shared_block"])
                    block["shared_in"] = sp["shared_in"]
                    layers.append(block)
                else:
                    layers.append(sp)
    return layers
