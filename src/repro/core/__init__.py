"""FloE core: the paper's contribution as composable JAX modules.

hqq         — half-quadratic ultra-low-bit quantization (§3.2.2)
sparsify    — contextual activation sparsification S_t (§3.2.1)
predictor   — inter-/intra-expert sparsity predictors (§3.3)
cache       — HBM-resident LRU expert cache
offload     — host expert store, compact layout, link cost model (§3.4.2)
floe_layer  — compressed expert forward (kernel-facing)
pipeline    — the on-the-fly decode pipeline tying it together (Fig. 1c)
"""
from repro.core import cache, floe_layer, hqq, offload, pipeline, predictor, sparsify

__all__ = ["cache", "floe_layer", "hqq", "offload", "pipeline", "predictor",
           "sparsify"]
