"""HBM-resident expert cache with LRU replacement (FloE Fig. 1(b/c) ③).

The cache is host-controlled (Python) and device-resident (jax arrays in
fixed slots), mirroring the GPU-resident cache of the paper: predictions
prefetch compressed expert slices into slots ahead of use; a hit serves the
expert with zero transfer, a miss pays the (modeled + real) transfer cost.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Hashable, Optional


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    prefetch_hits: int = 0  # distinct prefetches consumed (once each)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset(self) -> None:
        self.hits = self.misses = self.evictions = self.prefetch_hits = 0


class ExpertCache:
    """Fixed-capacity LRU of (layer, expert) -> device payload."""

    def __init__(self, capacity: int):
        assert capacity >= 1
        self.capacity = capacity
        self._slots: "collections.OrderedDict[Hashable, Any]" = \
            collections.OrderedDict()
        self._prefetched: set = set()
        self.stats = CacheStats()

    def __contains__(self, key: Hashable) -> bool:
        return key in self._slots

    def __len__(self) -> int:
        return len(self._slots)

    def get(self, key: Hashable) -> Optional[Any]:
        if key in self._slots:
            self._slots.move_to_end(key)
            self.stats.hits += 1
            if key in self._prefetched:
                self.stats.prefetch_hits += 1
                self._prefetched.discard(key)
            return self._slots[key]
        self.stats.misses += 1
        return None

    def put(self, key: Hashable, value: Any, *, prefetch: bool = False) -> None:
        if key in self._slots:
            self._slots.move_to_end(key)
            self._slots[key] = value
            if prefetch:  # re-prefetch of a resident key counts anew
                self._prefetched.add(key)
            return
        while len(self._slots) >= self.capacity:
            evicted, _ = self._slots.popitem(last=False)
            # an evicted prefetch was never consumed; a later re-insert of
            # the same key must not count a phantom prefetch_hit
            self._prefetched.discard(evicted)
            self.stats.evictions += 1
        self._slots[key] = value
        if prefetch:
            self._prefetched.add(key)

    def keys(self):
        return list(self._slots.keys())

    def reset_stats(self):
        self.stats.reset()
