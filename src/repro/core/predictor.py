"""Dual sparsity predictors (FloE §3.3).

Both exploit Observation 3: hidden states entering consecutive MoE layers
have >0.95 cosine similarity, so the layer-i hidden state is a usable proxy
input for layer-(i+1)'s router and up projection.

* Inter-expert (§3.3.1): a learned per-layer MLP maps h_i -> multi-hot of
  layer-(i+1) routed experts.  Sized per layer depth (paper: 32K..2M params;
  we expose ``hidden`` — 0 gives the single-layer/linear variant).
* Intra-expert (§3.3.2): parameter-free — reuse layer-(i+1)'s (quantized)
  up projection on h_i and threshold, giving the predicted channel mask.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import nn


# ------------------------------------------------------------ inter-expert -
def init_inter_predictor(key, d_model: int, num_experts: int,
                         hidden: int = 0) -> dict:
    """hidden=0 -> linear probe (the paper's shallow-layer variant)."""
    if hidden <= 0:
        k1, = jax.random.split(key, 1)
        return {"p_w2": nn.dense_init(k1, (d_model, num_experts), jnp.float32),
                "p_b2": jnp.zeros((num_experts,), jnp.float32)}
    k1, k2 = jax.random.split(key)
    return {
        "p_w1": nn.dense_init(k1, (d_model, hidden), jnp.float32),
        "p_b1": jnp.zeros((hidden,), jnp.float32),
        "p_w2": nn.dense_init(k2, (hidden, num_experts), jnp.float32),
        "p_b2": jnp.zeros((num_experts,), jnp.float32),
    }


def inter_logits(params: dict, h: jax.Array) -> jax.Array:
    x = h.astype(jnp.float32)
    if "p_w1" in params:
        x = jax.nn.relu(x @ params["p_w1"] + params["p_b1"])
    return x @ params["p_w2"] + params["p_b2"]


def residual_inter_logits(params: dict, h: jax.Array,
                          base_logits: jax.Array) -> jax.Array:
    """Trained correction on top of router-reuse logits.

    Online serving trains the inter-predictor as a *residual* over the
    reuse fallback (today's router applied to the proxy hidden state):
    ``logits = base + probe(h)``.  Initialized near zero the probe starts
    at exactly the fallback's quality and can only move toward the
    observed routing it is trained on — the trained path dominates the
    fallback once enough tokens have been seen."""
    return base_logits.astype(jnp.float32) + inter_logits(params, h)


def multi_hot(expert_ids, num_experts: int) -> jax.Array:
    """(T, k) int expert ids -> (T, E) float32 multi-hot targets."""
    eids = jnp.asarray(expert_ids)
    oh = jax.nn.one_hot(eids, num_experts, dtype=jnp.float32)
    return jnp.clip(oh.sum(axis=-2), 0.0, 1.0)


def inter_predict_topk(params: dict, h: jax.Array, k: int) -> jax.Array:
    """Predicted expert ids for the next layer. h (T, D) -> (T, k) i32."""
    return jax.lax.top_k(inter_logits(params, h), k)[1].astype(jnp.int32)


def _bce(logits, multi_hot):
    z = jax.nn.log_sigmoid(logits)
    zn = jax.nn.log_sigmoid(-logits)
    return -jnp.mean(multi_hot * z + (1.0 - multi_hot) * zn)


@partial(jax.jit, static_argnames=("steps", "lr"))
def _train_inter(params: dict, h: jax.Array, targets: jax.Array,
                 base: jax.Array, steps: int, lr: float) -> dict:
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def step(carry, i):
        params, m, v = carry
        g = jax.grad(lambda p: _bce(base + inter_logits(p, h), targets))(
            params)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        t = i + 1
        mhat = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
        vhat = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
        params = jax.tree.map(
            lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8),
            params, mhat, vhat)
        return (params, m, v), None

    (params, _, _), _ = jax.lax.scan(step, (params, m, v),
                                     jnp.arange(steps, dtype=jnp.float32))
    return params


def train_inter_predictor(params: dict, h: jax.Array, targets: jax.Array,
                          steps: int = 200, lr: float = 3e-3,
                          base_logits=None) -> dict:
    """Fit on a trace. h (T, D) hidden states of layer i, targets (T, E)
    multi-hot expert selections of layer i+1. Plain Adam, full-batch.

    With ``base_logits`` (T, E) the probe is trained as a residual on top
    of those fixed logits (see ``residual_inter_logits``)."""
    if base_logits is None:
        base = jnp.zeros(targets.shape, jnp.float32)
    else:
        base = jnp.asarray(base_logits, jnp.float32)
    return _train_inter(params, h, targets, base, steps, lr)


class ConfidenceCalibrator:
    """Running calibration of predictor confidence against realized hits.

    ``update`` consumes (confidence, hit) pairs from reconciliation time —
    did the true router select the expert whose prefetch this confidence
    justified?  ``scale`` is the ratio of realized precision to mean
    claimed confidence (EMA-smoothed); applying it makes an overconfident
    predictor's speculation sort honestly against demand traffic and makes
    the ``weighted`` residency policy evict by real, not claimed, value.
    The instance is callable so it can plug directly into
    ``ExpertScheduler.calibrate``.
    """

    def __init__(self, beta: float = 0.98, floor: float = 0.05):
        self.beta = beta
        self.floor = floor
        self._conf = 0.0  # EMA of claimed confidence
        self._hit = 0.0  # EMA of realized outcome
        self._weight = 0.0  # EMA normalizer (debiasing)
        self.samples = 0

    def update(self, confidence: float, hit: bool) -> None:
        b = self.beta
        self._conf = b * self._conf + (1.0 - b) * float(confidence)
        self._hit = b * self._hit + (1.0 - b) * (1.0 if hit else 0.0)
        self._weight = b * self._weight + (1.0 - b)
        self.samples += 1

    @property
    def precision(self) -> float:
        return self._hit / self._weight if self._weight > 0 else 1.0

    @property
    def scale(self) -> float:
        """Capped at 1.0: calibration only ever DEMOTES speculation whose
        claimed confidence exceeds its realized precision — boosting an
        underconfident predictor would let speculative traffic outrank
        the depth discount without evidence about ordering."""
        if self._weight <= 0 or self._conf <= 0:
            return 1.0
        return min(1.0, max(self.floor, self._hit / self._conf))

    def __call__(self, confidence: float) -> float:
        return float(min(1.0, max(0.0, confidence * self.scale)))


def recall_at_k(pred_ids: jax.Array, true_ids: jax.Array) -> jax.Array:
    """Fraction of true experts covered by predictions. (T,k) vs (T,k')."""
    hit = (pred_ids[:, :, None] == true_ids[:, None, :]).any(axis=1)
    return jnp.mean(hit.astype(jnp.float32))


# ------------------------------------------------------------ intra-expert -
def intra_predict_mask(h_prev: jax.Array, w_up_next: jax.Array,
                       t: jax.Array) -> jax.Array:
    """Reuse-based channel-mask prediction (parameter-free).

    h_prev (T, D): hidden state entering layer i; w_up_next (D, F): layer
    i+1's up projection (dequantized INT2 in production); t: that expert's
    calibrated threshold.  Returns predicted bool mask (T, F).
    """
    v = h_prev.astype(jnp.float32) @ w_up_next.astype(jnp.float32)
    return jnp.abs(v) >= t


def mask_precision_recall(pred: jax.Array, true: jax.Array):
    """pred/true bool (T, F) -> (precision, recall)."""
    pred = pred.astype(jnp.float32)
    true = true.astype(jnp.float32)
    tp = jnp.sum(pred * true)
    return (tp / jnp.maximum(jnp.sum(pred), 1.0),
            tp / jnp.maximum(jnp.sum(true), 1.0))


def cosine_similarity(a: jax.Array, b: jax.Array) -> jax.Array:
    """Mean cosine similarity between rows of a and b (T, D)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    num = jnp.sum(a * b, -1)
    den = jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1)
    return jnp.mean(num / jnp.maximum(den, 1e-8))
