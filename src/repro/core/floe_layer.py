"""FloE compressed expert forward — the technique as a composable module.

Two device-side execution styles:

* ``floe_expert_fn(cfg)`` — an ``expert_fn`` for repro.models.moe: grouped
  (ragged) forward where the up projection is INT2-dequantized on the fly
  and gate/down are masked by the contextual threshold.  This is the
  dry-run / distributed integration path (mask realized as multiplicative
  zeroing — sparse *semantics* with dense shapes, which is what XLA can
  shard; the Pallas kernel below realizes the actual block skipping).
* ``sparse_expert_apply`` — single-expert decode path over gathered sparse
  slices (what the serving engine calls after the offload engine has moved
  only the masked records).  Shapes here ARE sparse (n_active channels).

Plus helpers to compress a resident MoE layer into FloE form.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.core import hqq, sparsify
from repro.models import nn


class FloEExpertWeights(NamedTuple):
    """Device-resident compressed weights for one MoE layer."""

    we_gate: jax.Array  # (E, D, F) bf16 (dense resident or streamed slices)
    we_down: jax.Array  # (E, F, D)
    up_q: hqq.QTensor  # (E, D, F) packed INT-b
    thresholds: jax.Array  # (E,) f32


def compress_moe_layer(moe_params: dict, thresholds, *, bits: int = 2,
                       group: int = 64) -> FloEExpertWeights:
    up_q = hqq.quantize_per_expert(moe_params["we_up"], bits=bits, group=group)
    return FloEExpertWeights(moe_params["we_gate"], moe_params["we_down"],
                             up_q, jnp.asarray(thresholds, jnp.float32))


# ------------------------------------------------- grouped (ragged) path ---
def _dequant_stack(up_q: hqq.QTensor, dtype=jnp.bfloat16) -> jax.Array:
    """(E, D, F) dequantized. XLA fuses this into the consumer matmul; on
    TPU the Pallas quant_gemv kernel performs it in-register instead."""
    def one(packed, scale, zero):
        qt = hqq.QTensor(packed, scale, zero, up_q.bits, up_q.group, up_q.shape)
        return hqq.dequantize(qt, dtype)
    return jax.vmap(one)(up_q.packed, up_q.scale, up_q.zero)


def floe_expert_fn(cfg: ModelConfig, weights: Optional[FloEExpertWeights] = None):
    """Returns an expert_fn(xs, wg, wu, wd, group_sizes) for moe_forward.

    When ``weights`` is given, its quantized up + thresholds override the
    dense wu passed by the MoE layer (wg/wd still come from the caller so
    sharding stays with the layer).
    """
    block = cfg.floe.block_size

    def expert_fn(xs, wg, wu, wd, group_sizes):
        if weights is not None:
            wu_eff = _dequant_stack(weights.up_q, xs.dtype)
            thr = weights.thresholds
        else:
            wu_eff = wu
            thr = None
        u = jax.lax.ragged_dot(xs, wu_eff, group_sizes).astype(jnp.float32)
        if thr is not None:
            # per-row threshold: rows belong to group g = searchsorted(cum)
            bounds = jnp.cumsum(group_sizes)
            row_group = jnp.searchsorted(bounds, jnp.arange(xs.shape[0]),
                                         side="right")
            t = thr[jnp.clip(row_group, 0, thr.shape[0] - 1)][:, None]
        else:
            t = jnp.quantile(jnp.abs(u), cfg.floe.sparsity, axis=-1,
                             keepdims=True)  # calibration-free fallback
        u = sparsify.s_t(u, t)
        mask = (u != 0.0)
        if block > 1 and u.shape[-1] % block == 0:
            bu = sparsify.block_union_mask(mask, block)
            mask = jnp.repeat(bu, block, axis=-1)  # TPU lane-block union
        g = jax.lax.ragged_dot(xs, wg, group_sizes).astype(jnp.float32)
        h = nn.silu(g) * u * mask
        return jax.lax.ragged_dot(h.astype(xs.dtype), wd, group_sizes)

    return expert_fn


# ------------------------------------------- sparse single-expert decode ---
def sparse_expert_apply(x: jax.Array, gate_cols: jax.Array,
                        down_rows: jax.Array, v_active: jax.Array
                        ) -> jax.Array:
    """Decode-path expert over gathered ACTIVE channels only.

    x (B, D); gate_cols (n, D) = W_gate[:, mask].T; down_rows (n, D) =
    W_down[mask, :]; v_active (B, n) = S_t(x W_up)[mask].
    This is Algorithm 1 with the mask already realized by the offload
    gather — the FLOPs and bytes are the sparse ones.
    """
    g = nn.silu((x.astype(jnp.float32) @ gate_cols.T.astype(jnp.float32)))
    h = g * v_active.astype(jnp.float32)
    return (h @ down_rows.astype(jnp.float32)).astype(x.dtype)


def up_and_mask(x: jax.Array, up_q: hqq.QTensor, t: jax.Array,
                ) -> tuple[jax.Array, jax.Array]:
    """v = x W_up^(q); mask = |v| >= t. x (B, D) -> v (B, F), mask (B, F)."""
    wu = hqq.dequantize(up_q, jnp.float32)
    v = x.astype(jnp.float32) @ wu
    return v, jnp.abs(v) >= t


def union_channels(mask: jax.Array, cap: Optional[int] = None) -> jax.Array:
    """Batched decode: union of per-token masks -> channel index list."""
    u = mask.any(axis=0)
    idx = jnp.nonzero(u, size=cap or u.shape[-1], fill_value=-1)[0]
    return idx[idx >= 0] if cap is None else idx
