"""Half-Quadratic Quantization (HQQ, Badri & Shaji 2023) in pure JAX.

FloE §3.2.2 quantizes ONLY the up projection at ultra-low bit-width (INT2 by
default); we implement the full bit range (8/4/3/2/1) so the quantization-
sensitivity experiment (paper Fig. 3b / Table 7) can be reproduced.

HQQ is calibration-free: per quantization group it alternately solves

    min_{z, e}  || W - s·(Q(W/s + z) - z) ||_p^p      (0 < p < 1)

via a half-quadratic split — an l_p shrinkage proximal step on the residual
``e`` followed by a closed-form zero-point update.  The scale ``s`` comes
from the group min/max and stays fixed (as in reference HQQ).

Storage: sub-byte codes are bit-packed into uint8 along the group axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QTensor:
    """Group-quantized tensor.

    packed: uint8 codes, shape (G, group/codes_per_byte, N) — bit-packed
    scale:  (G, 1, N) f16 (stored; dequantization upcasts to f32)
    zero:   (G, 1, N) f16
    bits / group / shape: static metadata (pytree aux data, vmap-safe)

    Storing scale/zero at fp16 halves the group-metadata footprint, which
    dominates ``nbytes`` at small group sizes; the quantization solve and
    every dequantize still run in f32.
    """

    packed: jax.Array
    scale: jax.Array
    zero: jax.Array
    bits: int
    group: int
    shape: tuple

    def tree_flatten(self):
        return (self.packed, self.scale, self.zero), \
            (self.bits, self.group, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def nbytes(self) -> int:
        return (self.packed.size * self.packed.dtype.itemsize
                + self.scale.size * self.scale.dtype.itemsize
                + self.zero.size * self.zero.dtype.itemsize)


def _shrink_lp(x: jax.Array, beta: float, p: float) -> jax.Array:
    """Generalized soft-threshold for the l_p proximal operator."""
    return jnp.sign(x) * jnp.maximum(
        jnp.abs(x) - (1.0 / beta) * jnp.power(jnp.abs(x) + 1e-8, p - 1.0), 0.0)


def _pack(q: jax.Array, bits: int) -> jax.Array:
    """Pack codes (G, L, N) into uint8 along axis 1."""
    per = 8 // bits
    g, l, n = q.shape
    pad = (-l) % per
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0)))
    q = q.reshape(g, (l + pad) // per, per, n).astype(jnp.uint8)
    shifts = jnp.arange(per, dtype=jnp.uint8) * bits
    return jnp.sum(q << shifts[None, None, :, None], axis=2).astype(jnp.uint8)


def _unpack(packed: jax.Array, bits: int, length: int) -> jax.Array:
    per = 8 // bits
    g, lp, n = packed.shape
    shifts = jnp.arange(per, dtype=jnp.uint8) * bits
    mask = jnp.uint8((1 << bits) - 1)
    q = (packed[:, :, None, :] >> shifts[None, None, :, None]) & mask
    return q.reshape(g, lp * per, n)[:, :length]


@partial(jax.jit, static_argnames=("bits", "group", "iters", "p"))
def quantize(w: jax.Array, bits: int = 2, group: int = 64,
             iters: int = 20, p: float = 0.7) -> QTensor:
    """HQQ-quantize a 2-D weight (M, N), grouping along M (input dim)."""
    m, n = w.shape
    assert m % group == 0, f"rows {m} not divisible by group {group}"
    wf = w.astype(jnp.float32).reshape(m // group, group, n)
    qmax = float(2 ** bits - 1)

    wmin = wf.min(axis=1, keepdims=True)
    wmax = wf.max(axis=1, keepdims=True)
    scale = jnp.maximum((wmax - wmin) / qmax, 1e-8)
    zero = -wmin / scale

    def _q(zero):
        return jnp.clip(jnp.round(wf / scale + zero), 0.0, qmax)

    beta = 10.0

    def body(carry, _):
        zero, beta = carry
        q = _q(zero)
        wr = scale * (q - zero)
        e = _shrink_lp(wf - wr, beta, p)
        zero = jnp.mean(q - (wf - e) / scale, axis=1, keepdims=True)
        return (zero, beta * 1.05), None

    (zero, _), _ = jax.lax.scan(body, (zero, beta), None, length=iters)
    # round metadata to its fp16 storage format FIRST, then solve the final
    # codes against the rounded values so dequantization sees no mismatch
    # (floor keeps a degenerate all-equal group's scale from flushing to 0)
    scale16 = jnp.maximum(scale, 6.2e-5).astype(jnp.float16)
    zero16 = jnp.clip(zero, -6e4, 6e4).astype(jnp.float16)
    q = jnp.clip(jnp.round(wf / scale16.astype(jnp.float32)
                           + zero16.astype(jnp.float32)),
                 0.0, qmax).astype(jnp.uint8)
    packed = _pack(q, bits) if bits < 8 else q
    return QTensor(packed, scale16, zero16, bits, group, (m, n))


def dequantize(qt: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    m, n = qt.shape
    g = m // qt.group
    if qt.bits < 8:
        q = _unpack(qt.packed, qt.bits, qt.group)
    else:
        q = qt.packed
    w = qt.scale.astype(jnp.float32) * \
        (q.astype(jnp.float32) - qt.zero.astype(jnp.float32))
    return w.reshape(m, n).astype(dtype)


def quantize_per_expert(w: jax.Array, bits: int = 2, group: int = 64) -> QTensor:
    """Quantize a stacked expert weight (E, M, N) via vmap."""
    fn = partial(quantize, bits=bits, group=group)
    return jax.vmap(fn)(w)


def dequantize_expert(qt: QTensor, e: int, dtype=jnp.bfloat16) -> jax.Array:
    one = QTensor(qt.packed[e], qt.scale[e], qt.zero[e], qt.bits, qt.group,
                  qt.shape)
    return dequantize(one, dtype)


def rel_error(w: jax.Array, qt: QTensor) -> float:
    wr = dequantize(qt, jnp.float32)
    w = w.astype(jnp.float32)
    return float(jnp.linalg.norm(w - wr) / jnp.maximum(jnp.linalg.norm(w), 1e-8))


def compression_ratio(w: jax.Array, qt: QTensor, dense_bytes: int = 2) -> float:
    return (w.size * dense_bytes) / qt.nbytes
