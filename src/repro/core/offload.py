"""Host-side expert store, compact layout, and the transfer cost model
(FloE §3.4.2 — adapted to TPU host→HBM DMA per DESIGN.md §2).

Compact weights layout: the activation of intermediate channel i uses gate
COLUMN i and down ROW i, so both are co-located as one contiguous record of
2·d_model elements.  A sparse expert slice (the ~10-20% of channels the mask
keeps) then moves as `len(mask)` records instead of 2·len(mask) scattered
rows/columns — exactly the paper's chunk-doubling (Fig. 5).

Because this container has no PCIe/ICI to measure, latency comes from an
explicit cost model calibrated to the paper's setup (PCIe 4.0 x16):

    t(chunks, bytes) = chunks·t_launch + bytes/BW_eff(chunk_bytes)

with BW_eff an efficiency curve that is low for tiny chunks (launch-bound)
and saturates for large ones — reproducing Fig. 7's shape.  Real
``jax.device_put`` transfers still happen so functional behavior is exact.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hqq


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """PCIe-4.0-x16-like link (paper's setup); swap constants for TPU DMA."""

    peak_bw: float = 32e9  # bytes/s
    launch_us: float = 10.0  # per-chunk API/launch overhead
    pack_bw: float = 200e9  # host packing bandwidth (SIMD memcpy)

    def transfer_time(self, total_bytes: int, num_chunks: int,
                      pinned: bool = True) -> float:
        """Seconds for a transfer split into num_chunks requests."""
        if total_bytes == 0:
            return 0.0
        num_chunks = max(num_chunks, 1)
        launch = num_chunks * self.launch_us * 1e-6
        bw = self.peak_bw if pinned else self.peak_bw * 0.35
        pack = total_bytes / self.pack_bw if pinned else 0.0
        # packing overlaps with transfer except for the first chunk
        return launch + total_bytes / bw + pack / num_chunks

    def effective_bw(self, total_bytes: int, num_chunks: int,
                     pinned: bool = True) -> float:
        t = self.transfer_time(total_bytes, num_chunks, pinned)
        return total_bytes / t if t > 0 else 0.0


@dataclasses.dataclass
class TransferLog:
    bytes_moved: int = 0
    transfers: int = 0
    modeled_seconds: float = 0.0
    wall_seconds: float = 0.0


@dataclasses.dataclass(frozen=True)
class FetchInfo:
    """Tier metadata for one staged fetch, consumed by the transfer
    engine's timeline: ``nbytes`` crosses the host→device link;
    ``disk_s`` is the modeled disk→host prefill that pipelines with it
    (0.0 for a host-tier hit or a flat in-host store)."""

    nbytes: int
    disk_s: float = 0.0
    precision: str = "full"


class ExpertStore:
    """Host (DRAM) store of compressed experts in compact layout.

    For one MoE layer:
      records:   (E, F, 2·D) f16/bf16 — row i = [gate[:, i] ‖ down[i, :]]
      up_q:      QTensor (E, D, F) INT-b packed — transferred whole
      thresholds (E,) f32
    """

    def __init__(self, we_gate: np.ndarray, we_down: np.ndarray,
                 up_q: hqq.QTensor, thresholds: np.ndarray,
                 link: Optional[LinkModel] = None):
        e, d, f = we_gate.shape
        # compact: co-locate gate column i with down row i
        gate_cols = np.transpose(np.asarray(we_gate), (0, 2, 1))  # (E, F, D)
        down_rows = np.asarray(we_down)  # (E, F, D)
        self.records = np.ascontiguousarray(
            np.concatenate([gate_cols, down_rows], axis=-1))  # (E, F, 2D)
        self.up_q = jax.tree.map(np.asarray, up_q)
        self.thresholds = np.asarray(thresholds)
        self.num_experts, self.d_model, self.d_ff = e, d, f
        self.link = link or LinkModel()
        self.log = TransferLog()

    # ------------------------------------------------------------ sizing ---
    def dense_expert_bytes(self, dense_bytes: int = 2) -> int:
        return 3 * self.d_model * self.d_ff * dense_bytes

    def compressed_expert_bytes(self, keep_ratio: float) -> int:
        rec = int(self.records.shape[1] * keep_ratio) * 2 * self.d_model * \
            self.records.dtype.itemsize
        up = self.up_q.packed[0].nbytes + self.up_q.scale[0].nbytes + \
            self.up_q.zero[0].nbytes
        return rec + up

    def slice_nbytes(self, channel_idx, precision: str = "full") -> int:
        """Link bytes for a staged slice of these channel records."""
        return int(len(channel_idx) * 2 * self.d_model *
                   self.records.dtype.itemsize)

    # ------------------------------------------------------------- tiers ---
    # The flat in-host store is the degenerate one-tier case of the tiered
    # store (repro.store.tiered): everything is "host resident", nothing is
    # format-restricted, and no fetch ever touches a disk stage.  The
    # runtime talks to stores only through this interface.
    def available_channels(self, e: int):
        """Channels the store can stage for expert e; None = all."""
        return None

    def progressive_available(self, e: int) -> bool:
        """Whether expert e supports draft-then-refine demand fetches."""
        return False

    def fetch_slice(self, e: int, channel_idx: np.ndarray, *,
                    chunk_channels: int = 50, precision: str = "full"
                    ) -> tuple[np.ndarray, jax.Array, jax.Array, FetchInfo]:
        """(served_idx, gate_cols, down_rows, FetchInfo) — the tier-aware
        fetch the transfer engine drives.  The flat store serves every
        requested channel at full precision with no disk stage."""
        idx = np.asarray(channel_idx)
        gate_cols, down_rows = self.fetch_sparse(
            e, idx, chunk_channels=chunk_channels)
        return idx, gate_cols, down_rows, FetchInfo(self.slice_nbytes(idx))

    # --------------------------------------------------------- transfers ---
    def fetch_up(self, e: int) -> hqq.QTensor:
        """Move expert e's packed up projection host->device."""
        parts = (self.up_q.packed[e], self.up_q.scale[e], self.up_q.zero[e])
        nbytes = sum(p.nbytes for p in parts)
        t0 = time.perf_counter()
        dev = [jax.device_put(p) for p in parts]
        jax.block_until_ready(dev)
        self._account(nbytes, 1, time.perf_counter() - t0)
        return hqq.QTensor(dev[0], dev[1], dev[2], self.up_q.bits,
                           self.up_q.group, self.up_q.shape)

    def fetch_sparse(self, e: int, channel_idx: np.ndarray,
                     chunk_channels: int = 50) -> tuple[jax.Array, jax.Array]:
        """Move the masked gate-column/down-row records of expert e.

        Returns (gate_cols (n, D), down_rows (n, D)) on device.  The chunking
        parameter reproduces the paper's chunk-size trade-off: latency is
        modeled per chunk of `chunk_channels` records.
        """
        channel_idx = np.asarray(channel_idx)
        recs = self.records[e][channel_idx]  # host gather (packing step)
        nbytes = recs.nbytes
        chunks = max(1, -(-len(channel_idx) // max(chunk_channels, 1)))
        t0 = time.perf_counter()
        dev = jax.device_put(np.ascontiguousarray(recs))
        jax.block_until_ready(dev)
        self._account(nbytes, chunks, time.perf_counter() - t0)
        gate_cols = dev[:, :self.d_model]
        down_rows = dev[:, self.d_model:]
        return gate_cols, down_rows

    def fetch_dense(self, e: int) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Naive offload baseline: move the WHOLE fp16 expert."""
        recs = self.records[e]
        up = hqq.dequantize(
            hqq.QTensor(self.up_q.packed[e], self.up_q.scale[e],
                        self.up_q.zero[e], self.up_q.bits, self.up_q.group,
                        self.up_q.shape))
        nbytes = self.dense_expert_bytes()
        t0 = time.perf_counter()
        dev = jax.device_put(recs)
        jax.block_until_ready(dev)
        self._account(nbytes, 3, time.perf_counter() - t0)
        return dev[:, :self.d_model].T, up, dev[:, self.d_model:]

    def _account(self, nbytes: int, chunks: int, wall: float):
        self.log.bytes_moved += nbytes
        self.log.transfers += 1
        self.log.modeled_seconds += self.link.transfer_time(nbytes, chunks)
        self.log.wall_seconds += wall

    def reset_log(self):
        self.log = TransferLog()


def build_expert_store(moe_params: dict, thresholds, *, bits: int = 2,
                       group: int = 64, link: Optional[LinkModel] = None
                       ) -> ExpertStore:
    """Construct the host store from a resident MoE layer's params."""
    up_q = hqq.quantize_per_expert(jnp.asarray(moe_params["we_up"]),
                                   bits=bits, group=group)
    return ExpertStore(
        np.asarray(moe_params["we_gate"], np.float16),
        np.asarray(moe_params["we_down"], np.float16),
        up_q, np.asarray(thresholds), link=link)
