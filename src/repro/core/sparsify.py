"""Contextual sparsification (FloE §3.2.1).

Implements the magnitude threshold function S_t (Eq. 5), offline threshold
calibration from the empirical CDF of |activation| at a target sparsity
(Eq. 6), and the three pruning variants compared by the paper (gate / up /
down) plus the production forward (Eq. 11) that prunes on the up-projection
output — the variant FloE ships because it is *predictable* and saves both
gate and down traffic.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import nn


def s_t(a: jax.Array, t: jax.Array) -> jax.Array:
    """Eq. (5): zero activations with |a| < t."""
    return jnp.where(jnp.abs(a) >= t, a, 0.0)


def threshold_from_samples(abs_samples: jax.Array, sparsity: float) -> jax.Array:
    """Eq. (6): t = min{t' : F(t') >= k} — the k-quantile of |a|."""
    return jnp.quantile(abs_samples.reshape(-1).astype(jnp.float32), sparsity)


def calibrate_expert_thresholds(up_acts: jax.Array, sparsity: float) -> jax.Array:
    """Per-expert thresholds from sampled |x W_up|. up_acts (E, T, F)."""
    return jax.vmap(lambda a: threshold_from_samples(jnp.abs(a), sparsity))(up_acts)


# ------------------------------------------------- pruning-variant forwards -
def expert_forward_dense(x, wg, wu, wd):
    """Eq. (1) — uncompressed."""
    g = nn.silu((x @ wg).astype(jnp.float32))
    u = (x @ wu).astype(jnp.float32)
    return ((g * u).astype(x.dtype)) @ wd


def expert_forward_sparse_up(x, wg, wu, wd, t):
    """Eq. (11) — FloE production forward: prune on |x W_up|.

    Channels with |u| < t contribute nothing, so their gate columns and down
    rows are dead: this is what the offload path never transfers and the
    Pallas kernel never loads.
    """
    u = (x @ wu).astype(jnp.float32)
    u = s_t(u, t)
    g = nn.silu((x @ wg).astype(jnp.float32))
    return ((g * u).astype(x.dtype)) @ wd


def expert_forward_sparse_gate(x, wg, wu, wd, t):
    """Ablation: prune on SiLU(x W_gate) (paper: most sensitive)."""
    g = nn.silu((x @ wg).astype(jnp.float32))
    g = s_t(g, t)
    u = (x @ wu).astype(jnp.float32)
    return ((g * u).astype(x.dtype)) @ wd


def expert_forward_sparse_down(x, wg, wu, wd, t):
    """Ablation: prune the W_down input (paper: least sensitive but
    unpredictable — requires both gate and up outputs first)."""
    g = nn.silu((x @ wg).astype(jnp.float32))
    u = (x @ wu).astype(jnp.float32)
    h = s_t(g * u, t)
    return h.astype(x.dtype) @ wd


VARIANTS: dict[str, Callable] = {
    "up": expert_forward_sparse_up,
    "gate": expert_forward_sparse_gate,
    "down": expert_forward_sparse_down,
}


def mask_from_up(u: jax.Array, t: jax.Array) -> jax.Array:
    """Channel activity mask (|u| >= t). u (..., F) -> bool (..., F)."""
    return jnp.abs(u) >= t


def block_union_mask(mask: jax.Array, block: int) -> jax.Array:
    """TPU adaptation: per-block activity (any active lane in a 128-lane
    block keeps the block). mask (..., F) -> (..., F/block) bool."""
    f = mask.shape[-1]
    assert f % block == 0
    return mask.reshape(mask.shape[:-1] + (f // block, block)).any(-1)


def achieved_sparsity(mask: jax.Array) -> jax.Array:
    return 1.0 - jnp.mean(mask.astype(jnp.float32))


# --------------------------------------------- theorem 3.1 empirical check -
def pruning_losses(x, wg, wu, wd, sparsity: float, key=None):
    """Monte-Carlo L_down / L_up / L_gate of Theorem 3.1 on given inputs.

    Thresholds are calibrated per-variant so all three prune the SAME
    fraction, as the theorem requires.  Returns dict of mean L2^2 errors.
    """
    g = nn.silu((x @ wg).astype(jnp.float32))
    u = (x @ wu).astype(jnp.float32)
    h = g * u
    ref = h @ wd.astype(jnp.float32)

    t_down = threshold_from_samples(jnp.abs(h), sparsity)
    t_up = threshold_from_samples(jnp.abs(u), sparsity)
    t_gate = threshold_from_samples(jnp.abs(g), sparsity)

    l_down = jnp.mean(jnp.sum(((h - s_t(h, t_down)) @ wd.astype(jnp.float32)) ** 2, -1))
    l_up = jnp.mean(jnp.sum(((h - g * s_t(u, t_up)) @ wd.astype(jnp.float32)) ** 2, -1))
    l_gate = jnp.mean(jnp.sum(((h - s_t(g, t_gate) * u) @ wd.astype(jnp.float32)) ** 2, -1))
    return {"down": l_down, "up": l_up, "gate": l_gate}
