"""Production traffic scenarios — deterministic workload generation.

The evaluation substrate for the serving stack (the ROADMAP traffic-
harness item): a typed, JSON-round-trippable :class:`ScenarioSpec`
composes arrival processes (stationary Poisson, diurnal sinusoid,
flash-crowd bursts) × tenant mixes (per-tenant SLOs, length
distributions, router-distribution biases, session affinity) × drift
models (gradual rotation / abrupt phase change of each tenant's routing
bias over modeled time).  :func:`generate_requests` turns a spec into a
seeded, replay-deterministic stream of
:class:`~repro.serving.SLORequest`\\ s; :mod:`repro.workload.trace`
saves/replays a generated workload as a byte-deterministic JSON
artifact.
"""
from repro.workload.generate import (WorkloadError, generate_requests,
                                     rotation_offset, tenant_token_probs)
from repro.workload.scenario import (ArrivalSpec, BurstSpec, DriftSpec,
                                     ScenarioSpec, TenantSpec)
from repro.workload.trace import load_trace, save_trace, trace_str

__all__ = [
    "ArrivalSpec", "BurstSpec", "DriftSpec", "ScenarioSpec", "TenantSpec",
    "WorkloadError", "generate_requests", "rotation_offset",
    "tenant_token_probs", "load_trace", "save_trace", "trace_str",
]
