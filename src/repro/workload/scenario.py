"""Typed traffic-scenario spec — the workload analogue of ``repro.deploy``.

A :class:`ScenarioSpec` is the declarative description of a production
traffic pattern on the simulated clock, mirroring the
``repro.deploy.spec`` conventions: frozen dataclasses, lossless JSON
round-trip (``spec == ScenarioSpec.from_json(spec.to_json())``), and
EAGER cross-field validation — every invalid field (or combination)
raises a typed :class:`~repro.deploy.spec.SpecError` naming the dotted
field at construction time, so a bad scenario file fails at load, not
ten thousand simulated requests in.

Three orthogonal axes compose:

* :class:`ArrivalSpec` — WHEN sessions arrive: stationary Poisson, a
  diurnal sinusoid rate envelope, and flash-crowd :class:`BurstSpec`
  windows that multiply the instantaneous rate.
* :class:`TenantSpec` — WHO arrives: traffic classes (chat / code /
  long-context) with per-tenant SLOs, prompt/output-length ranges,
  session affinity (requests per session, think-time gaps, shared
  prompt prefixes), and a distinct router-distribution bias (a skewed
  token distribution over a tenant-specific vocab permutation, which is
  what drives per-tenant expert-routing skew downstream).
* :class:`DriftSpec` — HOW routing pressure moves over modeled time:
  ``rotate`` slides every tenant's token-rank permutation gradually
  (gradual expert-frequency rotation), ``phase`` swaps to an unrelated
  permutation at one instant (abrupt phase change).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

from repro.deploy.spec import SpecError

_ARRIVALS = ("poisson", "diurnal")
_DRIFTS = ("none", "rotate", "phase")


# ------------------------------------------------------------------ bursts --
@dataclasses.dataclass(frozen=True)
class BurstSpec:
    """A flash-crowd window: the arrival rate is multiplied by
    ``multiplier`` for ``duration_s`` starting at ``start_t``."""

    start_t: float = 0.0
    duration_s: float = 1.0
    multiplier: float = 4.0


# ---------------------------------------------------------------- arrivals --
@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """The session arrival process on the simulated clock.

    ``kind="poisson"`` is a stationary process at ``rate`` sessions per
    modeled second; ``kind="diurnal"`` modulates that base rate with a
    sinusoid of relative ``amplitude`` and period ``period_s`` (phase
    in fractions of a period).  ``bursts`` multiply the instantaneous
    rate inside their windows in either kind.
    """

    kind: str = "poisson"
    rate: float = 1.0  # mean session arrivals / modeled second (base)
    period_s: float = 60.0  # diurnal period
    amplitude: float = 0.5  # diurnal modulation depth in [0, 1)
    phase: float = 0.0  # fraction of a period
    bursts: Tuple[BurstSpec, ...] = ()


# ----------------------------------------------------------------- tenants --
@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One traffic class: SLO, shape distributions, sessions, routing bias.

    ``router_bias`` is the Zipf-like skew exponent of the tenant's token
    distribution over a tenant-specific vocab permutation (seeded by
    ``bias_seed``): 0 is uniform, larger concentrates traffic on fewer
    tokens — and therefore on fewer routed experts downstream.  Session
    affinity: each session issues 1..``session_len`` requests that SHARE
    the session's prompt prefix (the first ``prompt_len_min`` tokens)
    and arrive ``think_time_s``-mean exponential gaps apart.
    """

    name: str = "chat"
    weight: float = 1.0  # mix share (normalized across tenants)
    slo_ms: float = 1000.0
    prompt_len_min: int = 8
    prompt_len_max: int = 16
    max_new_min: int = 4
    max_new_max: int = 8
    temperature: float = 0.8
    session_len: int = 1  # max requests per session (uniform 1..N)
    think_time_s: float = 0.5  # mean gap between a session's requests
    router_bias: float = 1.0  # Zipf skew of the token distribution
    bias_seed: int = 0  # tenant vocab-permutation seed


# ------------------------------------------------------------------- drift --
@dataclasses.dataclass(frozen=True)
class DriftSpec:
    """Routing-distribution drift over modeled time, applied by
    reweighting every tenant's token distribution:

    * ``rotate`` — each tenant's token-rank permutation rotates by
      ``strength`` of the vocab per ``period_s`` (gradual, monotone
      expert-frequency rotation).
    * ``phase``  — at ``at_t`` every tenant swaps to an unrelated
      permutation (abrupt phase change).
    """

    kind: str = "none"
    period_s: float = 30.0  # rotate: seconds per full-strength rotation
    at_t: float = 0.0  # phase: the change instant
    strength: float = 1.0  # fraction of the vocab rotated / in (0, 1]


# ---------------------------------------------------------------- scenario --
@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One traffic scenario: arrivals × tenant mix × drift, seeded.

    ``n_requests`` bounds the generated stream (sessions are truncated
    mid-flight if needed); ``duration_s`` (optional) additionally stops
    generation at a modeled horizon.  Same spec + same seed produces a
    byte-identical request stream (pinned by test).
    """

    name: str = "scenario"
    seed: int = 0
    n_requests: int = 16
    duration_s: Optional[float] = None
    arrival: ArrivalSpec = dataclasses.field(default_factory=ArrivalSpec)
    tenants: Tuple[TenantSpec, ...] = dataclasses.field(
        default_factory=lambda: (TenantSpec(),))
    drift: DriftSpec = dataclasses.field(default_factory=DriftSpec)

    def __post_init__(self):
        self.validate()

    # -------------------------------------------------------- validation --
    def validate(self) -> None:
        a, d = self.arrival, self.drift
        if not self.name:
            raise SpecError("scenario.name", "need a non-empty name")
        if self.seed < 0:
            raise SpecError("scenario.seed",
                            f"need >= 0 (np.random seed), got {self.seed}")
        if self.n_requests < 1:
            raise SpecError("scenario.n_requests",
                            f"need >= 1, got {self.n_requests}")
        if self.duration_s is not None and self.duration_s <= 0:
            raise SpecError("scenario.duration_s",
                            f"need > 0 (or null), got {self.duration_s}")
        if a.kind not in _ARRIVALS:
            raise SpecError("arrival.kind",
                            f"unknown kind {a.kind!r}; choose from "
                            f"{_ARRIVALS}")
        if a.rate <= 0:
            raise SpecError("arrival.rate", f"need > 0, got {a.rate}")
        if a.kind == "diurnal":
            if a.period_s <= 0:
                raise SpecError("arrival.period_s",
                                f"need > 0, got {a.period_s}")
            if not 0.0 <= a.amplitude < 1.0:
                raise SpecError(
                    "arrival.amplitude",
                    f"need 0 <= amplitude < 1 (the rate must stay "
                    f"positive), got {a.amplitude}")
        for i, b in enumerate(a.bursts):
            if b.duration_s <= 0:
                raise SpecError(f"arrival.bursts[{i}].duration_s",
                                f"need > 0, got {b.duration_s}")
            if b.multiplier <= 0:
                raise SpecError(f"arrival.bursts[{i}].multiplier",
                                f"need > 0, got {b.multiplier}")
            if b.start_t < 0:
                raise SpecError(f"arrival.bursts[{i}].start_t",
                                f"need >= 0, got {b.start_t}")
        if not self.tenants:
            raise SpecError("tenants", "need at least one TenantSpec")
        seen = set()
        for i, t in enumerate(self.tenants):
            f = f"tenants[{i}]"
            if not t.name:
                raise SpecError(f"{f}.name", "tenant name must be set")
            if t.name in seen:
                raise SpecError(f"{f}.name",
                                f"duplicate tenant name {t.name!r}")
            seen.add(t.name)
            if t.weight <= 0:
                raise SpecError(f"{f}.weight", f"need > 0, got {t.weight}")
            if t.slo_ms <= 0:
                raise SpecError(f"{f}.slo_ms", f"need > 0, got {t.slo_ms}")
            if t.prompt_len_min < 1:
                raise SpecError(f"{f}.prompt_len_min",
                                f"need >= 1, got {t.prompt_len_min}")
            if t.prompt_len_max < t.prompt_len_min:
                raise SpecError(
                    f"{f}.prompt_len_max",
                    f"need >= prompt_len_min={t.prompt_len_min}, got "
                    f"{t.prompt_len_max}")
            if t.max_new_min < 1:
                raise SpecError(f"{f}.max_new_min",
                                f"need >= 1, got {t.max_new_min}")
            if t.max_new_max < t.max_new_min:
                raise SpecError(f"{f}.max_new_max",
                                f"need >= max_new_min={t.max_new_min}, "
                                f"got {t.max_new_max}")
            if t.temperature < 0:
                raise SpecError(f"{f}.temperature",
                                f"need >= 0, got {t.temperature}")
            if t.session_len < 1:
                raise SpecError(f"{f}.session_len",
                                f"need >= 1, got {t.session_len}")
            if t.think_time_s < 0:
                raise SpecError(f"{f}.think_time_s",
                                f"need >= 0, got {t.think_time_s}")
            if t.router_bias < 0:
                raise SpecError(f"{f}.router_bias",
                                f"need >= 0, got {t.router_bias}")
            if t.bias_seed < 0:
                raise SpecError(f"{f}.bias_seed",
                                f"need >= 0, got {t.bias_seed}")
        if d.kind not in _DRIFTS:
            raise SpecError("drift.kind",
                            f"unknown kind {d.kind!r}; choose from "
                            f"{_DRIFTS}")
        if d.kind != "none" and not 0.0 < d.strength <= 1.0:
            raise SpecError("drift.strength",
                            f"need 0 < strength <= 1, got {d.strength}")
        if d.kind == "rotate" and d.period_s <= 0:
            raise SpecError("drift.period_s",
                            f"need > 0, got {d.period_s}")
        if d.kind == "phase" and d.at_t < 0:
            raise SpecError("drift.at_t", f"need >= 0, got {d.at_t}")

    # ---------------------------------------------------- JSON round-trip --
    def to_dict(self) -> dict:
        d = {
            "name": self.name,
            "seed": self.seed,
            "n_requests": self.n_requests,
            "duration_s": self.duration_s,
            "arrival": dataclasses.asdict(self.arrival),
            "tenants": [dataclasses.asdict(t) for t in self.tenants],
            "drift": dataclasses.asdict(self.drift),
        }
        d["arrival"]["bursts"] = [dataclasses.asdict(b)
                                  for b in self.arrival.bursts]
        return d

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent,
                          sort_keys=True) + "\n"

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        known = ("name", "seed", "n_requests", "duration_s", "arrival",
                 "tenants", "drift")
        bad = sorted(set(d) - set(known))
        if bad:  # a typo'd section must not load as all-defaults
            raise SpecError(bad[0],
                            f"unknown section(s) {bad}; expected {known}")

        def sub(klass, payload, where):
            payload = dict(payload or {})
            fields = {f.name for f in dataclasses.fields(klass)}
            extra = sorted(set(payload) - fields)
            if extra:
                raise SpecError(f"{where}.{extra[0]}",
                                f"unknown field(s) {extra} for "
                                f"{klass.__name__}")
            return klass(**payload)

        arr = sub(ArrivalSpec, d.get("arrival"), "arrival")
        arr = dataclasses.replace(arr, bursts=tuple(
            sub(BurstSpec, b, f"arrival.bursts[{i}]")
            for i, b in enumerate(arr.bursts)))
        tenants = tuple(sub(TenantSpec, t, f"tenants[{i}]")
                        for i, t in enumerate(d.get("tenants") or ({},)))
        return cls(
            name=d.get("name", "scenario"),
            seed=d.get("seed", 0),
            n_requests=d.get("n_requests", 16),
            duration_s=d.get("duration_s"),
            arrival=arr,
            tenants=tenants,
            drift=sub(DriftSpec, d.get("drift"), "drift"),
        )

    @classmethod
    def from_json(cls, text: str) -> "ScenarioSpec":
        try:
            d = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError("<json>", f"not valid JSON: {e}") from e
        if not isinstance(d, dict):
            raise SpecError("<json>", "scenario JSON must be an object")
        return cls.from_dict(d)

    @classmethod
    def load(cls, path) -> "ScenarioSpec":
        """Load a spec from a JSON file path."""
        with open(path) as f:
            return cls.from_json(f.read())
