"""ScenarioSpec -> deterministic SLORequest stream on the simulated clock.

Generation is a single seeded pass, so the same spec + seed produces a
byte-identical stream (pinned by test):

1. **Session arrivals** — a non-homogeneous Poisson process sampled by
   thinning against the rate envelope's upper bound: stationary base
   rate × diurnal sinusoid × flash-crowd burst multipliers.
2. **Tenant mix** — each session draws its tenant by normalized weight;
   the session issues 1..``session_len`` requests with ``think_time_s``
   exponential gaps, all SHARING the session's prompt prefix (the
   affinity a prefix cache / KV reuse layer would exploit).
3. **Router-distribution bias** — prompt tokens are drawn from a
   Zipf-skewed distribution over a tenant-specific vocab permutation;
   because routing downstream is a function of the embedded tokens,
   tenants with different biases exercise visibly different expert
   frequencies.  :class:`~repro.workload.scenario.DriftSpec` reweights
   that distribution over modeled time — ``rotate`` slides the
   permutation monotonically (:func:`rotation_offset`), ``phase`` swaps
   it wholesale at one instant.
4. **uid allocation** — uids are assigned centrally, sequential from
   ``uid_base`` in arrival order, so every request stream the generator
   produces is collision-free by construction (the controller asserts
   uniqueness again at submit).
"""
from __future__ import annotations

import math
from typing import List, Optional

import numpy as np

from repro.workload.scenario import ScenarioSpec, TenantSpec


class WorkloadError(ValueError):
    """Workload generation failed (e.g. vocab too small for the spec)."""


# ------------------------------------------------------------ rate envelope --
def instantaneous_rate(spec: ScenarioSpec, t: float) -> float:
    """Session arrivals / modeled second at time ``t``."""
    a = spec.arrival
    r = a.rate
    if a.kind == "diurnal":
        r *= 1.0 + a.amplitude * math.sin(
            2.0 * math.pi * (t / a.period_s + a.phase))
    for b in a.bursts:
        if b.start_t <= t < b.start_t + b.duration_s:
            r *= b.multiplier
    return r


def _peak_rate(spec: ScenarioSpec) -> float:
    """An upper bound of the rate envelope (thinning proposal rate)."""
    a = spec.arrival
    r = a.rate * (1.0 + a.amplitude if a.kind == "diurnal" else 1.0)
    for b in a.bursts:  # overlapping bursts multiply — bound them all
        r *= max(b.multiplier, 1.0)
    return r


# --------------------------------------------------------- token distribution
def rotation_offset(spec: ScenarioSpec, t: float, vocab_size: int) -> int:
    """How far (in vocab ranks) the drift has rotated the tenant
    permutations by modeled time ``t`` — monotone non-decreasing in
    ``t`` for ``kind="rotate"``, 0 otherwise."""
    d = spec.drift
    if d.kind != "rotate":
        return 0
    return int(vocab_size * d.strength * (max(t, 0.0) / d.period_s))


def tenant_token_probs(spec: ScenarioSpec, tenant: TenantSpec,
                       vocab_size: int, t: float) -> np.ndarray:
    """The tenant's token distribution at modeled time ``t``.

    Rank weights are Zipf-like, ``(1+rank)^-router_bias``, laid over a
    tenant-specific permutation of the vocab (seeded by
    ``(spec.seed, tenant.bias_seed)``) so two tenants with the same
    skew still stress DIFFERENT tokens — and therefore different
    experts.  Drift moves the distribution over time without touching
    its shape: ``rotate`` shifts every token's rank by
    :func:`rotation_offset`; ``phase`` switches to an unrelated
    permutation at ``at_t``.
    """
    d = spec.drift
    phase_flip = int(d.kind == "phase" and t >= d.at_t)
    perm_rng = np.random.default_rng(
        (spec.seed, 7919 + tenant.bias_seed, phase_flip))
    perm = perm_rng.permutation(vocab_size)  # rank -> token id
    ranks = np.arange(vocab_size, dtype=np.float64)
    if d.kind == "rotate":
        ranks = (ranks + rotation_offset(spec, t, vocab_size)) % vocab_size
    w = (1.0 + ranks) ** (-float(tenant.router_bias))
    probs = np.zeros(vocab_size, np.float64)
    probs[perm] = w
    return probs / probs.sum()


# -------------------------------------------------------------- generation --
def generate_requests(spec: ScenarioSpec, vocab_size: int, *,
                      uid_base: int = 0) -> List["SLORequest"]:
    """Generate the scenario's request stream (sorted by arrival time).

    Returns at most ``spec.n_requests`` requests; generation also stops
    at ``spec.duration_s`` when set.  Deterministic: one
    ``np.random.default_rng(spec.seed)`` drives every draw in a fixed
    order, so identical (spec, vocab_size, uid_base) inputs reproduce
    the stream exactly.
    """
    from repro.serving import SLORequest

    if vocab_size < 2:
        raise WorkloadError(f"need vocab_size >= 2, got {vocab_size}")
    for i, t in enumerate(spec.tenants):
        if t.prompt_len_max > 4 * vocab_size:
            raise WorkloadError(
                f"tenants[{i}].prompt_len_max={t.prompt_len_max} is "
                f"implausible for vocab_size={vocab_size}")

    rng = np.random.default_rng(spec.seed)
    weights = np.array([t.weight for t in spec.tenants], np.float64)
    weights /= weights.sum()
    peak = _peak_rate(spec)
    horizon = (spec.duration_s if spec.duration_s is not None
               else float("inf"))

    raw = []  # (arrival_t, order, request-fields) before uid assignment
    t = 0.0
    order = 0
    while len(raw) < spec.n_requests:
        # thinning: propose at the peak rate, accept at the true rate
        t += float(rng.exponential(1.0 / peak))
        if t > horizon:
            break
        if rng.random() >= instantaneous_rate(spec, t) / peak:
            continue
        tenant = spec.tenants[int(rng.choice(len(spec.tenants), p=weights))]
        n_sess = int(rng.integers(1, tenant.session_len + 1))
        # the session's shared prompt prefix (affinity: every request in
        # the session starts with these tokens)
        probs = tenant_token_probs(spec, tenant, vocab_size, t)
        prefix = rng.choice(vocab_size, size=tenant.prompt_len_min,
                            p=probs).astype(np.int32)
        t_req = t
        for j in range(n_sess):
            if j > 0:
                t_req += float(rng.exponential(tenant.think_time_s)) \
                    if tenant.think_time_s > 0 else 0.0
            plen = int(rng.integers(tenant.prompt_len_min,
                                    tenant.prompt_len_max + 1))
            fresh = plen - len(prefix)
            if fresh > 0:
                probs_j = tenant_token_probs(spec, tenant, vocab_size,
                                             t_req)
                tail = rng.choice(vocab_size, size=fresh,
                                  p=probs_j).astype(np.int32)
                prompt = np.concatenate([prefix, tail])
            else:
                prompt = prefix.copy()
            max_new = int(rng.integers(tenant.max_new_min,
                                       tenant.max_new_max + 1))
            raw.append((t_req, order, tenant, prompt, max_new))
            order += 1

    raw.sort(key=lambda r: (r[0], r[1]))
    del raw[spec.n_requests:]  # sessions may overshoot the cap
    return [
        SLORequest(
            uid=uid_base + i,
            prompt=prompt,
            max_new_tokens=max_new,
            slo_ms=tenant.slo_ms,
            arrival_t=arrival_t,
            temperature=tenant.temperature,
            tenant=tenant.name,
        )
        for i, (arrival_t, _, tenant, prompt, max_new) in enumerate(raw)
    ]
