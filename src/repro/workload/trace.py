"""Workload traces — save / replay a generated request stream.

A trace is a JSON artifact binding the :class:`ScenarioSpec` that
produced it to the exact request stream it produced, so a workload can
be committed (``examples/scenarios/``), diffed across PRs, and replayed
byte-for-byte without regenerating:

    {"scenario": {...spec...}, "requests": [{...}, ...]}

Rendering is byte-deterministic — sorted keys, fixed indent, exact
float round-trip through Python's shortest-repr JSON floats — so
``generate -> save -> load -> save`` produces identical bytes (pinned
by test), and replay reconstructs :class:`~repro.serving.SLORequest`\\ s
whose fields (including prompt token ids) equal the generated ones.
"""
from __future__ import annotations

import json
from typing import List, Tuple

import numpy as np

from repro.workload.scenario import ScenarioSpec


def _request_dict(r) -> dict:
    return {
        "uid": int(r.uid),
        "tenant": r.tenant,
        "arrival_t": float(r.arrival_t),
        "slo_ms": float(r.slo_ms),
        "max_new_tokens": int(r.max_new_tokens),
        "temperature": float(r.temperature),
        "prompt": [int(x) for x in np.asarray(r.prompt).reshape(-1)],
    }


def trace_str(spec: ScenarioSpec, requests) -> str:
    """Byte-deterministic JSON rendering of (spec, request stream)."""
    return json.dumps(
        {"scenario": spec.to_dict(),
         "requests": [_request_dict(r) for r in requests]},
        indent=1, sort_keys=True) + "\n"


def save_trace(path, spec: ScenarioSpec, requests) -> None:
    with open(path, "w") as f:
        f.write(trace_str(spec, requests))


def load_trace(path) -> Tuple[ScenarioSpec, List["SLORequest"]]:
    """Replay a saved trace: (spec, reconstructed request stream)."""
    from repro.serving import SLORequest
    with open(path) as f:
        d = json.load(f)
    spec = ScenarioSpec.from_dict(d["scenario"])
    reqs = [
        SLORequest(
            uid=r["uid"],
            prompt=np.asarray(r["prompt"], np.int32),
            max_new_tokens=r["max_new_tokens"],
            slo_ms=r["slo_ms"],
            arrival_t=r["arrival_t"],
            temperature=r["temperature"],
            tenant=r.get("tenant", ""),
        )
        for r in d["requests"]
    ]
    return spec, reqs
