"""InternVL2-76B — VLM: InternViT frontend (STUB) + LLM decoder backbone
[arXiv:2404.16821].

80L d_model=8192 64H (kv=8) d_ff=28672 vocab=128256.  The vision encoder +
projector is the carve-out stub: ``input_specs`` supplies 256 precomputed
patch embeddings per sequence.  Full attention: long_500k skipped.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    kind="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision",
    frontend_tokens=256,
    source="arXiv:2404.16821",
)
