"""Mistral-Large-123B — dense LM [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (kv=8) d_ff=28672 vocab=32768.  Pure full attention:
long_500k decode skipped.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    kind="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1e6,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)
