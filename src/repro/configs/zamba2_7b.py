"""Zamba2-7B — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242].

81L d_model=3584 32H (kv=32, MHA) d_ff=14336 ssm_state=64 vocab=32000.
Layout: every 6th block is the SHARED transformer block (one set of
attention+MLP weights reused across its 13 invocations, each with its own
input projection over concat(x, x_embed)); the rest are Mamba2 mixers.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    kind="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    shared_attn_every=6,
    source="arXiv:2411.15242",
)
