"""GLM4-9B — dense LM, RoPE + GQA [hf:THUDM/glm-4-9b].

40L d_model=4096 32H (kv=2) d_ff=13696 vocab=151552.  Pure full attention:
long_500k decode is skipped (no sub-quadratic variant in the architecture).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    kind="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=1e4,
    source="hf:THUDM/glm-4-9b",
)
