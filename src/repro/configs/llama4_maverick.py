"""Llama-4 Maverick (400B total / 17B active) — MoE 128 experts top-1,
early fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (kv=8) expert d_ff=8192 vocab=202048.  Every other
layer is MoE (interleave step 2, like Maverick); chunked attention is
modeled as an 8192 sliding window, which makes long_500k decode valid.
Top-1 routing is FloE's easiest inter-expert prediction case.
"""
from repro.common.config import FloEConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    kind="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    num_experts_per_tok=1,
    moe_every=2,
    sliding_window=8192,
    floe=FloEConfig(enabled=True, sparsity=0.8, up_bits=2),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
