"""HuBERT-XLarge — audio encoder backbone [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (k-means cluster units).
Encoder-only (bidirectional), GELU FFN, learned conv frontend is a STUB:
``input_specs`` supplies precomputed frame embeddings (B, S, 1280).
No autoregressive decode — decode shapes are skipped (see DESIGN.md §5).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    kind="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    use_rope=False,  # hubert uses conv positional embedding (in the stub)
    mlp_activation="gelu",
    frontend="audio",
    source="arXiv:2106.07447",
)
