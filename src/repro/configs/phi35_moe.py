"""Phi-3.5-MoE (42B total / 6.6B active) — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (kv=8) expert d_ff=6400 vocab=32064, SWA 131072.
The paper validates FloE on Phi-3.5-MoE itself (App. D/E) — this is the
technique's home arch alongside Mixtral.
"""
from repro.common.config import FloEConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    kind="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    num_experts_per_tok=2,
    sliding_window=131072,
    floe=FloEConfig(enabled=True, sparsity=0.8, up_bits=2),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
