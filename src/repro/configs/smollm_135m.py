"""SmolLM-135M — llama-arch small dense LM [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (kv=3) d_ff=1536 vocab=49152.  Full attention:
long_500k skipped.  Also the end-to-end training example arch (~135M).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    kind="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
