"""Assigned architecture configs (+ the paper's own Mixtral-8x7B).

Each module exposes ``CONFIG``; ``get_config(name)`` resolves by id.
"""
from __future__ import annotations

import importlib

from repro.common.config import ModelConfig

ARCH_IDS = [
    "hubert_xlarge",
    "mamba2_780m",
    "starcoder2_7b",
    "glm4_9b",
    "zamba2_7b",
    "phi35_moe",
    "llama4_maverick",
    "mistral_large",
    "internvl2_76b",
    "smollm_135m",
    "mixtral_8x7b",
]

_ALIASES = {
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-780m": "mamba2_780m",
    "starcoder2-7b": "starcoder2_7b",
    "glm4-9b": "glm4_9b",
    "zamba2-7b": "zamba2_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "mistral-large-123b": "mistral_large",
    "internvl2-76b": "internvl2_76b",
    "smollm-135m": "smollm_135m",
    "mixtral-8x7b": "mixtral_8x7b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {aid: get_config(aid) for aid in ARCH_IDS}
