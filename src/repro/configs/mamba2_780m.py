"""Mamba2-780m — attention-free SSM with SSD [arXiv:2405.21060].

48L d_model=1536, d_inner=3072 (expand 2), 48 SSD heads of 64 channels,
state N=128, vocab=50280.  FloE's expert compression is INAPPLICABLE here
(no SwiGLU MLPs) — implemented without the technique per DESIGN.md
§Arch-applicability.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    kind="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,  # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    use_rope=False,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
