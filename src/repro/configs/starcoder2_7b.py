"""StarCoder2-7B — dense code LM, GQA + RoPE + 4k sliding window
[arXiv:2402.19173].

32L d_model=4608 36H (kv=4) d_ff=18432 vocab=49152.  The native sliding
window makes long_500k decode architecturally valid (ring-buffer KV).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    kind="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    rope_theta=1e5,
    sliding_window=4096,
    source="arXiv:2402.19173",
)
