"""Mixtral-8x7B — the paper's evaluation model [arXiv:2401.04088].

32L d_model=4096 32H (kv=8) expert d_ff=14336 vocab=32000, 8 experts top-2.
FloE headline numbers (9.3x per-expert compression, 11GB VRAM deployment)
are computed against this config — see benchmarks/bench_compression.py.
"""
from repro.common.config import FloEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    kind="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    num_experts_per_tok=2,
    rope_theta=1e6,
    floe=FloEConfig(enabled=True, sparsity=0.8, up_bits=2),
    source="arXiv:2401.04088",
)
