"""Declarative deployment spec — construction resolved once, not per call.

Four PRs of subsystem growth scattered construction across ~20
``FloEPipeline.__init__`` kwargs, the controller's untyped
``offload_opts`` tunnel, and a dozen ``launch/serve.py`` flags.  This
module is the single typed description of a deployment:

    ModelSpec     — which model, how its params come to exist
    ResourceSpec  — vram / host / devices / replication (what the
                    planner spends)
    RuntimeSpec   — scheduler & decode knobs (what the runtime obeys)
    ServingSpec   — control-plane knobs (slots / SLO / policy /
                    predictor training)

composing into a :class:`DeploymentSpec` with JSON round-trip
(``spec == DeploymentSpec.from_json(spec.to_json())``) and EAGER
cross-field validation: every invalid combination raises a typed
:class:`SpecError` naming the offending field at construction time,
replacing the deep-in-constructor asserts a bad kwarg used to hit only
after minutes of setup.

``repro.deploy.build(spec)`` resolves a spec into a live
:class:`~repro.deploy.builder.Deployment`;
``repro.deploy.build_fleet([specs])`` resolves several over one shared
host/disk tier.  The old kwargs constructors keep working as thin shims
that build these specs internally, so spec-built and kwargs-built
deployments are bitwise-identical (pinned by test).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple


class SpecError(ValueError):
    """A deployment spec field (or combination) is invalid.

    ``field`` is the dotted path of the offending field, e.g.
    ``"resources.vram_gb"`` — every raise names exactly one field so the
    error is actionable without reading the validator.
    """

    def __init__(self, field: str, message: str):
        self.field = field
        super().__init__(f"{field}: {message}")


# ------------------------------------------------------------------ model --
@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Which model, and how its parameters come to exist."""

    arch: str = "mixtral-8x7b"
    reduced: bool = True  # smoke-scale variant (layers/d_model below)
    layers: int = 4
    d_model: int = 128
    max_experts: int = 4
    vocab: int = 512
    seed: int = 0  # init_model PRNG seed
    train_steps: int = 0  # >0: briefly pre-train so routing has structure
    ckpt: str = ""  # load params from a checkpoint instead of init
    name: str = ""  # fleet label; defaults to arch


# -------------------------------------------------------------- resources --
@dataclasses.dataclass(frozen=True)
class ResourceSpec:
    """What the planner may spend: memory budgets and device topology."""

    vram_gb: float = 0.0  # 0 disables the tiered store (flat host store)
    host_gb: float = 4.0  # host (pinned DRAM) tier budget
    devices: int = 1  # >1 simulates a multi-GPU cluster
    replicate: int = 0  # hottest experts/layer homed on EVERY device
    store_dir: str = ""  # disk-tier shard dir ("" = tmp dir)
    progressive: bool = True  # INT8-draft demand fetches + refine
    ladder: Optional[Tuple[str, ...]] = None  # format ladder restriction
    max_slots: Optional[int] = None
    max_pinned: Optional[int] = None  # per device when devices > 1


# ---------------------------------------------------------------- runtime --
@dataclasses.dataclass(frozen=True)
class RuntimeSpec:
    """Scheduler / decode knobs (``FloEPipeline``'s former kwargs)."""

    mode: str = "floe"  # "floe" | "naive" | "resident"
    use_runtime: bool = True  # event-loop scheduler vs synchronous path
    prefetch: bool = True
    lookahead: int = 2
    residency_policy: str = "lru"  # "lru" | "lfu" | "weighted"
    num_buffers: int = 2
    cache_slots: int = 4  # residency slots (planner overrides when tiered)
    cancel_stale: bool = True
    cross_token: bool = True
    batched_demand: bool = False


# ---------------------------------------------------------------- serving --
@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """Control-plane knobs (``ServingController``'s former kwargs)."""

    slots: int = 4  # concurrent batch slots
    max_len: int = 256
    policy: str = "slo"  # "slo" | "static"
    slo_ms: float = 1000.0  # default per-request SLO for front-ends
    eos_id: int = -1
    seed: int = 0
    online_train: bool = True
    train_every_tokens: int = 16
    train_window: int = 256
    train_steps: int = 60
    predictor_hidden: int = 0
    min_train_rows: int = 64
    max_preemptions: int = 2
    cross_token: bool = True  # controller-side cross-token speculation


# ----------------------------------------------------------------- replan --
@dataclasses.dataclass(frozen=True)
class ReplanSpec:
    """Live re-planning knobs (:class:`~repro.replan.Replanner`)."""

    enabled: bool = True
    window: int = 64  # min demand events before drift evaluates
    threshold: float = 0.25  # mean per-layer TV distance that triggers
    hysteresis: float = 0.5  # re-arm when dist <= hysteresis * threshold
    cooldown_s: float = 0.25  # min modeled seconds between re-plans
    check_every: int = 8  # controller steps between drift checks
    bandwidth_share: float = 0.5  # migration's cap on link seconds
    trigger: str = "drift"  # "drift" (TV detector) | "health" (page alerts)


# ----------------------------------------------------------------- health --
@dataclasses.dataclass(frozen=True)
class HealthSpec:
    """Live health-layer knobs (:class:`~repro.obs.health.HealthMonitor`).

    Burn-rate windows and cooldowns are in MODELED seconds — every
    detector runs off the simulated clock, so alerting is deterministic
    for a given scenario + seed.
    """

    enabled: bool = True
    # -- multi-window SLO burn-rate alerting --------------------------------
    slo_target: float = 0.9  # attainment objective; budget = 1 - target
    fast_window_s: float = 5.0  # fast burn window (page needs BOTH)
    slow_window_s: float = 30.0  # slow burn window (ticket needs this)
    page_burn: float = 4.0  # burn rate that pages (fast AND slow exceed)
    ticket_burn: float = 2.0  # burn rate that tickets (slow window exceeds)
    tpot_budget_ms: float = 0.0  # per-token latency budget; 0 disables rule
    min_events: int = 4  # min outcomes in the fast window before evaluating
    # -- anomaly detection --------------------------------------------------
    anomaly_window: int = 16  # stall events per live composition window
    anomaly_threshold: float = 0.3  # TV distance on stall-cause shares
    link_window_s: float = 5.0  # link utilization / queue-delay window
    link_util_threshold: float = 1.5  # laid link-seconds per wall-second
    queue_delay_s: float = 0.5  # max transfer queue delay; 0 disables rule
    hysteresis: float = 0.5  # re-arm when signal <= hysteresis * threshold
    cooldown_s: float = 10.0  # min modeled seconds between same-key alerts
    # -- flight recorder / incident bundles ---------------------------------
    ring_events: int = 4096  # bounded ring of recent events (per model)
    max_incidents: int = 8  # incident bundles captured per run
    incident_dir: str = ""  # write bundles here ("" = in-memory only)


# ------------------------------------------------------------ speculation --
@dataclasses.dataclass(frozen=True)
class SpeculationSpec:
    """Speculative big-little execution knobs (``repro.spec_exec``).

    When set (and enabled), the store planner prices an always-resident
    ``shadow_format`` little copy per affordable expert into the VRAM
    spend, and the serving controller serves demand misses from those
    shadows under a verify-or-rollback loop gated at ``max_divergence``
    (relative-L2, measured at big-expert arrival).
    """

    enabled: bool = True
    shadow_format: str = "draft-int8"  # repro.store.formats.SHADOW_FORMATS
    max_divergence: float = 0.05  # accept bound; predictor gate threshold
    beta: float = 0.9  # divergence-EMA smoothing
    min_samples: int = 2  # per-expert evidence before its EMA speaks


# ------------------------------------------------------------- deployment --
_MODES = ("floe", "naive", "resident")
_POLICIES = ("slo", "static")
_RESIDENCY = ("lru", "lfu", "weighted")
_TRIGGERS = ("drift", "health")


@dataclasses.dataclass(frozen=True)
class DeploymentSpec:
    """One deployable model: model + resources + runtime (+ serving).

    Validation is EAGER: constructing an invalid spec raises
    :class:`SpecError` immediately (``from_json`` goes through the same
    constructor, so a bad JSON file fails at load time, not mid-build).
    """

    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    resources: ResourceSpec = dataclasses.field(default_factory=ResourceSpec)
    runtime: RuntimeSpec = dataclasses.field(default_factory=RuntimeSpec)
    serving: Optional[ServingSpec] = None
    replan: Optional[ReplanSpec] = None
    health: Optional[HealthSpec] = None
    speculation: Optional[SpeculationSpec] = None
    name: str = ""

    def __post_init__(self):
        self.validate()

    # ------------------------------------------------------------ labels --
    @property
    def label(self) -> str:
        return self.name or self.model.name or self.model.arch

    # -------------------------------------------------------- validation --
    def resolve_config(self):
        """The :class:`~repro.common.config.ModelConfig` this spec names
        (reduced when requested) — also the cross-field validation
        anchor: expert counts and the VRAM feasibility floor are
        properties of the resolved config, not of any one field."""
        from repro.common.config import reduced as reduce_cfg
        from repro.configs import get_config
        try:
            cfg = get_config(self.model.arch)
        except (ImportError, ModuleNotFoundError, KeyError) as e:
            raise SpecError("model.arch",
                            f"unknown architecture {self.model.arch!r} "
                            f"({e})") from e
        if self.model.reduced:
            cfg = reduce_cfg(cfg, layers=self.model.layers,
                             d_model=self.model.d_model,
                             max_experts=self.model.max_experts,
                             vocab=self.model.vocab)
        return cfg

    def validate(self) -> None:
        m, r, rt, sv = self.model, self.resources, self.runtime, self.serving
        # ---- per-field floors ------------------------------------------
        if m.reduced and m.layers < 1:
            raise SpecError("model.layers", f"need >= 1, got {m.layers}")
        if m.reduced and m.d_model < 8:
            raise SpecError("model.d_model", f"need >= 8, got {m.d_model}")
        if m.max_experts < 0:
            raise SpecError("model.max_experts",
                            f"need >= 0, got {m.max_experts}")
        if m.train_steps < 0:
            raise SpecError("model.train_steps",
                            f"need >= 0, got {m.train_steps}")
        if rt.mode not in _MODES:
            raise SpecError("runtime.mode",
                            f"unknown mode {rt.mode!r}; choose from {_MODES}")
        if rt.residency_policy not in _RESIDENCY:
            raise SpecError("runtime.residency_policy",
                            f"unknown policy {rt.residency_policy!r}; "
                            f"choose from {_RESIDENCY}")
        if rt.lookahead < 1:
            raise SpecError("runtime.lookahead",
                            f"need >= 1, got {rt.lookahead}")
        if rt.num_buffers < 1:
            raise SpecError("runtime.num_buffers",
                            f"need >= 1, got {rt.num_buffers}")
        if rt.cache_slots < 1:
            raise SpecError("runtime.cache_slots",
                            f"need >= 1, got {rt.cache_slots}")
        if r.devices < 1:
            raise SpecError("resources.devices",
                            f"need >= 1 device, got {r.devices}")
        if r.replicate < 0:
            raise SpecError("resources.replicate",
                            f"need >= 0, got {r.replicate}")
        if r.vram_gb < 0:
            raise SpecError("resources.vram_gb",
                            f"need >= 0, got {r.vram_gb}")
        if sv is not None:
            if sv.policy not in _POLICIES:
                raise SpecError("serving.policy",
                                f"unknown policy {sv.policy!r}; choose "
                                f"from {_POLICIES}")
            if sv.slots < 1:
                raise SpecError("serving.slots",
                                f"need >= 1 batch slot, got {sv.slots}")
            if sv.slo_ms <= 0:
                raise SpecError("serving.slo_ms",
                                f"need > 0, got {sv.slo_ms}")
            if sv.max_len < 1:
                raise SpecError("serving.max_len",
                                f"need >= 1, got {sv.max_len}")
            if sv.max_preemptions < 0:
                raise SpecError("serving.max_preemptions",
                                f"need >= 0, got {sv.max_preemptions}")
        rp = self.replan
        if rp is not None:
            if rp.window < 1:
                raise SpecError("replan.window",
                                f"need >= 1, got {rp.window}")
            if not 0.0 < rp.threshold <= 1.0:
                raise SpecError("replan.threshold",
                                f"need 0 < threshold <= 1 (TV distance), "
                                f"got {rp.threshold}")
            if not 0.0 <= rp.hysteresis <= 1.0:
                raise SpecError("replan.hysteresis",
                                f"need 0 <= hysteresis <= 1, "
                                f"got {rp.hysteresis}")
            if rp.cooldown_s < 0:
                raise SpecError("replan.cooldown_s",
                                f"need >= 0, got {rp.cooldown_s}")
            if rp.check_every < 1:
                raise SpecError("replan.check_every",
                                f"need >= 1, got {rp.check_every}")
            if not 0.0 < rp.bandwidth_share <= 1.0:
                raise SpecError("replan.bandwidth_share",
                                f"need 0 < share <= 1, "
                                f"got {rp.bandwidth_share}")
            if rp.trigger not in _TRIGGERS:
                raise SpecError("replan.trigger",
                                f"unknown trigger {rp.trigger!r}; choose "
                                f"from {_TRIGGERS}")
        hs = self.health
        if hs is not None:
            if not 0.0 < hs.slo_target < 1.0:
                raise SpecError("health.slo_target",
                                f"need 0 < target < 1, got {hs.slo_target}")
            if hs.fast_window_s <= 0:
                raise SpecError("health.fast_window_s",
                                f"need > 0, got {hs.fast_window_s}")
            if hs.slow_window_s <= hs.fast_window_s:
                raise SpecError("health.slow_window_s",
                                f"slow window must exceed the fast window "
                                f"({hs.fast_window_s}), got "
                                f"{hs.slow_window_s}")
            if hs.page_burn <= 0:
                raise SpecError("health.page_burn",
                                f"need > 0, got {hs.page_burn}")
            if not 0.0 < hs.ticket_burn <= hs.page_burn:
                raise SpecError("health.ticket_burn",
                                f"need 0 < ticket_burn <= page_burn "
                                f"({hs.page_burn}), got {hs.ticket_burn}")
            if hs.tpot_budget_ms < 0:
                raise SpecError("health.tpot_budget_ms",
                                f"need >= 0 (0 disables the TPOT rule), "
                                f"got {hs.tpot_budget_ms}")
            if hs.min_events < 1:
                raise SpecError("health.min_events",
                                f"need >= 1, got {hs.min_events}")
            if hs.anomaly_window < 2:
                raise SpecError("health.anomaly_window",
                                f"need >= 2, got {hs.anomaly_window}")
            if not 0.0 < hs.anomaly_threshold <= 1.0:
                raise SpecError("health.anomaly_threshold",
                                f"need 0 < threshold <= 1 (TV distance), "
                                f"got {hs.anomaly_threshold}")
            if hs.link_window_s <= 0:
                raise SpecError("health.link_window_s",
                                f"need > 0, got {hs.link_window_s}")
            if hs.link_util_threshold <= 0:
                raise SpecError("health.link_util_threshold",
                                f"need > 0, got {hs.link_util_threshold}")
            if hs.queue_delay_s < 0:
                raise SpecError("health.queue_delay_s",
                                f"need >= 0 (0 disables the rule), "
                                f"got {hs.queue_delay_s}")
            if not 0.0 <= hs.hysteresis <= 1.0:
                raise SpecError("health.hysteresis",
                                f"need 0 <= hysteresis <= 1, "
                                f"got {hs.hysteresis}")
            if hs.cooldown_s < 0:
                raise SpecError("health.cooldown_s",
                                f"need >= 0, got {hs.cooldown_s}")
            if hs.ring_events < 1:
                raise SpecError("health.ring_events",
                                f"need >= 1, got {hs.ring_events}")
            if hs.max_incidents < 0:
                raise SpecError("health.max_incidents",
                                f"need >= 0, got {hs.max_incidents}")
        sp = self.speculation
        if sp is not None:
            from repro.store.formats import SHADOW_FORMATS
            if sp.shadow_format not in SHADOW_FORMATS:
                raise SpecError(
                    "speculation.shadow_format",
                    f"unknown shadow format {sp.shadow_format!r}; choose "
                    f"from {tuple(SHADOW_FORMATS)}")
            if sp.max_divergence <= 0:
                raise SpecError("speculation.max_divergence",
                                f"need > 0, got {sp.max_divergence}")
            if not 0.0 < sp.beta < 1.0:
                raise SpecError("speculation.beta",
                                f"need 0 < beta < 1, got {sp.beta}")
            if sp.min_samples < 1:
                raise SpecError("speculation.min_samples",
                                f"need >= 1, got {sp.min_samples}")

        # ---- cross-field ----------------------------------------------
        offloaded = rt.mode == "floe" and rt.use_runtime
        if r.vram_gb > 0 and not offloaded:
            raise SpecError(
                "resources.vram_gb",
                "a tiered store needs runtime.mode='floe' and "
                "runtime.use_runtime=True")
        if r.vram_gb > 0 and r.host_gb <= 0:
            raise SpecError("resources.host_gb",
                            "the tiered store needs a positive host "
                            f"budget, got {r.host_gb}")
        if (r.devices > 1 or r.replicate > 0) and not offloaded:
            raise SpecError(
                "resources.devices",
                "a cluster needs runtime.mode='floe' and "
                "runtime.use_runtime=True")
        if sv is not None and not rt.use_runtime:
            raise SpecError("runtime.use_runtime",
                            "the serving controller requires the runtime "
                            "scheduler (use_runtime=True)")
        if rp is not None and rp.enabled:
            if r.vram_gb <= 0:
                raise SpecError("replan.enabled",
                                "live re-planning needs a tiered store "
                                "plan (resources.vram_gb > 0)")
            if sv is None:
                raise SpecError("replan.enabled",
                                "live re-planning runs inside the serving "
                                "controller (serving must be set)")
            if rp.trigger == "health" and not (hs is not None and hs.enabled):
                raise SpecError("replan.trigger",
                                "trigger='health' needs an enabled health "
                                "section to raise the page alerts")
        if hs is not None and hs.enabled and sv is None:
            raise SpecError("health.enabled",
                            "the health layer watches serving-plane events "
                            "(serving must be set)")
        if sp is not None and sp.enabled:
            if r.vram_gb <= 0:
                raise SpecError("speculation.enabled",
                                "speculative execution needs a tiered store "
                                "plan to price shadows (resources.vram_gb "
                                "> 0)")
            if sv is None:
                raise SpecError("speculation.enabled",
                                "speculative execution runs inside the "
                                "serving controller (serving must be set)")

        # ---- config-anchored (expert counts, feasibility floor) --------
        cfg = self.resolve_config()
        if cfg.num_experts and r.replicate >= cfg.num_experts:
            raise SpecError(
                "resources.replicate",
                f"replicate={r.replicate} must be < num_experts="
                f"{cfg.num_experts} (replicating every expert leaves "
                f"nothing to place)")
        if sv is not None and not cfg.num_experts:
            raise SpecError("serving.policy",
                            "the serving controller needs an MoE model; "
                            f"{self.model.arch!r} has no experts")
        if r.vram_gb > 0:
            if not cfg.num_experts:
                raise SpecError("resources.vram_gb",
                                "a tiered store needs an MoE model; "
                                f"{self.model.arch!r} has no experts")
            from repro.store import floor_bytes
            from repro.store.formats import FORMATS
            if r.ladder is not None:
                for fmt in r.ladder:
                    if fmt not in FORMATS:
                        raise SpecError(
                            "resources.ladder",
                            f"unknown format {fmt!r}; choose from "
                            f"{tuple(FORMATS)}")
            floor = floor_bytes(cfg, r.ladder)
            if int(r.vram_gb * 2 ** 30) < floor:
                raise SpecError(
                    "resources.vram_gb",
                    f"{r.vram_gb:.6f}GiB is below the feasibility floor "
                    f"{floor / 2 ** 30:.6f}GiB for {cfg.name} (leanest "
                    f"format + 1-slot arena)")

    # ---------------------------------------------------- JSON round-trip --
    def to_dict(self) -> dict:
        d = {
            "name": self.name,
            "model": dataclasses.asdict(self.model),
            "resources": dataclasses.asdict(self.resources),
            "runtime": dataclasses.asdict(self.runtime),
        }
        if self.resources.ladder is not None:  # tuples are not JSON-native
            d["resources"]["ladder"] = list(self.resources.ladder)
        if self.serving is not None:
            d["serving"] = dataclasses.asdict(self.serving)
        if self.replan is not None:
            d["replan"] = dataclasses.asdict(self.replan)
        if self.health is not None:
            d["health"] = dataclasses.asdict(self.health)
        if self.speculation is not None:
            d["speculation"] = dataclasses.asdict(self.speculation)
        return d

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent) + "\n"

    @classmethod
    def from_dict(cls, d: dict) -> "DeploymentSpec":
        known_sections = ("name", "model", "resources", "runtime",
                          "serving", "replan", "health", "speculation")
        bad_sections = sorted(set(d) - set(known_sections))
        if bad_sections:  # a typo'd section must not load as all-defaults
            raise SpecError(bad_sections[0],
                            f"unknown section(s) {bad_sections}; expected "
                            f"{known_sections}")

        def sub(klass, key):
            payload = dict(d.get(key) or {})
            known = {f.name for f in dataclasses.fields(klass)}
            bad = sorted(set(payload) - known)
            if bad:
                raise SpecError(f"{key}.{bad[0]}",
                                f"unknown field(s) {bad} for {klass.__name__}")
            return klass(**payload)

        res = sub(ResourceSpec, "resources")
        if res.ladder is not None:
            res = dataclasses.replace(res, ladder=tuple(res.ladder))
        return cls(
            model=sub(ModelSpec, "model"),
            resources=res,
            runtime=sub(RuntimeSpec, "runtime"),
            # an explicit "serving": null means NO serving plane
            serving=(sub(ServingSpec, "serving")
                     if d.get("serving") is not None else None),
            replan=(sub(ReplanSpec, "replan")
                    if d.get("replan") is not None else None),
            health=(sub(HealthSpec, "health")
                    if d.get("health") is not None else None),
            speculation=(sub(SpeculationSpec, "speculation")
                         if d.get("speculation") is not None else None),
            name=d.get("name", ""),
        )

    @classmethod
    def from_json(cls, text: str) -> "DeploymentSpec":
        try:
            d = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError("<json>", f"not valid JSON: {e}") from e
        if not isinstance(d, dict):
            raise SpecError("<json>", "spec JSON must be an object")
        return cls.from_dict(d)
