"""``build(spec)`` — the one engine-build path from spec to live system.

Resolution order (each step consumes only the typed spec):

  1. model    — config (+reduction), params (init / brief train / ckpt),
                threshold calibration, activation frequencies
  2. plans    — ``plan_store`` (single device) or ``plan_cluster``
                (devices > 1 / replication); ``PlanError`` surfaces as a
                ``SpecError`` naming ``resources.vram_gb``
  3. system   — ``FloEPipeline`` (and a ``ServingController`` when the
                spec carries a ``ServingSpec``), constructed through the
                SAME kwargs shims the legacy call sites use, so a
                spec-built system is bitwise-identical to a hand-wired
                one (pinned by test)

The result is a :class:`Deployment` session object: ``generate()`` for
single-stream decode, ``serve()`` for the SLO control plane, and one
``report()`` merging pipeline / store / cluster / controller telemetry.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.deploy.spec import DeploymentSpec, ModelSpec, SpecError


# ------------------------------------------------------------- resolution --
def resolve_params(m: ModelSpec, cfg) -> dict:
    """Model parameters per the spec: checkpoint > brief train > init."""
    import jax
    import jax.numpy as jnp

    if m.ckpt:
        from repro.checkpoint import load_checkpoint
        return load_checkpoint(m.ckpt)
    if m.train_steps > 0:
        from repro.common.config import TrainConfig
        from repro.launch.train import train_loop
        tc = TrainConfig(learning_rate=2e-3, total_steps=m.train_steps,
                         warmup_steps=max(m.train_steps // 10, 1))
        params, _, _ = train_loop(cfg, tc, batch=8, seq=64,
                                  steps=m.train_steps, log_every=10 ** 9)
        return params
    from repro.models import transformer as tf
    return tf.init_model(jax.random.PRNGKey(m.seed), cfg, jnp.float32)


def calibrate_thresholds(layers: List[dict], cfg, *, samples: int = 128,
                         seed: int = 9, scale: float = 0.5) -> np.ndarray:
    """(L, E) sparsification thresholds from routing calibration states
    (the loop every launcher used to inline)."""
    import jax
    import jax.numpy as jnp

    from repro.core import sparsify

    xcal = jax.random.normal(jax.random.PRNGKey(seed),
                             (samples, cfg.d_model)) * scale
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    return thr


def plan_resources(spec: DeploymentSpec, cfg, layers: List[dict], *,
                   freqs: Optional[np.ndarray] = None):
    """(plan, freqs) for the spec's ResourceSpec: a ``ClusterPlan`` when
    devices > 1 or replication is requested, a ``StorePlan`` for a
    single-device VRAM budget, ``None`` for the flat in-host store."""
    r = spec.resources
    clustered = r.devices > 1 or r.replicate > 0
    if not clustered and r.vram_gb <= 0:
        return None, freqs
    # speculation prices always-resident shadows into the planner spend
    sp = spec.speculation
    shadows = sp.shadow_format if sp is not None and sp.enabled else None
    from repro.store import measure_frequencies
    if freqs is None:
        freqs = measure_frequencies(layers, cfg)
    try:
        if clustered:
            from repro.cluster import plan_cluster, uniform_cluster_plan
            if r.vram_gb > 0:
                plan = plan_cluster(
                    cfg, freqs, n_devices=r.devices,
                    vram_gb_per_device=r.vram_gb, host_gb=r.host_gb,
                    replicate=r.replicate, max_slots=r.max_slots,
                    max_pinned_per_device=r.max_pinned, ladder=r.ladder,
                    progressive=r.progressive, shadows=shadows)
            else:
                plan = uniform_cluster_plan(cfg, r.devices, freqs=freqs,
                                            replicate=r.replicate)
        else:
            from repro.store import plan_store
            plan = plan_store(cfg, freqs, vram_gb=r.vram_gb,
                              host_gb=r.host_gb, max_slots=r.max_slots,
                              max_pinned=r.max_pinned, ladder=r.ladder,
                              progressive=r.progressive, shadows=shadows)
    except Exception as e:
        from repro.store import PlanError
        if isinstance(e, PlanError):
            raise SpecError("resources.vram_gb", str(e)) from e
        raise
    return plan, freqs


def pipeline_opts(spec: DeploymentSpec, plan, freqs) -> dict:
    """The FloEPipeline kwargs a spec resolves to (plan wiring + the
    typed RuntimeSpec — nothing tunnels through untyped dicts)."""
    opts: dict = dict(runtime_spec=spec.runtime)
    if plan is None:
        return opts
    from repro.cluster import ClusterPlan
    store_dir = spec.resources.store_dir or None
    if isinstance(plan, ClusterPlan):
        opts.update(cluster_plan=plan)
        if plan.store_plan is not None:
            opts.update(store_freqs=freqs, store_dir=store_dir)
    else:
        opts.update(store_plan=plan, store_freqs=freqs, store_dir=store_dir)
    return opts


# -------------------------------------------------------------- the build --
def build(spec: DeploymentSpec, *,
          params: Optional[dict] = None,
          thresholds: Optional[np.ndarray] = None,
          freqs: Optional[np.ndarray] = None,
          device=None, link=None,
          inter_predictors: Optional[list] = None,
          paper_scale: bool = True,
          engine=None, layer_stores=None, plan=None) -> "Deployment":
    """Resolve a :class:`DeploymentSpec` into a live :class:`Deployment`.

    ``params`` / ``thresholds`` / ``freqs`` injection lets callers that
    already hold model state (parity tests, the fleet builder, trained
    checkpoints in memory) skip re-resolution; everything else follows
    the spec.  ``paper_scale=True`` uses the paper-ratio device/link
    models (the launcher default) unless explicit models are passed.
    """
    from repro.core.pipeline import (FloEPipeline, _unstack_layers,
                                     paper_scaled_models)

    spec.validate()
    cfg = spec.resolve_config()
    if params is None:
        params = resolve_params(spec.model, cfg)
    layers = _unstack_layers(params, cfg)
    if thresholds is None:
        thresholds = calibrate_thresholds(layers, cfg)
    if paper_scale and (device is None or link is None):
        pdev, plink = paper_scaled_models(cfg)
        device = device if device is not None else pdev
        link = link if link is not None else plink
    if plan is None:
        plan, freqs = plan_resources(spec, cfg, layers, freqs=freqs)

    opts = pipeline_opts(spec, plan, freqs)
    if engine is not None:
        opts.update(engine=engine)
    if layer_stores is not None:
        opts.update(layer_stores=layer_stores)
    if inter_predictors is not None:
        opts.update(inter_predictors=inter_predictors)

    controller = None
    if spec.serving is not None:
        from repro.serving import ServingController
        # the controller owns batching and cross-token speculation: its
        # pipeline always runs the scheduler with union demands and
        # pipeline-side cross-token OFF (exactly what the kwargs shim
        # defaults to), regardless of the single-stream RuntimeSpec
        opts["runtime_spec"] = dataclasses.replace(
            spec.runtime, use_runtime=True, batched_demand=True,
            cross_token=False)
        controller = ServingController(
            params, cfg, thresholds=thresholds,
            serving_spec=spec.serving,
            offload_opts=dict(device=device, link=link, **opts))
        pipeline = controller.pipe
    else:
        pipeline = FloEPipeline(params, cfg, thresholds=thresholds,
                                device=device, link=link, **opts)
    return Deployment(spec=spec, cfg=cfg, params=params,
                      thresholds=thresholds, freqs=freqs, plan=plan,
                      pipeline=pipeline, controller=controller)


# -------------------------------------------------------------- the session -
@dataclasses.dataclass
class Deployment:
    """A resolved deployment: one model wired through its plans."""

    spec: DeploymentSpec
    cfg: object
    params: dict
    thresholds: np.ndarray
    freqs: Optional[np.ndarray]
    plan: object  # StorePlan | ClusterPlan | None
    pipeline: object  # FloEPipeline
    controller: object = None  # ServingController | None
    _replanner: object = None  # repro.replan.Replanner once attached
    _replan_ledger: object = None  # fleet hook: (new_plan) -> None | raise
    _health: object = None  # repro.obs.health.HealthMonitor once attached
    _speculator: object = None  # repro.spec_exec.SpeculativeExecutor

    @property
    def name(self) -> str:
        return self.spec.label

    # ------------------------------------------------------------ decode --
    def h_stream(self, tokens: int, batch: int = 1, seed: int = 100,
                 alpha: Optional[float] = None) -> list:
        """A deterministic hidden-state stream for offloaded decode:
        independent draws (the launcher's historical inputs) or an
        AR(1) stream when ``alpha`` is given (temporally-correlated
        routing, the benches' regime)."""
        import jax
        import jax.numpy as jnp
        if alpha is None:
            return [jax.random.normal(jax.random.PRNGKey(seed + i),
                                      (batch, self.cfg.d_model),
                                      jnp.float32) * 0.3
                    for i in range(tokens)]
        key = jax.random.PRNGKey(seed)
        h = jax.random.normal(key, (batch, self.cfg.d_model), jnp.float32)
        out = [h]
        for _ in range(tokens - 1):
            key, sub = jax.random.split(key)
            n = jax.random.normal(sub, (batch, self.cfg.d_model),
                                  jnp.float32)
            h = alpha * h + (1.0 - alpha ** 2) ** 0.5 * n
            out.append(h)
        return out

    def generate(self, tokens: int = 8, *, batch: int = 1, seed: int = 100,
                 h_stream: Optional[list] = None) -> list:
        """Decode ``tokens`` steps through the pipeline; returns the
        per-step metrics (also appended to ``pipeline.metrics``)."""
        if h_stream is None:
            h_stream = self.h_stream(tokens, batch, seed)
        out = []
        for h in h_stream:
            _, m = self.pipeline.decode_token(h)
            out.append(m)
        return out

    # ----------------------------------------------------------- serving --
    _uid_seq: int = 0  # next uid for synthesized/scenario requests

    # ------------------------------------------------------------ replan --
    def _plan_fn(self):
        """Planner re-run closure with this spec's own resource knobs
        (what a drift trigger feeds the live frequency window to)."""
        from repro.cluster import ClusterPlan, plan_cluster
        from repro.store import plan_store
        r, cfg = self.spec.resources, self.cfg
        sp = self.spec.speculation
        shadows = (sp.shadow_format
                   if sp is not None and sp.enabled else None)
        if isinstance(self.plan, ClusterPlan):
            return lambda freqs: plan_cluster(
                cfg, freqs, n_devices=r.devices,
                vram_gb_per_device=r.vram_gb, host_gb=r.host_gb,
                replicate=r.replicate, max_slots=r.max_slots,
                max_pinned_per_device=r.max_pinned, ladder=r.ladder,
                progressive=r.progressive, shadows=shadows)
        return lambda freqs: plan_store(
            cfg, freqs, vram_gb=r.vram_gb, host_gb=r.host_gb,
            max_slots=r.max_slots, max_pinned=r.max_pinned,
            ladder=r.ladder, progressive=r.progressive, shadows=shadows)

    def _attach_replan(self, rp) -> object:
        """Build (once) and attach the live re-planner to the serving
        controller.  ``rp`` is a validated ``ReplanSpec``."""
        if self.plan is None or self.spec.resources.vram_gb <= 0:
            raise SpecError("replan",
                            "live re-planning needs a planner-solved "
                            "deployment (resources.vram_gb > 0)")
        if self._replanner is None:
            from repro.replan import Replanner
            reference = self.freqs
            if reference is None:  # injected plan without measured freqs
                reference = np.full(
                    (self.cfg.num_layers, self.cfg.num_experts),
                    1.0 / max(self.cfg.num_experts, 1))
            trigger = getattr(rp, "trigger", "drift")
            if trigger == "health" and self._health is None:
                raise SpecError("replan.trigger",
                                "trigger='health' needs the health layer "
                                "attached (health section enabled)")
            self._replanner = Replanner(
                self.controller.pipe.sched, self.plan, reference,
                self._plan_fn(), window=rp.window,
                threshold=rp.threshold, hysteresis=rp.hysteresis,
                cooldown_s=rp.cooldown_s, check_every=rp.check_every,
                bandwidth_share=rp.bandwidth_share,
                ledger=self._replan_ledger, trigger=trigger,
                health=self._health if trigger == "health" else None)
        self.controller.replan = self._replanner
        return self._replanner

    # ------------------------------------------------------- speculation --
    def _attach_speculate(self, sp) -> object:
        """Build (once) and attach the speculative big-little executor.
        ``sp`` is a validated ``SpeculationSpec``; shadows must have been
        priced into the plan at BUILD time (the bank decodes
        ``plan.shadows``), so only divergence knobs can change here."""
        base = self.spec.speculation
        if base is None:
            raise SpecError(
                "speculation",
                "shadows are priced at plan time: build the deployment "
                "with a speculation section before serve(speculate=...)")
        if sp.shadow_format != base.shadow_format:
            raise SpecError(
                "speculation.shadow_format",
                f"built with {base.shadow_format!r}; the resident shadow "
                f"bank cannot switch to {sp.shadow_format!r} at serve "
                f"time")
        if self._speculator is None:
            from repro.cluster import ClusterPlan
            from repro.core.pipeline import _unstack_layers
            from repro.spec_exec import (SpeculativeExecutor,
                                         build_shadow_bank)
            plan = self.plan
            if isinstance(plan, ClusterPlan):
                plan = plan.store_plan
            layers = _unstack_layers(self.params, self.cfg)
            bank = build_shadow_bank(layers, plan)
            self._speculator = SpeculativeExecutor(
                bank, max_divergence=sp.max_divergence, beta=sp.beta,
                min_samples=sp.min_samples)
        else:
            self._speculator.reconfigure(max_divergence=sp.max_divergence)
        self._speculator.enabled = True
        self._speculator.attach(self.controller)
        return self._speculator

    # ------------------------------------------------------------ health --
    def _attach_health(self, hs) -> object:
        """Build (once) the live health monitor for this deployment.
        ``hs`` is a validated ``HealthSpec``; the monitor is attached to
        the bus only for the duration of each ``serve()`` call."""
        if self._health is None:
            from repro.obs.health import HealthMonitor
            # filter by this deployment's label so per-member monitors
            # coexist on the shared bus under fleet scoping (unscoped
            # standalone events carry model="" and are always accepted)
            self._health = HealthMonitor(hs, model=self.name)
        return self._health

    def serve(self, requests: Optional[list] = None, *,
              scenario=None, n_requests: int = 4, rate: float = 2.0,
              max_new: int = 16, prompt_len: int = 8, seed: int = 0,
              replan=None, health=None, speculate=None) -> list:
        """Run the SLO control plane over one of three request sources:
        explicit ``SLORequest``s, a ``repro.workload`` scenario (a
        :class:`~repro.workload.ScenarioSpec` or a path to its JSON),
        or a Poisson arrival stream synthesized from the spec's
        defaults.  Synthesized/scenario uids are allocated from a
        per-deployment sequence so repeated ``serve()`` calls never
        collide (the controller rejects duplicate uids), and their
        arrival times are offsets rebased onto the controller's
        current clock — a later ``serve()`` (or a fleet sibling having
        advanced the lockstep clock) must not make every deadline
        pre-expired.  Explicit ``requests`` keep their absolute
        times."""
        if self.controller is None:
            raise SpecError("serving",
                            f"deployment {self.name!r} has no ServingSpec")
        # ``replan`` / ``health`` resolve alike: None -> the spec's
        # section; True -> the spec's section or all-defaults; False ->
        # off for this call; a spec instance -> exactly those knobs.
        # Health resolves FIRST so a trigger='health' replanner finds
        # its monitor.
        from repro.deploy.spec import (HealthSpec, ReplanSpec,
                                       SpeculationSpec)
        hl = health
        if hl is None:
            hl = self.spec.health
        elif hl is True:
            hl = self.spec.health or HealthSpec()
        elif hl is False:
            hl = None
        monitor = None
        if hl is not None and hl.enabled:
            monitor = self._attach_health(hl)
        rp = replan
        if rp is None:
            rp = self.spec.replan
        elif rp is True:
            rp = self.spec.replan or ReplanSpec()
        elif rp is False:
            rp = None
        if rp is not None and rp.enabled:
            self._attach_replan(rp)
        else:
            self.controller.replan = None
        # ``speculate`` resolves the same way; the shadow bank itself is
        # immutable after build (planner-priced), only on/off + knobs
        sp = speculate
        if sp is None:
            sp = self.spec.speculation
        elif sp is True:
            sp = self.spec.speculation or SpeculationSpec()
        elif sp is False:
            sp = None
        if sp is not None and sp.enabled:
            self._attach_speculate(sp)
        else:
            self.controller.speculator = None
        if scenario is not None and requests is not None:
            raise SpecError("serving",
                            "pass either requests or scenario, not both")
        from repro.serving import SLORequest
        t0 = self.controller.sched.clock
        if scenario is not None:
            from repro.workload import ScenarioSpec, generate_requests
            if not isinstance(scenario, ScenarioSpec):
                scenario = ScenarioSpec.load(scenario)
            requests = generate_requests(scenario, self.cfg.vocab_size,
                                         uid_base=self._uid_seq)
            self._uid_seq += len(requests)
            for r in requests:
                r.arrival_t += t0
            if monitor is not None:  # replayable incident-bundle slice
                monitor.bind_scenario(scenario, requests)
        elif requests is None:
            rng = np.random.default_rng(seed)
            slo_ms = self.spec.serving.slo_ms
            t, requests = t0, []
            for _ in range(n_requests):
                t += float(rng.exponential(1.0 / max(rate, 1e-6)))
                requests.append(SLORequest(
                    self._uid_seq,
                    rng.integers(0, self.cfg.vocab_size,
                                 prompt_len).astype(np.int32),
                    max_new_tokens=max_new, slo_ms=slo_ms, arrival_t=t))
                self._uid_seq += 1
        for r in requests:
            self.controller.submit(r)
        if monitor is None:
            return self.controller.run()
        from repro import obs
        with obs.consumer(monitor):  # live only while this serve runs
            return self.controller.run()

    # --------------------------------------------------------- telemetry --
    def report(self) -> dict:
        """One merged report: decode throughput + store / cluster /
        controller telemetry, whichever subsystems this spec lit up."""
        pipe = self.pipeline
        rep: dict = {
            "name": self.name,
            "mode": self.spec.runtime.mode,
            "tokens_per_s": pipe.tokens_per_second(),
            "decode_steps": len(pipe.metrics),
            "stall_s": sum(m.stall_s for m in pipe.metrics),
            "coverage": (float(np.mean([m.coverage for m in pipe.metrics]))
                         if pipe.metrics else 1.0),
        }
        if self.plan is not None:
            rep["plan"] = self.plan.summary()
        if pipe.sched is not None:
            s = pipe.sched.stats
            rep.update(demand_fetches=s.demand_fetches,
                       demand_topups=s.demand_topups,
                       draft_fetches=s.draft_fetches,
                       refines_applied=s.refines_applied,
                       prefetch_recall=pipe.sched.prefetch_recall())
        if pipe.host_tier is not None:
            rep.update(host_hit_rate=pipe.host_tier.stats.hit_rate,
                       host_bytes=pipe.host_tier.bytes_in_use,
                       disk_reads=pipe.host_tier.disk.stats.reads
                       if pipe.host_tier.disk is not None else 0)
        pools = pipe.device_pools or (
            [pipe.device_pool] if pipe.device_pool is not None else [])
        if pools:
            rep["pool_free_slabs"] = [p.free_slabs for p in pools]
        if pipe.cluster_plan is not None:
            rep.update(
                devices=pipe.cluster_plan.n_devices,
                agg_link_utilization=pipe.engine.aggregate_utilization(
                    pipe.sched.clock),
                replica_routed=pipe.sched.selector.replica_choices)
        if self.controller is not None:
            rep["serving"] = self.controller.report()
        if self._replanner is not None:
            rep["replan"] = self._replanner.report()
        if self._health is not None:
            rep["health"] = self._health.report()
        if self._speculator is not None:
            rep["speculation"] = {
                **self._speculator.report(),
                "divergence": self._speculator.divergence.snapshot()}
        rep["metrics"] = self.metrics_snapshot()
        return rep

    def metrics_snapshot(self) -> dict:
        """Deterministic flat metrics snapshot (``repro.obs`` registry)
        for this deployment: the controller's full serving snapshot when
        a control plane exists, else the scheduler-level view (stall
        attribution with the conservation check, prefetch quality,
        per-expert activation frequencies)."""
        if self.controller is not None:
            return self.controller.metrics_snapshot()
        if self.pipeline.sched is None:
            return {}
        from repro.obs.metrics import MetricsRegistry, scheduler_metrics
        return scheduler_metrics(MetricsRegistry(),
                                 self.pipeline.sched).snapshot()
