"""Multi-model serving over shared tiers (the ROADMAP open item).

``build_fleet([specs])`` resolves several :class:`DeploymentSpec`s over
ONE memory hierarchy:

  * **one shared HostTier / DiskTier** — every model's expert records
    live in the same sharded checkpoint and the same byte-budget LRU
    host cache, scoped by per-model key prefixes; host warming ranks
    ALL models' experts in one global temperature order.
  * **disjoint per-device arenas** — each admitted model carves its own
    ``DevicePool`` slab arenas (one per device) out of the device
    budget; arenas never overlap, so one model's residency churn cannot
    fragment another's.
  * **footprint-aware admission** — a model is admitted iff its plan's
    per-device footprints (non-expert weights + resident ups + arena)
    AND its host share fit what previous admissions left; a model whose
    plan cannot fit raises a typed :class:`AdmissionError` naming it.
  * **one link per device, arbitrated** — all models share one
    ``ClusterEngine`` (per-device ``TransferEngine`` timelines), so
    their traffic genuinely contends per link and each model's
    ``LinkSelector`` routes replicas around the OTHER models' transfers
    too.  Model clocks run lockstep (synced around every operation).
  * **idle-model pinned-set eviction** — ``suspend(name)`` drops an
    idle model's pinned staged slices (its strongest VRAM claim) and
    credits the freed arena bytes back to the ledger; ``resume(name)``
    re-admits and re-stages them, failing with ``AdmissionError`` when
    the headroom has since been spent.
"""
from __future__ import annotations

import dataclasses
import tempfile
from typing import Dict, List, Optional, Sequence

from repro import obs
from repro.deploy.spec import DeploymentSpec, SpecError


class AdmissionError(SpecError):
    """A model's plan does not fit the fleet's remaining footprint."""


@dataclasses.dataclass
class FleetMember:
    """One admitted model: its deployment plus the fleet's ledger view."""

    name: str
    spec: DeploymentSpec
    deployment: object  # repro.deploy.Deployment
    plan: object  # ClusterPlan
    device_bytes: List[int]  # per-device footprint committed at admission
    host_share: int  # host bytes promised at admission
    pinned_bytes: List[int] = dataclasses.field(default_factory=list)
    active: bool = True


def _member_host_share(plan, cfg, spec: DeploymentSpec) -> int:
    """The host bytes a member's admission promises: its full record
    set, capped at its own requested host budget."""
    from repro.store import formats as F
    total = sum(
        F.host_bytes(F.get_format(name), cfg.d_model, cfg.moe_d_ff)
        for name in plan.store_plan.formats.values())
    return min(total, int(spec.resources.host_gb * 2 ** 30))


class Fleet:
    """Several deployments over one shared memory hierarchy."""

    def __init__(self, *, n_devices: int, vram_gb_per_device: float,
                 host, store_dir: str, engine, link):
        self.n_devices = n_devices
        self.capacity_per_device = int(vram_gb_per_device * 2 ** 30)
        self.host = host  # shared HostTier (disk attached)
        self.store_dir = store_dir
        self.engine = engine  # shared ClusterEngine
        self.link = link
        self.committed: List[int] = [0] * n_devices
        self.committed_host = 0
        self.admitted: List[str] = []  # admission order (ledger holders)
        self.members: Dict[str, FleetMember] = {}

    # ----------------------------------------------------------- ledger ---
    def headroom_bytes(self, d: int) -> int:
        return self.capacity_per_device - self.committed[d]

    def host_headroom_bytes(self) -> int:
        return self.host.capacity_bytes - self.committed_host

    def admit(self, name: str, plan, cfg, spec: DeploymentSpec) -> int:
        """Commit a model's footprint to the ledger, or raise a typed
        :class:`AdmissionError` naming the model and the tight device."""
        host_share = _member_host_share(plan, cfg, spec)
        for d in range(self.n_devices):
            need = plan.footprint_bytes(d)
            if need > self.headroom_bytes(d):
                raise AdmissionError(
                    f"fleet.{name}",
                    f"device {d} footprint {need / 2 ** 30:.4f}GiB exceeds "
                    f"remaining {self.headroom_bytes(d) / 2 ** 30:.4f}GiB "
                    f"of {self.capacity_per_device / 2 ** 30:.4f}GiB "
                    f"(committed by: {self.admitted})")
        if host_share > self.host_headroom_bytes():
            raise AdmissionError(
                f"fleet.{name}",
                f"host share {host_share / 2 ** 30:.4f}GiB exceeds "
                f"remaining "
                f"{self.host_headroom_bytes() / 2 ** 30:.4f}GiB of the "
                f"shared host tier")
        for d in range(self.n_devices):
            self.committed[d] += plan.footprint_bytes(d)
        self.committed_host += host_share
        self.admitted.append(name)
        return host_share

    # ------------------------------------------------------------ clocks --
    def _sync_clocks(self) -> None:
        """Bring every member's per-device schedulers forward to the
        fleet-wide max clock — in-flight transfers of models that were
        not decoding keep completing on the shared link timelines."""
        scheds = [m.deployment.pipeline.sched for m in self.members.values()]
        if not scheds:
            return
        t = max(s.clock for s in scheds)
        for s in scheds:
            if s.clock < t:
                s.advance(t - s.clock)

    # -------------------------------------------------------- operations --
    def __getitem__(self, name: str) -> FleetMember:
        return self.members[name]

    def generate(self, name: str, tokens: int = 4, *, batch: int = 1,
                 seed: int = 100, h_stream: Optional[list] = None) -> list:
        m = self.members[name]
        if not m.active:
            raise SpecError(f"fleet.{name}",
                            "model is suspended; resume() it first")
        self._sync_clocks()
        with obs.scope(name):  # events from this model's decode carry it
            out = m.deployment.generate(tokens, batch=batch, seed=seed,
                                        h_stream=h_stream)
        self._sync_clocks()
        return out

    def serve(self, name: str, requests: Optional[list] = None, **kw):
        m = self.members[name]
        if not m.active:
            raise SpecError(f"fleet.{name}",
                            "model is suspended; resume() it first")
        self._sync_clocks()
        with obs.scope(name):
            out = m.deployment.serve(requests, **kw)
        self._sync_clocks()
        return out

    def recommit(self, name: str, new_plan) -> None:
        """Move a member's ledger commitment to a re-planned footprint.

        The live re-planner calls this before migrating: each device's
        delta (new footprint minus the member's current commitment) must
        fit that device's headroom or the whole re-plan is denied with a
        typed :class:`AdmissionError` — the ledger either moves atomically
        or not at all, so a denied re-plan leaves the fleet untouched."""
        m = self.members[name]
        deltas = [new_plan.footprint_bytes(d) - m.device_bytes[d]
                  for d in range(self.n_devices)]
        for d, delta in enumerate(deltas):
            if delta > self.headroom_bytes(d):
                raise AdmissionError(
                    f"fleet.{name}",
                    f"re-plan needs {delta / 2 ** 30:+.4f}GiB on device "
                    f"{d}, only {self.headroom_bytes(d) / 2 ** 30:.4f}GiB "
                    f"headroom left (committed by: {self.admitted})")
        for d, delta in enumerate(deltas):
            self.committed[d] += delta
            m.device_bytes[d] += delta
        m.plan = new_plan
        if obs.enabled():
            sched = m.deployment.pipeline.sched
            obs.emit("fleet.recommit", sched.clock if sched else 0.0,
                     cat="fleet",
                     args={"model": name,
                           "delta_bytes_per_device": deltas})

    # ------------------------------------------- idle pinned-set eviction --
    def suspend(self, name: str) -> int:
        """Evict an idle model's pinned staged slices and credit the
        freed arena bytes back to the ledger.  Returns bytes freed."""
        m = self.members[name]
        if not m.active:
            return 0
        pipe = m.deployment.pipeline
        m.pinned_bytes = []
        for d in range(self.n_devices):
            pool = pipe.device_pools[d]
            before = pool.free_slabs
            for (li, e) in m.plan.pinned_per_device[d]:
                pipe.cluster_residency[d][li].drop((li, e))
            freed = (pool.free_slabs - before) * pool.slab_bytes
            m.pinned_bytes.append(freed)
            self.committed[d] -= freed
        m.active = False
        if obs.enabled():
            obs.emit("fleet.suspend", pipe.sched.clock, cat="fleet",
                     args={"model": name,
                           "freed_bytes": sum(m.pinned_bytes)})
        return sum(m.pinned_bytes)

    def resume(self, name: str) -> None:
        """Re-admit a suspended model's pinned set (AdmissionError when
        the headroom has since been spent) and re-stage it at the
        current clock."""
        m = self.members[name]
        if m.active:
            return
        for d in range(self.n_devices):
            if m.pinned_bytes[d] > self.headroom_bytes(d):
                raise AdmissionError(
                    f"fleet.{name}",
                    f"cannot resume: pinned set needs "
                    f"{m.pinned_bytes[d] / 2 ** 30:.4f}GiB on device {d}, "
                    f"only {self.headroom_bytes(d) / 2 ** 30:.4f}GiB left")
        for d in range(self.n_devices):
            self.committed[d] += m.pinned_bytes[d]
        with obs.scope(name):
            m.deployment.pipeline._stage_pinned_cluster()
        m.pinned_bytes = []
        m.active = True
        if obs.enabled():
            obs.emit("fleet.resume", m.deployment.pipeline.sched.clock,
                     cat="fleet", args={"model": name})

    # --------------------------------------------------------- telemetry --
    def report(self) -> dict:
        eng = self.engine.summary()
        return {
            "models": {n: dict(m.deployment.report(), active=m.active,
                               host_share_bytes=m.host_share,
                               host_resident_bytes=self.host.bytes_for_prefix(
                                   f"{n}/"))
                       for n, m in self.members.items()},
            "devices": self.n_devices,
            "committed_bytes_per_device": list(self.committed),
            "capacity_bytes_per_device": self.capacity_per_device,
            "host_bytes_in_use": self.host.bytes_in_use,
            "host_capacity_bytes": self.host.capacity_bytes,
            "host_hit_rate": self.host.stats.hit_rate,
            "disk_reads": (self.host.disk.stats.reads
                           if self.host.disk is not None else 0),
            "link_busy_s_per_device": eng["busy_s_per_device"],
        }


def build_fleet(specs: Sequence[DeploymentSpec], *,
                vram_gb_per_device: float,
                host_gb: float,
                store_dir: Optional[str] = None,
                device=None, link=None,
                params: Optional[Sequence[dict]] = None,
                thresholds: Optional[Sequence] = None,
                freqs: Optional[Sequence] = None) -> Fleet:
    """Resolve several specs into one :class:`Fleet` over shared tiers.

    Every member needs a tiered store (``resources.vram_gb > 0``) and
    the same ``resources.devices``; admission runs in list order, so the
    first model that cannot fit raises :class:`AdmissionError` before
    any heavy build work happens for it.
    """
    from repro.cluster import ClusterEngine, plan_cluster
    from repro.checkpoint.io import ShardWriter
    from repro.core.pipeline import _unstack_layers, paper_scaled_models
    from repro.deploy.builder import (build, calibrate_thresholds,
                                      resolve_params)
    from repro.store import DiskTier, HostTier, build_layer_stores
    from repro.store.planner import measure_frequencies
    from repro.store.tiered import warm_host_tier

    if not specs:
        raise SpecError("fleet", "need at least one DeploymentSpec")
    n_devices = specs[0].resources.devices
    names: List[str] = []
    for i, spec in enumerate(specs):
        if spec.resources.vram_gb <= 0:
            raise SpecError(f"fleet.{spec.label}.resources.vram_gb",
                            "fleet members need a tiered store "
                            "(vram_gb > 0)")
        if spec.resources.devices != n_devices:
            raise SpecError(f"fleet.{spec.label}.resources.devices",
                            f"all members must agree on devices; got "
                            f"{spec.resources.devices} vs {n_devices}")
        name = spec.label
        if name in names:
            raise SpecError(f"fleet.{name}.name",
                            "duplicate model label; set distinct "
                            "spec.name / model.name values")
        names.append(name)

    if store_dir is None:
        store_dir = tempfile.mkdtemp(prefix="floe-fleet-")
    if link is None:
        _, link = paper_scaled_models(specs[0].resolve_config())
    num_buffers = max(s.runtime.num_buffers for s in specs)
    host = HostTier(int(host_gb * 2 ** 30))
    engine = ClusterEngine(link, n_devices=n_devices,
                           num_buffers=num_buffers)
    fleet = Fleet(n_devices=n_devices,
                  vram_gb_per_device=vram_gb_per_device, host=host,
                  store_dir=store_dir, engine=engine, link=link)

    # ---- resolve + plan + ADMIT everything before heavy store builds -----
    resolved = []
    for i, spec in enumerate(specs):
        cfg = spec.resolve_config()
        p = params[i] if params is not None else resolve_params(spec.model,
                                                                cfg)
        layers = _unstack_layers(p, cfg)
        thr = (thresholds[i] if thresholds is not None
               else calibrate_thresholds(layers, cfg))
        fq = (freqs[i] if freqs is not None
              else measure_frequencies(layers, cfg))
        r = spec.resources
        sp = spec.speculation
        try:
            plan = plan_cluster(
                cfg, fq, n_devices=n_devices,
                vram_gb_per_device=r.vram_gb, host_gb=r.host_gb,
                replicate=r.replicate, max_slots=r.max_slots,
                max_pinned_per_device=r.max_pinned, ladder=r.ladder,
                progressive=r.progressive,
                shadows=(sp.shadow_format
                         if sp is not None and sp.enabled else None))
        except Exception as e:
            from repro.store import PlanError
            if isinstance(e, PlanError):
                raise SpecError(f"fleet.{names[i]}.resources.vram_gb",
                                str(e)) from e
            raise
        host_share = fleet.admit(names[i], plan, cfg, spec)
        resolved.append((names[i], spec, cfg, p, layers, thr, fq, plan,
                         host_share))

    # ---- one shared shard + host tier under every admitted model ---------
    writer = ShardWriter(store_dir)
    built_stores = []
    for (name, spec, cfg, p, layers, thr, fq, plan, _) in resolved:
        stores, _ = build_layer_stores(
            layers, thr, plan.store_plan, store_dir, link=link,
            quant_group=cfg.floe.quant_group, host=host, writer=writer,
            key_prefix=f"{name}/")
        built_stores.append(stores)
    writer.close()
    host.disk = DiskTier(store_dir)

    # global hottest-first warming across ALL models' experts
    entries = []
    for (name, spec, cfg, p, layers, thr, fq, plan, _), stores in zip(
            resolved, built_stores):
        for li, store in enumerate(stores):
            if store is None:
                continue
            for e in range(store.num_experts):
                entries.append((float(fq[li, e]), store, e))
    warm_host_tier(host, entries)

    # ---- wire each member's pipeline over the shared substrate -----------
    for (name, spec, cfg, p, layers, thr, fq, plan, host_share), stores \
            in zip(resolved, built_stores):
        dep = build(spec, params=p, thresholds=thr, freqs=fq,
                    device=device, link=link, engine=engine,
                    layer_stores=(stores, host), plan=plan)
        # re-plans debit/credit the shared admission ledger: a re-plan
        # whose footprint delta does not fit is denied, not migrated
        dep._replan_ledger = (
            lambda nm: lambda new_plan: fleet.recommit(nm, new_plan))(name)
        fleet.members[name] = FleetMember(
            name=name, spec=spec, deployment=dep, plan=plan,
            device_bytes=[plan.footprint_bytes(d)
                          for d in range(n_devices)],
            host_share=host_share)
    fleet._sync_clocks()
    return fleet
