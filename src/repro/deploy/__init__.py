"""repro.deploy — declarative deployment specs resolved once into systems.

    DeploymentSpec (spec.py)   typed ModelSpec/ResourceSpec/RuntimeSpec/
                               ServingSpec composition; JSON round-trip;
                               eager cross-field validation (SpecError)
    build (builder.py)         ONE engine-build path: spec -> plans ->
                               pipeline (+ controller) -> Deployment
                               session (generate / serve / report)
    build_fleet (fleet.py)     multi-model serving over shared tiers:
                               one HostTier/DiskTier under every model,
                               disjoint per-device arenas, footprint-
                               aware admission (AdmissionError), idle-
                               model pinned-set eviction

The builder/fleet modules import the pipeline and controller, which in
turn read ``repro.deploy.spec`` for their kwargs shims — so this package
re-exports them lazily (PEP 562) to keep the import graph acyclic.
"""
from repro.deploy.spec import (DeploymentSpec, HealthSpec, ModelSpec,
                               ReplanSpec, ResourceSpec, RuntimeSpec,
                               ServingSpec, SpecError, SpeculationSpec)

_LAZY = {
    "build": "builder", "Deployment": "builder",
    "calibrate_thresholds": "builder", "resolve_params": "builder",
    "build_fleet": "fleet", "Fleet": "fleet", "FleetMember": "fleet",
    "AdmissionError": "fleet",
}

__all__ = [
    "DeploymentSpec", "HealthSpec", "ModelSpec", "ReplanSpec",
    "ResourceSpec", "RuntimeSpec", "ServingSpec", "SpecError",
    "SpeculationSpec",
    *sorted(_LAZY),
]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f"repro.deploy.{mod}"), name)
