from repro.serving.controller import (ServingController, SLORequest,
                                      UnionDemandTracker)
from repro.serving.engine import Request, ServingEngine

__all__ = ["Request", "ServingEngine", "ServingController", "SLORequest",
           "UnionDemandTracker"]
