"""Batched serving engine: request queue → prefill → decode loop.

Continuous-batching-lite: requests are grouped into fixed-size batches
(padded prompts, shared KV allocation); decode steps are jitted once per
(batch, cache_len) shape.  Sampling is greedy or temperature.

Two decode paths:

* resident (default) — all weights on device, whole-model jitted decode
  ("Mixtral-GPU" in FloE Fig. 6), the general serving substrate.
* offloaded (``offload_thresholds=...``) — expert weights live in host
  DRAM and move through ``repro.runtime``'s ExpertScheduler: a host
  layer loop runs real attention + KV cache per layer and serves every
  MoE FFN via batched scheduler demands, so one staged expert slice is
  shared by every request in the batch that routed to it, and
  speculative prefetch (cross-layer + cross-token) overlaps the batch's
  attention/head compute.  Prefill stays on the resident path (compute-
  bound; the offloaded regime is decode, FloE §3.1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.models import attention as attn_lib
from repro.models import blocks as blk
from repro.models import mlp as mlp_lib
from repro.models import nn
from repro.models import transformer as tf
from repro.models.moe import Dist


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    done: bool = False
    output: list = dataclasses.field(default_factory=list)


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch_size: int = 4,
                 max_len: int = 512, dist: Optional[Dist] = None,
                 eos_id: int = -1, seed: int = 0,
                 offload_thresholds: Optional[np.ndarray] = None,
                 offload_opts: Optional[dict] = None):
        self.params = params
        self.cfg = cfg
        self.batch = batch_size
        self.max_len = max_len
        self.dist = dist
        self.eos = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self._prefill = jax.jit(
            lambda p, b, s: tf.prefill(p, b, s, cfg, dist))
        self._decode = jax.jit(
            lambda p, t, s: tf.decode_step(p, t, s, cfg, dist))
        self.stats = {"tokens": 0, "steps": 0, "wall_s": 0.0,
                      "stall_s": 0.0, "compute_s": 0.0,
                      "queue_wait_s": 0.0}

        # ------------------------------------------- offloaded MoE mode ---
        self.floe = None
        if offload_thresholds is not None:
            if not cfg.num_experts:
                raise ValueError("offloaded mode needs an MoE model")
            for pattern, _ in cfg.segments():
                bad = [k for k in pattern if k not in ("dense", "moe")]
                if bad:
                    raise ValueError(
                        f"offloaded serving supports dense/moe stacks, "
                        f"found {bad}")
            from repro.core.pipeline import FloEPipeline
            opts = dict(use_runtime=True, batched_demand=True)
            opts.update(offload_opts or {})
            self.floe = FloEPipeline(params, cfg,
                                     thresholds=offload_thresholds, **opts)

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------- batch ---
    def _next_batch(self) -> list[Request]:
        """Length-bucketed batching: a batch shares one prompt length, so
        positions and KV contents stay exact (no pad pollution)."""
        want = len(self.queue[0].prompt)
        batch, rest = [], []
        for r in self.queue:
            if len(r.prompt) == want and len(batch) < self.batch:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return batch

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        length = len(reqs[0].prompt)
        toks = np.zeros((self.batch, length), np.int32)
        for i, r in enumerate(reqs):
            toks[i] = r.prompt  # bucketed: all equal length
        return toks

    def _sample(self, logits: jax.Array, temps: np.ndarray) -> np.ndarray:
        self.key, sub = jax.random.split(self.key)
        greedy = jnp.argmax(logits, -1)
        temped = jax.random.categorical(sub, logits /
                                        jnp.maximum(temps[:, None], 1e-4))
        return np.asarray(jnp.where(temps > 0, temped, greedy), np.int32)

    # -------------------------------------------------------------- serve --
    def run(self) -> list[Request]:
        t_run0 = time.perf_counter()
        while self.queue:
            reqs = self._next_batch()
            # requests in this batch waited for every earlier batch to
            # finish — admission delay, accounted separately from service
            self.stats["queue_wait_s"] += \
                (time.perf_counter() - t_run0) * len(reqs)
            self._serve_batch(reqs)
            self.completed.extend(reqs)
        return self.completed

    def _serve_batch(self, reqs: list[Request]):
        if self.floe is not None:
            return self._serve_batch_offloaded(reqs)
        cfg = self.cfg
        toks = self._pad_prompts(reqs)
        n_active = len(reqs)
        temps = np.array([r.temperature for r in reqs] +
                         [0.0] * (self.batch - n_active), np.float32)
        state = tf.init_decode_state(cfg, self.batch, self.max_len,
                                     jnp.float32)
        t0 = time.perf_counter()
        logits, state = self._prefill(self.params,
                                      {"tokens": jnp.asarray(toks)}, state)
        cur = self._sample(logits[:, -1], temps)
        max_new = max(r.max_new_tokens for r in reqs)
        for step in range(max_new):
            for i, r in enumerate(reqs):
                if not r.done and len(r.output) < r.max_new_tokens:
                    r.output.append(int(cur[i]))
                    if cur[i] == self.eos:
                        r.done = True
                elif len(r.output) >= r.max_new_tokens:
                    r.done = True
            if all(r.done for r in reqs):
                break
            logits, state = self._decode(self.params,
                                         jnp.asarray(cur[:, None]), state)
            cur = self._sample(logits[:, 0], temps)
            self.stats["steps"] += 1
            self.stats["tokens"] += n_active
        self.stats["wall_s"] += time.perf_counter() - t0
        for r in reqs:
            r.done = True

    # ------------------------------------------------- offloaded decode ---
    def _serve_batch_offloaded(self, reqs: list[Request]):
        """Host-driven layer loop: real attention + KV caches per layer,
        MoE FFNs through the runtime scheduler with batch-shared expert
        slices.  Batch width is the number of live requests (padding rows
        would trigger spurious expert fetches)."""
        from repro.core.pipeline import StepMetrics
        cfg = self.cfg
        floe = self.floe
        n = len(reqs)
        toks = self._pad_prompts(reqs)[:n]
        temps = np.array([r.temperature for r in reqs], np.float32)
        states = [blk.init_block_state(
            "moe" if "moe" in layer else "dense", cfg, n, self.max_len,
            jnp.float32) for layer in floe.layers]

        t0 = time.perf_counter()
        # prefill on the resident path (per-layer host loop fills KV)
        x = tf._embed_inputs(self.params, {"tokens": jnp.asarray(toks)}, cfg)
        for li, layer in enumerate(floe.layers):
            kind = "moe" if "moe" in layer else "dense"
            x, states[li] = blk.block_prefill(layer, kind, x, states[li],
                                              cfg, None)
        logits = tf._head(self.params, x[:, -1:, :], cfg)
        cur = self._sample(logits[:, -1], temps)

        max_new = max(r.max_new_tokens for r in reqs)
        for step in range(max_new):
            for i, r in enumerate(reqs):
                if not r.done and len(r.output) < r.max_new_tokens:
                    r.output.append(int(cur[i]))
                    if cur[i] == self.eos:
                        r.done = True
                elif len(r.output) >= r.max_new_tokens:
                    r.done = True
            if all(r.done for r in reqs):
                break
            metrics = StepMetrics()
            x = tf._embed_inputs(self.params,
                                 {"tokens": jnp.asarray(cur[:, None])}, cfg)
            x = self._decode_offloaded_step(x, states, metrics)
            logits = tf._head(self.params, x, cfg)
            cur = self._sample(logits[:, 0], temps)
            floe.metrics.append(metrics)
            self.stats["steps"] += 1
            self.stats["tokens"] += n
            self.stats["stall_s"] += metrics.stall_s
            self.stats["compute_s"] += metrics.compute_s
        self.stats["wall_s"] += time.perf_counter() - t0
        for r in reqs:
            r.done = True

    def _decode_offloaded_step(self, x: jax.Array, states: list,
                               metrics) -> jax.Array:
        """One decode step over (B, 1, D) through the runtime scheduler."""
        cfg = self.cfg
        floe = self.floe
        sched = floe.sched
        moe_layers = set(floe._moe_layer_indices())
        h = x
        h_in = h[:, 0, :]
        covs: list = []

        for li, layer in enumerate(floe.layers):
            # cross-layer speculative prefetch from the live hidden state
            if floe.prefetch:
                floe.speculate(h[:, 0, :], li)

            # real attention with this layer's KV cache
            hn = nn.rms_norm(h, layer["attn_norm"]["scale"], cfg.norm_eps)
            a, states[li] = attn_lib.decode_attention(
                layer["attn"], hn, states[li], cfg, None)
            h = h + a
            t_attn = floe.device.matmul_time(
                2 * h.shape[0] * 4 * cfg.d_model * cfg.num_heads *
                cfg.head_dim,
                4 * cfg.d_model * cfg.num_heads * cfg.head_dim * 2)
            metrics.compute_s += t_attn
            sched.advance(t_attn)

            hn = nn.rms_norm(h, layer["mlp_norm"]["scale"], cfg.norm_eps)
            if li in moe_layers:
                hn2 = hn[:, 0, :]
                gates, eids, _ = floe._route(hn2, li)
                sched.reconcile(li, np.unique(eids.reshape(-1)).tolist())
                y = floe.moe_apply_batched(hn2, li, gates, eids, metrics,
                                           covs)
                h = h + y[:, None, :].astype(h.dtype)
            else:
                h = h + mlp_lib.mlp(layer["mlp"], hn, cfg)

        # cross-token speculation overlaps the LM head + sampling
        floe.speculate_cross_token(h_in)
        t_head = floe._head_time(h.shape[0])
        metrics.compute_s += t_head
        sched.advance(t_head)
        metrics.coverage = float(np.mean(covs)) if covs else 1.0
        return h

    def tokens_per_second(self) -> float:
        """Decode throughput over SERVICE time.

        Offloaded path: tokens over the *modeled* service time
        (compute + stall) — queue-wait / admission delay and host-driver
        overhead are excluded, so the figure measures the decode engine,
        not the arrival pattern.  (The old definition divided by wall
        time including admission delay, which understated throughput for
        any run with more requests than batch slots.)  Resident path:
        wall-clock over the jitted serve loop, whose wall time IS the
        service time (one batch at a time, measured around the loop).
        """
        if self.floe is not None:
            service = self.stats["compute_s"] + self.stats["stall_s"]
            return self.stats["tokens"] / max(service, 1e-9)
        return self.stats["tokens"] / max(self.stats["wall_s"], 1e-9)

    def modeled_stall_per_token(self) -> float:
        return self.stats["stall_s"] / max(self.stats["tokens"], 1)
