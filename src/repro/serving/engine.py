"""Batched serving engine: request queue → prefill → decode loop.

Continuous-batching-lite: requests are grouped into fixed-size batches
(padded prompts, shared KV allocation); decode steps are jitted once per
(batch, cache_len) shape.  Sampling is greedy or temperature.

The FloE-offloaded path (single-batch, latency-sensitive — the paper's
regime) lives in repro.core.pipeline; this engine is the resident-weights
baseline ("Mixtral-GPU" in Fig. 6) and the general serving substrate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.models import transformer as tf
from repro.models.moe import Dist


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    done: bool = False
    output: list = dataclasses.field(default_factory=list)


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch_size: int = 4,
                 max_len: int = 512, dist: Optional[Dist] = None,
                 eos_id: int = -1, seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.batch = batch_size
        self.max_len = max_len
        self.dist = dist
        self.eos = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self._prefill = jax.jit(
            lambda p, b, s: tf.prefill(p, b, s, cfg, dist))
        self._decode = jax.jit(
            lambda p, t, s: tf.decode_step(p, t, s, cfg, dist))
        self.stats = {"tokens": 0, "steps": 0, "wall_s": 0.0}

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------- batch ---
    def _next_batch(self) -> list[Request]:
        """Length-bucketed batching: a batch shares one prompt length, so
        positions and KV contents stay exact (no pad pollution)."""
        want = len(self.queue[0].prompt)
        batch, rest = [], []
        for r in self.queue:
            if len(r.prompt) == want and len(batch) < self.batch:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return batch

    def _pad_prompts(self, reqs: list[Request]) -> np.ndarray:
        length = len(reqs[0].prompt)
        toks = np.zeros((self.batch, length), np.int32)
        for i, r in enumerate(reqs):
            toks[i] = r.prompt  # bucketed: all equal length
        return toks

    def _sample(self, logits: jax.Array, temps: np.ndarray) -> np.ndarray:
        self.key, sub = jax.random.split(self.key)
        greedy = jnp.argmax(logits, -1)
        temped = jax.random.categorical(sub, logits /
                                        jnp.maximum(temps[:, None], 1e-4))
        return np.asarray(jnp.where(temps > 0, temped, greedy), np.int32)

    # -------------------------------------------------------------- serve --
    def run(self) -> list[Request]:
        while self.queue:
            reqs = self._next_batch()
            self._serve_batch(reqs)
            self.completed.extend(reqs)
        return self.completed

    def _serve_batch(self, reqs: list[Request]):
        cfg = self.cfg
        toks = self._pad_prompts(reqs)
        n_active = len(reqs)
        temps = np.array([r.temperature for r in reqs] +
                         [0.0] * (self.batch - n_active), np.float32)
        state = tf.init_decode_state(cfg, self.batch, self.max_len,
                                     jnp.float32)
        t0 = time.perf_counter()
        logits, state = self._prefill(self.params,
                                      {"tokens": jnp.asarray(toks)}, state)
        cur = self._sample(logits[:, -1], temps)
        max_new = max(r.max_new_tokens for r in reqs)
        for step in range(max_new):
            for i, r in enumerate(reqs):
                if not r.done and len(r.output) < r.max_new_tokens:
                    r.output.append(int(cur[i]))
                    if cur[i] == self.eos:
                        r.done = True
                elif len(r.output) >= r.max_new_tokens:
                    r.done = True
            if all(r.done for r in reqs):
                break
            logits, state = self._decode(self.params,
                                         jnp.asarray(cur[:, None]), state)
            cur = self._sample(logits[:, 0], temps)
            self.stats["steps"] += 1
            self.stats["tokens"] += n_active
        self.stats["wall_s"] += time.perf_counter() - t0
        for r in reqs:
            r.done = True

    def tokens_per_second(self) -> float:
        return self.stats["tokens"] / max(self.stats["wall_s"], 1e-9)
