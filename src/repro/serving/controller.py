"""SLO-aware continuous-batching serving controller (the control plane).

The step from "decode loop" to "serving system": requests arrive on the
runtime's simulated clock with a per-request latency SLO, and a controller
decides — between decode steps — who runs, who waits, who is preempted,
and who is rejected outright:

  request queue ──admission (EDF, SLO-feasibility)──▶ batch slots
        ▲                                               │ decode step
        │ preempt (deadline pressure)                   ▼
        └────────────── swap-out ◀──────────── finished / preempted

Design points:

* **Continuous batching** — every request owns its per-layer decode state
  (KV caches, batch dim 1), so the running set can change between any two
  decode steps without touching anyone else's state.  Attention runs
  per-request on private caches; routing / expert compute are row-wise;
  expert *transfers* are shared batch-wide through union-channel demands
  (``ExpertScheduler.demand_union``), whose top-up fetches guarantee
  coverage — a request's outputs are bitwise identical whether it decodes
  solo or is swapped mid-stream into a busy batch (pinned by test).

* **SLO admission** — deadline = arrival_t + slo_ms on the modeled clock.
  Per-step latency is estimated from the scheduler's measured telemetry
  (clock deltas = compute + observed stall), and a request that cannot
  meet its deadline even if admitted immediately is rejected instead of
  poisoning the batch.  Deadline pressure can preempt the running request
  with the slackest deadline (bounded per request to avoid thrash).

* **Trained-predictor-driven residency** — the inter-expert predictor is
  trained *online* from the routing the controller observes (residual on
  the router-reuse fallback, so it starts at fallback quality and only
  improves), and a running ``ConfidenceCalibrator`` rescales predictor
  confidence by realized precision before it becomes a prefetch priority
  or a ``weighted``-policy residency score.

* **Incremental union demand masks** — per-request speculative expert
  demands are tracked as channel *counters* (``UnionDemandTracker``);
  swap-in/out adds/removes only that request's contribution instead of
  rebuilding every union mask from scratch (incremental == from-scratch
  is pinned by test).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.common.config import ModelConfig
from repro.core import floe_layer, predictor
from repro.obs.metrics import (MetricsRegistry, request_metrics,
                               scheduler_metrics)
from repro.core.pipeline import FloEPipeline, StepMetrics
from repro.models import attention as attn_lib
from repro.models import blocks as blk
from repro.models import mlp as mlp_lib
from repro.models import nn
from repro.models import transformer as tf


# ---------------------------------------------------------------- request --
@dataclasses.dataclass
class SLORequest:
    """A serving request with an arrival time and a latency SLO, all on the
    runtime's modeled clock (seconds)."""

    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    slo_ms: float = 1000.0
    arrival_t: float = 0.0
    temperature: float = 0.0
    tenant: str = ""  # traffic class (repro.workload), "" when untagged

    # lifecycle (filled by the controller)
    admitted_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    rejected: bool = False
    preemptions: int = 0
    done: bool = False
    output: list = dataclasses.field(default_factory=list)
    # latency breakdown: stalled vs computing seconds accrued over every
    # decode step this request rode in (queue-wait is admitted_t -
    # arrival_t) — the per-request TTFT/TPOT decomposition the metrics
    # registry snapshots
    stall_share_s: float = 0.0
    compute_share_s: float = 0.0

    # private decode state (per-layer KV caches, batch dim 1)
    states: Optional[list] = dataclasses.field(default=None, repr=False)
    cur: Optional[int] = None  # next input token id
    # previous token's entry hidden state — the cross-token prediction
    # proxy, kept per request so training pairs match the usage
    prev_entry: Optional[np.ndarray] = dataclasses.field(default=None,
                                                         repr=False)

    @property
    def deadline_t(self) -> float:
        return self.arrival_t + self.slo_ms * 1e-3

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.arrival_t

    @property
    def tpot(self) -> Optional[float]:
        if self.finish_t is None or self.first_token_t is None:
            return None
        n = max(len(self.output) - 1, 1)
        return (self.finish_t - self.first_token_t) / n

    @property
    def attained(self) -> bool:
        return (not self.rejected and self.finish_t is not None
                and self.finish_t <= self.deadline_t + 1e-12)


# ----------------------------------------------------- union-mask tracker --
class UnionDemandTracker:
    """Incrementally-maintained union of per-request channel demand masks.

    Per (layer, expert) key a channel *counter* array records how many
    live requests demand each channel.  Adding or removing one request
    touches only that request's contribution — the union mask
    (``counts > 0``) never has to be rebuilt by re-predicting the whole
    batch at a swap boundary.  ``rebuild()`` recomputes every union from
    the stored contributions from scratch; incremental == rebuild is the
    conformance property pinned by tests.
    """

    def __init__(self, num_channels: int):
        self.num_channels = num_channels
        self._counts: Dict[Hashable, np.ndarray] = {}
        self._conf: Dict[Hashable, Dict[int, Tuple[float, int]]] = {}
        self._contrib: Dict[int, Dict[Hashable, np.ndarray]] = {}

    def set_contribution(self, rid: int,
                         masks: Dict[Hashable, np.ndarray],
                         conf: Dict[Hashable, Tuple[float, int]]) -> None:
        """Replace request ``rid``'s demand contribution (delta-applied)."""
        self.remove(rid)
        self._contrib[rid] = {}
        for key, mask in masks.items():
            mask = np.asarray(mask, bool)
            assert mask.shape == (self.num_channels,)
            cnt = self._counts.get(key)
            if cnt is None:
                cnt = np.zeros(self.num_channels, np.int32)
                self._counts[key] = cnt
            cnt += mask
            self._contrib[rid][key] = mask
            self._conf.setdefault(key, {})[rid] = conf[key]

    def remove(self, rid: int) -> None:
        for key, mask in self._contrib.pop(rid, {}).items():
            self._counts[key] -= mask
            self._conf[key].pop(rid, None)
            if not self._conf[key]:  # last contributor gone
                del self._counts[key]
                del self._conf[key]

    def keys(self) -> List[Hashable]:
        return list(self._counts.keys())

    def union(self, key: Hashable) -> np.ndarray:
        return self._counts[key] > 0

    def confidence(self, key: Hashable) -> Tuple[float, int]:
        """(max confidence, min depth) over contributing requests."""
        entries = self._conf[key].values()
        return (max(c for c, _ in entries), min(d for _, d in entries))

    def rebuild(self) -> Dict[Hashable, np.ndarray]:
        """From-scratch recompute of all union masks (reference path)."""
        out: Dict[Hashable, np.ndarray] = {}
        for contrib in self._contrib.values():
            for key, mask in contrib.items():
                if key in out:
                    out[key] = out[key] | mask
                else:
                    out[key] = mask.copy()
        return out


# ------------------------------------------------------------- controller --
class ServingController:
    """Continuous-batching request controller over the runtime scheduler.

    ``policy`` selects the control plane:

    * ``"slo"``    — continuous batching: EDF admission with SLO-
                     feasibility rejection, swap-in/out between decode
                     steps, deadline-pressure preemption.
    * ``"static"`` — the baseline the benches compare against: fixed
                     batches run to completion in arrival order (exactly
                     the old one-batch-at-a-time serve loop), same decode
                     machinery and timing model.
    """

    def __init__(self, params, cfg: ModelConfig, *,
                 thresholds: np.ndarray,
                 slots: int = 4,
                 max_len: int = 256,
                 policy: str = "slo",
                 eos_id: int = -1,
                 seed: int = 0,
                 online_train: bool = True,
                 train_every_tokens: int = 16,
                 train_window: int = 256,
                 train_steps: int = 60,
                 predictor_hidden: int = 0,
                 min_train_rows: int = 64,
                 max_preemptions: int = 2,
                 cross_token: bool = True,
                 offload_opts: Optional[dict] = None,
                 serving_spec=None):  # repro.deploy.ServingSpec (overrides
        #                               the individual kwargs above)
        from repro.deploy.spec import ServingSpec, SpecError

        # The kwargs are a thin shim over the typed spec: they are
        # normalized into ONE ServingSpec and every knob below reads from
        # it, so a spec-built controller (repro.deploy.build) and a
        # kwargs-built one construct identically (parity pinned by test).
        if serving_spec is None:
            serving_spec = ServingSpec(
                slots=slots, max_len=max_len, policy=policy, eos_id=eos_id,
                seed=seed, online_train=online_train,
                train_every_tokens=train_every_tokens,
                train_window=train_window, train_steps=train_steps,
                predictor_hidden=predictor_hidden,
                min_train_rows=min_train_rows,
                max_preemptions=max_preemptions, cross_token=cross_token)
        sv = self.serving_spec = serving_spec
        slots, max_len, policy = sv.slots, sv.max_len, sv.policy
        eos_id, seed, online_train = sv.eos_id, sv.seed, sv.online_train
        train_every_tokens = sv.train_every_tokens
        train_window, train_steps = sv.train_window, sv.train_steps
        predictor_hidden = sv.predictor_hidden
        min_train_rows = sv.min_train_rows
        max_preemptions, cross_token = sv.max_preemptions, sv.cross_token

        if policy not in ("slo", "static"):
            raise SpecError("serving.policy", f"unknown policy {policy!r}")
        if slots < 1:
            raise SpecError("serving.slots",
                            f"need at least one batch slot, got {slots}")
        if not cfg.num_experts:
            raise SpecError("serving.policy",
                            "the serving controller needs an MoE model")
        for pattern, _ in cfg.segments():
            bad = [k for k in pattern if k not in ("dense", "moe")]
            if bad:
                raise ValueError(
                    f"controller supports dense/moe stacks, found {bad}")
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.policy = policy
        self.eos = eos_id
        self.cross_token = cross_token
        self.max_preemptions = max_preemptions
        self._key = jax.random.PRNGKey(seed)

        opts = dict(use_runtime=True, batched_demand=True, cross_token=False)
        opts.update(offload_opts or {})
        self.pipe = FloEPipeline(params, cfg, thresholds=thresholds, **opts)
        assert self.pipe.sched is not None, "controller requires use_runtime"
        self.sched = self.pipe.sched
        self._moe_layers = set(self.pipe._moe_layer_indices())
        # layers reached by cross-token speculation (trained on
        # prev-token-entry pairs in addition to same-token pairs)
        self._first_moe = set(
            self.pipe._moe_layer_indices()[:self.sched.lookahead])

        # ---- trained-predictor control plane -----------------------------
        self.online_train = online_train
        self.train_every_tokens = train_every_tokens
        self.train_window = train_window
        self.train_steps = train_steps
        self.predictor_hidden = predictor_hidden
        self.min_train_rows = min_train_rows
        self.calibrator = predictor.ConfidenceCalibrator()
        self.sched.calibrate = self.calibrator
        if online_train:
            if self.pipe.inter is None:
                self.pipe.inter = [None] * len(self.pipe.layers)
            # normalize the residual flag to a per-layer set so online
            # residual probes can coexist with user-supplied standalone
            # predictors (their layers keep their own residual setting)
            ir = self.pipe.inter_residual
            if not isinstance(ir, set):
                ir = (set(range(len(self.pipe.layers))) if ir else set())
                self.pipe.inter_residual = ir
            self._user_residual = set(ir)
            self._user_inter = list(self.pipe.inter)
        # two probe banks for two input distributions: _bank_xl serves
        # cross-LAYER speculation (same-token proxy, one layer earlier)
        # and is projected into pipe.inter per adoption; inter_ct serves
        # cross-TOKEN speculation (previous token's entry state).  Mixing
        # them in one probe degrades both usages.
        self._bank_xl: Dict[int, dict] = {}
        self.inter_ct: Dict[int, dict] = {}
        self._train_buf: Dict[int, list] = {}  # layer -> [(h, base, tgt)]
        self._train_buf_ct: Dict[int, list] = {}
        self._tokens_since_train = 0
        self.train_rounds = 0

        # ---- request books -----------------------------------------------
        # pending is a heap of (arrival_t, uid, req): O(log n) intake
        # instead of the old sort-on-every-submit + pop(0) list, which
        # went quadratic at 10k+ requests.  Pop order (arrival_t, uid) is
        # identical to the old sorted path (pinned by test).
        self.pending: List[Tuple[float, int, SLORequest]] = []
        self._uids: set = set()  # every uid ever submitted (collision gate)
        self.queue: List[SLORequest] = []  # arrived, waiting for a slot
        self.running: List[SLORequest] = []
        self.completed: List[SLORequest] = []
        self.rejected: List[SLORequest] = []
        self.tracker = UnionDemandTracker(cfg.moe_d_ff)

        # ---- telemetry ---------------------------------------------------
        self.est_tpot: Optional[float] = None  # EMA of measured step time
        self._ema_beta = 0.7
        self.stats = {"steps": 0, "tokens": 0, "preemptions": 0,
                      "rejections": 0, "swaps_in": 0, "swaps_out": 0,
                      "busy_s": 0.0, "idle_s": 0.0}
        # prediction recall graded against the true router at reconcile
        # time: xl = cross-layer depth-1, ct = cross-token.  This measures
        # the PREFETCHER (what fraction of needed experts it named),
        # independent of cache-capacity effects on staging.
        self.pred_stats = {"xl_hit": 0, "xl_true": 0,
                           "ct_hit": 0, "ct_true": 0}
        self.metrics: List[StepMetrics] = []
        # live re-planner hook (repro.replan.Replanner); attached by
        # Deployment.serve(replan=...), polled once per step
        self.replan = None
        # speculative big-little executor (repro.spec_exec); attached by
        # Deployment.serve(speculate=...) / SpeculativeExecutor.attach().
        # None (the default) leaves every decode path bitwise untouched.
        self.speculator = None

    # ------------------------------------------------------------ intake ---
    def submit(self, req: SLORequest) -> None:
        uid = int(req.uid)
        if uid in self._uids:
            # colliding uids silently merge two requests into one tracer
            # lane (tid = 1000 + uid) and corrupt per-request metrics —
            # allocate uids centrally (repro.workload) or per-controller
            raise ValueError(f"duplicate request uid {uid}: uids must be "
                             f"unique per controller")
        self._uids.add(uid)
        req.prompt = np.asarray(req.prompt, np.int32)
        heapq.heappush(self.pending, (req.arrival_t, uid, req))

    def _ingest(self, now: float) -> None:
        while self.pending and self.pending[0][0] <= now + 1e-12:
            self.queue.append(heapq.heappop(self.pending)[2])

    # --------------------------------------------------------- estimation --
    def _est_step(self) -> Optional[float]:
        return self.est_tpot

    def _est_prefill(self, req: SLORequest) -> float:
        """Modeled resident prefill seconds for this prompt."""
        if req.states is not None:  # resuming a preempted request
            return 0.0
        return self._prefill_time(len(req.prompt))

    def _prefill_time(self, s: int) -> float:
        cfg, dev = self.cfg, self.pipe.device
        t = 0.0
        ah = 4 * cfg.d_model * cfg.num_heads * cfg.head_dim
        for li in range(len(self.pipe.layers)):
            t += dev.matmul_time(2 * s * ah, ah * 2)
            if li in self._moe_layers:
                f = cfg.moe_d_ff
                k = cfg.num_experts_per_tok
                t += dev.matmul_time(6 * s * k * cfg.d_model * f,
                                     6 * cfg.d_model * f)
            else:
                t += dev.matmul_time(6 * s * cfg.d_model * cfg.d_ff,
                                     6 * cfg.d_model * cfg.d_ff)
        return t + self.pipe._head_time(1)

    def _feasible(self, req: SLORequest, now: float) -> bool:
        """Can this request still meet its SLO if admitted right now?"""
        est = self._est_step()
        if est is None:  # no telemetry yet: optimistic bootstrap
            return True
        remaining = max(req.max_new_tokens - len(req.output), 0)
        finish = now + self._est_prefill(req) + remaining * est
        return finish <= req.deadline_t + 1e-12

    # ---------------------------------------------------------- admission --
    def _retire(self, now: float) -> None:
        if self.policy == "static":
            if self.running and all(r.done for r in self.running):
                for r in self.running:
                    self.tracker.remove(r.uid)
                    self.stats["swaps_out"] += 1
                self.completed.extend(self.running)
                self.running = []
            return
        still = []
        for r in self.running:
            if r.done:
                self.tracker.remove(r.uid)
                self.completed.append(r)
                self.stats["swaps_out"] += 1
            else:
                still.append(r)
        self.running = still

    def _admit(self, req: SLORequest, now: float) -> None:
        if req.states is None:
            self._prefill(req)
        req.admitted_t = now if req.admitted_t is None else req.admitted_t
        self.running.append(req)
        self.stats["swaps_in"] += 1
        if obs.enabled():
            obs.emit("request.admit", self.sched.clock, cat="serving",
                     lane=req.uid, args={"uid": req.uid,
                                         "queue_s": max(
                                             req.admitted_t - req.arrival_t,
                                             0.0)})
            obs.emit("swap.in", self.sched.clock, cat="serving",
                     args={"uid": req.uid})
        if self.cross_token and self.pipe.prefetch:
            h = np.asarray(tf._embed_inputs(
                self.params,
                {"tokens": jnp.asarray([[req.cur]], jnp.int32)},
                self.cfg))[:, 0, :]
            self._track_request(req, h)
            self._enqueue_tracked()

    def _admission(self, now: float) -> None:
        if self.policy == "static":
            if not self.running:
                while self.queue and len(self.running) < self.slots:
                    self._admit(self.queue.pop(0), self.sched.clock)
            return
        # EDF order; drop requests that can no longer meet their SLO
        self.queue.sort(key=lambda r: (r.deadline_t, r.uid))
        keep = []
        for r in self.queue:
            if not self._feasible(r, now):
                r.rejected = True
                self.rejected.append(r)
                self.stats["rejections"] += 1
                self.tracker.remove(r.uid)
                if obs.enabled():
                    rej_args = {"uid": r.uid, "deadline_t": r.deadline_t}
                    if r.tenant:
                        rej_args["tenant"] = r.tenant
                    obs.emit("request.reject", now, cat="serving",
                             lane=r.uid, args=rej_args)
            else:
                keep.append(r)
        self.queue = keep
        while self.queue and len(self.running) < self.slots:
            self._admit(self.queue.pop(0), self.sched.clock)
        self._maybe_preempt(now)

    def _maybe_preempt(self, now: float) -> None:
        """Deadline pressure: if the most-urgent waiting request would
        miss its SLO before a slot frees naturally, swap out the running
        request with the slackest (latest) deadline."""
        est = self._est_step()
        if (est is None or not self.queue or
                len(self.running) < self.slots or not self.running):
            return
        urgent = self.queue[0]  # EDF head
        free_in = est * min(r.max_new_tokens - len(r.output)
                            for r in self.running)
        remaining = max(urgent.max_new_tokens - len(urgent.output), 0)
        misses_waiting = (now + free_in + self._est_prefill(urgent) +
                          remaining * est > urgent.deadline_t)
        if not misses_waiting or not self._feasible(urgent, now):
            return
        victim = max(self.running, key=lambda r: (r.deadline_t, r.uid))
        if (victim.deadline_t <= urgent.deadline_t or
                victim.preemptions >= self.max_preemptions):
            return
        self.running.remove(victim)
        self.tracker.remove(victim.uid)
        victim.preemptions += 1
        self.stats["preemptions"] += 1
        self.stats["swaps_out"] += 1
        if obs.enabled():
            obs.emit("request.preempt", now, cat="serving",
                     lane=victim.uid,
                     args={"uid": victim.uid, "for_uid": urgent.uid})
            obs.emit("swap.out", now, cat="serving",
                     args={"uid": victim.uid})
        self.queue.insert(0, victim)
        self.queue.sort(key=lambda r: (r.deadline_t, r.uid))
        self._admit(urgent, self.sched.clock)
        self.queue.remove(urgent)

    # ------------------------------------------------------------ prefill --
    def _prefill(self, req: SLORequest) -> None:
        """Resident-path prefill on private (batch 1) states; the modeled
        prefill time advances the clock, so in-flight prefetches overlap
        it like any other compute."""
        cfg = self.cfg
        req.states = [blk.init_block_state(
            "moe" if "moe" in layer else "dense", cfg, 1, self.max_len,
            jnp.float32) for layer in self.pipe.layers]
        x = tf._embed_inputs(self.params,
                             {"tokens": jnp.asarray(req.prompt[None])}, cfg)
        for li, layer in enumerate(self.pipe.layers):
            kind = "moe" if "moe" in layer else "dense"
            x, req.states[li] = blk.block_prefill(layer, kind, x,
                                                  req.states[li], cfg, None)
        logits = tf._head(self.params, x[:, -1:, :], cfg)
        t_pre = self._prefill_time(len(req.prompt))
        self.sched.advance(t_pre)
        self.stats["busy_s"] += t_pre
        tok = self._sample_one(req, np.asarray(logits)[0, -1])
        req.cur = tok
        req.output.append(tok)
        req.first_token_t = self.sched.clock
        if tok == self.eos or len(req.output) >= req.max_new_tokens:
            self._finish(req)

    def _finish(self, req: SLORequest) -> None:
        req.done = True
        req.finish_t = self.sched.clock
        if obs.enabled():
            args = {"uid": req.uid, "tokens": len(req.output),
                    "stall_s": req.stall_share_s,
                    "compute_s": req.compute_share_s,
                    "attained": req.attained}
            if req.tenant:  # only when set: keeps tenant-less traces stable
                args["tenant"] = req.tenant
            if req.ttft is not None:
                args["ttft_s"] = req.ttft
            if req.tpot is not None:
                args["tpot_s"] = req.tpot
            if req.admitted_t is not None:
                args["queue_s"] = max(req.admitted_t - req.arrival_t, 0.0)
            # request lifetime span on the request's own lane, plus the
            # finish instant the metrics collector folds into histograms
            if req.admitted_t is not None:
                obs.emit("request.lifetime", req.arrival_t, cat="serving",
                         dur=max(req.finish_t - req.arrival_t, 0.0),
                         lane=req.uid, args={"uid": req.uid})
            obs.emit("request.finish", req.finish_t, cat="serving",
                     lane=req.uid, args=args)

    # ------------------------------------------------------------ sampling -
    def _sample_one(self, req: SLORequest, logits: np.ndarray) -> int:
        """Per-request sampling, keyed by (uid, position) so the value is
        independent of batch composition."""
        if req.temperature <= 0.0:
            return int(np.argmax(logits))
        key = jax.random.fold_in(jax.random.fold_in(self._key, req.uid),
                                 len(req.output))
        return int(jax.random.categorical(
            key, jnp.asarray(logits) / max(req.temperature, 1e-4)))

    # --------------------------------------------------------- decode step -
    def _decode_step(self) -> None:
        pipe, sched, cfg = self.pipe, self.sched, self.cfg
        reqs = self.running
        n = len(reqs)
        metrics = StepMetrics()
        t0 = sched.clock
        spec = self.speculator
        if spec is not None and spec.enabled:
            # verify every speculation whose big expert has arrived;
            # rollbacks rewind their requests BEFORE this step reads
            # r.cur / r.states, so the re-decode starts here
            spec.settle(metrics)
            spec.begin_step(reqs)
        cur = np.array([r.cur for r in reqs], np.int32)
        h = tf._embed_inputs(self.params,
                             {"tokens": jnp.asarray(cur[:, None])}, cfg)
        h_entry = np.asarray(h[:, 0, :])
        prev_entries = [r.prev_entry for r in reqs]
        h_tops: Dict[int, jax.Array] = {}
        covs: list = []

        for li, layer in enumerate(pipe.layers):
            h2d = h[:, 0, :]
            h_tops[li] = h2d
            if pipe.prefetch:
                pipe.speculate(h2d, li)

            hn = nn.rms_norm(h, layer["attn_norm"]["scale"], cfg.norm_eps)
            outs = []
            for i, r in enumerate(reqs):
                a, r.states[li] = attn_lib.decode_attention(
                    layer["attn"], hn[i:i + 1], r.states[li], cfg, None)
                outs.append(a)
            h = h + jnp.concatenate(outs, axis=0)
            t_attn = pipe.device.matmul_time(
                2 * n * 4 * cfg.d_model * cfg.num_heads * cfg.head_dim,
                4 * cfg.d_model * cfg.num_heads * cfg.head_dim * 2)
            metrics.compute_s += t_attn
            sched.advance(t_attn)

            hn = nn.rms_norm(h, layer["mlp_norm"]["scale"], cfg.norm_eps)
            if li in self._moe_layers:
                hn2 = hn[:, 0, :]
                gates, eids, _ = pipe._route(hn2, li)
                truth = np.unique(eids.reshape(-1)).tolist()
                if li in self._first_moe:
                    # grade cross-token predictions (tracked contributions
                    # are from the previous step / admission — exactly
                    # this token's cross-token prediction)
                    for i, r in enumerate(reqs):
                        contrib = self.tracker._contrib.get(r.uid, {})
                        pred_e = {e for (l, e) in contrib if l == li}
                        tset = set(int(x) for x in eids[i])
                        self.pred_stats["ct_true"] += len(tset)
                        self.pred_stats["ct_hit"] += len(tset & pred_e)
                self._grade_and_buffer(li, h_tops, eids, truth,
                                       prev_entries)
                sched.reconcile(li, truth)
                y = self._moe_apply_union(hn2, li, gates, eids, metrics,
                                          covs)
                h = h + y[:, None, :].astype(h.dtype)
            else:
                h = h + mlp_lib.mlp(layer["mlp"], hn, cfg)

        self._cross_token_speculate(reqs, h_entry)
        t_head = pipe._head_time(n)
        metrics.compute_s += t_head
        sched.advance(t_head)
        logits = np.asarray(tf._head(self.params, h, cfg))[:, 0]

        live = 0
        for i, r in enumerate(reqs):
            if spec is not None and r.uid in spec.rolled_uids:
                continue  # rolled back mid-step: state already rewound
            r.prev_entry = h_entry[i]
            tok = self._sample_one(r, logits[i])
            r.cur = tok
            if r.done:
                continue  # static policy: finished rows ride along
            live += 1
            # every live rider waits out the step's stalls and compute —
            # the per-request latency breakdown accrues the full step
            r.stall_share_s += metrics.stall_s
            r.compute_share_s += metrics.compute_s
            r.output.append(tok)
            if tok == self.eos or len(r.output) >= r.max_new_tokens:
                if spec is not None and spec.enabled:
                    # a request may not finish with unverified
                    # speculative tokens: force-verify (waiting under
                    # speculative_fallback if the big is still late)
                    spec.flush_uid(r.uid, metrics)
                    if r.uid in spec.rolled_uids:
                        continue  # rewound: re-decodes in a later step
                self._finish(r)

        metrics.coverage = float(np.mean(covs)) if covs else 1.0
        self.metrics.append(metrics)
        pipe.metrics.append(metrics)
        now = sched.clock
        dt = now - t0
        if obs.enabled():
            obs.emit("serving.step", t0, cat="serving", dur=dt,
                     args={"batch": n, "live": live,
                           "stall_s": metrics.stall_s,
                           "compute_s": metrics.compute_s})
        self.stats["steps"] += 1
        self.stats["tokens"] += live
        self.stats["busy_s"] += dt
        self.est_tpot = (dt if self.est_tpot is None else
                         self._ema_beta * self.est_tpot +
                         (1 - self._ema_beta) * dt)
        self._tokens_since_train += live
        if (self.online_train and
                self._tokens_since_train >= self.train_every_tokens):
            self._train_predictors()

    # ----------------------------------------- union-mask expert execution -
    def _moe_apply_union(self, hn2: jax.Array, li: int, gates: np.ndarray,
                         eids: np.ndarray, metrics: StepMetrics,
                         covs: list) -> jax.Array:
        """Each distinct routed expert is demanded ONCE with the union of
        its tokens' true channel masks (top-up fetches guarantee the
        staged slice covers the union); each token then computes with
        exactly its OWN mask's channels, so a request's expert output
        never depends on its batch neighbors — only the *transfer* is
        shared.  Demands issue up front (phase A) so each DMA overlaps
        the other experts' up-GEMV compute."""
        pipe, sched, cfg = self.pipe, self.sched, self.cfg
        d = cfg.d_model
        y = jnp.zeros((hn2.shape[0], d), jnp.float32)
        experts = np.unique(eids.reshape(-1)).tolist()
        gates = np.asarray(gates)
        issued = {}
        for e in experts:
            rows = np.nonzero((eids == e).any(axis=1))[0]
            hb = hn2[rows]
            v, row_mask = pipe._up_mask_rows(hb, li, int(e))
            # a tiered store can only stage its format's kept channels —
            # clip the demand to the servable set (the rest is the
            # planner's footprint/quality knob, logged as coverage)
            avail = pipe.stores[li].available_channels(int(e))
            if avail is not None:
                am = np.zeros(row_mask.shape[1], bool)
                am[avail] = True
                served_mask = row_mask & am[None, :]
            else:
                served_mask = row_mask
            t_up = pipe._up_time(hb.shape[0], li, e)
            metrics.compute_s += t_up
            sched.advance(t_up)
            union_idx = np.nonzero(served_mask.any(axis=0))[0]
            payload, was_miss = sched.demand_union(li, int(e), union_idx)
            if was_miss:
                metrics.expert_misses += 1
            else:
                metrics.expert_hits += 1
            issued[e] = (rows, v, row_mask, served_mask, payload, was_miss)
        spec = self.speculator
        for e in experts:
            rows, v, row_mask, served_mask, payload, was_miss = issued[e]
            if spec is not None and spec.enabled:
                # demand miss with a resident shadow: compute from the
                # little expert NOW and skip the wait — the big transfer
                # keeps streaming and settles verify-or-rollback later
                res = spec.try_speculate(
                    hn2, li, int(e), rows, row_mask, served_mask, v,
                    (gates * (eids == e)).sum(axis=1), self.running,
                    metrics, covs)
                if res is not None:
                    y = y + res.contribution
                    continue
            metrics.stall_s += sched.wait_for(li, int(e), was_miss=was_miss)
            # pick up an applied progressive refine (same slice, full
            # precision); an evicted entry keeps the original payload
            cur = sched.staged_payload(li, int(e))
            if cur is not None and np.array_equal(np.asarray(cur[0]),
                                                  np.asarray(payload[0])):
                payload = cur
            idx, gate_cols, down_rows = payload
            n_act = 0
            for j, b in enumerate(rows.tolist()):
                own = np.nonzero(served_mask[j])[0]
                sel = np.searchsorted(idx, own)
                # demand_union's contract (property-tested): the staged
                # slice covers the union of SERVABLE row masks, so
                # coverage over that set is 1.0 by construction —
                # channels can only be lost to the planner's format
                # choice, never to cache staleness.  Fail loudly if that
                # ever breaks; a silent filter would corrupt outputs.
                assert sel.size == 0 or (int(sel[-1]) < idx.size and
                                         np.array_equal(idx[sel], own)), \
                    "demand_union contract violated: staged slice " \
                    "misses needed channels"
                covs.append(float(own.size) /
                            max(int(np.count_nonzero(row_mask[j])), 1)
                            if row_mask[j].any() else 1.0)
                ye = floe_layer.sparse_expert_apply(
                    hn2[b:b + 1], gate_cols[sel], down_rows[sel],
                    v[j:j + 1, own])
                wgt = (gates * (eids == e)).sum(axis=1)[b]
                y = y.at[b].add(ye[0].astype(jnp.float32) * float(wgt))
                n_act += int(own.size)
            t_sparse = pipe.device.matmul_time(4 * d * n_act, 4 * d * n_act)
            metrics.compute_s += t_sparse
            sched.advance(t_sparse)
        return y

    # -------------------------------------------- cross-token speculation --
    def _predict_ct(self, h: jax.Array, li0: int):
        """Cross-token prediction: the trained cross-token probe (residual
        over router reuse) when one exists, else the pure reuse fallback
        (never the cross-layer probe — wrong input distribution)."""
        return self.pipe._predict_next(h, li0,
                                       probe=self.inter_ct.get(li0),
                                       residual=True)

    def _track_request(self, req: SLORequest, h_entry_row: np.ndarray
                       ) -> None:
        """Recompute this request's speculative demand contribution from
        its token-entry state (the cross-token routing proxy)."""
        pipe, sched = self.pipe, self.sched
        moe_list = pipe._moe_layer_indices()
        masks: Dict[Hashable, np.ndarray] = {}
        conf: Dict[Hashable, Tuple[float, int]] = {}
        for depth, li0 in enumerate(moe_list[:sched.lookahead], start=1):
            eids, pmasks, pconf = self._predict_ct(
                jnp.asarray(h_entry_row), li0)
            for e in eids:
                masks[(li0, e)] = pmasks[e]
                conf[(li0, e)] = (pconf[e], depth)
        self.tracker.set_contribution(req.uid, masks, conf)

    def _enqueue_tracked(self) -> None:
        sched = self.sched
        for key in self.tracker.keys():
            li, e = key
            mask = self.tracker.union(key)
            c, depth = self.tracker.confidence(key)
            sched.enqueue_prefetch(li, e, np.nonzero(mask)[0], c, depth)
        sched.pump()

    def _cross_token_speculate(self, reqs: List[SLORequest],
                               h_entry: np.ndarray) -> None:
        if not (self.pipe.prefetch and self.cross_token):
            return
        for i, r in enumerate(reqs):
            self._track_request(r, h_entry[i:i + 1])
        self._enqueue_tracked()

    # ----------------------------------------------- predictor train loop --
    def _grade_and_buffer(self, li: int, h_tops: Dict[int, jax.Array],
                          eids: np.ndarray, truth: list,
                          prev_entries: list) -> None:
        """Feed the calibrator with graded depth-1 predictions and buffer
        (proxy hidden, reuse logits, multi-hot truth) training rows.

        Two pair distributions, matching the two prediction usages:

        * same-token — proxy is the hidden state one layer earlier (the
          cross-layer depth-1 speculation input); the probe learns the
          residual of one block's transform on the router.
        * cross-token — proxy is the *previous* token's entry state (the
          cross-token speculation input for the first MoE layers).  The
          reuse fallback structurally cannot close this gap: its base is
          a different token's routing.  The probe learns temporal expert
          persistence on top of it — this is where trained beats reuse.
        """
        pred = self.pipe.last_pred.pop(li, None)
        if pred is not None:
            p_eids, p_conf, row_pred = pred
            tset = set(truth)
            for e in p_eids:
                self.calibrator.update(p_conf[e], e in tset)
            # per-row recall: a prediction's job is to name each token's
            # experts (union coverage conflates it with batch diversity)
            if row_pred.shape[0] == eids.shape[0]:
                for i in range(eids.shape[0]):
                    tr = set(int(x) for x in eids[i])
                    self.pred_stats["xl_true"] += len(tr)
                    self.pred_stats["xl_hit"] += \
                        len(tr & set(int(x) for x in row_pred[i]))
        if not self.online_train:
            return
        router = np.asarray(self.pipe.layers[li]["moe"]["router"],
                            np.float32)
        tgt = np.asarray(predictor.multi_hot(eids, self.cfg.num_experts))
        if li >= 1:
            proxy = np.asarray(h_tops[li - 1])
            base = proxy.astype(np.float32) @ router
            self._train_buf.setdefault(li, []).append((proxy, base, tgt))
        if li in self._first_moe:
            rows = [i for i, p in enumerate(prev_entries) if p is not None]
            if rows:
                proxy = np.stack([prev_entries[i] for i in rows])
                base = proxy.astype(np.float32) @ router
                self._train_buf_ct.setdefault(li, []).append(
                    (proxy, base, tgt[rows]))

    @staticmethod
    def _recall_at_k(logits: np.ndarray, tgt: np.ndarray, k: int) -> float:
        """Mean |top-k(logits) ∩ true| / |true| over rows."""
        pred = np.argsort(-logits, axis=1)[:, :k]
        hits = np.take_along_axis(tgt, pred, axis=1) > 0
        denom = np.maximum(tgt.sum(axis=1), 1.0)
        return float((hits.sum(axis=1) / denom).mean())

    def _fit_bank(self, bufs: Dict[int, list], bank: dict) -> bool:
        """Train one probe bank from its buffered (proxy, base, target)
        rows; ``bank`` maps layer -> probe params (updated in place).

        Adoption is VALIDATION-GATED: the freshly trained probe must beat
        both the router-reuse base and the currently adopted probe on a
        held-out slice of the freshest rows, otherwise the layer keeps
        what it has.  A trained predictor only ever replaces the fallback
        by *measured* payoff, so the trained path dominates reuse by
        construction (up to holdout noise)."""
        k = self.cfg.num_experts_per_tok
        trained = False
        for li, buf in bufs.items():
            rows = sum(b[0].shape[0] for b in buf)
            if rows < self.min_train_rows:
                continue
            h0 = np.concatenate([b[0] for b in buf])[-self.train_window:]
            base0 = np.concatenate([b[1] for b in buf])[-self.train_window:]
            tgt0 = np.concatenate([b[2] for b in buf])[-self.train_window:]
            # bound the buffer even if this round ends up skipped below
            bufs[li] = [(h0, base0, tgt0)]
            n_hold = max(h0.shape[0] // 4, 4)
            h_tr, h_ho = h0[:-n_hold], h0[-n_hold:]
            b_tr, b_ho = base0[:-n_hold], base0[-n_hold:]
            t_tr, t_ho = tgt0[:-n_hold], tgt0[-n_hold:]
            if h_tr.shape[0] < 4:
                continue
            # tile partial windows up to a fixed shape: full-batch Adam is
            # invariant to sample duplication and jit traces exactly once
            reps = -(-self.train_window // h_tr.shape[0])
            h = np.tile(h_tr, (reps, 1))[:self.train_window]
            base = np.tile(b_tr, (reps, 1))[:self.train_window]
            tgt = np.tile(t_tr, (reps, 1))[:self.train_window]
            params = bank.get(li)
            if params is None:
                self._key, sub = jax.random.split(self._key)
                params = predictor.init_inter_predictor(
                    sub, self.cfg.d_model, self.cfg.num_experts,
                    hidden=self.predictor_hidden)
            new = predictor.train_inter_predictor(
                params, jnp.asarray(h), jnp.asarray(tgt),
                steps=self.train_steps, base_logits=jnp.asarray(base))

            def probe_recall(p):
                lg = np.asarray(predictor.residual_inter_logits(
                    p, jnp.asarray(h_ho), jnp.asarray(b_ho)))
                return self._recall_at_k(lg, t_ho, k)

            r_base = self._recall_at_k(b_ho, t_ho, k)
            r_new = probe_recall(new)
            r_old = probe_recall(bank[li]) if li in bank else -1.0
            if r_new > max(r_base, r_old):  # strict: ties keep fallback
                bank[li] = new
                trained = True
            elif r_old < r_base:
                bank.pop(li, None)  # adopted probe went stale: fall back
            # keep a sliding window of the freshest (untiled) rows
            bufs[li] = [(h0[-self.train_window // 2:],
                         base0[-self.train_window // 2:],
                         tgt0[-self.train_window // 2:])]
        return trained

    def _train_predictors(self) -> None:
        self._tokens_since_train = 0
        t_xl = self._fit_bank(self._train_buf, self._bank_xl)
        t_ct = self._fit_bank(self._train_buf_ct, self.inter_ct)
        # project the cross-layer bank into the pipeline: adopted layers
        # get the residual probe; everything else reverts to whatever the
        # user supplied (standalone predictors keep their own residual
        # setting — the flag is per-layer, never global)
        for li in range(len(self.pipe.inter)):
            if li in self._bank_xl:
                self.pipe.inter[li] = self._bank_xl[li]
                self.pipe.inter_residual.add(li)
            else:
                self.pipe.inter[li] = self._user_inter[li]
                if li in self._user_residual:
                    self.pipe.inter_residual.add(li)
                else:
                    self.pipe.inter_residual.discard(li)
        trained = t_xl or t_ct
        if trained:
            self.train_rounds += 1
            # re-rank already-staged speculation under the new calibration
            # (from the RAW score each time — scales must not compound)
            scale = self.calibrator.scale
            for res in self.pipe.residency:
                if res is None:
                    continue
                for key in res.keys():
                    ent = res.peek(key)
                    if ent is not None and ent.prefetch:
                        res.rescore(key, min(1.0, ent.raw_score * scale))

    # -------------------------------------------------------------- loop ---
    def step(self) -> bool:
        """One control cycle; returns False when there is nothing left."""
        now = self.sched.clock
        if self.replan is not None:
            self.replan.on_step(now)
        self._ingest(now)
        self._retire(now)
        self._admission(now)
        if not self.running:
            if self.pending:  # idle: jump to the next arrival
                t_next = self.pending[0][0]
                dt = max(t_next - self.sched.clock, 0.0)
                self.stats["idle_s"] += dt
                # advance EXACTLY dt (the old +1e-12 tie-breaker drifted
                # busy+idle away from the clock by one epsilon per idle
                # gap); ingest against the arrival time itself so float
                # rounding of clock+dt can never strand the head request
                self.sched.advance(dt)
                self._ingest(max(self.sched.clock, t_next))
                return True
            return bool(self.queue)
        self._decode_step()
        return True

    def run(self) -> List[SLORequest]:
        while self.step():
            pass
        self._retire(self.sched.clock)
        return self.completed

    # ----------------------------------------------------------- reporting -
    def tokens_per_second(self) -> float:
        """Decode throughput over BUSY modeled time — queue-wait and idle
        gaps between arrivals are excluded (see ServingEngine fix)."""
        return self.stats["tokens"] / max(self.stats["busy_s"], 1e-12)

    def prediction_recall(self) -> float:
        """Fraction of true routed experts the prefetcher's predictions
        named (cross-layer + cross-token), graded at reconcile time."""
        hit = self.pred_stats["xl_hit"] + self.pred_stats["ct_hit"]
        true = self.pred_stats["xl_true"] + self.pred_stats["ct_true"]
        return hit / true if true else 1.0

    def reset_pred_stats(self) -> None:
        for k in self.pred_stats:
            self.pred_stats[k] = 0

    def tenant_report(self) -> dict:
        """Per-tenant attainment / latency over every tracked request
        (``repro.workload`` tags requests with their traffic class;
        untagged requests group under ``""``)."""
        groups: Dict[str, dict] = {}
        for r in self.completed + self.rejected:
            g = groups.setdefault(r.tenant, {
                "completed": 0, "rejected": 0, "attained": 0,
                "ttfts": [], "tpots": []})
            if r.rejected:
                g["rejected"] += 1
                continue
            g["completed"] += 1
            g["attained"] += int(r.attained)
            if r.ttft is not None:
                g["ttfts"].append(r.ttft)
            if r.tpot is not None:
                g["tpots"].append(r.tpot)
        out = {}
        for name in sorted(groups):
            g = groups[name]
            total = g["completed"] + g["rejected"]
            out[name] = {
                "completed": g["completed"],
                "rejected": g["rejected"],
                "slo_attainment": g["attained"] / total if total else 1.0,
                "ttft_ms_mean": (1e3 * float(np.mean(g["ttfts"]))
                                 if g["ttfts"] else 0.0),
                "tpot_ms_mean": (1e3 * float(np.mean(g["tpots"]))
                                 if g["tpots"] else 0.0),
            }
        return out

    def slo_attainment(self) -> float:
        total = (len(self.completed) + len(self.rejected) +
                 len(self.queue) + len(self.running) + len(self.pending))
        if total == 0:
            return 1.0
        return sum(r.attained for r in self.completed) / total

    def report(self) -> dict:
        done = self.completed
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tpots = [r.tpot for r in done if r.tpot is not None]
        cluster = {}
        if self.pipe.cluster_plan is not None:
            # batched decode over repro.cluster: per-device links under
            # the same union-demand path (split per owning device)
            cluster = {
                "devices": self.pipe.cluster_plan.n_devices,
                "agg_link_utilization":
                    self.pipe.engine.aggregate_utilization(self.sched.clock),
                "replica_routed": self.sched.selector.replica_choices,
            }
        return {
            **cluster,
            "policy": self.policy,
            "completed": len(done),
            "rejected": len(self.rejected),
            "preemptions": self.stats["preemptions"],
            "swaps_in": self.stats["swaps_in"],
            "swaps_out": self.stats["swaps_out"],
            "slo_attainment": self.slo_attainment(),
            "ttft_ms_mean": 1e3 * float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_ms_p99": 1e3 * float(np.percentile(ttfts, 99))
            if ttfts else 0.0,
            "tpot_ms_mean": 1e3 * float(np.mean(tpots)) if tpots else 0.0,
            "tokens": self.stats["tokens"],
            "tokens_per_s": self.tokens_per_second(),
            "busy_s": self.stats["busy_s"],
            "prefetch_recall": self.sched.prefetch_recall(),
            "prefetch_precision": self.sched.prefetch_precision(),
            "prediction_recall": self.prediction_recall(),
            "demand_topups": self.sched.stats.demand_topups,
            "draft_fetches": self.sched.stats.draft_fetches,
            "refines_applied": self.sched.stats.refines_applied,
            "train_rounds": self.train_rounds,
            "calibration_scale": self.calibrator.scale,
            **(self.speculator.report()
               if self.speculator is not None else {}),
        }

    def metrics_snapshot(self) -> dict:
        """Deterministic flat metrics snapshot (``repro.obs`` registry):
        scheduler counters, stall attribution by cause (with the
        conservation check), prefetch precision/recall, per-expert
        activation frequencies, request TTFT/TPOT histograms broken into
        queue-wait / stall / compute, and the serving control-plane
        counters."""
        reg = MetricsRegistry()
        scheduler_metrics(reg, self.sched)
        request_metrics(reg, self.completed)
        for k, v in self.stats.items():
            reg.counter(f"serving.{k}").inc(v)
        reg.counter("serving.completed").inc(len(self.completed))
        reg.counter("serving.rejected_total").inc(len(self.rejected))
        reg.gauge("serving.slo_attainment").set(self.slo_attainment())
        reg.gauge("serving.prediction_recall").set(self.prediction_recall())
        if self.speculator is not None:
            for k, val in self.speculator.report().items():
                if k == "spec_accept_rate":
                    reg.gauge("spec.accept_rate").set(val)
                else:
                    reg.counter(f"spec.{k[5:] if k.startswith('spec_') else k}"
                                ).inc(val)
        return reg.snapshot()
