"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def silu(x):
    return x * jax.nn.sigmoid(x)


def sparse_gemv_ref(x: jax.Array, v: jax.Array, w_gate: jax.Array,
                    w_down: jax.Array, block_active: jax.Array,
                    block_size: int) -> jax.Array:
    """Oracle for the block-sparse fused SwiGLU GEMV (Algorithm 1, TPU form).

    x (B, D); v (B, F) already-thresholded up output (zeros pruned);
    w_gate (D, F); w_down (F, D); block_active (F/block,) int32.
    Inactive blocks contribute exactly nothing.
    """
    b, d = x.shape
    f = v.shape[-1]
    mask = jnp.repeat(block_active.astype(bool), block_size)[None, :]
    g = silu((x.astype(jnp.float32) @ w_gate.astype(jnp.float32)))
    h = g * v.astype(jnp.float32) * mask
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


def unpack_codes_ref(packed: jax.Array, bits: int, length: int) -> jax.Array:
    """packed (G, L/per, F) uint8 -> codes (G, L, F) uint8."""
    per = 8 // bits
    g, lp, f = packed.shape
    shifts = jnp.arange(per, dtype=jnp.uint8) * bits
    mask = jnp.uint8((1 << bits) - 1)
    q = (packed[:, :, None, :] >> shifts[None, None, :, None]) & mask
    return q.reshape(g, lp * per, f)[:, :length]


def quant_gemv_ref(x: jax.Array, packed: jax.Array, scale: jax.Array,
                   zero: jax.Array, bits: int, group: int) -> jax.Array:
    """Oracle for the fused INT-b dequant GEMV.

    x (B, D); packed (G, group/per, F) uint8; scale/zero (G, 1, F) f32,
    with D = G*group.  Returns x @ dequant(W) as f32 (B, F).
    """
    codes = unpack_codes_ref(packed, bits, group)  # (G, group, F)
    w = scale * (codes.astype(jnp.float32) - zero)  # (G, group, F)
    d = w.shape[0] * w.shape[1]
    w = w.reshape(d, -1)
    return x.astype(jnp.float32) @ w
