"""Fused INT-b dequant GEMV — the up-projection kernel (FloE §3.2.2).

Computes v = x · dequant(W_up^q) where W_up is HQQ group-quantized and
bit-packed.  Dequantization (unpack → scale·(q - zero)) happens in VMEM
registers per tile, so HBM traffic is the PACKED bytes — the whole point of
shipping the up projection at INT2.

Tiling: grid over (F blocks); each step processes the full D (= G·group)
contraction for one 128-wide F tile.  Packed codes arrive as
(G, group/per, blk) uint8 tiles; scales/zeros as (G, 1, blk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hqq import QTensor


def _kernel(x_ref, packed_ref, scale_ref, zero_ref, o_ref, *, bits: int,
            group: int):
    per = 8 // bits
    codes_mask = (1 << bits) - 1
    packed = packed_ref[...]  # (G, group/per, blk) uint8
    g_, lp, blk = packed.shape
    # unpack bits -> (G, group, blk). uint8 shifts keep it integer-only.
    shifts = (jnp.arange(per, dtype=jnp.int32) * bits)
    codes = (packed[:, :, None, :].astype(jnp.int32)
             >> shifts[None, None, :, None]) & codes_mask
    codes = codes.reshape(g_, lp * per, blk)[:, :group]
    w = scale_ref[...] * (codes.astype(jnp.float32) - zero_ref[...])
    w = w.reshape(g_ * group, blk)  # (D, blk) dequantized tile
    x = x_ref[...].astype(jnp.float32)  # (B, D)
    o_ref[...] = (x @ w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_size", "interpret"))
def quant_gemv(x: jax.Array, qt: QTensor, *, block_size: int = 128,
               interpret: bool = True) -> jax.Array:
    """x (B, D) @ dequant(qt (D, F)) -> (B, F) f32."""
    b, d = x.shape
    m, f = qt.shape
    assert m == d, (m, d)
    assert f % block_size == 0
    g = d // qt.group
    lp = qt.packed.shape[1]
    nblk = f // block_size

    kernel = functools.partial(_kernel, bits=qt.bits, group=qt.group)
    fn = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((g, lp, block_size), lambda i: (0, 0, i)),
            pl.BlockSpec((g, 1, block_size), lambda i: (0, 0, i)),
            pl.BlockSpec((g, 1, block_size), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((b, block_size), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.float32),
        interpret=interpret,
    )
    return fn(x, qt.packed, qt.scale.astype(jnp.float32),
              qt.zero.astype(jnp.float32))
