"""Block-sparse fused SwiGLU GEMV — Pallas TPU adaptation of FloE Alg. 1.

The Triton original gathers individual gate columns / down rows by a
per-channel mask.  TPUs cannot gather lanes from HBM, but they CAN skip
whole VMEM tiles: we tile the intermediate dimension F into lane-aligned
blocks (128 by default), precompute a per-block activity flag (any channel
in the block above threshold — sparsify.block_union_mask), prefetch the
flags as scalars, and ``@pl.when``-skip the gate/down tile compute for dead
blocks.  Memory traffic and MXU work scale with the number of *active
blocks*, which is the TPU-native unit of the paper's saving.

Grid: one step per F-block.  Output (B, D) is accumulated across steps in
VMEM (constant index_map), initialized at step 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(active_ref, x_ref, v_ref, wg_ref, wd_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(active_ref[i] > 0)
    def _compute():
        x = x_ref[...].astype(jnp.float32)  # (B, D)
        wg = wg_ref[...].astype(jnp.float32)  # (D, blk)
        g = x @ wg  # MXU
        g = g * jax.nn.sigmoid(g)  # fused SiLU (VPU)
        h = g * v_ref[...].astype(jnp.float32)  # (B, blk)
        wd = wd_ref[...].astype(jnp.float32)  # (blk, D)
        o_ref[...] += (h @ wd).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_size", "interpret"))
def sparse_gemv(x: jax.Array, v: jax.Array, w_gate: jax.Array,
                w_down: jax.Array, block_active: jax.Array,
                *, block_size: int = 128, interpret: bool = True
                ) -> jax.Array:
    """y = (SiLU(x W_gate) * v) W_down computed only on active F-blocks.

    x (B, D); v (B, F) thresholded up output; w_gate (D, F); w_down (F, D);
    block_active (F/block_size,) int32 (nonzero = compute the block).
    """
    b, d = x.shape
    f = v.shape[-1]
    assert f % block_size == 0, (f, block_size)
    nblk = f // block_size
    assert block_active.shape == (nblk,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i, *_: (0, 0)),  # x: whole
            pl.BlockSpec((b, block_size), lambda i, *_: (0, i)),  # v block
            pl.BlockSpec((d, block_size), lambda i, *_: (0, i)),  # gate cols
            pl.BlockSpec((block_size, d), lambda i, *_: (i, 0)),  # down rows
        ],
        out_specs=pl.BlockSpec((b, d), lambda i, *_: (0, 0)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )
    return fn(block_active.astype(jnp.int32), x, v, w_gate, w_down
              ).astype(x.dtype)


# --------------------------------------------------- compacted-grid variant -
def _kernel_compact(meta_ref, x_ref, v_ref, wg_ref, wd_ref, o_ref):
    """meta = [n_active, idx_0, idx_1, ...]; grid step i handles the i-th
    ACTIVE block — dead blocks are never visited, so HBM→VMEM traffic for
    gate/down tiles scales with the active count, not F."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(i < meta_ref[0])
    def _compute():
        x = x_ref[...].astype(jnp.float32)
        g = x @ wg_ref[...].astype(jnp.float32)
        g = g * jax.nn.sigmoid(g)
        h = g * v_ref[...].astype(jnp.float32)
        o_ref[...] += (h @ wd_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_size", "max_blocks", "interpret"))
def sparse_gemv_compact(x: jax.Array, v: jax.Array, w_gate: jax.Array,
                        w_down: jax.Array, block_active: jax.Array,
                        *, block_size: int = 128,
                        max_blocks: int = 0, interpret: bool = True
                        ) -> jax.Array:
    """Like sparse_gemv but the grid enumerates only active blocks.

    The index_map reads the scalar-prefetched active-block ids, so the
    pipeline fetches gate/down tiles ONLY for active blocks — the TPU
    equivalent of the paper's masked column loads.  ``max_blocks`` bounds
    the grid statically (0 = F/block_size, i.e. worst case).
    """
    b, d = x.shape
    f = v.shape[-1]
    assert f % block_size == 0
    nblk = f // block_size
    max_blocks = max_blocks or nblk
    assert block_active.shape == (nblk,)

    flags = block_active.astype(jnp.int32)
    n_active = jnp.sum(flags)
    # stable compaction of active ids; tail padded with last valid id
    order = jnp.argsort(-flags, stable=True).astype(jnp.int32)
    safe = jnp.where(jnp.arange(nblk) < n_active, order, order[0])
    meta = jnp.concatenate([jnp.minimum(n_active, max_blocks)[None],
                            safe[:max_blocks]]).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(max_blocks,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i, meta: (0, 0)),
            pl.BlockSpec((b, block_size), lambda i, meta: (0, meta[i + 1])),
            pl.BlockSpec((d, block_size), lambda i, meta: (0, meta[i + 1])),
            pl.BlockSpec((block_size, d), lambda i, meta: (meta[i + 1], 0)),
        ],
        out_specs=pl.BlockSpec((b, d), lambda i, meta: (0, 0)),
    )
    fn = pl.pallas_call(
        _kernel_compact,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )
    return fn(meta, x, v, w_gate, w_down).astype(x.dtype)
