"""Public jit'd wrappers around the Pallas kernels.

``interpret=True`` (the default on CPU) executes the kernel bodies in
Python for correctness; on a real TPU pass ``interpret=False``.

``floe_expert_gemv`` is the end-to-end Algorithm 1: fused INT-b up GEMV →
threshold mask → block-union → compacted block-sparse SwiGLU GEMV.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.hqq import QTensor
from repro.core import sparsify
from repro.kernels import ref
from repro.kernels.quant_gemv import quant_gemv
from repro.kernels.sparse_gemv import sparse_gemv, sparse_gemv_compact

ON_TPU = any(d.platform == "tpu" for d in jax.devices())
DEFAULT_INTERPRET = not ON_TPU


@functools.partial(jax.jit, static_argnames=("block_size", "interpret",
                                             "compact"))
def floe_expert_gemv(x: jax.Array, qt_up: QTensor, w_gate: jax.Array,
                     w_down: jax.Array, threshold: jax.Array,
                     *, block_size: int = 128,
                     interpret: bool = DEFAULT_INTERPRET,
                     compact: bool = True) -> jax.Array:
    """FloE Algorithm 1 on TPU tiles.

    x (B, D); qt_up packed (D, F); w_gate (D, F); w_down (F, D);
    threshold scalar (this expert's calibrated t). Returns y (B, D).
    """
    v = quant_gemv(x, qt_up, block_size=block_size, interpret=interpret)
    v = sparsify.s_t(v, threshold)
    mask = v != 0.0
    block_active = sparsify.block_union_mask(mask, block_size).any(axis=0)
    kern = sparse_gemv_compact if compact else sparse_gemv
    return kern(x, v, w_gate, w_down, block_active.astype(jnp.int32),
                block_size=block_size, interpret=interpret)


def floe_expert_gemv_ref(x, qt_up: QTensor, w_gate, w_down, threshold,
                         block_size: int = 128):
    """Pure-jnp oracle of the full fused path."""
    v = ref.quant_gemv_ref(x, qt_up.packed, qt_up.scale, qt_up.zero,
                           qt_up.bits, qt_up.group)
    v = sparsify.s_t(v, threshold)
    mask = v != 0.0
    ba = sparsify.block_union_mask(mask, block_size).any(axis=0)
    return ref.sparse_gemv_ref(x, v, w_gate, w_down,
                               ba.astype(jnp.int32), block_size)


__all__ = ["quant_gemv", "sparse_gemv", "sparse_gemv_compact",
           "floe_expert_gemv", "floe_expert_gemv_ref", "DEFAULT_INTERPRET"]
