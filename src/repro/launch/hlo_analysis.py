"""Post-SPMD HLO analysis: collective bytes with while-loop trip weighting.

``compiled.as_text()`` exposes one partition's optimized HLO.  Collectives
inside ``while`` bodies (scan-over-layers!) appear once statically but run
once per trip — we recover trip counts from the loop-condition constant
(`compare(induction, constant(N)), direction=LT`) and weight bytes
accordingly, recursing through nested loops (layer scan × attention
query-chunk scan).

Bytes metric: the RESULT shape bytes of each collective op (≈ per-device
payload; all-gather counts the gathered size, reduce-scatter the scattered
size).  This is the operand-size convention the roofline instructions ask
for, applied on the receiving side.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "all-to-all", "reduce-scatter",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+(?:\([^)]*\)\s*->.*)?{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every tensor shape appearing in the string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class Computation:
    name: str
    collective_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    collective_counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    whiles: List[Tuple[str, str]] = field(default_factory=list)  # (cond, body)
    calls: List[str] = field(default_factory=list)  # call/cond targets
    fusion_calls: List[str] = field(default_factory=list)  # fusion bodies
    dot_flops: float = 0.0
    result_bytes: float = 0.0  # sum of non-trivial instruction result bytes
    constants: List[int] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # var -> shape str
    has_compare: bool = False


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if stripped.endswith("{") and ("(" in stripped or "ENTRY" in stripped):
            m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
            continue
        if stripped.strip() == "}":
            continue
        if cur is None:
            continue
        # collectives: "%name = SHAPE op-name(...)"
        for op in COLLECTIVE_OPS:
            m = re.search(rf"=\s*((?:\([^)]*\))|(?:\S+))\s+{op}(-start|-done)?\(",
                          stripped)
            if m:
                if m.group(2) == "-done":
                    break  # start/done pairs: count the start only
                b = _shape_bytes(m.group(1))
                cur.collective_bytes[op] += b
                cur.collective_counts[op] += 1
                break
        m = _WHILE_RE.search(stripped)
        if m and "while(" in stripped:
            cur.whiles.append((m.group(1), m.group(2)))
        for c in _CONST_RE.findall(stripped):
            cur.constants.append(int(c))
        if _COMPARE_RE.search(stripped):
            cur.has_compare = True

        # instruction shape table: "%var = TYPE[dims]{layout} op(...)"
        mi = re.match(r"\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                      r"((?:\([^)]*\))|(?:\S+))\s+([\w\-]+)", stripped)
        if mi:
            var, shape_str, opname = mi.groups()
            cur.shapes[var] = shape_str
            if opname == "dot":
                cur.dot_flops += _dot_flops(stripped, cur.shapes)
            elif opname == "fusion":
                mc = re.search(r"calls=%?([\w\.\-]+)", stripped)
                if mc:
                    cur.fusion_calls.append(mc.group(1))
            elif opname == "call":
                mc = re.search(r"to_apply=%?([\w\.\-]+)", stripped)
                if mc:
                    cur.calls.append(mc.group(1))
            elif opname == "conditional":
                for b in re.findall(r"([\w\.\-]+)",
                                    (re.search(r"branch_computations=\{([^}]*)\}",
                                               stripped) or [None, ""])[1]):
                    cur.calls.append(b)
            if opname not in _FREE_OPS:
                cur.result_bytes += _shape_bytes(var and mi.group(2))
    return comps


_FREE_OPS = frozenset({
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
})


# operand may carry an inline shape ("dot(f32[4,32]{1,0} %x, ...)") in
# newer jax as_text output, or be bare ("dot(%x, ...)")
_DOT_OPERANDS_RE = re.compile(
    r"dot\(\s*(?:(\w+\[[\d,]*\](?:\{[^}]*\})?)\s+)?%?([\w\.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dims_of(shape_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d)


def _dot_flops(line: str, shapes: Dict[str, str]) -> float:
    """2 · prod(result dims) · prod(lhs contracting dims)."""
    mres = re.search(r"=\s*((?:\([^)]*\))|(?:\S+))\s+dot\(", line)
    if not mres:
        return 0.0
    result = 1
    for d in _dims_of(mres.group(1)):
        result *= d
    mop = _DOT_OPERANDS_RE.search(line)
    mcd = _LHS_CDIMS_RE.search(line)
    contract = 1
    if mop and mcd:
        if mop.group(1):  # inline operand shape
            lhs_shape = _dims_of(mop.group(1))
        else:
            lhs_shape = _dims_of(shapes.get(mop.group(2), ""))
        for idx in (int(i) for i in mcd.group(1).split(",") if i):
            if idx < len(lhs_shape):
                contract *= lhs_shape[idx]
    return 2.0 * result * contract


def _trip_count(cond: Optional[Computation]) -> int:
    """Best-effort trip count from the loop condition's compare constant."""
    if cond is None or not cond.constants:
        return 1
    cands = [c for c in cond.constants if 0 < c <= 100000]
    return max(cands) if cands else 1


def _entry_name(comps: Dict[str, Computation]) -> str:
    return next((n for n in comps if n.startswith("main")), None) or \
        list(comps.keys())[-1]


def computation_weights(comps: Dict[str, Computation],
                        entry: Optional[str] = None) -> Dict[str, float]:
    """Execution multiplicity per computation: while bodies × trip count,
    fusion/call/conditional targets × 1 per call site, summed over call
    sites (the computation graph is a DAG; iterate to fixpoint)."""
    if not comps:
        return {}
    entry = entry or _entry_name(comps)

    # edge list: parent -> [(child, multiplier)]
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for name, comp in comps.items():
        for cond_name, body_name in comp.whiles:
            trips = _trip_count(comps.get(cond_name))
            edges[name].append((body_name, float(trips)))
            edges[name].append((cond_name, float(trips) + 1.0))
        for c in comp.calls + comp.fusion_calls:
            if c in comps:
                edges[name].append((c, 1.0))

    # Kahn-style accumulation over the call DAG
    indeg: Dict[str, int] = defaultdict(int)
    for parent, outs in edges.items():
        for child, _ in outs:
            indeg[child] += 1
    weights: Dict[str, float] = defaultdict(float)
    weights[entry] = 1.0
    queue = [n for n in comps if indeg[n] == 0]
    seen = set()
    while queue:
        n = queue.pop()
        if n in seen:
            continue
        seen.add(n)
        for child, mult in edges.get(n, []):
            weights[child] += weights[n] * mult
            indeg[child] -= 1
            if indeg[child] == 0:
                queue.append(child)
    return weights


def collective_summary(text: str, entry: Optional[str] = None
                       ) -> Dict[str, Dict[str, float]]:
    """Returns {op: {"bytes": weighted_bytes, "count": weighted_count}}."""
    comps = parse_hlo(text)
    if not comps:
        return {}
    weights = computation_weights(comps, entry)
    out: Dict[str, Dict[str, float]] = defaultdict(lambda: {"bytes": 0.0,
                                                            "count": 0.0})
    for name, comp in comps.items():
        w = weights.get(name, 0.0)
        if w <= 0:
            continue
        for op, b in comp.collective_bytes.items():
            out[op]["bytes"] += w * b
            out[op]["count"] += w * comp.collective_counts[op]
    return dict(out)


def total_collective_bytes(text: str) -> float:
    return sum(v["bytes"] for v in collective_summary(text).values())


def dot_flops_total(text: str, entry: Optional[str] = None) -> float:
    """Trip-weighted matmul FLOPs across the module (dots only; elementwise
    flops are negligible at model scale and loop-invisible in XLA's own
    cost analysis anyway)."""
    comps = parse_hlo(text)
    weights = computation_weights(comps, entry)
    return sum(weights.get(n, 0.0) * c.dot_flops for n, c in comps.items())


def hbm_bytes_estimate(text: str, entry: Optional[str] = None) -> float:
    """Trip-weighted HBM traffic estimate.

    Convention: each non-fusion-internal instruction writes its result once
    and reads its operands once; with producer-consumer pairing that is ≈ 2×
    the weighted result bytes.  Fusion-internal instructions never touch
    HBM, so computations reached (only) through fusion calls are excluded.
    """
    comps = parse_hlo(text)
    weights = computation_weights(comps, entry)
    fusion_children = set()
    for c in comps.values():
        fusion_children.update(c.fusion_calls)
    total = 0.0
    for name, comp in comps.items():
        if name in fusion_children:
            continue
        total += weights.get(name, 0.0) * comp.result_bytes
    return 2.0 * total
