"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run script
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (smoke tests, benches) sees the real single device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.common.compat import mesh_kwargs
from repro.common.config import MeshConfig, MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_kwargs(len(axes)))


def make_mesh(cfg: MeshConfig) -> Mesh:
    return jax.make_mesh(cfg.shape, cfg.axes, **mesh_kwargs(len(cfg.axes)))


def make_local_mesh(*, model: int = 1, data: int = 1) -> Mesh:
    """Tiny mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"), **mesh_kwargs(2))


def mesh_config(mesh: Mesh) -> MeshConfig:
    return MeshConfig(tuple(mesh.devices.shape), tuple(mesh.axis_names))


__all__ = ["make_production_mesh", "make_mesh", "make_local_mesh",
           "mesh_config", "SINGLE_POD", "MULTI_POD"]
