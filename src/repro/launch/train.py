"""Training launcher: pjit train step + host loop.

Usage (CPU smoke):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --reduced --steps 50 --batch 8 --seq 128

The same ``build_train_step`` is what the multi-pod dry-run lowers against
the production mesh (launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import ModelConfig, TrainConfig, reduced as reduce_cfg
from repro.common import sharding as shd
from repro.models import transformer as tf
from repro.models import nn
from repro.models.moe import Dist
from repro.optim import adamw_init, adamw_update
from repro.data import SyntheticLM, make_batches


def make_dist(mesh: Optional[Mesh], *, batch_sharded: bool = True
              ) -> Optional[Dist]:
    if mesh is None:
        return None
    axes = tuple(mesh.axis_names)
    batch_axes = ("pod", "data") if "pod" in axes else ("data",)
    return Dist(mesh=mesh, batch_axes=batch_axes, batch_sharded=batch_sharded)


def build_train_step(cfg: ModelConfig, tc: TrainConfig,
                     mesh: Optional[Mesh] = None, *,
                     microbatch: int = 0, donate: bool = True):
    """Returns (step_fn, in_shardings, out_shardings) — jit-ready."""
    dist = make_dist(mesh)

    def loss(params, batch):
        return tf.loss_fn(params, batch, cfg, dist, remat=tc.remat)

    def step(params, opt_state, batch):
        if microbatch and microbatch > 1:
            # gradient accumulation over the leading batch axis
            def one(carry, mb):
                gsum, lsum = carry
                (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None
            mbatch = jax.tree.map(
                lambda a: a.reshape((microbatch, -1) + a.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(one, (zeros, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / microbatch, gsum)
            l = lsum / microbatch
            metrics = {"loss": l}
        else:
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
        params, opt_state, om = adamw_update(grads, opt_state, params, tc)
        metrics = dict(metrics)
        metrics.update(om)
        return params, opt_state, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ()), None, None

    axes, shape = tuple(mesh.axis_names), tuple(mesh.devices.shape)
    pspec = shd.shard_params_spec(
        jax.eval_shape(lambda k: tf.init_model(k, cfg), jax.random.PRNGKey(0)),
        axes, shape, cfg)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                          is_leaf=lambda x: isinstance(x, P))

    def batch_shardings(batch_tree):
        return jax.tree.map(
            lambda v: NamedSharding(mesh, shd.batch_spec(axes, v.ndim - 1)),
            batch_tree)

    step_jit = jax.jit(
        step,
        in_shardings=(pshard, _opt_sharding(mesh, pshard), None),
        out_shardings=(pshard, _opt_sharding(mesh, pshard), None),
        donate_argnums=(0, 1) if donate else (),
    )
    return step_jit, pshard, batch_shardings


def _opt_sharding(mesh, pshard):
    from repro.optim.adamw import AdamWState
    return AdamWState(NamedSharding(mesh, P()), pshard, pshard)


def init_sharded(cfg: ModelConfig, mesh: Optional[Mesh], seed: int = 0,
                 dtype=nn.DEFAULT_DTYPE):
    key = jax.random.PRNGKey(seed)
    if mesh is None:
        params = tf.init_model(key, cfg, dtype)
        return params, adamw_init(params)
    axes, shape = tuple(mesh.axis_names), tuple(mesh.devices.shape)
    pspec = shd.shard_params_spec(
        jax.eval_shape(lambda k: tf.init_model(k, cfg, dtype), key),
        axes, shape, cfg)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                          is_leaf=lambda x: isinstance(x, P))
    params = jax.jit(lambda k: tf.init_model(k, cfg, dtype),
                     out_shardings=pshard)(key)
    opt = jax.jit(adamw_init,
                  out_shardings=_opt_sharding(mesh, pshard))(params)
    return params, opt


# ----------------------------------------------------------------- loop ----
def train_loop(cfg: ModelConfig, tc: TrainConfig, *, batch: int, seq: int,
               steps: int, mesh: Optional[Mesh] = None, log_every: int = 10,
               microbatch: int = 0, data_seed: int = 0, dtype=jnp.float32):
    params, opt_state = init_sharded(cfg, mesh, tc.seed, dtype)
    step_fn, _, _ = build_train_step(cfg, tc, mesh, microbatch=microbatch)
    source = SyntheticLM(cfg.vocab_size, seed=data_seed)
    history = []
    t0 = time.perf_counter()
    for i, hbatch in enumerate(make_batches(source, batch, seq, steps,
                                            seed=data_seed)):
        jbatch = {k: jnp.asarray(v) for k, v in hbatch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, jbatch)
        if i % log_every == 0 or i == steps - 1:
            l = float(metrics["loss"])
            history.append((i, l))
            dt = time.perf_counter() - t0
            print(f"step {i:5d} loss {l:7.4f} "
                  f"gnorm {float(metrics.get('grad_norm', 0)):8.3f} "
                  f"({dt:6.1f}s)", flush=True)
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d_model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--save", default="")
    args = ap.parse_args()

    from repro.configs import get_config
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, layers=args.layers, d_model=args.d_model)
    tc = TrainConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1))
    params, opt_state, hist = train_loop(
        cfg, tc, batch=args.batch, seq=args.seq, steps=args.steps,
        microbatch=args.microbatch)
    if args.save:
        from repro.checkpoint import save_checkpoint
        n = save_checkpoint(args.save, params)
        print(f"saved {n/1e6:.1f}MB -> {args.save}")
    first, last = hist[0][1], hist[-1][1]
    print(f"loss {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
