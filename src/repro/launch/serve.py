"""Serving launcher — a thin front-end over ``repro.deploy``.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --reduced --mode floe --requests 8 --max_new 16

Flags are parsed into ONE typed :class:`repro.deploy.DeploymentSpec`
(eagerly validated — a bad combination fails here, not mid-build), or
the whole spec is loaded from a file:

    python -m repro.launch.serve --spec examples/deploy_mixtral_11gb.json
    python -m repro.launch.serve --mode floe --vram-gb 0.0012 --dump-spec

``repro.deploy.build(spec)`` then resolves params, thresholds, plans
(``plan_store`` / ``plan_cluster``), pipeline, and — for floe-serve —
the SLO controller; this file only prints the resulting telemetry.

Modes:
  resident   — all weights on device, batched engine (repro.serving)
  naive      — whole-expert fp16 offload per miss (baseline)
  floe       — the paper's pipeline: hybrid compression + dual predictors +
               prefetch (repro.core.pipeline)
  floe-serve — SLO-aware continuous-batching controller over the runtime
               scheduler (repro.serving.controller)

``--vram-gb B`` plans the tiered parameter store for the budget;
``--devices N`` (with ``--replicate R``) spreads experts over N
simulated GPUs — with ``--vram-gb`` the budget is PER DEVICE.
"""
from __future__ import annotations

import argparse
import sys


def spec_from_args(args) -> "DeploymentSpec":
    """Flags -> typed spec (the validation lives in the spec, not here)."""
    from repro.deploy import (DeploymentSpec, HealthSpec, ModelSpec,
                              ReplanSpec, ResourceSpec, RuntimeSpec,
                              ServingSpec, SpeculationSpec)
    offloaded = args.mode in ("floe", "naive")
    serving = None
    replan = None
    health = None
    speculation = None
    if args.mode == "floe-serve":
        serving = ServingSpec(
            slots=args.slots, max_len=256, policy=args.policy,
            slo_ms=args.slo_ms, online_train=True, train_every_tokens=16,
            train_window=64, min_train_rows=32, train_steps=40)
        if args.replan:
            replan = ReplanSpec()
        if args.health:
            health = HealthSpec(incident_dir=args.incident_dir)
        if args.speculate:
            speculation = SpeculationSpec()
    return DeploymentSpec(
        model=ModelSpec(arch=args.arch, reduced=args.reduced,
                        layers=args.layers, d_model=args.d_model,
                        train_steps=args.train_steps, ckpt=args.ckpt),
        resources=ResourceSpec(
            vram_gb=args.vram_gb, host_gb=args.host_gb,
            devices=args.devices, replicate=args.replicate,
            store_dir=args.store_dir,
            progressive=not args.no_progressive),
        runtime=RuntimeSpec(
            mode="floe" if args.mode == "floe-serve" else args.mode,
            use_runtime=(args.vram_gb > 0 or args.devices > 1 or
                         args.replicate > 0 or args.mode == "floe-serve"),
            cache_slots=args.cache_slots),
        serving=serving, replan=replan, health=health,
        speculation=speculation)


def print_plan(dep) -> None:
    from repro.cluster import ClusterPlan
    from repro.store import dense_residency_bytes
    plan = dep.plan
    if plan is None:
        return
    dense_gb = dense_residency_bytes(dep.cfg) / 2 ** 30
    if isinstance(plan, ClusterPlan):
        tag = "" if plan.store_plan is not None else " (placement-only)"
        print(f"cluster plan{tag}: {plan.summary()}")
        if plan.vram_budget_per_device:
            print(f"  dense-resident needs {dense_gb:.3f}GiB on one "
                  f"device; budget "
                  f"{plan.vram_budget_per_device / 2 ** 30:.3f}GiB x "
                  f"{plan.n_devices} devices")
        for d in range(plan.n_devices):
            print(f"  {plan.device_summary(d)}")
    else:
        budget_gb = plan.vram_budget / 2 ** 30
        print(f"store plan: {plan.summary()}")
        print(f"  dense-resident would need {dense_gb:.3f}GiB; budget "
              f"{budget_gb:.3f}GiB ({budget_gb / dense_gb:.2f}x dense)")
        for part, nbytes in plan.breakdown.items():
            print(f"  {part:>16}: {nbytes / 2 ** 20:8.2f}MiB")


def print_store_telemetry(dep) -> None:
    pipe = dep.pipeline
    if pipe.sched is None or pipe.store_plan is None and \
            pipe.cluster_plan is None:
        return
    s = pipe.sched.stats
    if pipe.cluster_plan is not None:
        for pool in pipe.device_pools:
            pool.check_invariants()
        eng = pipe.engine
        busy = eng.summary()["busy_s_per_device"]
        print(f"cluster: devices={pipe.cluster_plan.n_devices} "
              f"agg_link_util="
              f"{eng.aggregate_utilization(pipe.sched.clock):.2%} "
              f"busy/dev={[round(b * 1e3, 1) for b in busy]}ms "
              f"demand_fetches={s.demand_fetches} "
              f"replica_routed={pipe.sched.selector.replica_choices}")
        if pipe.host_tier is not None:
            print(f"  host_hit_rate={pipe.host_tier.stats.hit_rate:.2f} "
                  f"disk_reads={pipe.host_tier.disk.stats.reads} "
                  f"pool_free=" +
                  "/".join(f"{p.free_slabs}:{p.num_slabs}"
                           for p in pipe.device_pools))
    elif pipe.store_plan is not None:
        pipe.device_pool.check_invariants()
        print(f"store: demand_fetches={s.demand_fetches} "
              f"drafts={s.draft_fetches} refined={s.refines_applied} "
              f"topups={s.demand_topups} "
              f"host_hit_rate={pipe.host_tier.stats.hit_rate:.2f} "
              f"disk_reads={pipe.host_tier.disk.stats.reads} "
              f"pool_free={pipe.device_pool.free_slabs}/"
              f"{pipe.device_pool.num_slabs}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default="",
                    help="load the full DeploymentSpec from a JSON file "
                         "(the other flags are ignored)")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the resolved spec JSON and exit")
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--mode",
                    choices=["resident", "naive", "floe", "floe-serve"],
                    default="floe")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d_model", type=int, default=128)
    ap.add_argument("--train_steps", type=int, default=0,
                    help="briefly pre-train so activations have structure")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max_new", type=int, default=16)
    ap.add_argument("--cache_slots", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2,
                    help="floe-serve: concurrent batch slots")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="floe-serve: mean arrivals per modeled second")
    ap.add_argument("--scenario", default="",
                    help="floe-serve: drive the run from a repro.workload "
                         "ScenarioSpec JSON (see examples/scenarios/; "
                         "overrides --requests/--rate)")
    ap.add_argument("--replan", action="store_true",
                    help="floe-serve: live re-planning — watch routing "
                         "drift and migrate expert placement while "
                         "serving (needs --vram-gb)")
    ap.add_argument("--health", action="store_true",
                    help="floe-serve: live health layer — SLO burn-rate "
                         "alerting, stall-composition/link anomaly "
                         "detection, incident bundles")
    ap.add_argument("--incident-dir", dest="incident_dir", default="",
                    help="write incident bundles (JSON) here when an "
                         "alert fires (implies nothing without --health)")
    ap.add_argument("--speculate", action="store_true",
                    help="floe-serve: speculative big-little execution — "
                         "serve demand misses from always-resident "
                         "low-bit shadow experts under verify-or-"
                         "rollback (needs --vram-gb; shadows are priced "
                         "by the planner)")
    ap.add_argument("--slo_ms", type=float, default=3000.0,
                    help="floe-serve: per-request latency SLO")
    ap.add_argument("--policy", choices=["slo", "static"], default="slo")
    ap.add_argument("--ckpt", default="", help="load params instead of init")
    ap.add_argument("--vram-gb", dest="vram_gb", type=float, default=0.0,
                    help="device memory budget; >0 enables the tiered "
                         "store + VRAM planner (floe / floe-serve)")
    ap.add_argument("--host-gb", dest="host_gb", type=float, default=4.0,
                    help="host (pinned DRAM) tier budget")
    ap.add_argument("--store-dir", default="",
                    help="disk-tier shard directory (tmp dir if empty)")
    ap.add_argument("--no-progressive", action="store_true",
                    help="disable progressive-precision demand fetches")
    ap.add_argument("--devices", type=int, default=1,
                    help=">1 simulates a multi-GPU cluster (per-device "
                         "links + residency; --vram-gb becomes per-device)")
    ap.add_argument("--replicate", type=int, default=0,
                    help="hottest experts per layer homed on EVERY device")
    ap.add_argument("--trace", default="",
                    help="export a Chrome/Perfetto trace-event JSON of the "
                         "run to this path (open in ui.perfetto.dev)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the deterministic metrics snapshot "
                         "(counters/gauges/histograms) after the run")
    args = ap.parse_args()

    from repro.deploy import DeploymentSpec, build

    if args.spec:
        spec = DeploymentSpec.from_json(open(args.spec).read())
    else:
        spec = spec_from_args(args)

    if args.dump_spec:
        sys.stdout.write(spec.to_json())
        return

    if spec.runtime.mode == "resident" or \
            not spec.resolve_config().is_moe:
        # resident serving keeps the batched ServingEngine path (no
        # offload plans to resolve — not a deploy concern)
        import numpy as np
        from repro.deploy.builder import resolve_params
        from repro.serving import Request, ServingEngine
        cfg = spec.resolve_config()
        params = resolve_params(spec.model, cfg)
        eng = ServingEngine(params, cfg, batch_size=min(args.requests, 4),
                            max_len=256)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 16,
                                               dtype=np.int64).astype(np.int32),
                               max_new_tokens=args.max_new))
        done = eng.run()
        for r in done[:4]:
            print(f"req {r.uid}: {r.output[:10]}...")
        print(f"{eng.tokens_per_second():.1f} tok/s wall-clock")
        return

    # --- offloaded MoE decode / serving (the paper's scenario) ------------
    # Attach observability consumers BEFORE build so staging transfers
    # land in the trace too; disabled flags keep the bus a no-op.
    from repro import obs
    tracer = None
    collector = None
    if args.trace:
        tracer = obs.Tracer()
        obs.attach(tracer)
    if args.metrics:
        collector = obs.MetricsCollector()
        obs.attach(collector)
    try:
        dep = run_offloaded(args, spec)
    finally:
        if tracer is not None:
            obs.detach(tracer)
        if collector is not None:
            obs.detach(collector)
    if dep is not None:
        finish_obs(args, dep, tracer, collector)


def run_offloaded(args, spec):
    from repro.deploy import build
    dep = build(spec)
    print_plan(dep)

    if dep.controller is not None:  # floe-serve
        # --replan / --health with --spec turn the subsystem on even when
        # the spec file carries no section (serve resolves True ->
        # defaults); --incident-dir overrides the spec's bundle sink
        rp = True if getattr(args, "replan", False) else None
        hl = None
        if getattr(args, "health", False):
            from repro.deploy import HealthSpec
            import dataclasses as _dc
            hl = spec.health or HealthSpec()
            if getattr(args, "incident_dir", ""):
                hl = _dc.replace(hl, incident_dir=args.incident_dir)
        sp = True if getattr(args, "speculate", False) else None
        if getattr(args, "scenario", ""):
            dep.serve(scenario=args.scenario, replan=rp, health=hl,
                      speculate=sp)
        else:
            dep.serve(n_requests=args.requests, rate=args.rate,
                      max_new=args.max_new, replan=rp, health=hl,
                      speculate=sp)
        ctl = dep.controller
        rep = ctl.report()
        for r in sorted(ctl.completed, key=lambda r: r.uid):
            print(f"req {r.uid}: ttft={1e3 * r.ttft:7.1f}ms "
                  f"tpot={1e3 * (r.tpot or 0.0):6.1f}ms "
                  f"deadline={'MET' if r.attained else 'MISSED'} "
                  f"preempted={r.preemptions}")
        for r in ctl.rejected:
            print(f"req {r.uid}: REJECTED (SLO infeasible at admission)")
        print(f"policy={rep['policy']}  "
              f"slo_attainment={rep['slo_attainment']:.0%}"
              f"  tokens/s={rep['tokens_per_s']:.1f} (modeled, busy-time)")
        print(f"preemptions={rep['preemptions']}  rejected={rep['rejected']}"
              f"  swaps={rep['swaps_in']}/{rep['swaps_out']}"
              f"  topups={rep['demand_topups']}")
        print(f"prefetch recall={rep['prefetch_recall']:.2f} "
              f"precision={rep['prefetch_precision']:.2f}  "
              f"train_rounds={rep['train_rounds']}  "
              f"calibration={rep['calibration_scale']:.2f}")
        tenants = ctl.tenant_report()
        if set(tenants) - {""}:  # scenario runs: per-traffic-class rollup
            for name, t in tenants.items():
                print(f"tenant {name or '(untagged)'}: "
                      f"attainment={t['slo_attainment']:.0%} "
                      f"completed={t['completed']} "
                      f"rejected={t['rejected']} "
                      f"ttft={t['ttft_ms_mean']:.1f}ms")
        if dep._speculator is not None:
            sr = dep._speculator.report()
            print(f"speculate: served={sr['spec_served']} "
                  f"accepts={sr['spec_accepts']} "
                  f"rollbacks={sr['spec_rollbacks']} "
                  f"declined={sr['spec_declined']} "
                  f"accept_rate={sr['spec_accept_rate']:.2f}")
        if dep._replanner is not None:
            rr = dep._replanner.report()
            print(f"replan: triggers={rr['drift_triggers']} "
                  f"replans={rr['replans']} denied={rr['denied']} "
                  f"migrated={rr['migrate_transfers']} transfers "
                  f"({rr['migrate_bytes'] / 2 ** 20:.2f}MiB, "
                  f"pins={rr['migrate_pins']} unpins={rr['migrate_unpins']} "
                  f"rehomes={rr['migrate_rehomes']})")
        if dep._health is not None:
            hr = dep._health.report()
            print(f"health: alerts={hr['alerts']} (pages={hr['pages']} "
                  f"tickets={hr['tickets']} anomalies={hr['anomalies']})"
                  f"  incidents={len(hr['incidents'])}")
            for a in hr["alerts_detail"][:8]:
                print(f"  [{a['severity']}] t={a['t']:.2f}s "
                      f"{a['signal']}({a['key']}) value={a['value']:.2f} "
                      f"> {a['threshold']:.2f}")
            for inc in hr["incidents"]:
                where = inc["path"] or "(in memory)"
                print(f"  bundle {inc['name']}: {inc['bytes']}B -> {where}")
        return dep

    metrics = dep.generate(args.max_new)
    stalls = sum(m.stall_s for m in dep.pipeline.metrics)
    print(f"mode={spec.runtime.mode}: "
          f"{dep.pipeline.tokens_per_second():.1f} tok/s (modeled)"
          f"  coverage={metrics[-1].coverage:.2f}"
          f"  total_stall={stalls * 1e3:.2f}ms")
    print_store_telemetry(dep)
    return dep


def finish_obs(args, dep, tracer, collector) -> None:
    """Flush retired-transfer spans, export the trace, print metrics."""
    from repro import obs
    pipe = dep.pipeline
    if pipe is not None and pipe.engine is not None and \
            (tracer is not None or collector is not None):
        # transfer.complete spans are emitted at poll()-retire time (final,
        # preemption-proof timings); drain whatever is still in flight.
        with obs.consumer(*[c for c in (tracer, collector) if c]):
            pipe.engine.drain_events()
    if tracer is not None:
        n = tracer.export(args.trace)
        print(f"trace: {n} events -> {args.trace}")
    if args.metrics:
        snap = dict(dep.metrics_snapshot())
        if collector is not None:
            snap.update(collector.registry.snapshot())
        print("metrics snapshot:")
        for k in sorted(snap):
            v = snap[k]
            print(f"  {k} = {v:.6g}" if isinstance(v, float)
                  else f"  {k} = {v}")


if __name__ == "__main__":
    main()
