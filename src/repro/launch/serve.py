"""Serving launcher: batched resident serving or FloE-offloaded decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --reduced --mode floe --requests 8 --max_new 16

Modes:
  resident   — all weights on device, batched engine (repro.serving)
  naive      — whole-expert fp16 offload per miss (baseline)
  floe       — the paper's pipeline: hybrid compression + dual predictors +
               prefetch (repro.core.pipeline)
  floe-serve — SLO-aware continuous-batching controller over the runtime
               scheduler (repro.serving.controller): Poisson arrivals with
               per-request SLOs, online-trained inter-predictor, per-request
               TTFT/TPOT + SLO attainment report

``--vram-gb B`` (floe / floe-serve) turns on the tiered parameter store:
activation frequencies are measured, ``repro.store.plan_store`` solves
per-expert formats / pinned set / residency pool for the budget, and the
decode runs through the disk/host/device tier stack (runtime scheduler,
progressive-precision demand fetches).  ``--host-gb`` bounds the host tier.

``--devices N`` (floe / floe-serve) spreads the experts over N simulated
GPUs (``repro.cluster``): frequency-balanced partition, per-device
host→device links and residency arenas, ``--replicate R`` homes each
layer's R hottest experts on every device.  With ``--vram-gb`` the
budget is PER DEVICE (``plan_cluster``); without it the cluster is
placement-only over the flat in-host store.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import TrainConfig, reduced as reduce_cfg
from repro.configs import get_config
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--mode",
                    choices=["resident", "naive", "floe", "floe-serve"],
                    default="floe")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d_model", type=int, default=128)
    ap.add_argument("--train_steps", type=int, default=0,
                    help="briefly pre-train so activations have structure")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max_new", type=int, default=16)
    ap.add_argument("--cache_slots", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2,
                    help="floe-serve: concurrent batch slots")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="floe-serve: mean arrivals per modeled second")
    ap.add_argument("--slo_ms", type=float, default=3000.0,
                    help="floe-serve: per-request latency SLO")
    ap.add_argument("--policy", choices=["slo", "static"], default="slo")
    ap.add_argument("--ckpt", default="", help="load params instead of init")
    ap.add_argument("--vram-gb", dest="vram_gb", type=float, default=0.0,
                    help="device memory budget; >0 enables the tiered "
                         "store + VRAM planner (floe / floe-serve)")
    ap.add_argument("--host-gb", dest="host_gb", type=float, default=4.0,
                    help="host (pinned DRAM) tier budget")
    ap.add_argument("--store-dir", default="",
                    help="disk-tier shard directory (tmp dir if empty)")
    ap.add_argument("--no-progressive", action="store_true",
                    help="disable progressive-precision demand fetches")
    ap.add_argument("--devices", type=int, default=1,
                    help=">1 simulates a multi-GPU cluster (per-device "
                         "links + residency; --vram-gb becomes per-device)")
    ap.add_argument("--replicate", type=int, default=0,
                    help="hottest experts per layer homed on EVERY device")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, layers=args.layers, d_model=args.d_model)

    if args.ckpt:
        from repro.checkpoint import load_checkpoint
        params = load_checkpoint(args.ckpt)
    elif args.train_steps:
        from repro.launch.train import train_loop
        tc = TrainConfig(learning_rate=2e-3, total_steps=args.train_steps,
                         warmup_steps=max(args.train_steps // 10, 1))
        params, _, _ = train_loop(cfg, tc, batch=8, seq=64,
                                  steps=args.train_steps, log_every=50)
    else:
        params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)

    if args.mode == "resident" or not cfg.is_moe:
        from repro.serving import Request, ServingEngine
        eng = ServingEngine(params, cfg, batch_size=min(args.requests, 4),
                            max_len=256)
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 16,
                                               dtype=np.int64).astype(np.int32),
                               max_new_tokens=args.max_new))
        done = eng.run()
        for r in done[:4]:
            print(f"req {r.uid}: {r.output[:10]}...")
        print(f"{eng.tokens_per_second():.1f} tok/s wall-clock")
        return

    # --- offloaded MoE decode (the paper's scenario) ---
    from repro.core import sparsify
    from repro.core.pipeline import (FloEPipeline, _unstack_layers,
                                     paper_scaled_models)
    layers = _unstack_layers(params, cfg)
    xcal = jax.random.normal(jax.random.PRNGKey(9), (128, cfg.d_model)) * 0.5
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    device, link = paper_scaled_models(cfg)

    # ---- tiered store: plan formats/pins/pool for the VRAM budget --------
    store_opts: dict = {}
    if args.devices > 1 or args.replicate > 0:
        from repro.store import dense_residency_bytes, measure_frequencies
        freqs = measure_frequencies(layers, cfg)
        if args.vram_gb > 0:
            from repro.cluster import plan_cluster
            plan = plan_cluster(cfg, freqs, n_devices=args.devices,
                                vram_gb_per_device=args.vram_gb,
                                host_gb=args.host_gb,
                                replicate=args.replicate,
                                progressive=not args.no_progressive)
            dense_gb = dense_residency_bytes(cfg) / 2 ** 30
            print(f"cluster plan: {plan.summary()}")
            print(f"  dense-resident needs {dense_gb:.3f}GiB on one device; "
                  f"budget {args.vram_gb:.3f}GiB x {args.devices} devices")
            for d in range(plan.n_devices):
                print(f"  {plan.device_summary(d)}")
            store_opts = dict(cluster_plan=plan, store_freqs=freqs,
                              store_dir=args.store_dir or None,
                              use_runtime=True)
        else:  # placement-only: flat in-host store behind the dispatcher
            from repro.cluster import uniform_cluster_plan
            plan = uniform_cluster_plan(cfg, args.devices, freqs=freqs,
                                        replicate=args.replicate)
            print(f"cluster plan (placement-only): {plan.summary()}")
            for d in range(plan.n_devices):
                print(f"  {plan.device_summary(d)}")
            store_opts = dict(cluster_plan=plan, use_runtime=True)
    elif args.vram_gb > 0:
        from repro.store import (dense_residency_bytes, measure_frequencies,
                                 plan_store)
        freqs = measure_frequencies(layers, cfg)
        plan = plan_store(cfg, freqs, vram_gb=args.vram_gb,
                          host_gb=args.host_gb,
                          progressive=not args.no_progressive)
        dense_gb = dense_residency_bytes(cfg) / 2 ** 30
        print(f"store plan: {plan.summary()}")
        print(f"  dense-resident would need {dense_gb:.3f}GiB; budget "
              f"{args.vram_gb:.3f}GiB "
              f"({args.vram_gb / dense_gb:.2f}x dense)")
        for part, nbytes in plan.breakdown.items():
            print(f"  {part:>16}: {nbytes / 2 ** 20:8.2f}MiB")
        store_opts = dict(store_plan=plan, store_freqs=freqs,
                          store_dir=args.store_dir or None,
                          use_runtime=True)

    if args.mode == "floe-serve":
        from repro.serving import ServingController, SLORequest
        ctl = ServingController(
            params, cfg, thresholds=thr, slots=args.slots, max_len=256,
            policy=args.policy, online_train=True, train_every_tokens=16,
            train_window=64, min_train_rows=32, train_steps=40,
            offload_opts=dict(device=device, link=link,
                              cache_slots=args.cache_slots, **store_opts))
        rng = np.random.default_rng(0)
        t = 0.0
        for i in range(args.requests):
            t += float(rng.exponential(1.0 / max(args.rate, 1e-6)))
            ctl.submit(SLORequest(
                i, rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=args.max_new, slo_ms=args.slo_ms,
                arrival_t=t))
        ctl.run()
        rep = ctl.report()
        for r in sorted(ctl.completed, key=lambda r: r.uid):
            print(f"req {r.uid}: ttft={1e3 * r.ttft:7.1f}ms "
                  f"tpot={1e3 * (r.tpot or 0.0):6.1f}ms "
                  f"deadline={'MET' if r.attained else 'MISSED'} "
                  f"preempted={r.preemptions}")
        for r in ctl.rejected:
            print(f"req {r.uid}: REJECTED (SLO infeasible at admission)")
        print(f"policy={rep['policy']}  slo_attainment={rep['slo_attainment']:.0%}"
              f"  tokens/s={rep['tokens_per_s']:.1f} (modeled, busy-time)")
        print(f"preemptions={rep['preemptions']}  rejected={rep['rejected']}"
              f"  swaps={rep['swaps_in']}/{rep['swaps_out']}"
              f"  topups={rep['demand_topups']}")
        print(f"prefetch recall={rep['prefetch_recall']:.2f} "
              f"precision={rep['prefetch_precision']:.2f}  "
              f"train_rounds={rep['train_rounds']}  "
              f"calibration={rep['calibration_scale']:.2f}")
        return

    if store_opts and args.mode != "floe":
        raise SystemExit(
            "--vram-gb/--devices require --mode floe or floe-serve")
    pipe = FloEPipeline(params, cfg, thresholds=thr,
                        cache_slots=args.cache_slots, mode=args.mode,
                        device=device, link=link, **store_opts)
    for i in range(args.max_new):
        h = jax.random.normal(jax.random.PRNGKey(100 + i),
                              (1, cfg.d_model), jnp.float32) * 0.3
        _, m = pipe.decode_token(h)
    stalls = sum(x.stall_s for x in pipe.metrics)
    print(f"mode={args.mode}: {pipe.tokens_per_second():.1f} tok/s (modeled)"
          f"  coverage={m.coverage:.2f}  total_stall={stalls * 1e3:.2f}ms")
    if store_opts and pipe.cluster_plan is not None:
        s = pipe.sched.stats
        for pool in pipe.device_pools:
            pool.check_invariants()
        eng = pipe.engine
        busy = eng.summary()["busy_s_per_device"]
        print(f"cluster: devices={pipe.cluster_plan.n_devices} "
              f"agg_link_util="
              f"{eng.aggregate_utilization(pipe.sched.clock):.2%} "
              f"busy/dev={[round(b * 1e3, 1) for b in busy]}ms "
              f"demand_fetches={s.demand_fetches} "
              f"replica_routed={pipe.sched.selector.replica_choices}")
        if pipe.host_tier is not None:
            print(f"  host_hit_rate={pipe.host_tier.stats.hit_rate:.2f} "
                  f"disk_reads={pipe.host_tier.disk.stats.reads} "
                  f"pool_free=" +
                  "/".join(f"{p.free_slabs}:{p.num_slabs}"
                           for p in pipe.device_pools))
    elif store_opts:
        s = pipe.sched.stats
        pipe.device_pool.check_invariants()
        print(f"store: demand_fetches={s.demand_fetches} "
              f"drafts={s.draft_fetches} refined={s.refines_applied} "
              f"topups={s.demand_topups} "
              f"host_hit_rate={pipe.host_tier.stats.hit_rate:.2f} "
              f"disk_reads={pipe.host_tier.disk.stats.reads} "
              f"pool_free={pipe.device_pool.free_slabs}/"
              f"{pipe.device_pool.num_slabs}")


if __name__ == "__main__":
    main()
