"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh without allocating anything (ShapeDtypeStruct stand-ins only).

MUST set the device-count override BEFORE any other import — jax locks the
device count on first init.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common import sharding as shd
from repro.common.config import MULTI_POD, SHAPES, SINGLE_POD, ModelConfig, \
    ShapeConfig, TrainConfig
from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_analysis import collective_summary
from repro.launch.mesh import make_production_mesh
from repro.launch.train import make_dist
from repro.models import transformer as tf
from repro.models import nn
from repro.optim import adamw_init, adamw_update

DTYPE = jnp.bfloat16

# -------------------------------------------------- applicability ----------
SKIPS: dict[tuple[str, str], str] = {
    ("hubert_xlarge", "decode_32k"): "encoder-only: no autoregressive decode",
    ("hubert_xlarge", "long_500k"): "encoder-only: no autoregressive decode",
    ("glm4_9b", "long_500k"): "pure full attention (no sub-quadratic variant)",
    ("mistral_large", "long_500k"): "pure full attention",
    ("internvl2_76b", "long_500k"): "pure full attention",
    ("smollm_135m", "long_500k"): "pure full attention",
}


def applicable_pairs() -> list[tuple[str, str]]:
    pairs = []
    for aid in ARCH_IDS:
        if aid == "mixtral_8x7b":
            continue  # the paper's own arch; dry-run via --arch if desired
        for sname in SHAPES:
            if (aid, sname) not in SKIPS:
                pairs.append((aid, sname))
    return pairs


# -------------------------------------------------- step builders ----------
def _params_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda k: tf.init_model(k, cfg, DTYPE),
                          jax.random.PRNGKey(0))


def _pshard(cfg, mesh):
    axes, shape = tuple(mesh.axis_names), tuple(mesh.devices.shape)
    spec = shd.shard_params_spec(_params_shapes(cfg), axes, shape, cfg)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_shard(cfg, mesh, specs):
    axes = tuple(mesh.axis_names)
    return jax.tree.map(
        lambda v: NamedSharding(mesh, shd.batch_spec(axes, v.ndim - 1)), specs)


def lower_pair(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               remat: bool = True, microbatch: int = 0,
               donate_state: bool = False, infer_shard: bool = False,
               kvseq: bool = False, cap_factor: float = 2.0):
    """Returns (lowered, meta) for one (arch, shape, mesh).

    Hillclimb knobs (EXPERIMENTS.md §Perf):
      donate_state — alias the decode state in/out (kills the cache copy)
      infer_shard  — replicate embed over data at inference (no FSDP
                     weight gathers per decode step)
      kvseq        — flash-decode with KV sequence sharded over model
      cap_factor   — MoE per-shard dispatch buffer headroom
    """
    dist = make_dist(mesh, batch_sharded=shape.global_batch > 1)
    dist = dist._replace(kv_seq_shard=kvseq, capacity_factor=cap_factor)
    if infer_shard:
        # no-FSDP sharding: weights replicated over data, tensor-sharded
        # over model only.  For serving this kills the per-step weight
        # all-gathers outright; for training it is valid whenever
        # params+opt fit model-sharded (e.g. <=10B-class archs).
        import repro.common.sharding as _shd
        _orig = _shd._physical_rules

        def _rules(cfg_, axes_, shape_):
            r = _orig(cfg_, axes_, shape_)
            r["embed"] = None
            return r

        _shd._physical_rules = _rules
        try:
            pshard = _pshard(cfg, mesh)
        finally:
            _shd._physical_rules = _orig
    else:
        pshard = _pshard(cfg, mesh)
    pshapes = _params_shapes(cfg)
    axes = tuple(mesh.axis_names)
    meta = {"mode": shape.mode}

    if shape.mode == "train":
        tc = TrainConfig(remat=remat)
        inputs = tf.input_specs(cfg, shape, DTYPE)
        oshapes = jax.eval_shape(adamw_init, pshapes)
        oshard = type(oshapes)(NamedSharding(mesh, P()),
                               jax.tree.map(lambda s: s, pshard),
                               jax.tree.map(lambda s: s, pshard))

        def step(params, opt_state, batch):
            def loss(p, b):
                if microbatch > 1:
                    raise NotImplementedError
                return tf.loss_fn(p, b, cfg, dist, remat=tc.remat)
            if microbatch > 1:
                def one(carry, mb):
                    gsum, lsum = carry
                    (l, _), g = jax.value_and_grad(
                        lambda p: tf.loss_fn(p, mb, cfg, dist, remat=tc.remat),
                        has_aux=True)(params)
                    return (jax.tree.map(jnp.add, gsum, g), lsum + l), None
                mb_batch = jax.tree.map(
                    lambda a: a.reshape((microbatch, -1) + a.shape[1:]), batch)
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), _ = jax.lax.scan(one, (zeros, 0.0), mb_batch)
                grads = jax.tree.map(lambda g: g / microbatch, gsum)
                l = lsum / microbatch
            else:
                (l, _), grads = jax.value_and_grad(
                    loss, has_aux=True)(params, batch)
            params2, opt2, _ = adamw_update(grads, opt_state, params, tc)
            return params2, opt2, l

        bshard = _batch_shard(cfg, mesh, inputs)
        jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None))
        return jitted.lower(pshapes, oshapes, inputs), meta

    if shape.mode == "prefill":
        inputs = tf.input_specs(cfg, shape, DTYPE)
        bshard = _batch_shard(cfg, mesh, inputs)
        if not cfg.causal:  # encoder: plain forward
            def step(params, batch):
                logits, _ = tf.forward(params, batch, cfg, dist)
                return logits
            jitted = jax.jit(step, in_shardings=(pshard, bshard))
            return jitted.lower(pshapes, inputs), meta
        sshapes = jax.eval_shape(
            lambda: tf.init_decode_state(cfg, shape.global_batch,
                                         shape.seq_len, DTYPE))
        sspec = tf.decode_state_spec(cfg, axes, tuple(mesh.devices.shape),
                                     batch_sharded=True, kv_seq_shard=kvseq)
        sshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                              is_leaf=lambda x: isinstance(x, P))

        def step(params, batch, state):
            return tf.prefill(params, batch, state, cfg, dist)

        jitted = jax.jit(step, in_shardings=(pshard, bshard, sshard),
                         out_shardings=(None, sshard),
                         donate_argnums=(2,) if donate_state else ())
        return jitted.lower(pshapes, inputs, sshapes), meta

    # decode: ONE new token against a seq_len KV cache
    batch_sharded = shape.global_batch > 1
    inputs = tf.input_specs(cfg, shape, DTYPE)
    sshapes = jax.eval_shape(
        lambda: tf.init_decode_state(cfg, shape.global_batch,
                                     shape.seq_len, DTYPE))
    sspec = tf.decode_state_spec(cfg, axes, tuple(mesh.devices.shape),
                                 batch_sharded=batch_sharded,
                                 kv_seq_shard=kvseq)
    sshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                          is_leaf=lambda x: isinstance(x, P))
    tshard = NamedSharding(
        mesh, shd.batch_spec(axes, 1)) if batch_sharded else \
        NamedSharding(mesh, P(None, None))

    def serve_step(params, tokens, state):
        return tf.decode_step(params, tokens, state, cfg, dist)

    jitted = jax.jit(serve_step, in_shardings=(pshard, tshard, sshard),
                     out_shardings=(None, sshard),
                     donate_argnums=(2,) if donate_state else ())
    return jitted.lower(pshapes, inputs["tokens"], sshapes), meta


# ------------------------------------------------------------ analysis -----
def analyze(compiled, lowered=None) -> dict:
    from repro.launch.hlo_analysis import dot_flops_total, hbm_bytes_estimate
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    colls = collective_summary(txt)
    out = {
        # trip-weighted (XLA's own numbers count loop bodies once — useless
        # under scan-over-layers; see hlo_analysis.py)
        "flops_per_device": dot_flops_total(txt),
        "hbm_bytes_per_device": hbm_bytes_estimate(txt),
        "flops_per_device_raw": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_device_raw": float(ca.get("bytes accessed", 0.0)),
        "argument_bytes_per_device": int(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes_per_device": int(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes_per_device": int(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes_per_device": int(getattr(ma, "alias_size_in_bytes", 0)),
        "collectives": colls,
        "collective_bytes_per_device": sum(v["bytes"] for v in colls.values()),
    }
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            remat: bool = True, microbatch: int = 0,
            donate_state: bool = False, infer_shard: bool = False,
            kvseq: bool = False, cap_factor: float = 2.0,
            pad_heads: int = 0) -> dict:
    cfg = get_config(arch)
    if pad_heads:
        # mesh-alignment experiment: pad Q heads to a multiple of the model
        # axis (zero-extended wq/wo keep the function identical at init);
        # switches "seq"-mode archs into head-parallel attention.
        import dataclasses
        cfg = dataclasses.replace(cfg, num_heads=pad_heads,
                                  head_dim=cfg.head_dim)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    lowered, meta = lower_pair(cfg, shape, mesh, remat=remat,
                               microbatch=microbatch,
                               donate_state=donate_state,
                               infer_shard=infer_shard, kvseq=kvseq,
                               cap_factor=cap_factor)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    print(compiled.memory_analysis())
    ca = compiled.cost_analysis()
    print({k: v for k, v in (ca or {}).items()
           if k in ("flops", "bytes accessed")})
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": len(mesh.devices.flatten()),
        "lower_s": t1 - t0,
        "compile_s": t2 - t1,
        "divisibility_notes": shd.check_divisibility(
            cfg, MULTI_POD if multi_pod else SINGLE_POD),
        **meta,
        **analyze(compiled),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--no_remat", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--donate_state", action="store_true")
    ap.add_argument("--infer_shard", action="store_true")
    ap.add_argument("--kvseq", action="store_true")
    ap.add_argument("--cap_factor", type=float, default=2.0)
    ap.add_argument("--pad_heads", type=int, default=0)
    args = ap.parse_args()

    if args.list:
        for a, s in applicable_pairs():
            print(f"{a},{s}")
        for (a, s), why in SKIPS.items():
            print(f"SKIP,{a},{s},{why}")
        return

    res = run_one(args.arch, args.shape, args.multi_pod,
                  remat=not args.no_remat, microbatch=args.microbatch,
                  donate_state=args.donate_state,
                  infer_shard=args.infer_shard, kvseq=args.kvseq,
                  cap_factor=args.cap_factor, pad_heads=args.pad_heads)
    blob = json.dumps(res, indent=1, default=float)
    print(blob)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(blob)


if __name__ == "__main__":
    main()
