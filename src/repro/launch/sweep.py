"""Run the full dry-run sweep: every applicable (arch × shape) × mesh.

Each pair runs in a subprocess (jax device-count lock + memory hygiene).
Results land in results/dryrun/<arch>.<shape>.<mesh>.json.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]
OUT = ROOT / "results" / "dryrun"


def pairs():
    from repro.launch.dryrun import applicable_pairs
    return applicable_pairs()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--only", default="", help="substring filter arch.shape")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    todo = []
    for multi in meshes:
        for arch, shape in pairs():
            tag = f"{arch}.{shape}.{'2x16x16' if multi else '16x16'}"
            if args.only and args.only not in tag:
                continue
            out = OUT / f"{tag}.json"
            if out.exists() and not args.force:
                continue
            todo.append((arch, shape, multi, out))

    print(f"{len(todo)} dry-runs to do", flush=True)
    failures = []
    for i, (arch, shape, multi, out) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", str(out)]
        if multi:
            cmd.append("--multi_pod")
        t0 = time.perf_counter()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={**__import__("os").environ,
                                    "PYTHONPATH": str(ROOT / "src")})
            ok = r.returncode == 0 and out.exists()
        except subprocess.TimeoutExpired:
            ok, r = False, None
        dt = time.perf_counter() - t0
        status = "ok" if ok else "FAIL"
        print(f"[{i + 1}/{len(todo)}] {out.stem}: {status} ({dt:.0f}s)",
              flush=True)
        if not ok:
            failures.append(out.stem)
            if r is not None:
                (OUT / f"{out.stem}.err").write_text(
                    (r.stdout or "")[-4000:] + "\n" + (r.stderr or "")[-8000:])
    print(f"done; {len(failures)} failures: {failures}", flush=True)


if __name__ == "__main__":
    main()
