"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD forward for train/prefill (O(S·L) with chunk length L) and an
O(1) recurrent decode step.  Layout: d_inner = expand·d_model split into H
heads of P channels; B/C projections use a single group of state size N
shared across heads (n_groups = 1).

The SSD head axis shards over the ``model`` mesh axis; the inter-chunk
recurrence is a ``lax.scan`` over chunk states (B, H, N, P), which is
embarrassingly parallel across heads and batch.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import nn


class SSMState(NamedTuple):
    h: jax.Array  # (B, H, N, P) recurrent state
    conv: jax.Array  # (B, W-1, conv_dim) rolling conv input window
    length: jax.Array  # () int32


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    h = cfg.ssm_heads
    p = cfg.ssm_head_dim
    n = cfg.ssm_state
    assert h * p == d_in, f"ssm heads {h} * head_dim {p} != d_inner {d_in}"
    conv_dim = d_in + 2 * n  # x, B, C all pass through the causal conv
    return d_in, h, p, n, conv_dim


def init_mamba2(key, cfg: ModelConfig, dtype=nn.DEFAULT_DTYPE) -> dict:
    d = cfg.d_model
    d_in, h, p, n, conv_dim = _dims(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    # in_proj emits [z (d_in), xBC (conv_dim), dt (h)]
    proj_out = d_in + conv_dim + h
    dt_init = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(k4, (h,), jnp.float32) *
                (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))))
    return {
        "in_proj": nn.dense_init(k1, (d, proj_out), dtype, fan_in=d),
        "conv_w": nn.dense_init(k2, (cfg.ssm_conv_width, conv_dim), dtype,
                                fan_in=cfg.ssm_conv_width),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_init,
        "ssm_norm": jnp.zeros((d_in,), dtype),
        "out_proj": nn.dense_init(k5, (d_in, d), dtype, fan_in=d_in),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_in, h, p, n, conv_dim = _dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_in, d_in + conv_dim], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xbc (B, S, C), w (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    # convention: w[width-1] is the current-token tap (matches decode path)
    for i in range(width):  # width is 4 — unrolled taps beat a conv op here
        out = out + pad[:, i:i + xbc.shape[1]].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    x (B,S,H,P); dt (B,S,H) post-softplus; A (H,) negative; B,C (B,S,N).
    Returns y (B,S,H,P) and final state (B,H,N,P).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    l = min(chunk, s)
    pad = (-s) % l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // l

    xc = x.reshape(b, nc, l, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, l, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, l, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, l, n).astype(jnp.float32)

    a = dtc * A  # (B,NC,L,H) log-decay increments (negative)
    cum = jnp.cumsum(a, axis=2)  # inclusive

    # --- intra-chunk (quadratic within chunk) ---
    # M[t, u] = exp(cum_t - cum_u) for u <= t (decay from u to t, inclusive of
    # steps u+1..t) times dt_u; score = (C_t . B_u)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,NC,L,L,H)
    mask = jnp.tril(jnp.ones((l, l), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    score = jnp.einsum("bcln,bcun->bclu", Cc, Bc)  # (B,NC,L,L)
    w = score[..., None] * decay * dtc[:, :, None, :, :]  # (B,NC,L,L,H)
    y_intra = jnp.einsum("bcluh,bcuhp->bclhp", w, xc)

    # --- chunk summary states ---
    seg = jnp.exp(cum[:, :, -1:, :] - cum)  # decay from t to chunk end
    sstate = jnp.einsum("bcln,bclh,bclhp->bchnp",
                        Bc, seg * dtc, xc)  # (B,NC,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,NC,H)

    # --- inter-chunk recurrence (sequential over chunks) ---
    def step(hprev, inp):
        sst, dec = inp  # (B,H,N,P), (B,H)
        hnew = hprev * dec[..., None, None] + sst
        return hnew, hprev

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    hfinal, hprevs = jax.lax.scan(
        step, h0, (sstate.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    hprevs = hprevs.swapaxes(0, 1)  # (B,NC,H,N,P) state entering each chunk

    # --- inter-chunk contribution ---
    y_inter = jnp.einsum("bcln,bclh,bchnp->bclhp",
                         Cc, jnp.exp(cum), hprevs)
    y = (y_intra + y_inter).reshape(b, nc * l, h, p)[:, :s]
    return y.astype(x.dtype), hfinal


def _forward_impl(params: dict, x: jax.Array, cfg: ModelConfig):
    d_in, h, p, n, conv_dim = _dims(cfg)
    b, s, _ = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc_raw, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs, B, C = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xs = xs.reshape(b, s, h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, hfinal = _ssd_chunked(xs, dt, A, B, C, cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32).astype(y.dtype) * \
        params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = nn.rms_norm(y * nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                    params["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, hfinal, xbc_raw


def mamba2_forward(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence forward. x (B, S, D) -> (B, S, D)."""
    return _forward_impl(params, x, cfg)[0]


def mamba2_prefill(params: dict, x: jax.Array, state: "SSMState",
                   cfg: ModelConfig) -> tuple[jax.Array, "SSMState"]:
    """Forward that also returns the decode state after S tokens."""
    b, s, _ = x.shape
    w = cfg.ssm_conv_width
    out, hfinal, xbc_raw = _forward_impl(params, x, cfg)
    # rolling conv window: last W-1 raw xbc inputs (zero-pad short prefills)
    if s >= w - 1:
        conv = xbc_raw[:, s - (w - 1):]
    else:
        conv = jnp.concatenate(
            [jnp.zeros((b, w - 1 - s, xbc_raw.shape[-1]), xbc_raw.dtype),
             xbc_raw], axis=1)
    return out, SSMState(hfinal, conv.astype(state.conv.dtype),
                         jnp.asarray(s, jnp.int32))


# ------------------------------------------------------------- decoding ----
def init_ssm_state(cfg: ModelConfig, batch: int,
                   dtype=jnp.float32) -> SSMState:
    d_in, h, p, n, conv_dim = _dims(cfg)
    return SSMState(
        h=jnp.zeros((batch, h, n, p), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def mamba2_decode(params: dict, x: jax.Array, state: SSMState,
                  cfg: ModelConfig) -> tuple[jax.Array, SSMState]:
    """One-token decode. x (B, 1, D)."""
    d_in, h, p, n, conv_dim = _dims(cfg)
    b = x.shape[0]
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])[:, 0]
    z, xbc, dt = _split_proj(cfg, proj)

    # conv over rolling window [conv_state ++ xbc]
    win = jnp.concatenate([state.conv, xbc[:, None, :]], axis=1)  # (B,W,C)
    w = params["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bwc,wc->bc", win.astype(jnp.float32), w)
    xbc_c = nn.silu(conv_out + params["conv_b"].astype(jnp.float32)
                    ).astype(x.dtype)
    new_conv = win[:, 1:]

    xs, B, C = jnp.split(xbc_c, [d_in, d_in + n], axis=-1)
    xs = xs.reshape(b, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,)
    decay = jnp.exp(dt * A)  # (B,H)
    Bf = B.astype(jnp.float32)
    hnew = state.h * decay[..., None, None] + \
        jnp.einsum("bn,bh,bhp->bhnp", Bf, dt, xs)
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), hnew)
    y = y + xs * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, d_in).astype(x.dtype)
    y = nn.rms_norm(y * nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                    params["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"])[:, None, :]
    return out, SSMState(hnew, new_conv, state.length + 1)
