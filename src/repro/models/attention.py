"""Attention: GQA + RoPE + sliding window, train/prefill and cached decode.

Weights are head-structured — wq (D, H, hd), wk/wv (D, KV, hd), wo (H, hd, D).

Distribution (chosen per arch by repro.common.sharding.attn_mode):

* "head" — Q heads shard over the ``model`` axis (Megatron layout); KV heads
  shard too when divisible, otherwise stay replicated (GQA with few KV
  heads).  No attention-internal collectives; WO's contraction psum is the
  layer's only one (same as a TP MLP).
* "seq"  — for head counts not divisible by the axis (starcoder2 36H,
  llama4 40H, smollm 9H): context parallelism — the QUERY sequence shards
  over ``model`` while KV stays replicated, so scores remain local.  Decode
  (S_q = 1) falls back to replicated attention compute.

Both are realized with an explicit ``jax.shard_map`` core so XLA cannot
invent score-sized collectives (which a naive head_dim sharding does).

Long sequences use query-chunked attention (scan over query blocks):
live memory O(B·H·chunk·S_kv).  Sliding-window archs slice only the KV span
a query chunk can see, making prefill FLOPs O(S·window).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map
from repro.common.config import ModelConfig
from repro.models import nn

Q_CHUNK = 512  # query block for chunked attention
_NEG = -1e30


class KVCache(NamedTuple):
    """Ring-buffer KV cache. ``length`` counts tokens written so far."""

    k: jax.Array  # (B, S_cache, KV, hd)
    v: jax.Array  # (B, S_cache, KV, hd)
    length: jax.Array  # () int32 — tokens seen so far (may exceed S_cache)


def init_attn(key, cfg: ModelConfig, dtype=nn.DEFAULT_DTYPE) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kq, kk, kv_, ko = jax.random.split(key, 4)
    return {
        "wq": nn.dense_init(kq, (d, h, hd), dtype, fan_in=d),
        "wk": nn.dense_init(kk, (d, kv, hd), dtype, fan_in=d),
        "wv": nn.dense_init(kv_, (d, kv, hd), dtype, fan_in=d),
        "wo": nn.dense_init(ko, (h, hd, d), dtype, fan_in=h * hd),
    }


# ---------------------------------------------------------------- RoPE -----
def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (B, S, H, hd), positions (B, S) -> rotated x."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (B, S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------- core sdpa -----
def _scores_mask(q_pos, k_pos, window: int, causal: bool):
    """(B, Sq, Sk) bool; True = attend."""
    dq = q_pos[:, :, None]
    dk = k_pos[:, None, :]
    mask = jnp.ones((q_pos.shape[0], q_pos.shape[1], k_pos.shape[1]), bool)
    if causal:
        mask &= dk <= dq
    if window > 0:
        mask &= dk > dq - window
    return mask


def _sdpa_block(q, k, v, mask, gidx) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), mask (B,Sq,Sk), gidx (H,) int32
    mapping each (local) q head to its kv head."""
    b, sq, h, hd = q.shape
    kf = jnp.take(k, gidx, axis=2).astype(jnp.float32)  # (B,Sk,H,hd)
    vf = jnp.take(v, gidx, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32) / jnp.sqrt(hd)
    scores = jnp.einsum("bshd,bthd->bhst", qf, kf)
    scores = jnp.where(mask[:, None, :, :], scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = probs * mask[:, None, :, :]  # fully-masked rows -> 0
    out = jnp.einsum("bhst,bthd->bshd", probs, vf)
    return out.astype(q.dtype)


def _sdpa(q, k, v, q_pos, k_pos, window: int, causal: bool, gidx,
          q_chunk: int = 0, k_valid: Optional[jax.Array] = None
          ) -> jax.Array:
    """Query-chunked attention over local shards. Shapes as in _sdpa_block."""
    q_chunk = q_chunk or Q_CHUNK
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    if sq <= q_chunk:
        mask = _scores_mask(q_pos, k_pos, window, causal)
        if k_valid is not None:
            mask &= k_valid[:, None, :]
        return _sdpa_block(q, k, v, mask, gidx)

    n = -(-sq // q_chunk)
    pad = n * q_chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)

    # KV span a query chunk can see (sliding window -> bounded span)
    if window > 0:
        span = min(sk, -(-(window + q_chunk) // 128) * 128)
    else:
        span = sk

    qc = q.reshape(b, n, q_chunk, h, hd).swapaxes(0, 1)
    pc = q_pos.reshape(b, n, q_chunk).swapaxes(0, 1)

    def body(carry, inp):
        i, (q_i, p_i) = inp
        if span < sk:
            end_pos = jnp.max(p_i) + 1  # last valid position in chunk
            start = jnp.clip(end_pos - span, 0, sk - span)
            k_i = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
            v_i = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
            kp_i = jax.lax.dynamic_slice_in_dim(k_pos, start, span, 1)
        else:
            k_i, v_i, kp_i = k, v, k_pos
        mask = _scores_mask(p_i, kp_i, window, causal)
        mask &= p_i[:, :, None] >= 0  # padded queries
        out = _sdpa_block(q_i, k_i, v_i, mask, gidx)
        return carry, out

    idx = jnp.arange(n)
    _, outs = jax.lax.scan(body, None, (idx, (qc, pc)))
    out = outs.swapaxes(0, 1).reshape(b, n * q_chunk, h, hd)
    return out[:, :sq]


# ------------------------------------------------- distributed wrapper -----
def _dist_info(cfg: ModelConfig, dist):
    from repro.common import sharding as shd
    mesh = dist.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    mode = shd.attn_mode(cfg, model)
    batch = dist.batch_axes if dist.batch_sharded else None
    return mesh, model, mode, batch


def _sdpa_dist(q, k, v, q_pos, k_pos, cfg: ModelConfig, dist,
               k_valid: Optional[jax.Array] = None) -> jax.Array:
    """Dispatch to the sharded attention core."""
    window, causal = cfg.sliding_window, cfg.causal
    rep = cfg.num_heads // cfg.num_kv_heads
    h = cfg.num_heads

    if dist is None:
        gidx = jnp.arange(h, dtype=jnp.int32) // rep
        return _sdpa(q, k, v, q_pos, k_pos, window, causal, gidx,
                     k_valid=k_valid)

    mesh, model, mode, batch = _dist_info(cfg, dist)
    if model <= 1:
        gidx = jnp.arange(h, dtype=jnp.int32) // rep
        return _sdpa(q, k, v, q_pos, k_pos, window, causal, gidx,
                     k_valid=k_valid)

    sq = q.shape[1]
    kv_div = cfg.num_kv_heads % model == 0

    if mode == "head":
        h_l = h // model
        kv_spec = "model" if kv_div else None

        def body(q, k, v, q_pos, k_pos, k_valid):
            if kv_div:
                gidx = jnp.arange(h_l, dtype=jnp.int32) // rep
            else:
                s = jax.lax.axis_index("model")
                gidx = (s * h_l + jnp.arange(h_l, dtype=jnp.int32)) // rep
            return _sdpa(q, k, v, q_pos, k_pos, window, causal, gidx,
                         k_valid=k_valid)

        in_specs = (P(batch, None, "model", None),
                    P(batch, None, kv_spec, None),
                    P(batch, None, kv_spec, None),
                    P(batch, None), P(batch, None),
                    P(batch, None) if k_valid is not None else P())
        out_specs = P(batch, None, "model", None)
    elif mode == "seq" and sq > 1 and sq % model == 0:
        def body(q, k, v, q_pos, k_pos, k_valid):
            gidx = jnp.arange(h, dtype=jnp.int32) // rep
            return _sdpa(q, k, v, q_pos, k_pos, window, causal, gidx,
                         k_valid=k_valid)

        in_specs = (P(batch, "model", None, None),
                    P(batch, None, None, None),
                    P(batch, None, None, None),
                    P(batch, "model"), P(batch, None),
                    P(batch, None) if k_valid is not None else P())
        out_specs = P(batch, "model", None, None)
    else:  # replicated attention compute (e.g. decode on "seq" archs)
        def body(q, k, v, q_pos, k_pos, k_valid):
            gidx = jnp.arange(h, dtype=jnp.int32) // rep
            return _sdpa(q, k, v, q_pos, k_pos, window, causal, gidx,
                         k_valid=k_valid)

        in_specs = (P(batch, None, None, None),
                    P(batch, None, None, None),
                    P(batch, None, None, None),
                    P(batch, None), P(batch, None),
                    P(batch, None) if k_valid is not None else P())
        out_specs = P(batch, None, None, None)

    if k_valid is None:
        k_valid = jnp.zeros((), jnp.bool_)  # placeholder, unused

        def body2(q, k, v, qp, kp, _):
            return body(q, k, v, qp, kp, None)
    else:
        body2 = body

    return shard_map(body2, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)(
        q, k, v, q_pos, k_pos, k_valid)


# ----------------------------------------------------- public entry points -
def _qkv(params, x, positions, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention(params: dict, x: jax.Array, positions: jax.Array,
              cfg: ModelConfig, dist=None) -> jax.Array:
    """Full-sequence attention (train / prefill). x (B, S, D)."""
    q, k, v = _qkv(params, x, positions, cfg)
    out = _sdpa_dist(q, k, v, positions, positions, cfg, dist)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=nn.DEFAULT_DTYPE) -> KVCache:
    """Cache length is min(max_len, window) — SWA archs keep a ring buffer."""
    s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, s, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def _flash_decode_kvseq(q, k_cache, v_cache, k_new, v_new, pos,
                        cfg: ModelConfig, dist) -> tuple:
    """Flash-decode with the KV cache SEQUENCE sharded over ``model``.

    Each model shard owns S/model ring slots: it updates its slot if the new
    token lands there, computes partial (unnormalized out, max, sumexp) over
    its KV slice, and the shards combine with pmax/psum — attention memory
    AND bandwidth scale 1/model_size, which head-replicated GQA decode
    cannot achieve when kv_heads < model.
    """
    mesh, model, _, batch = _dist_info(cfg, dist)
    b, _, h, hd = q.shape
    s_cache = k_cache.shape[1]
    s_l = s_cache // model
    rep = cfg.num_heads // cfg.num_kv_heads
    gidx = jnp.arange(h, dtype=jnp.int32) // rep
    window = cfg.sliding_window

    def body(q, k_c, v_c, k_n, v_n, pos):
        m = jax.lax.axis_index("model")
        slot = jnp.mod(pos, s_cache)
        own = slot // s_l == m
        lslot = jnp.mod(slot, s_l)
        k_upd = jax.lax.dynamic_update_slice(
            k_c, k_n.astype(k_c.dtype), (0, lslot, 0, 0))
        v_upd = jax.lax.dynamic_update_slice(
            v_c, v_n.astype(v_c.dtype), (0, lslot, 0, 0))
        k_c = jnp.where(own, k_upd, k_c)
        v_c = jnp.where(own, v_upd, v_c)

        gslots = m * s_l + jnp.arange(s_l)
        wraps = pos // s_cache
        slot_pos = jnp.where(gslots <= slot, wraps * s_cache + gslots,
                             (wraps - 1) * s_cache + gslots)
        valid = (slot_pos >= 0) & (slot_pos <= pos)
        if window:
            valid &= slot_pos > pos - window

        kf = jnp.take(k_c, gidx, axis=2).astype(jnp.float32)  # (B,s_l,H,hd)
        vf = jnp.take(v_c, gidx, axis=2).astype(jnp.float32)
        qf = q[:, 0].astype(jnp.float32) / jnp.sqrt(hd)  # (B,H,hd)
        scores = jnp.einsum("bhd,bthd->bht", qf, kf)
        scores = jnp.where(valid[None, None, :], scores, _NEG)
        mx = scores.max(axis=-1, keepdims=True)  # (B,H,1)
        p = jnp.exp(scores - mx) * valid[None, None, :]
        l = p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("bht,bthd->bhd", p, vf)

        gmx = jax.lax.pmax(mx, "model")
        scale = jnp.exp(mx - gmx)
        o_tot = jax.lax.psum(o * scale, "model")
        l_tot = jax.lax.psum(l * scale, "model")
        out = (o_tot / jnp.maximum(l_tot, 1e-30))[:, None].astype(q.dtype)
        return out, k_c, v_c

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(batch, None, None, None),
                  P(batch, "model", None, None),
                  P(batch, "model", None, None),
                  P(batch, None, None, None),
                  P(batch, None, None, None), P()),
        out_specs=(P(batch, None, None, None),
                   P(batch, "model", None, None),
                   P(batch, "model", None, None)),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, pos)


def decode_attention(params: dict, x: jax.Array, cache: KVCache,
                     cfg: ModelConfig, dist=None) -> tuple[jax.Array, KVCache]:
    """One-token decode. x (B, 1, D); returns (out (B,1,D), new cache)."""
    b = x.shape[0]
    s_cache = cache.k.shape[1]
    pos = cache.length  # scalar: position of the new token
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _qkv(params, x, positions, cfg)

    if dist is not None and getattr(dist, "kv_seq_shard", False):
        sizes = dict(zip(dist.mesh.axis_names, dist.mesh.devices.shape))
        model = sizes.get("model", 1)
        if model > 1 and s_cache % model == 0:
            out, k, v = _flash_decode_kvseq(q, cache.k, cache.v, k_new,
                                            v_new, pos, cfg, dist)
            out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
            return out, KVCache(k, v, pos + 1)

    slot = jnp.mod(pos, s_cache)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, slot, 0, 0))

    # Absolute position held by each ring slot; invalid slots masked off.
    slots = jnp.arange(s_cache)
    wraps = pos // s_cache
    slot_pos = jnp.where(slots <= slot, wraps * s_cache + slots,
                         (wraps - 1) * s_cache + slots)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if cfg.sliding_window:
        valid &= slot_pos > pos - cfg.sliding_window
    k_pos = jnp.broadcast_to(slot_pos[None], (b, s_cache)).astype(jnp.int32)
    k_valid = jnp.broadcast_to(valid[None], (b, s_cache))

    import dataclasses
    cfg_nw = dataclasses.replace(cfg, sliding_window=0)  # handled via k_valid
    out = _sdpa_dist(q, k, v, positions, k_pos, cfg_nw, dist, k_valid=k_valid)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, KVCache(k, v, pos + 1)


def prefill_attention(params: dict, x: jax.Array, cfg: ModelConfig,
                      cache: KVCache, dist=None) -> tuple[jax.Array, KVCache]:
    """Prefill S tokens into an empty cache (positions 0..S-1)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    q, k, v = _qkv(params, x, positions, cfg)
    out = _sdpa_dist(q, k, v, positions, positions, cfg, dist)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])

    s_cache = cache.k.shape[1]
    if s <= s_cache:
        kc = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                          (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                          (0, 0, 0, 0))
    else:  # keep the trailing window, ring-aligned so slot = pos % s_cache
        start = s - s_cache
        ks = jax.lax.dynamic_slice_in_dim(k, start, s_cache, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, start, s_cache, 1)
        roll = start % s_cache
        kc = jnp.roll(ks, roll, axis=1).astype(cache.k.dtype)
        vc = jnp.roll(vs, roll, axis=1).astype(cache.v.dtype)
    return out, KVCache(kc, vc, jnp.asarray(s, jnp.int32))
