"""Transformer / SSM / hybrid blocks with train, prefill, and decode paths.

Block kinds (see ModelConfig.segments):
  "dense"  — pre-norm attention + dense MLP
  "moe"    — pre-norm attention + MoE FFN
  "mamba"  — pre-norm Mamba2 mixer (residual)
  "shared" — zamba2-style shared transformer block: weights are shared
             across invocations; each invocation has its own input
             projection applied to concat(x, x_embed_original).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import nn


def _norm_params():
    return None  # placeholder, scales created inline


def init_block(key, kind: str, cfg: ModelConfig, dtype=nn.DEFAULT_DTYPE) -> dict:
    d = cfg.d_model
    if kind == "mamba":
        k1, = jax.random.split(key, 1)
        return {
            "pre_norm": {"scale": jnp.zeros((d,), dtype)},
            "mixer": mamba_lib.init_mamba2(k1, cfg, dtype),
        }
    ka, km, ks = jax.random.split(key, 3)
    p = {
        "attn_norm": {"scale": jnp.zeros((d,), dtype)},
        "attn": attn_lib.init_attn(ka, cfg, dtype),
        "mlp_norm": {"scale": jnp.zeros((d,), dtype)},
    }
    if kind == "moe":
        p["moe"] = moe_lib.init_moe(km, cfg, dtype)
    else:
        p["mlp"] = mlp_lib.init_mlp(km, cfg, dtype)
    if kind == "shared":
        p["shared_in"] = nn.dense_init(ks, (2 * d, d), dtype, fan_in=2 * d)
    return p


# --------------------------------------------------------------- forward ---
def block_forward(params: dict, kind: str, x: jax.Array,
                  positions: jax.Array, cfg: ModelConfig,
                  dist=None, x0: Optional[jax.Array] = None,
                  shared_in: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Returns (x_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h = nn.rms_norm(x, params["pre_norm"]["scale"], cfg.norm_eps)
        return x + mamba_lib.mamba2_forward(params["mixer"], h, cfg), aux

    if kind == "shared":
        inp = jnp.concatenate([x, x0], axis=-1) @ shared_in
    else:
        inp = x
    h = nn.rms_norm(inp, params["attn_norm"]["scale"], cfg.norm_eps)
    x = x + attn_lib.attention(params["attn"], h, positions, cfg, dist)
    h = nn.rms_norm(x, params["mlp_norm"]["scale"], cfg.norm_eps)
    if kind == "moe":
        y, aux = moe_lib.moe_forward(params["moe"], h, cfg, dist)
    else:
        y = mlp_lib.mlp(params["mlp"], h, cfg)
    return x + y, aux


# ---------------------------------------------------------------- decode ---
def init_block_state(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype=nn.DEFAULT_DTYPE) -> Any:
    if kind == "mamba":
        return mamba_lib.init_ssm_state(cfg, batch, dtype)
    return attn_lib.init_kv_cache(cfg, batch, max_len, dtype)


def block_decode(params: dict, kind: str, x: jax.Array, state: Any,
                 cfg: ModelConfig, dist=None,
                 x0: Optional[jax.Array] = None,
                 shared_in: Optional[jax.Array] = None
                 ) -> tuple[jax.Array, Any]:
    if kind == "mamba":
        h = nn.rms_norm(x, params["pre_norm"]["scale"], cfg.norm_eps)
        y, new_state = mamba_lib.mamba2_decode(params["mixer"], h, state, cfg)
        return x + y, new_state

    if kind == "shared":
        inp = jnp.concatenate([x, x0], axis=-1) @ shared_in
    else:
        inp = x
    h = nn.rms_norm(inp, params["attn_norm"]["scale"], cfg.norm_eps)
    a, new_state = attn_lib.decode_attention(params["attn"], h, state, cfg,
                                             dist)
    x = x + a
    h = nn.rms_norm(x, params["mlp_norm"]["scale"], cfg.norm_eps)
    if kind == "moe":
        y, _ = moe_lib.moe_forward(params["moe"], h, cfg, dist)
    else:
        y = mlp_lib.mlp(params["mlp"], h, cfg)
    return x + y, new_state


# --------------------------------------------------------------- prefill ---
def block_prefill(params: dict, kind: str, x: jax.Array, state: Any,
                  cfg: ModelConfig, dist=None,
                  x0: Optional[jax.Array] = None,
                  shared_in: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, Any]:
    """Forward that also fills the decode state."""
    b, s, _ = x.shape
    if kind == "mamba":
        h = nn.rms_norm(x, params["pre_norm"]["scale"], cfg.norm_eps)
        y, new_state = mamba_lib.mamba2_prefill(params["mixer"], h, state, cfg)
        return x + y, new_state

    if kind == "shared":
        inp = jnp.concatenate([x, x0], axis=-1) @ shared_in
    else:
        inp = x
    h = nn.rms_norm(inp, params["attn_norm"]["scale"], cfg.norm_eps)
    a, new_state = attn_lib.prefill_attention(params["attn"], h, cfg, state,
                                              dist)
    x = x + a
    h = nn.rms_norm(x, params["mlp_norm"]["scale"], cfg.norm_eps)
    if kind == "moe":
        y, _ = moe_lib.moe_forward(params["moe"], h, cfg, dist)
    else:
        y = mlp_lib.mlp(params["mlp"], h, cfg)
    return x + y, new_state
