"""Small pure-JAX NN building blocks (no flax)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

DEFAULT_DTYPE = jnp.bfloat16


def dense_init(key, shape, dtype=jnp.float32, *, fan_in: Optional[int] = None):
    """Truncated-normal init scaled by 1/sqrt(fan_in)."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) <= 2 else int(jnp.prod(jnp.array(shape[:-1])))
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy. logits (..., V) f32-upcast, labels (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
