"""Dense MLPs: SwiGLU (llama-family) and GELU (encoder FFN)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import nn


def init_mlp(key, cfg: ModelConfig, dtype=nn.DEFAULT_DTYPE) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_activation == "swiglu":
        kg, ku, kd = jax.random.split(key, 3)
        return {
            "w_gate": nn.dense_init(kg, (d, f), dtype),
            "w_up": nn.dense_init(ku, (d, f), dtype),
            "w_down": nn.dense_init(kd, (f, d), dtype),
        }
    ki, ko = jax.random.split(key)
    return {
        "w_in": nn.dense_init(ki, (d, f), dtype),
        "w_out": nn.dense_init(ko, (f, d), dtype),
    }


def mlp(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "w_gate" in params:
        g = nn.silu(x @ params["w_gate"])
        u = x @ params["w_up"]
        return (g * u) @ params["w_down"]
    h = nn.gelu(x @ params["w_in"])
    return h @ params["w_out"]


def swiglu_ref(x, w_gate, w_up, w_down):
    """Eq. (1) of the paper — the uncompressed expert forward."""
    return (nn.silu(x @ w_gate) * (x @ w_up)) @ w_down
