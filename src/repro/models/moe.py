"""Mixture-of-Experts layer.

Dispatch is sort-based (MegaBlocks-style) built on ``jax.lax.ragged_dot``:
tokens are argsorted by destination expert, run through grouped matmuls, and
scattered back weighted by their gate values.  No capacity-style one-hot
dispatch tensor is ever materialized, so FLOPs and memory scale with the
tokens actually routed.

Two execution paths:

* ``_moe_local`` — single-shard oracle: all experts resident, exact.
* ``_moe_sharded`` — expert parallelism under ``jax.shard_map``: activations
  are replicated across the ``model`` axis (they are already sharded over
  ``data``/``pod`` by batch), each model shard keeps ``E / model`` experts,
  selects + sorts only the assignments that target its experts into a
  fixed-capacity buffer, computes, scatters back, and ``psum``s over
  ``model``.  This is the "no-all-to-all" EP layout: the only collective is
  the same (T_local, D) psum a tensor-parallel dense MLP would need.

The FloE-compressed expert forward (contextual sparsity + INT2 up) plugs in
via ``expert_fn`` — see repro.core.floe_layer.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map
from repro.common.config import ModelConfig
from repro.models import nn


class Dist(NamedTuple):
    """Distribution context threaded through model code (None = local)."""

    mesh: object  # jax.sharding.Mesh
    batch_axes: tuple  # ("data",) or ("pod", "data")
    batch_sharded: bool  # False for batch=1 decode
    kv_seq_shard: bool = False  # flash-decode: KV cache seq over "model"
    capacity_factor: float = 2.0  # MoE per-shard buffer headroom


def init_moe(key, cfg: ModelConfig, dtype=nn.DEFAULT_DTYPE) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": nn.dense_init(kr, (d, e), jnp.float32),
        "we_gate": nn.dense_init(kg, (e, d, f), dtype, fan_in=d),
        "we_up": nn.dense_init(ku, (e, d, f), dtype, fan_in=d),
        "we_down": nn.dense_init(kd, (e, f, d), dtype, fan_in=f),
    }


def router_topk(x: jax.Array, router_w: jax.Array, k: int
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x (T, D) -> (gates (T,k) f32, experts (T,k) i32, probs (T,E) f32)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    top_vals, top_idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(top_vals, axis=-1)  # Mixtral-style renorm over k
    probs = jax.nn.softmax(logits, axis=-1)
    return gates, top_idx.astype(jnp.int32), probs


def load_balance_loss(probs: jax.Array, expert_idx: jax.Array, e: int
                      ) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    t = probs.shape[0]
    assign = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)  # (T, k, E)
    f = assign.sum(axis=(0, 1)) / jnp.maximum(t * expert_idx.shape[1], 1)
    p = probs.mean(axis=0)
    return e * jnp.sum(f * p)


def _swiglu_grouped(xs, wg, wu, wd, group_sizes, expert_fn=None):
    """xs (N, D) sorted by group; w* (E, D, F)/(E, F, D)."""
    if expert_fn is not None:
        return expert_fn(xs, wg, wu, wd, group_sizes)
    g = jax.lax.ragged_dot(xs, wg, group_sizes)
    u = jax.lax.ragged_dot(xs, wu, group_sizes)
    h = (nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(xs.dtype)
    return jax.lax.ragged_dot(h, wd, group_sizes)


def _sort_dispatch(xf, gates, eids, num_local: int, cap: int,
                   local_offset) -> tuple:
    """Pack assignments targeting local experts into a (cap, D) buffer.

    xf (T, D); gates/eids (T, k).  Returns (xs, group_sizes, tok_idx, scale,
    valid) where xs is expert-sorted.
    """
    t, k = eids.shape
    a = t * k
    flat_eid = eids.reshape(a)
    flat_gate = gates.reshape(a)
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    local_eid = flat_eid - local_offset
    is_local = (local_eid >= 0) & (local_eid < num_local)
    sort_key = jnp.where(is_local, local_eid, num_local)  # sentinel last
    order = jnp.argsort(sort_key, stable=True)
    order = order[:cap]  # assignments beyond capacity are dropped
    sorted_eid = sort_key[order]
    valid = sorted_eid < num_local
    xs = jnp.take(xf, tok[order], axis=0)
    xs = xs * valid[:, None].astype(xs.dtype)
    # bincount with sentinel bucket; drop the sentinel
    group_sizes = jnp.bincount(sorted_eid, length=num_local + 1)[:num_local]
    # clip: the sentinel bucket may start before cap if few local tokens —
    # group_sizes only counts true locals, and trailing buffer rows are zero.
    scale = flat_gate[order] * valid
    return xs, group_sizes.astype(jnp.int32), tok[order], scale, valid


def _capacity(tokens: int, k: int, num_shards: int, factor: float = 2.0,
              num_experts: int = 0) -> int:
    cap = int(tokens * k / max(num_shards, 1) * factor)
    cap = max(cap, 8 * k)
    cap = min(cap, tokens * k)
    return -(-cap // 8) * 8


def _moe_local(params, xf, cfg: ModelConfig, expert_fn=None):
    """All experts resident on one shard; exact (cap = T*k)."""
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    gates, eids, probs = router_topk(xf, params["router"], k)
    t = xf.shape[0]
    xs, group_sizes, tok_idx, scale, valid = _sort_dispatch(
        xf, gates, eids, e, t * k, 0)
    ys = _swiglu_grouped(xs, params["we_gate"], params["we_up"],
                         params["we_down"], group_sizes, expert_fn)
    out = jnp.zeros_like(xf)
    out = out.at[tok_idx].add((ys.astype(jnp.float32)
                               * scale[:, None]).astype(xf.dtype))
    aux = load_balance_loss(probs, eids, e)
    return out, aux


def _moe_sharded_body(xf, router_w, wg, wu, wd, cfg: ModelConfig,
                      cap: int, model_size: int, batch_ax: tuple,
                      expert_fn=None):
    """shard_map body. xf (T_local, D) replicated over 'model'."""
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    num_local = e // model_size
    m = jax.lax.axis_index("model")
    offset = m * num_local

    gates, eids, probs = router_topk(xf, router_w, k)
    xs, group_sizes, tok_idx, scale, valid = _sort_dispatch(
        xf, gates, eids, num_local, cap, offset)
    ys = _swiglu_grouped(xs, wg, wu, wd, group_sizes, expert_fn)
    out = jnp.zeros_like(xf)
    out = out.at[tok_idx].add((ys.astype(jnp.float32)
                               * scale[:, None]).astype(xf.dtype))
    out = jax.lax.psum(out, "model")
    aux = load_balance_loss(probs, eids, e)  # identical on every model shard
    if batch_ax:
        aux = jax.lax.pmean(aux, batch_ax)
    return out, aux


def moe_forward(params: dict, x: jax.Array, cfg: ModelConfig,
                dist: Optional[Dist] = None,
                expert_fn: Optional[Callable] = None
                ) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    if dist is None:
        out, aux = _moe_local(params, x.reshape(b * s, d), cfg, expert_fn)
        return out.reshape(b, s, d), aux

    mesh = dist.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = sizes.get("model", 1)
    if model_size <= 1 or cfg.num_experts % model_size:
        out, aux = _moe_local(params, x.reshape(b * s, d), cfg, expert_fn)
        return out.reshape(b, s, d), aux

    batch_ax = dist.batch_axes if dist.batch_sharded else ()
    n_batch_shards = 1
    for ax in batch_ax:
        n_batch_shards *= sizes.get(ax, 1)
    t_local = b * s // n_batch_shards
    cap = _capacity(t_local, cfg.num_experts_per_tok, model_size,
                    factor=getattr(dist, "capacity_factor", 2.0))

    x_spec = P(batch_ax if batch_ax else None, None, None)
    body = partial(_moe_sharded_body, cfg=cfg, cap=cap,
                   model_size=model_size, batch_ax=batch_ax,
                   expert_fn=expert_fn)
    out, aux = shard_map(
        lambda xf, rw, wg, wu, wd: body(
            xf.reshape(-1, d), rw, wg, wu, wd),
        mesh=mesh,
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(batch_ax if batch_ax else None, None), P()),
        check_vma=False,
    )(x, params["router"], params["we_gate"], params["we_up"],
      params["we_down"])
    # aux comes back identical on all shards
    return out.reshape(b, s, d), aux
