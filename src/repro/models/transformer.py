"""Model assembly: embeddings → segment-scanned blocks → head.

The layer stack is organized as *segments* (ModelConfig.segments()): each
segment is a (pattern, repeats) pair scanned with ``jax.lax.scan`` over
stacked per-repeat parameters, keeping HLO size and compile time independent
of depth.  Heterogeneous stacks (llama4 dense/moe interleave, zamba2
mamba×5+shared) become patterns longer than one.

Zamba2 "shared" blocks keep ONE set of transformer weights per segment
(closure-captured, not scanned) plus a per-invocation input projection that
IS scanned — faithful to Zamba's parameter sharing.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import ModelConfig, ShapeConfig
from repro.models import blocks as blk
from repro.models import nn
from repro.models.moe import Dist


# ------------------------------------------------------------------ init ---
def init_model(key, cfg: ModelConfig, dtype=nn.DEFAULT_DTYPE) -> dict:
    keys = jax.random.split(key, 16)
    params: dict = {}
    if cfg.frontend != "audio":
        params["embedding"] = nn.embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype)
    segs = cfg.segments()
    for si, (pattern, reps) in enumerate(segs):
        seg_key = jax.random.fold_in(keys[1], si)
        seg: dict = {}
        for pi, kind in enumerate(pattern):
            pos_key = jax.random.fold_in(seg_key, pi)
            if kind == "shared":
                # shared weights once; per-invocation input proj stacked
                shared = blk.init_block(pos_key, "shared", cfg, dtype)
                shared_in = shared.pop("shared_in")
                seg["shared_block"] = shared
                stack = {"shared_in": jnp.broadcast_to(
                    shared_in, (reps,) + shared_in.shape).copy()}
                seg[f"pos{pi}"] = stack
            else:
                def one(i, pos_key=pos_key, kind=kind):
                    return blk.init_block(jax.random.fold_in(pos_key, i), kind, cfg, dtype)
                stacked = jax.vmap(lambda i: one(i))(jnp.arange(reps))
                seg[f"pos{pi}"] = stacked
        params[f"seg{si}"] = seg
    params["final_norm"] = {"scale": jnp.zeros((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.dense_init(keys[2], (cfg.d_model, cfg.vocab_size), dtype)
    return params


# ------------------------------------------------------------- embedding ---
def _embed_inputs(params, batch: dict, cfg: ModelConfig):
    """Returns x (B, S, D)."""
    if cfg.frontend == "audio":
        return batch["embeddings"]
    tok = params["embedding"]
    x = jnp.take(tok, batch["tokens"], axis=0)
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        x = jnp.concatenate([batch["vision_embeds"].astype(x.dtype), x], axis=1)
    return x


def _head(params, x, cfg: ModelConfig):
    x = nn.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embedding"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def _constrain(x, dist: Optional[Dist], spec: P):
    if dist is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(dist.mesh, spec))


# --------------------------------------------------------------- forward ---
def _run_segments(params, x, positions, cfg: ModelConfig, dist, *,
                  remat: bool = False):
    """Apply all segments; returns (x, aux_total)."""
    x0 = x  # original embeddings, for zamba shared blocks
    aux_total = jnp.zeros((), jnp.float32)
    bspec = P(dist.batch_axes if (dist and dist.batch_sharded) else None,
              None, None)

    for si, (pattern, reps) in enumerate(cfg.segments()):
        seg = params[f"seg{si}"]
        shared_block = seg.get("shared_block")

        def body(carry, slice_params, pattern=pattern, shared_block=shared_block):
            x, aux = carry
            for pi, kind in enumerate(pattern):
                sp = slice_params[f"pos{pi}"]
                if kind == "shared":
                    x, a = blk.block_forward(
                        shared_block, "shared", x, positions, cfg, dist,
                        x0=x0, shared_in=sp["shared_in"])
                else:
                    x, a = blk.block_forward(sp, kind, x, positions, cfg, dist)
                x = _constrain(x, dist, bspec)
                aux = aux + a
            return (x, aux), None

        if remat:
            body = jax.checkpoint(body)
        stack = {k: v for k, v in seg.items() if k.startswith("pos")}
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stack)
    return x, aux_total


def forward(params: dict, batch: dict, cfg: ModelConfig,
            dist: Optional[Dist] = None, *, remat: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss)."""
    x = _embed_inputs(params, batch, cfg)
    bspec = P(dist.batch_axes if (dist and dist.batch_sharded) else None,
              None, None)
    x = _constrain(x, dist, bspec)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, aux = _run_segments(params, x, positions, cfg, dist, remat=remat)
    return _head(params, x, cfg), aux


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            dist: Optional[Dist] = None, *, remat: bool = False
            ) -> tuple[jax.Array, dict]:
    """Next-token (or masked-target) cross-entropy + router aux."""
    logits, aux = forward(params, batch, cfg, dist, remat=remat)
    if cfg.frontend == "audio":
        ce = nn.softmax_cross_entropy(logits, batch["targets"])
    else:
        n_text = batch["tokens"].shape[1]
        logits_text = logits[:, -n_text:]  # vlm: score only text positions
        ce = nn.softmax_cross_entropy(logits_text[:, :-1], batch["tokens"][:, 1:])
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


# ---------------------------------------------------------------- decode ---
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=nn.DEFAULT_DTYPE) -> dict:
    state: dict = {}
    for si, (pattern, reps) in enumerate(cfg.segments()):
        seg: dict = {}
        for pi, kind in enumerate(pattern):
            def one(_, kind=kind):
                return blk.init_block_state(kind, cfg, batch, max_len, dtype)
            seg[f"pos{pi}"] = jax.vmap(one)(jnp.arange(reps))
        state[f"seg{si}"] = seg
    return state


def _run_segments_step(params, state, x, cfg: ModelConfig, dist,
                       step_fn) -> tuple[jax.Array, dict]:
    """Shared driver for decode (and prefill) over the segment scans.

    x0 (zamba shared-block input) is the embedding sequence itself — for
    decode that is the current token's embedding, for prefill the prompt's.
    """
    x0 = x
    new_state: dict = {}

    for si, (pattern, reps) in enumerate(cfg.segments()):
        seg = params[f"seg{si}"]
        seg_state = state[f"seg{si}"]
        shared_block = seg.get("shared_block")

        def body(x, scanned, pattern=pattern, shared_block=shared_block):
            slice_params, slice_state = scanned
            out_state = {}
            for pi, kind in enumerate(pattern):
                sp = slice_params[f"pos{pi}"]
                st = slice_state[f"pos{pi}"]
                if kind == "shared":
                    x, ns = step_fn(shared_block, "shared", x, st,
                                    x0=x0, shared_in=sp["shared_in"])
                else:
                    x, ns = step_fn(sp, kind, x, st)
                out_state[f"pos{pi}"] = ns
            return x, out_state

        stack = {k: v for k, v in seg.items() if k.startswith("pos")}
        x, new_seg_state = jax.lax.scan(body, x, (stack, seg_state))
        new_state[f"seg{si}"] = new_seg_state
    return x, new_state


def decode_step(params: dict, tokens: jax.Array, state: dict,
                cfg: ModelConfig, dist: Optional[Dist] = None
                ) -> tuple[jax.Array, dict]:
    """One decode step. tokens (B, 1) -> (logits (B, 1, V), new state)."""
    batch = {"tokens": tokens}
    x = _embed_inputs(params, batch, cfg)
    if dist is not None:
        x = _constrain(x, dist, P(dist.batch_axes if dist.batch_sharded
                                  else None, None, None))
    step = partial(_step_decode, cfg=cfg, dist=dist)
    x, new_state = _run_segments_step(params, state, x, cfg, dist, step)
    return _head(params, x, cfg), new_state


def _step_decode(p, kind, x, st, cfg=None, dist=None, x0=None, shared_in=None):
    return blk.block_decode(p, kind, x, st, cfg, dist, x0=x0,
                            shared_in=shared_in)


def prefill(params: dict, batch: dict, state: dict, cfg: ModelConfig,
            dist: Optional[Dist] = None) -> tuple[jax.Array, dict]:
    """Prefill the decode state with a prompt. Returns (logits, state)."""
    x = _embed_inputs(params, batch, cfg)
    step = partial(_step_prefill, cfg=cfg, dist=dist)
    x, new_state = _run_segments_step(params, state, x, cfg, dist, step)
    return _head(params, x[:, -1:, :], cfg), new_state


def _step_prefill(p, kind, x, st, cfg=None, dist=None, x0=None, shared_in=None):
    return blk.block_prefill(p, kind, x, st, cfg, dist, x0=x0,
                             shared_in=shared_in)


def decode_state_spec(cfg: ModelConfig, mesh_axes, mesh_shape,
                      *, batch_sharded: bool, kv_seq_shard: bool = False
                      ) -> dict:
    """PartitionSpec tree mirroring init_decode_state's structure."""
    from repro.common import sharding as shd
    from repro.models.attention import KVCache
    from repro.models.mamba2 import SSMState

    sizes = dict(zip(mesh_axes, mesh_shape))
    model = sizes.get("model", 1)
    mode = shd.attn_mode(cfg, model)
    batch = (("pod", "data") if "pod" in mesh_axes else "data") \
        if batch_sharded else None
    kv_ax = "model" if (mode == "head" and
                        cfg.num_kv_heads % max(model, 1) == 0) else None
    inner_ax = "model" if (cfg.ssm_state and cfg.d_inner % max(model, 1) == 0) else None
    heads_ax = "model" if (cfg.ssm_state and cfg.ssm_heads % max(model, 1) == 0) else None

    if kv_seq_shard and model > 1:
        kv_spec = KVCache(P(None, batch, "model", None, None),
                          P(None, batch, "model", None, None), P(None))
    else:
        kv_spec = KVCache(P(None, batch, None, kv_ax, None),
                          P(None, batch, None, kv_ax, None), P(None))
    ssm_spec = SSMState(P(None, batch, heads_ax, None, None),
                        P(None, batch, None, inner_ax), P(None))

    state: dict = {}
    for si, (pattern, reps) in enumerate(cfg.segments()):
        seg: dict = {}
        for pi, kind in enumerate(pattern):
            seg[f"pos{pi}"] = ssm_spec if kind == "mamba" else kv_spec
        state[f"seg{si}"] = seg
    return state


# ------------------------------------------------------------ input specs --
def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=nn.DEFAULT_DTYPE) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a workload."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(dtype)
    i32 = jnp.dtype(jnp.int32)
    if shape.mode == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.frontend == "audio":
        spec = {"embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32)}
        if shape.mode == "train":
            spec["targets"] = jax.ShapeDtypeStruct((b, s), i32)
        return spec
    if cfg.frontend == "vision" and cfg.frontend_tokens:
        n_img = min(cfg.frontend_tokens, s // 2)
        return {
            "tokens": jax.ShapeDtypeStruct((b, s - n_img), i32),
            "vision_embeds": jax.ShapeDtypeStruct((b, n_img, cfg.d_model), f32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
