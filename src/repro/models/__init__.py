# Submodules are imported lazily by callers; transformer.py re-exports the
# public API once the full zoo exists.
