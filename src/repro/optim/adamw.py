"""AdamW + cosine schedule + global-norm clipping, pure JAX.

Optimizer state is f32 and shaped like the params, so the sharding rules
apply to it transparently (m/v shard exactly like their parameter).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array  # () i32
    m: Any  # pytree like params, f32
    v: Any  # pytree like params, f32


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def cosine_schedule(step: jax.Array, tc: TrainConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - tc.warmup_steps) /
                    jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 tc: TrainConfig) -> tuple[Any, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tc.max_grad_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)

    m = jax.tree.map(lambda a, g: tc.beta1 * a + (1 - tc.beta1) * g,
                     state.m, grads)
    v = jax.tree.map(lambda a, g: tc.beta2 * a + (1 - tc.beta2) * g * g,
                     state.v, grads)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - tc.beta1 ** t
    bc2 = 1.0 - tc.beta2 ** t
    lr = cosine_schedule(step, tc)

    def upd(p, mi, vi):
        mhat = mi / bc1
        vhat = vi / bc2
        delta = mhat / (jnp.sqrt(vhat) + tc.eps) + tc.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step, m, v), \
        {"grad_norm": gnorm, "lr": lr}
