"""Speculative big-little expert execution with verify-or-rollback.

FloE removes stall by *predicting* transfers; this module removes the
residual demand-miss stall by *speculating through it* (MoBiLE's
big-little experts, MELINOE's proxy experts): every expert keeps an
always-resident low-bit "little" shadow (priced by the store planner —
``StorePlan.shadows``), and when a routed expert's slice is still in
flight the scheduler's wait is skipped entirely — the token computes
from the shadow, the big transfer keeps streaming in the background,
and its arrival triggers **verify-or-rollback**:

* **verify** — recompute the speculated rows' contributions from the
  arrived full-precision slice and measure the relative-L2 divergence
  against the shadow outputs.  A learned per-expert
  :class:`DivergencePredictor` (EMA, validation-gated like the serving
  controller's probe adoption) is trained online from these
  measurements and gates *future* speculation.
* **accept** — divergence within the configured bound: the speculative
  token stands (bounded-quality fast path), ``spec.accept`` emitted.
* **rollback** — divergence too large: the affected *requests* (KV
  state is per-request, batch dim 1, functionally updated) restore to
  their pre-speculation snapshot and re-decode; recomputed tokens are
  bitwise equal to a never-speculated decode (union-demand coverage +
  per-(uid, position) sampling keys make outputs batch-independent).

Accounting contract: a skipped wait charges **no** stall (that is the
win); every path that does end up waiting — the divergence gate
declining, a settle forced at request finish, an evicted slice
re-demanded at verify time — routes through ``ExpertScheduler.wait_for``
with the ``speculative_fallback`` cause hint, so stall attribution's
bitwise conservation (Σ causes == stats.stall_s) is preserved with
speculation on, off, or mid-rollback.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import floe_layer
from repro.store import formats as F


# -------------------------------------------------------------- shadows ----
def _qdq_int8(rec: np.ndarray) -> np.ndarray:
    """Per-record symmetric INT8 quantize-dequantize (the draft codec)."""
    rec32 = rec.astype(np.float32)
    scale = np.maximum(np.abs(rec32).max(axis=1, keepdims=True),
                       1e-8) / 127.0
    codes = np.clip(np.round(rec32 / scale), -127, 127)
    return (codes * scale).astype(np.float16)


def _qdq_int2(rec: np.ndarray) -> np.ndarray:
    """Per-record symmetric 2-bit quantize-dequantize: codes in
    {-1, 0, 1} against the record's absmax (the leanest shadow)."""
    rec32 = rec.astype(np.float32)
    scale = np.maximum(np.abs(rec32).max(axis=1, keepdims=True), 1e-8)
    codes = np.clip(np.round(rec32 / scale * 1.5), -1, 1)
    return (codes * scale / 1.5).astype(np.float16)


_CODECS = {8: _qdq_int8, 2: _qdq_int2}


class ShadowBank:
    """Always-resident little copies of the planner's shadowed experts.

    Built once at deployment-build time directly from the model params
    (shadows ship with the non-expert weights at load): no TransferEngine
    traffic, no host/disk-tier mutation, no obs events — so a bank that
    exists but is never *used* leaves the event timeline bitwise
    identical to a shadow-free build (the speculation-off noop pin).
    """

    def __init__(self):
        self._shadows: Dict[Tuple[int, int],
                            Tuple[np.ndarray, jax.Array, jax.Array]] = {}

    def add(self, layer: int, expert: int, chan_idx: np.ndarray,
            gate_cols: jax.Array, down_rows: jax.Array) -> None:
        self._shadows[(layer, expert)] = (
            np.asarray(chan_idx, np.int32), gate_cols, down_rows)

    def has(self, layer: int, expert: int) -> bool:
        return (layer, expert) in self._shadows

    def get(self, layer: int, expert: int
            ) -> Optional[Tuple[np.ndarray, jax.Array, jax.Array]]:
        return self._shadows.get((layer, expert))

    def __len__(self) -> int:
        return len(self._shadows)


def build_shadow_bank(layers: Sequence[dict], plan) -> ShadowBank:
    """Decode every ``plan.shadows`` entry into a resident
    :class:`ShadowBank` (compact record layout, quantize-dequantized at
    the shadow format's bit width, top channels by up-projection norm)."""
    bank = ShadowBank()
    for (li, e), name in sorted(plan.shadows.items()):
        fmt = F.get_shadow_format(name)
        moe = layers[li]["moe"]
        we_gate = np.asarray(moe["we_gate"][e], np.float16)
        we_down = np.asarray(moe["we_down"][e], np.float16)
        f = we_gate.shape[1]
        rank = F.rank_channels_by_upnorm(moe["we_up"][e])
        kept = np.sort(rank[:F.kept_channels(f, fmt.keep_ratio)])
        kept = kept.astype(np.int32)
        rec = np.concatenate([we_gate.T[kept], we_down[kept]], axis=-1)
        rec = _CODECS[fmt.bits](np.ascontiguousarray(rec))
        d = we_gate.shape[0]
        dev = jnp.asarray(rec)
        bank.add(li, e, kept, dev[:, :d], dev[:, d:])
    return bank


# ------------------------------------------------------------- predictor ---
class DivergencePredictor:
    """Online per-expert divergence estimate gating future speculation.

    Each verify feeds ``update`` with the measured shadow-vs-big
    relative-L2 divergence.  The estimate is validation-gated the same
    way the controller adopts trained probes: a per-expert EMA only
    speaks for itself after ``min_samples`` observations; below that the
    *global* EMA substitutes, and with no evidence at all the prior is
    optimistic (0.0 — speculate, measure, learn)."""

    def __init__(self, beta: float = 0.9, min_samples: int = 2):
        assert 0.0 < beta < 1.0, beta
        assert min_samples >= 1, min_samples
        self.beta = beta
        self.min_samples = min_samples
        self._ema: Dict[Tuple[int, int], float] = {}
        self._n: Dict[Tuple[int, int], int] = {}
        self._global = 0.0
        self._gn = 0

    def update(self, layer: int, expert: int, divergence: float) -> None:
        k = (layer, expert)
        d = float(divergence)
        prev = self._ema.get(k)
        self._ema[k] = d if prev is None else \
            self.beta * prev + (1.0 - self.beta) * d
        self._n[k] = self._n.get(k, 0) + 1
        self._global = d if self._gn == 0 else \
            self.beta * self._global + (1.0 - self.beta) * d
        self._gn += 1

    def predicted(self, layer: int, expert: int) -> float:
        k = (layer, expert)
        if self._n.get(k, 0) >= self.min_samples:
            return self._ema[k]
        if self._gn >= self.min_samples:
            return self._global
        return 0.0  # optimistic prior: speculate until measured

    def gate(self, layer: int, expert: int, max_divergence: float) -> bool:
        return self.predicted(layer, expert) <= max_divergence

    def snapshot(self) -> dict:
        return {"samples": self._gn, "global_ema": self._global,
                "experts": {f"{li}/{e}": self._ema[(li, e)]
                            for li, e in sorted(self._ema)}}


# --------------------------------------------------------------- results ---
@dataclasses.dataclass
class SpeculativeResult:
    """What the executor hands back in place of a ``wait_for`` stall."""

    layer: int
    expert: int
    contribution: jax.Array  # (B, d_model) f32, weighted, batch-aligned
    n_channels: int  # shadow channels actually applied
    stall_avoided_s: float  # the wait the shadow sidestepped


@dataclasses.dataclass
class _PendingRow:
    uid: int
    batch_row: int
    hb: np.ndarray  # (d,) the row's MoE input
    own: np.ndarray  # (n_own,) the row's servable channel set
    v_own: np.ndarray  # (n_own,) up activations on ``own``
    weight: float
    spec_out: np.ndarray  # (d,) f32 weighted shadow contribution


@dataclasses.dataclass
class _Pending:
    layer: int
    expert: int
    step: int
    rows: List[_PendingRow]


@dataclasses.dataclass
class _Snapshot:
    step: int
    cur: Optional[int]
    out_len: int
    states: list
    prev_entry: Optional[np.ndarray]
    stall_share_s: float
    compute_share_s: float


# -------------------------------------------------------------- executor ---
class SpeculativeExecutor:
    """The big-little control loop, attached to a ServingController.

    Lifecycle per decode step (driven by the controller):

    1. ``settle``      — verify every pending whose big expert arrived.
    2. ``begin_step``  — snapshot per-request restore points.
    3. ``try_speculate`` (from ``_moe_apply_union`` phase B) — serve a
       demand miss from the shadow instead of ``wait_for``.
    4. ``flush_uid``   — before a request finishes: force-verify its
       pendings (waiting under ``speculative_fallback`` if needed).
    """

    def __init__(self, bank: ShadowBank, *, max_divergence: float = 0.05,
                 beta: float = 0.9, min_samples: int = 2):
        assert max_divergence >= 0.0, max_divergence
        self.bank = bank
        self.max_divergence = float(max_divergence)
        self.divergence = DivergencePredictor(beta=beta,
                                              min_samples=min_samples)
        self.enabled = True
        self.ctrl = None  # ServingController, set by attach()
        self.pending: List[_Pending] = []
        self.rolled_uids: set = set()
        self._snaps: Dict[int, _Snapshot] = {}
        self._req_by_uid: Dict[int, object] = {}
        self._step = 0
        # local mirrors of the SchedulerStats spec_* counters so a
        # detached executor (unit tests) still reports
        self.served = 0
        self.accepts = 0
        self.rollbacks = 0
        self.declined = 0

    # ------------------------------------------------------------ wiring ---
    def attach(self, ctrl) -> None:
        self.ctrl = ctrl
        ctrl.speculator = self

    def reconfigure(self, *, max_divergence: Optional[float] = None) -> None:
        if max_divergence is not None:
            self.max_divergence = float(max_divergence)

    @property
    def sched(self):
        return self.ctrl.sched

    def accept_rate(self) -> float:
        settled = self.accepts + self.rollbacks
        return self.accepts / settled if settled else 1.0

    def report(self) -> dict:
        return {"spec_served": self.served, "spec_accepts": self.accepts,
                "spec_rollbacks": self.rollbacks,
                "spec_declined": self.declined,
                "spec_accept_rate": self.accept_rate(),
                "spec_pending": len(self.pending),
                "divergence_samples": self.divergence._gn}

    # ----------------------------------------------------------- stepping --
    def begin_step(self, reqs) -> None:
        """Snapshot restore points for this step's batch.  A request with
        live pendings keeps its EARLIEST snapshot (rollback must land
        before the first unverified token)."""
        self.rolled_uids.clear()
        live = {row.uid for p in self.pending for row in p.rows}
        for r in reqs:
            self._req_by_uid[r.uid] = r
            if r.uid not in live:
                self._snaps[r.uid] = _Snapshot(
                    step=self._step, cur=r.cur, out_len=len(r.output),
                    states=list(r.states) if r.states is not None else None,
                    prev_entry=r.prev_entry,
                    stall_share_s=r.stall_share_s,
                    compute_share_s=r.compute_share_s)
        self._step += 1

    def _device_id(self, li: int, e: int) -> int:
        """Emit-site device id: the single device, or — under the
        cluster dispatcher — the sticky home of (layer, expert)."""
        s = self.sched
        eng = getattr(s, "engine", None)
        if eng is not None:
            return eng.device_id
        return s.devs[s._sticky(li, e)].engine.device_id

    # --------------------------------------------------------- speculation -
    def try_speculate(self, hn2: jax.Array, li: int, e: int,
                      rows: np.ndarray, row_mask: np.ndarray,
                      served_mask: np.ndarray, v, weights: np.ndarray,
                      reqs, metrics, covs
                      ) -> Optional[SpeculativeResult]:
        """Serve a demand miss from the shadow, or return None to take
        the normal ``wait_for`` path.

        Declines (no shadow / no stall to hide / divergence gate) return
        None; a gate decline additionally hints ``speculative_fallback``
        so the wait the caller then pays is attributed to speculation."""
        if not self.enabled or not self.bank.has(li, e):
            return None
        stall_est = self.sched.stall_estimate(li, e)
        if stall_est <= 0.0:
            return None  # staged already: the normal path is free
        if not self.divergence.gate(li, e, self.max_divergence):
            self.declined += 1
            self.sched.bump_stat("spec_declined", li, e)
            self.sched.hint_cause(li, e, "speculative_fallback")
            return None

        sh_idx, sh_gate, sh_down = self.bank.get(li, e)
        d = int(sh_gate.shape[1])
        contrib = jnp.zeros((hn2.shape[0], d), jnp.float32)
        v_np = np.asarray(v)
        hn2_np = np.asarray(hn2)
        pend_rows: List[_PendingRow] = []
        n_act = 0
        for j, b in enumerate(rows.tolist()):
            own = np.nonzero(served_mask[j])[0]
            use = np.intersect1d(own, sh_idx)
            sel = np.searchsorted(sh_idx, use)
            covs.append(float(use.size) /
                        max(int(np.count_nonzero(row_mask[j])), 1)
                        if row_mask[j].any() else 1.0)
            ye = floe_layer.sparse_expert_apply(
                hn2[b:b + 1], sh_gate[sel], sh_down[sel],
                v[j:j + 1, use])
            wgt = float(weights[b])
            out = np.asarray(ye[0], np.float32) * wgt
            contrib = contrib.at[b].add(jnp.asarray(out))
            n_act += int(use.size)
            req = reqs[b] if b < len(reqs) else None
            if req is not None and not req.done:
                pend_rows.append(_PendingRow(
                    uid=req.uid, batch_row=b,
                    hb=hn2_np[b].copy(), own=own,
                    v_own=v_np[j, own].copy(),
                    weight=wgt, spec_out=out))
        t_sh = self.ctrl.pipe.device.matmul_time(4 * d * n_act,
                                                 4 * d * n_act)
        metrics.compute_s += t_sh
        self.sched.advance(t_sh)
        self.served += 1
        self.sched.bump_stat("spec_served", li, e)
        if pend_rows:
            self.pending.append(_Pending(layer=li, expert=e,
                                         step=self._step - 1,
                                         rows=pend_rows))
        if obs.enabled():
            obs.emit("spec.serve", self.sched.clock, cat="spec",
                     device=self._device_id(li, e),
                     args={"layer": li, "expert": e,
                           "stall_avoided_s": stall_est,
                           "rows": len(pend_rows)})
        return SpeculativeResult(layer=li, expert=e, contribution=contrib,
                                 n_channels=n_act,
                                 stall_avoided_s=stall_est)

    # ------------------------------------------------------------- settle --
    def settle(self, metrics, *, flush: bool = False,
               only_uid: Optional[int] = None) -> set:
        """Verify pendings: arrived ones always; the rest only when
        ``flush`` forces a wait (attributed ``speculative_fallback``).
        Returns the set of uids rolled back."""
        rolled: set = set()
        progress = True
        while progress:
            progress = False
            for p in list(self.pending):
                if p not in self.pending:
                    continue  # emptied by a rollback row-purge
                if only_uid is not None and \
                        not any(r.uid == only_uid for r in p.rows):
                    continue
                arrived = self.sched.stall_estimate(p.layer,
                                                    p.expert) <= 0.0
                if not arrived and not flush:
                    continue
                self._verify(p, metrics, rolled, wait=not arrived)
                if p in self.pending:
                    self.pending.remove(p)
                progress = True
                break  # restart: _verify may purge other pendings
        self.rolled_uids |= rolled
        return rolled

    def flush_uid(self, uid: int, metrics) -> set:
        return self.settle(metrics, flush=True, only_uid=uid)

    def _staged_covering(self, li: int, e: int, need: np.ndarray):
        payload = self.sched.staged_payload(li, e)
        if payload is None:
            return None
        idx = np.asarray(payload[0])
        if need.size and not np.all(np.isin(need, idx)):
            return None
        return payload

    def _verify(self, p: _Pending, metrics, rolled: set,
                *, wait: bool) -> None:
        sched = self.sched
        li, e = p.layer, p.expert
        need = np.unique(np.concatenate([r.own for r in p.rows])
                         if p.rows else np.empty(0, np.int64))
        payload = self._staged_covering(li, e, need)
        if wait or payload is None:
            # the big slice is late or got evicted: this wait is the
            # price of speculation — attribute it as such
            if payload is None:
                payload, was_miss = sched.demand_union(li, e, need)
            else:
                was_miss = False
            # hint AFTER the demand so the demand path's own cause
            # bookkeeping cannot override the speculation attribution
            sched.hint_cause(li, e, "speculative_fallback")
            stall = sched.wait_for(li, e, was_miss=was_miss)
            metrics.stall_s += stall
            cur = self._staged_covering(li, e, need)
            if cur is not None:
                payload = cur
        idx, gate_cols, down_rows = payload
        idx = np.asarray(idx)
        # recompute the speculated rows against the arrived big slice
        num = 0.0
        den = 0.0
        n_act = 0
        for r in p.rows:
            sel = np.searchsorted(idx, r.own)
            assert sel.size == 0 or (int(sel[-1]) < idx.size and
                                     np.array_equal(idx[sel], r.own)), \
                "speculative verify: big slice misses needed channels"
            ye = floe_layer.sparse_expert_apply(
                jnp.asarray(r.hb[None]), gate_cols[sel], down_rows[sel],
                jnp.asarray(r.v_own[None]))
            true_out = np.asarray(ye[0], np.float32) * r.weight
            diff = r.spec_out - true_out
            num += float(np.dot(diff, diff))
            den += float(np.dot(true_out, true_out))
            n_act += int(r.own.size)
        d = gate_cols.shape[1] if gate_cols.ndim == 2 else 1
        t_ver = self.ctrl.pipe.device.matmul_time(4 * d * n_act,
                                                  4 * d * n_act)
        metrics.compute_s += t_ver
        sched.advance(t_ver)
        div = float(np.sqrt(num / max(den, 1e-24)))
        self.divergence.update(li, e, div)
        if obs.enabled():
            obs.emit("spec.divergence", sched.clock, cat="spec",
                     device=self._device_id(li, e),
                     args={"layer": li, "expert": e, "divergence": div})
        if div <= self.max_divergence:
            self.accepts += 1
            sched.bump_stat("spec_accepts", li, e)
            if obs.enabled():
                obs.emit("spec.accept", sched.clock, cat="spec",
                         device=self._device_id(li, e),
                         args={"layer": li, "expert": e,
                               "divergence": div})
            return
        # ---- rollback -----------------------------------------------------
        self.rollbacks += 1
        sched.bump_stat("spec_rollbacks", li, e)
        uids = sorted({r.uid for r in p.rows})
        dropped = 0
        for uid in uids:
            dropped += self._restore(uid)
            rolled.add(uid)
        # every other pending row of a rolled-back request is void (its
        # inputs descend from the rolled-back state)
        for q in list(self.pending):
            if q is p:
                continue
            q.rows = [r for r in q.rows if r.uid not in rolled]
            if not q.rows:
                self.pending.remove(q)
        if obs.enabled():
            obs.emit("spec.rollback", sched.clock, cat="spec",
                     device=self._device_id(li, e),
                     args={"layer": li, "expert": e, "divergence": div,
                           "uids": uids, "tokens_dropped": dropped})

    def _restore(self, uid: int) -> int:
        """Rewind one request to its pre-speculation snapshot; returns
        the number of tokens dropped."""
        req = self._req_by_uid.get(uid)
        snap = self._snaps.get(uid)
        if req is None or snap is None:
            return 0
        dropped = max(len(req.output) - snap.out_len, 0)
        del req.output[snap.out_len:]
        req.cur = snap.cur
        req.prev_entry = snap.prev_entry
        if snap.states is not None:
            req.states = list(snap.states)
        req.stall_share_s = snap.stall_share_s
        req.compute_share_s = snap.compute_share_s
        return dropped
