"""repro.spec_exec — speculative big-little expert execution.

A demand miss no longer has to stall: every shadowed expert keeps an
always-resident low-bit "little" copy (``StorePlan.shadows``, priced by
the planner), the token computes from it while the big transfer keeps
streaming, and the big expert's arrival triggers verify-or-rollback
under a learned per-expert divergence gate.

    plan_store(shadows=...) ──▶ ShadowBank (resident little experts)
                                    │ try_speculate (skip wait_for)
    ServingController ──────▶ SpeculativeExecutor ──▶ settle/verify
                                    │ accept            │ rollback
                              token stands        restore snapshot,
                                                  re-decode bitwise

See ROADMAP.md §spec_exec for the architecture notes.
"""
from repro.spec_exec.executor import (DivergencePredictor, ShadowBank,
                                      SpeculativeExecutor,
                                      SpeculativeResult, build_shadow_bank)

__all__ = [
    "ShadowBank", "build_shadow_bank", "DivergencePredictor",
    "SpeculativeExecutor", "SpeculativeResult",
]
