"""Host data pipeline: deterministic synthetic corpora + byte-level files.

The synthetic LM stream is a learnable Markov/ngram mixture (NOT uniform
noise) so that small models trained on it actually reduce loss and develop
non-trivial activation statistics — the property the FloE sensitivity
experiments need.
"""
from __future__ import annotations

import hashlib
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Markov stream over `vocab` symbols: a learnable bigram backbone with a
    mild order-2 component, so losses drop fast (bigram) and keep improving
    (trigram) — useful activation statistics without real data."""

    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 8):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        self.branch = branch
        self.k = vocab_size  # order-1 contexts: one row per previous token
        self.succ = rng.integers(0, vocab_size, size=(self.k, branch))
        p = 1.0 / np.arange(1, branch + 1) ** 1.5
        self.p = p / p.sum()
        self.rng = rng

    def _ctx(self, a: int, b: int) -> int:
        return (b + (a & 1)) % self.k  # mostly bigram; parity of a adds order-2

    def stream(self, length: int, seed: Optional[int] = None) -> np.ndarray:
        rng = np.random.default_rng(seed if seed is not None else
                                    self.rng.integers(2**31))
        out = np.empty(length, np.int32)
        a, b = 1, 2
        choices = rng.choice(self.branch, size=length, p=self.p)
        noise = rng.random(length)
        rand_tok = rng.integers(0, self.vocab, size=length)
        for i in range(length):
            if noise[i] < 0.05:  # 5% noise keeps entropy > 0
                t = rand_tok[i]
            else:
                t = self.succ[self._ctx(a, b), choices[i]]
            out[i] = t
            a, b = b, int(t)
        return out


class TextFileLM:
    """Byte-level tokens from a file (vocab 256), for real-text smoke runs."""

    def __init__(self, path: str | Path):
        self.data = np.frombuffer(Path(path).read_bytes(), np.uint8).astype(np.int32)

    def stream(self, length: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        start = int(rng.integers(0, max(len(self.data) - length, 1)))
        out = self.data[start:start + length]
        if len(out) < length:
            out = np.pad(out, (0, length - len(out)), mode="wrap")
        return out


def make_batches(source, batch: int, seq_len: int, steps: int,
                 seed: int = 0) -> Iterator[dict]:
    """Yield {"tokens": (B, S+1) int32} batches (inputs+shifted labels)."""
    need = seq_len + 1
    for step in range(steps):
        toks = np.empty((batch, need), np.int32)
        for b in range(batch):
            toks[b] = source.stream(need, seed=seed * 100003 + step * 1009 + b)
        yield {"tokens": toks}
