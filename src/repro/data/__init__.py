from repro.data.pipeline import SyntheticLM, TextFileLM, make_batches

__all__ = ["SyntheticLM", "TextFileLM", "make_batches"]
