"""End-to-end driver: train a ~100M-parameter MoE LM for a few hundred
steps on the synthetic corpus, checkpoint it, then serve it.

    PYTHONPATH=src python examples/train_moe_e2e.py [--steps 300] [--small]

``--small`` shrinks the model for fast CI-style runs; the default is a
~100M-param Mixtral-family config (8 experts, top-2).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import dataclasses
from repro.common.config import TrainConfig, reduced
from repro.configs import get_config
from repro.launch.train import train_loop
from repro.checkpoint import save_checkpoint, load_checkpoint
from repro.models import nn
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/moe_e2e.ckpt.zst")
    args = ap.parse_args()

    base = get_config("mixtral_8x7b")
    if args.small:
        cfg = reduced(base, layers=2, d_model=128)
        batch, seq = 8, 64
    else:
        # ~100M params: 8L, d=512, 8 experts x (512->1024) top-2
        cfg = dataclasses.replace(
            reduced(base, layers=8, d_model=512, vocab=8192),
            moe_d_ff=1024, num_experts=8, num_experts_per_tok=2,
            name="moe-100m")
        batch, seq = 16, 128

    tc = TrainConfig(learning_rate=1e-3, total_steps=args.steps,
                     warmup_steps=max(args.steps // 20, 5))
    t0 = time.time()
    params, _, hist = train_loop(cfg, tc, batch=batch, seq=seq,
                                 steps=args.steps, log_every=25)
    n_params = nn.count_params(params)
    print(f"\ntrained {n_params / 1e6:.1f}M params in {time.time() - t0:.0f}s;"
          f" loss {hist[0][1]:.3f} -> {hist[-1][1]:.3f}")

    nbytes = save_checkpoint(args.ckpt, params)
    print(f"checkpoint: {nbytes / 2**20:.1f} MiB -> {args.ckpt}")
    params = load_checkpoint(args.ckpt)

    # --- serve it ---
    eng = ServingEngine(params, cfg, batch_size=4, max_len=seq + 32)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 16,
                                           dtype=np.int64).astype(np.int32),
                           max_new_tokens=16, temperature=0.7 if i % 2 else 0))
    done = eng.run()
    for r in done:
        print(f"req {r.uid}: {len(r.output)} tokens, head={r.output[:8]}")
    print(f"serving: {eng.tokens_per_second():.1f} tok/s wall-clock "
          f"(batched decode, CPU)")


if __name__ == "__main__":
    main()
