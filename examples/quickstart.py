"""Quickstart: the FloE pipeline end to end on a small Mixtral-style MoE.

    PYTHONPATH=src python examples/quickstart.py

1. build a reduced Mixtral-8x7B-family model
2. HQQ-INT2-quantize every expert's up projection (§3.2.2)
3. calibrate contextual-sparsity thresholds from sample activations (§3.2.1)
4. decode with the on-the-fly pipeline: dual predictors prefetch compressed
   expert slices while the previous layer computes (§3.3-3.4)
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import reduced
from repro.configs import get_config
from repro.core import sparsify
from repro.core.pipeline import (FloEPipeline, _unstack_layers,
                                 paper_scaled_models)
from repro.models import transformer as tf


def main():
    cfg = reduced(get_config("mixtral_8x7b"), layers=4, d_model=128)
    print(f"model: {cfg.name} — {cfg.num_layers}L d={cfg.d_model} "
          f"{cfg.num_experts}e top-{cfg.num_experts_per_tok}, "
          f"FloE sparsity={cfg.floe.sparsity} up_bits={cfg.floe.up_bits}")
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)

    # --- calibrate per-(layer, expert) thresholds (Eq. 6) ---
    layers = _unstack_layers(params, cfg)
    xcal = jax.random.normal(jax.random.PRNGKey(9), (256, cfg.d_model)) * 0.5
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    print(f"calibrated {thr.size} thresholds, mean t = {thr.mean():.4f}")

    # --- decode under the three serving modes ---
    device, link = paper_scaled_models(cfg)
    h = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.d_model)) * 0.3
    for mode in ("naive", "floe", "resident"):
        pipe = FloEPipeline(params, cfg, thresholds=thr, cache_slots=4,
                            mode=mode, device=device, link=link)
        for _ in range(4):
            out, m = pipe.decode_token(h)
        print(f"{mode:9s}: {pipe.tokens_per_second():8.1f} tok/s (modeled)  "
              f"coverage={m.coverage:.2f} "
              f"stall={sum(x.stall_s for x in pipe.metrics) * 1e3:.2f}ms")


if __name__ == "__main__":
    main()
