"""Serve a trained MoE with FloE offloading and compare against baselines —
the paper's Fig. 6 scenario at laptop scale.

    PYTHONPATH=src python examples/serve_offloaded.py [--tokens 8]

Trains briefly (so activations have real structure), calibrates thresholds,
trains the inter-expert predictors from a routing trace, then decodes the
SAME weights under four declarative deployments (``repro.deploy``):
naive / FloE(no prefetch) / FloE / resident — each mode is one
:class:`DeploymentSpec` differing only in its ``RuntimeSpec``.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import TrainConfig, reduced
from repro.configs import get_config
from repro.core import predictor, sparsify
from repro.core.pipeline import _unstack_layers
from repro.data import SyntheticLM, make_batches
from repro.deploy import DeploymentSpec, ModelSpec, RuntimeSpec, build
from repro.launch.train import train_loop
from repro.models import blocks as blk
from repro.models import nn
from repro.models.moe import router_topk


def collect_trace(cfg, params, n_batches=2):
    """(hidden states per layer, router targets per layer) on real data."""
    src = SyntheticLM(cfg.vocab_size, seed=11)
    layers = _unstack_layers(params, cfg)
    hs_all = [[] for _ in layers]
    for b in make_batches(src, 4, 64, n_batches, seed=11):
        x = jnp.take(params["embedding"], jnp.asarray(b["tokens"][:, :64]), 0)
        bsz, s, d = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))
        for li, layer in enumerate(layers):
            hs_all[li].append(x.reshape(-1, d))
            kind = "moe" if "moe" in layer else "dense"
            x, _ = blk.block_forward(layer, kind, x, pos, cfg)
    return [jnp.concatenate(h) for h in hs_all], layers


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--train_steps", type=int, default=120)
    args = ap.parse_args()

    cfg = reduced(get_config("mixtral_8x7b"), layers=4, d_model=128)
    tc = TrainConfig(learning_rate=2e-3, total_steps=args.train_steps,
                     warmup_steps=10)
    params, _, hist = train_loop(cfg, tc, batch=8, seq=64,
                                 steps=args.train_steps, log_every=10**9)
    print(f"trained: loss {hist[0][1]:.3f} -> {hist[-1][1]:.3f}")

    # thresholds from real activation traces (Eq. 6)
    hs, layers = collect_trace(cfg, params)
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    inter = [None] * cfg.num_layers
    k = cfg.num_experts_per_tok
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        hn = nn.rms_norm(hs[li], layer["mlp_norm"]["scale"], cfg.norm_eps)
        for e in range(cfg.num_experts):
            u = hn @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
        # inter-expert predictor for layer li trained on layer li-1 states
        if li > 0:
            _, ids, _ = router_topk(hn, layer["moe"]["router"], k)
            targets = jax.nn.one_hot(ids, cfg.num_experts).sum(1)
            ip = predictor.init_inter_predictor(
                jax.random.PRNGKey(li), cfg.d_model, cfg.num_experts, 64)
            inter[li] = predictor.train_inter_predictor(
                ip, hs[li - 1], targets, steps=150)
    print(f"calibrated thresholds + {sum(p is not None for p in inter)} "
          "inter-expert predictors")

    model = ModelSpec(arch="mixtral-8x7b", layers=4, d_model=128)
    results = {}
    for mode, pf in (("naive", False), ("floe-noprefetch", False),
                     ("floe", True), ("resident", False)):
        spec = DeploymentSpec(
            name=mode, model=model,
            runtime=RuntimeSpec(mode="floe" if mode.startswith("floe")
                                else mode,
                                prefetch=pf, use_runtime=False))
        dep = build(spec, params=params, thresholds=thr,
                    inter_predictors=inter if pf else None)
        dep.generate(args.tokens, seed=50)
        results[mode] = dep.report()["tokens_per_s"]
    base = results["naive"]
    print("\nmode              tok/s(modeled)  speedup-vs-naive")
    for mode, tps in results.items():
        print(f"{mode:<17s} {tps:12.1f}   {tps / base:10.2f}x")
    print("\n(paper Fig. 6: FloE = 48.7x vs DeepSpeed-MII, "
          "2.6x vs Mixtral-Offloading, 91% of resident)")


if __name__ == "__main__":
    main()
