"""Contextual sparsification S_t: calibration, variants, theorem ordering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import sparsify


@given(sparsity=st.floats(0.1, 0.95),
       dist=st.sampled_from(["normal", "laplace", "uniform"]))
@settings(max_examples=15, deadline=None)
def test_threshold_achieves_target_sparsity(sparsity, dist):
    key = jax.random.PRNGKey(int(sparsity * 1000))
    n = 20000
    if dist == "normal":
        a = jax.random.normal(key, (n,))
    elif dist == "laplace":
        a = jax.random.laplace(key, (n,))
    else:
        a = jax.random.uniform(key, (n,), minval=-1, maxval=1)
    t = sparsify.threshold_from_samples(jnp.abs(a), sparsity)
    got = sparsify.achieved_sparsity(jnp.abs(a) >= t)
    assert abs(float(got) - sparsity) < 0.02


def test_s_t_zeroes_below_threshold():
    a = jnp.array([-2.0, -0.5, 0.1, 0.9, 3.0])
    out = sparsify.s_t(a, 1.0)
    np.testing.assert_array_equal(np.asarray(out), [-2.0, 0.0, 0.0, 0.0, 3.0])


def test_sparse_up_equals_dense_at_zero_threshold():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 32))
    wg = jax.random.normal(jax.random.PRNGKey(1), (32, 64)) * 0.1
    wu = jax.random.normal(jax.random.PRNGKey(2), (32, 64)) * 0.1
    wd = jax.random.normal(jax.random.PRNGKey(3), (64, 32)) * 0.1
    dense = sparsify.expert_forward_dense(x, wg, wu, wd)
    sp = sparsify.expert_forward_sparse_up(x, wg, wu, wd, jnp.zeros(()))
    np.testing.assert_allclose(np.asarray(dense), np.asarray(sp), atol=1e-6)


def test_block_union_mask():
    m = jnp.zeros((2, 256), bool).at[0, 5].set(True).at[1, 200].set(True)
    bu = sparsify.block_union_mask(m, 128)
    assert bu.shape == (2, 2)
    assert bool(bu[0, 0]) and not bool(bu[0, 1])
    assert not bool(bu[1, 0]) and bool(bu[1, 1])


def test_pruning_loss_ordering_gaussian_exponential():
    """Theorem 3.1 under its own assumptions: a_up ~ N(0,s), a_gate ~
    shifted exponential (SiLU-like) => L_down <= L_up < L_gate."""
    key = jax.random.PRNGKey(0)
    t, f, d = 4096, 256, 64
    k1, k2, k3 = jax.random.split(key, 3)
    a_up = jax.random.normal(k1, (t, f))
    a_gate = jax.random.exponential(k2, (t, f)) / 11.0 - 0.28  # paper's fit
    wd = jax.random.normal(k3, (f, d)) / jnp.sqrt(f)
    h = a_gate * a_up
    for sp in (0.3, 0.5):
        t_d = sparsify.threshold_from_samples(jnp.abs(h), sp)
        t_u = sparsify.threshold_from_samples(jnp.abs(a_up), sp)
        t_g = sparsify.threshold_from_samples(jnp.abs(a_gate), sp)
        l_d = float(jnp.mean(jnp.sum(((h - sparsify.s_t(h, t_d)) @ wd) ** 2, -1)))
        l_u = float(jnp.mean(jnp.sum(((h - a_gate * sparsify.s_t(a_up, t_u)) @ wd) ** 2, -1)))
        l_g = float(jnp.mean(jnp.sum(((h - sparsify.s_t(a_gate, t_g) * a_up) @ wd) ** 2, -1)))
        assert l_d <= l_u + 1e-6, (sp, l_d, l_u)
        assert l_u < l_g, (sp, l_u, l_g)


def test_pruning_losses_on_trained_like_weights():
    """The helper runs end-to-end on expert weights."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (512, 64))
    wg = jax.random.normal(jax.random.PRNGKey(2), (64, 256)) * 0.1
    wu = jax.random.normal(jax.random.PRNGKey(3), (64, 256)) * 0.1
    wd = jax.random.normal(jax.random.PRNGKey(4), (256, 64)) * 0.1
    losses = sparsify.pruning_losses(x, wg, wu, wd, 0.5)
    assert losses["down"] <= losses["up"] + 1e-6
    assert all(np.isfinite(float(v)) for v in losses.values())
