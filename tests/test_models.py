"""Model-zoo correctness: decode==forward, SWA masks, SSD vs sequential."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig, reduced
from repro.configs import get_config
from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import transformer as tf


def _dense_cfg(**kw):
    base = dict(name="t", kind="dense", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=128)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("aid", ["starcoder2_7b", "mixtral_8x7b", "zamba2_7b",
                                 "mamba2_780m", "smollm_135m"])
def test_decode_matches_forward(aid):
    cfg = reduced(get_config(aid))
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    logits, _ = tf.forward(params, {"tokens": toks}, cfg)
    state = tf.init_decode_state(cfg, b, 32, jnp.float32)
    for t in range(s):
        lt, state = tf.decode_step(params, toks[:, t:t + 1], state, cfg)
        np.testing.assert_allclose(np.asarray(lt[:, 0]),
                                   np.asarray(logits[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_prefill_matches_forward():
    cfg = reduced(get_config("glm4_9b"))
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 9
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    logits, _ = tf.forward(params, {"tokens": toks}, cfg)
    state = tf.init_decode_state(cfg, b, 32, jnp.float32)
    lp, state = tf.prefill(params, {"tokens": toks}, state, cfg)
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_mask_limits_attention():
    """Token far outside the window must not influence the output."""
    cfg = _dense_cfg(sliding_window=4, vocab_size=64)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, 64)
    logits, _ = tf.forward(params, {"tokens": toks}, cfg)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % 64)  # outside window of t=11
    logits2, _ = tf.forward(params, {"tokens": toks2}, cfg)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(logits2[:, -1]), atol=1e-5)


def test_causality():
    cfg = _dense_cfg()
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, 128)
    logits, _ = tf.forward(params, {"tokens": toks}, cfg)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % 128)
    logits2, _ = tf.forward(params, {"tokens": toks2}, cfg)
    np.testing.assert_allclose(np.asarray(logits[:, :-1]),
                               np.asarray(logits2[:, :-1]), atol=1e-5)


def test_encoder_is_bidirectional():
    cfg = reduced(get_config("hubert_xlarge"))
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    emb = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    logits, _ = tf.forward(params, {"embeddings": emb}, cfg)
    emb2 = emb.at[0, -1].add(1.0)
    logits2, _ = tf.forward(params, {"embeddings": emb2}, cfg)
    # changing the LAST frame changes the FIRST position's logits
    assert float(jnp.abs(logits[:, 0] - logits2[:, 0]).max()) > 1e-6


def test_chunked_attention_matches_unchunked():
    cfg = _dense_cfg(num_layers=1)
    params = attn_lib.init_attn(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 100, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(100)[None], (2, 100)).astype(jnp.int32)
    big = attn_lib.attention(params, x, pos, cfg)
    import repro.models.attention as A
    old = A.Q_CHUNK
    try:
        A.Q_CHUNK = 32  # force chunked path
        small = attn_lib.attention(params, x, pos, cfg)
    finally:
        A.Q_CHUNK = old
    np.testing.assert_allclose(np.asarray(big), np.asarray(small),
                               rtol=2e-5, atol=2e-5)


def test_ssd_chunk_invariance():
    """Chunk length must not change SSD results."""
    cfg = ModelConfig(name="m", kind="ssm", num_layers=1, d_model=32,
                      num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=32,
                      ssm_state=8, ssm_head_dim=8, ssm_chunk=4)
    params = mamba_lib.init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 19, 32))
    y4 = mamba_lib.mamba2_forward(params, x, cfg)
    import dataclasses
    cfg16 = dataclasses.replace(cfg, ssm_chunk=16)
    y16 = mamba_lib.mamba2_forward(params, x, cfg16)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16),
                               rtol=1e-4, atol=1e-5)


def test_rope_relative_shift():
    """RoPE attention score depends only on relative distance."""
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def score(qpos, kpos):
        qr = attn_lib.apply_rope(q, jnp.full((1, 1), qpos), 1e4)
        kr = attn_lib.apply_rope(k, jnp.full((1, 1), kpos), 1e4)
        return float(jnp.sum(qr * kr))
    assert abs(score(5, 3) - score(105, 103)) < 1e-3


def test_ring_buffer_decode_long():
    """Decode past the window size stays finite and windowed."""
    cfg = _dense_cfg(sliding_window=8)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    state = tf.init_decode_state(cfg, 1, 8, jnp.float32)  # cache = window
    tok = jnp.ones((1, 1), jnp.int32)
    for _ in range(20):  # wraps the ring twice
        logits, state = tf.decode_step(params, tok, state, cfg)
    assert bool(jnp.isfinite(logits).all())
