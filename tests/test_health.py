"""repro.obs.health — burn-rate alerting, anomaly detection, forensics.

Covers the health-layer acceptance criteria:

* ``HealthSpec`` validation names the offending field and the section
  survives the JSON round-trip; ``replan.trigger="health"`` requires an
  enabled health section,
* multi-window burn-rate alerting: stationary error rates inside the
  budget stay silent, a burst pages on BOTH windows, per-tenant
  channels are independent, cooldown/hysteresis follow the
  ``TriggerState`` discipline,
* the composition detector judges only against a FULL aged reference
  (cold-start transients stay silent) and fires on a genuine flip,
* the flight recorder stays bounded and window extraction is
  span-overlap aware,
* incident bundles are byte-deterministic and carry the replayable
  pieces,
* the monitor scopes per model on a shared bus (fleet discipline),
* ``Deployment.serve(health=...)`` wires the monitor for exactly the
  duration of the call and ``report()["health"]`` summarizes it.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.deploy import (DeploymentSpec, HealthSpec, ModelSpec, ReplanSpec,
                          ResourceSpec, RuntimeSpec, ServingSpec, SpecError)
from repro.obs.events import Event
from repro.obs.health import (Alert, BurnRateAlerter, CompositionDetector,
                              FlightRecorder, HealthMonitor,
                              LinkHealthDetector, TriggerState)
from repro.obs.health.recorder import BUNDLE_SCHEMA, build_bundle


def _served_spec(**hkw):
    return DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=128),
        serving=ServingSpec(slots=2, max_len=32, online_train=False),
        health=HealthSpec(**hkw))


# -------------------------------------------------------------- spec layer --
def test_health_spec_defaults_validate():
    _served_spec().validate()


@pytest.mark.parametrize("field,kw", [
    ("health.slo_target", dict(slo_target=0.0)),
    ("health.slo_target", dict(slo_target=1.0)),
    ("health.fast_window_s", dict(fast_window_s=0.0)),
    ("health.slow_window_s", dict(slow_window_s=5.0, fast_window_s=5.0)),
    ("health.page_burn", dict(page_burn=0.0)),
    ("health.ticket_burn", dict(ticket_burn=0.0)),
    ("health.ticket_burn", dict(ticket_burn=9.0, page_burn=4.0)),
    ("health.tpot_budget_ms", dict(tpot_budget_ms=-1.0)),
    ("health.min_events", dict(min_events=0)),
    ("health.anomaly_window", dict(anomaly_window=1)),
    ("health.anomaly_threshold", dict(anomaly_threshold=0.0)),
    ("health.anomaly_threshold", dict(anomaly_threshold=1.5)),
    ("health.link_window_s", dict(link_window_s=0.0)),
    ("health.link_util_threshold", dict(link_util_threshold=0.0)),
    ("health.queue_delay_s", dict(queue_delay_s=-0.1)),
    ("health.hysteresis", dict(hysteresis=1.5)),
    ("health.cooldown_s", dict(cooldown_s=-1.0)),
    ("health.ring_events", dict(ring_events=0)),
    ("health.max_incidents", dict(max_incidents=-1)),
])
def test_invalid_health_spec_names_field(field, kw):
    with pytest.raises(SpecError) as ei:
        _served_spec(**kw).validate()
    assert ei.value.field == field


def test_health_requires_serving_section():
    with pytest.raises(SpecError) as ei:
        DeploymentSpec(
            model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=128),
            health=HealthSpec())
    assert ei.value.field == "health.enabled"


def test_replan_health_trigger_requires_health_section():
    with pytest.raises(SpecError) as ei:
        DeploymentSpec(
            model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=128),
            resources=ResourceSpec(vram_gb=1.0),
            serving=ServingSpec(slots=2, online_train=False),
            replan=ReplanSpec(trigger="health"))
    assert ei.value.field == "replan.trigger"
    # disabled health does not satisfy the trigger either
    with pytest.raises(SpecError):
        DeploymentSpec(
            model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=128),
            resources=ResourceSpec(vram_gb=1.0),
            serving=ServingSpec(slots=2, online_train=False),
            replan=ReplanSpec(trigger="health"),
            health=HealthSpec(enabled=False)).validate()


def test_replan_trigger_must_be_known():
    with pytest.raises(SpecError) as ei:
        DeploymentSpec(
            model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=128),
            resources=ResourceSpec(vram_gb=1.0),
            serving=ServingSpec(slots=2, online_train=False),
            replan=ReplanSpec(trigger="vibes"))
    assert ei.value.field == "replan.trigger"


def test_health_spec_json_round_trip():
    spec = _served_spec(slo_target=0.95, fast_window_s=2.0,
                        tpot_budget_ms=80.0, incident_dir="/tmp/x")
    again = DeploymentSpec.from_json(spec.to_json())
    assert again == spec
    assert again.health.tpot_budget_ms == 80.0


def test_health_spec_round_trip_none_and_unknown_field():
    spec = DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=128))
    assert DeploymentSpec.from_json(spec.to_json()).health is None
    d = json.loads(_served_spec().to_json())
    d["health"]["page_rate"] = 3.0
    with pytest.raises(SpecError):
        DeploymentSpec.from_dict(d)
    d2 = json.loads(_served_spec().to_json())
    d2["health"] = None  # explicit null: no health layer
    assert DeploymentSpec.from_dict(d2).health is None


# ------------------------------------------------------------ trigger state --
def test_trigger_state_fire_disarm_rearm_cooldown():
    st = TriggerState()
    assert st.update(0.0, 2.0, 1.0, hysteresis=0.5, cooldown_s=10.0)
    # disarmed until the value sinks to hysteresis * threshold
    assert not st.update(1.0, 2.0, 1.0, hysteresis=0.5, cooldown_s=10.0)
    assert not st.update(2.0, 0.4, 1.0, hysteresis=0.5, cooldown_s=10.0)
    # re-armed now, but cooldown still holds fire
    assert not st.update(5.0, 2.0, 1.0, hysteresis=0.5, cooldown_s=10.0)
    assert st.update(11.0, 2.0, 1.0, hysteresis=0.5, cooldown_s=10.0)


def test_trigger_state_eligible_gates_firing_only():
    st = TriggerState()
    assert not st.update(0.0, 9.0, 1.0, hysteresis=0.5, cooldown_s=0.0,
                         eligible=False)
    assert st.update(1.0, 9.0, 1.0, hysteresis=0.5, cooldown_s=0.0)


# ---------------------------------------------------------------- burn rate --
def _burn(**kw):
    base = dict(slo_target=0.9, fast_window_s=5.0, slow_window_s=30.0,
                page_burn=4.0, ticket_burn=2.0, min_events=4,
                hysteresis=0.5, cooldown_s=10.0)
    base.update(kw)
    return BurnRateAlerter(**base)


def test_burn_stationary_inside_budget_is_silent():
    b = _burn()
    alerts = []
    for i in range(200):  # 5% errors against a 10% budget
        b.record(i * 0.5, "chat", i % 20 == 19)
        alerts += b.evaluate(i * 0.5)
    assert alerts == []


def test_burn_burst_pages_on_both_windows():
    b = _burn()
    for i in range(40):  # healthy preamble
        b.record(i * 0.5, "chat", False)
    alerts = []
    for i in range(30):  # dense failures: fast AND slow windows burn
        t = 20.0 + i * 0.3
        b.record(t, "chat", True)
        alerts += b.evaluate(t)
    severities = [a.severity for a in alerts]
    assert "page" in severities
    page = next(a for a in alerts if a.severity == "page")
    assert page.signal == "attainment" and page.key == "chat"
    assert page.detail["burn_fast"] > 4.0 and page.detail["burn_slow"] > 4.0


def test_burn_slow_only_raises_ticket_not_page():
    b = _burn()
    alerts = []
    # 30% errors, spread: slow burn ~3 (> ticket 2, < page 4); the fast
    # 5s window holds ~2 events so the page channel never has both
    for i in range(60):
        t = i * 2.0
        b.record(t, "chat", i % 10 < 3)
        alerts += b.evaluate(t)
    assert any(a.severity == "ticket" for a in alerts)
    assert not any(a.severity == "page" for a in alerts)


def test_burn_tenants_are_independent_channels():
    b = _burn(min_events=2, cooldown_s=0.0)
    for i in range(20):
        b.record(i * 0.2, "chat", True)   # chat on fire
        b.record(i * 0.2, "code", False)  # code healthy
    alerts = b.evaluate(4.0)
    assert alerts and all(a.key == "chat" for a in alerts)


def test_burn_hysteresis_and_cooldown_limit_page_rate():
    b = _burn(min_events=2)
    pages = []

    def drive(t0, n, dt, err):
        got = []
        for i in range(n):
            t = t0 + i * dt
            b.record(t, "chat", err)
            got += [a for a in b.evaluate(t) if a.severity == "page"]
        return got

    # one sustained incident = ONE page: the channel disarms after
    # firing and the burn never sinks to the hysteresis re-arm level
    pages += drive(0.0, 40, 0.25, True)
    assert len(pages) == 1
    # recovery drains the windows, the channel re-arms silently
    pages += drive(10.0, 200, 0.5, False)
    assert len(pages) == 1
    # a second incident pages again, past the cooldown
    pages += drive(110.0, 40, 0.25, True)
    assert len(pages) == 2
    assert pages[1].t - pages[0].t >= 10.0


# -------------------------------------------------------------- composition --
def test_composition_warms_up_against_full_reference():
    det = CompositionDetector(window=4, threshold=0.2, cooldown_s=0.0)
    # 4 live + 4 aged are needed before any judgement: the first 7
    # observations must stay silent no matter how different they look
    segs = [{"eviction": 1.0}, {"eviction": 1.0}, {"link_contention": 1.0},
            {"predictor_miss": 1.0}, {"eviction": 1.0},
            {"disk_tier_miss": 1.0}, {"draft_residual": 1.0}]
    assert all(det.observe(float(i), s) is None
               for i, s in enumerate(segs))


def test_composition_flip_fires_with_top_cause_key():
    det = CompositionDetector(window=4, threshold=0.3, cooldown_s=0.0)
    alerts = []
    for i in range(8):
        alerts.append(det.observe(float(i), {"predictor_miss": 1.0}))
    assert alerts == [None] * 8  # stable composition: silent
    for i in range(8, 14):
        alerts.append(det.observe(float(i), {"link_contention": 1.0}))
    fired = [a for a in alerts if a is not None]
    assert fired and fired[0].key == "cause:link_contention"
    assert fired[0].severity == "anomaly"
    assert fired[0].value > 0.3


def test_composition_scaling_burst_stays_silent():
    det = CompositionDetector(window=4, threshold=0.3, cooldown_s=0.0)
    for i in range(8):
        det.observe(float(i), {"eviction": 0.1, "link_contention": 0.05})
    for i in range(8, 16):  # 10x the volume, same shares
        a = det.observe(float(i), {"eviction": 1.0, "link_contention": 0.5})
        assert a is None


# --------------------------------------------------------------- link health --
def test_link_util_alert_per_device():
    det = LinkHealthDetector(window_s=5.0, util_threshold=1.5,
                             queue_delay_s=0.0, cooldown_s=0.0)
    fired = []
    for i in range(10):  # 2.0s of link time laid down per 1s on dev 1
        fired += det.observe(i * 0.5, 1, 1.0, 0.0)
        fired += det.observe(i * 0.5, 0, 0.01, 0.0)  # dev 0 idle
    assert fired and all(a.key == "device:1" for a in fired)
    assert all(a.signal == "link_util" for a in fired)
    assert det.last_util[1] > 1.5 > det.last_util[0]


def test_queue_delay_rule_disabled_at_zero():
    det = LinkHealthDetector(window_s=5.0, util_threshold=100.0,
                             queue_delay_s=0.0, cooldown_s=0.0)
    assert det.observe(0.0, 0, 0.1, queue_delay=99.0) == []
    det2 = LinkHealthDetector(window_s=5.0, util_threshold=100.0,
                              queue_delay_s=0.5, cooldown_s=0.0)
    fired = det2.observe(0.0, 0, 0.1, queue_delay=99.0)
    assert [a.signal for a in fired] == ["queue_delay"]


# ----------------------------------------------------------- flight recorder --
def _ev(seq, t, name="serving.step", dur=0.0, model="", args=None):
    return Event(seq=seq, t=t, name=name, cat="serving", dur=dur,
                 device=0, model=model, lane=None, args=args)


def test_recorder_bounded_ring_and_drop_count():
    rec = FlightRecorder(maxlen=8)
    for i in range(20):
        rec.record(_ev(i, float(i)))
    assert len(rec) == 8
    assert rec.recorded == 20 and rec.dropped == 12
    assert [e.seq for e in rec.window(0.0, 100.0)] == list(range(12, 20))


def test_recorder_window_is_span_overlap_aware():
    rec = FlightRecorder()
    rec.record(_ev(0, 1.0, dur=0.0))          # instant before window
    rec.record(_ev(1, 2.0, dur=5.0))          # span overlapping into it
    rec.record(_ev(2, 6.0))                   # inside
    rec.record(_ev(3, 11.0))                  # after
    got = [e.seq for e in rec.window(5.0, 10.0)]
    assert got == [1, 2]


def test_recorder_scopes_per_model():
    rec = FlightRecorder()
    rec.record(_ev(0, 1.0, model="a"))
    rec.record(_ev(1, 1.5, model="b"))
    rec.record(_ev(2, 2.0, model=""))
    assert [e.seq for e in rec.window(0.0, 9.0, model="a")] == [0]
    assert [e.seq for e in rec.window(0.0, 9.0)] == [0, 1, 2]


# --------------------------------------------------------------- bundles --
def _alert(t=5.0):
    return Alert(t=t, signal="attainment", severity="page", key="chat",
                 value=6.0, threshold=4.0, detail={"burn_fast": 6.0})


def test_bundle_is_byte_deterministic_and_schema_tagged():
    evs = [_ev(0, 1.0, name="request.finish",
               args={"uid": 0, "attained": False, "tenant": "chat",
                     "stall_s": 0.2, "tokens": 4}),
           _ev(1, 2.0, name="demand.stall", dur=0.1,
               args={"stall_s": 0.1, "causes": {"eviction": 0.1}})]
    kw = dict(alert=_alert(), events=evs, metrics={"m": 1}, window=30.0,
              seq=0)
    a, b = build_bundle(**kw), build_bundle(**kw)
    assert a == b
    doc = json.loads(a)
    assert doc["schema"] == BUNDLE_SCHEMA
    assert set(doc) >= {"schema", "incident", "alert", "window", "trace",
                        "metrics", "stall_attribution", "requests"}
    assert doc["requests"]["offenders"] == [0]
    assert doc["stall_attribution"]["causes"]["eviction"] == 0.1
    assert doc["trace"]["traceEvents"]  # renders as a Perfetto slice


# ---------------------------------------------------------------- monitor --
def _spec_small(**kw):
    base = dict(slo_target=0.9, fast_window_s=5.0, slow_window_s=30.0,
                page_burn=4.0, ticket_burn=2.0, min_events=2,
                cooldown_s=0.0, max_incidents=2)
    base.update(kw)
    return HealthSpec(**base)


def _finish(seq, t, ok, tenant="chat", model=""):
    return _ev(seq, t, name="request.finish", model=model,
               args={"uid": seq, "attained": ok, "tenant": tenant})


def test_monitor_pages_on_failure_burst_and_caps_incidents():
    m = HealthMonitor(_spec_small(max_incidents=1))
    for i in range(10):
        m.on_event(_finish(i, i * 0.5, True))
    for i in range(10, 22):
        m.on_event(_finish(i, 5.0 + (i - 10) * 0.2, False))
    assert m.count("page") >= 1
    assert m.first_alert_t() is not None
    assert len(m.alerts) >= 2  # ticket + page at least
    assert len(m.bundles) == 1  # max_incidents caps capture, not alerts
    rep = m.report()
    assert rep["pages"] == m.count("page")
    assert rep["metrics"]["health.alerts.page"] == rep["pages"]
    assert rep["recorder"]["recorded"] == 22


def test_monitor_emits_health_alert_event_but_ignores_own():
    m = HealthMonitor(_spec_small())
    seen = []

    class Spy:
        def on_event(self, ev):
            seen.append(ev)

    with obs.use_bus(obs.EventBus()), obs.consumer(m, Spy()):
        for i in range(10):
            obs.emit("request.finish", i * 0.2, cat="serving",
                     args={"uid": i, "attained": False, "tenant": "t"})
    alerts = [e for e in seen if e.name == "health.alert"]
    assert alerts and alerts[0].cat == "health"
    assert alerts[0].args["severity"] in ("page", "ticket")
    # its own health.alert events are not folded back in
    assert m.events_seen == 10


def test_monitor_scopes_by_model_label():
    m = HealthMonitor(_spec_small(), model="a")
    m.on_event(_finish(0, 1.0, False, model="a"))
    m.on_event(_finish(1, 1.1, False, model="b"))  # other member
    m.on_event(_finish(2, 1.2, False, model=""))   # unscoped: accepted
    assert m.events_seen == 2


def test_monitor_writes_incident_files(tmp_path):
    m = HealthMonitor(_spec_small(max_incidents=1),
                      incident_dir=str(tmp_path))
    for i in range(12):
        m.on_event(_finish(i, i * 0.3, False))
    assert m.incidents and m.incidents[0]["path"] is not None
    text = (tmp_path / m.incidents[0]["name"]).read_text()
    assert text == m.bundles[0]
    assert json.loads(text)["schema"] == BUNDLE_SCHEMA


def test_monitor_consume_replan_trigger_drains():
    m = HealthMonitor(_spec_small())
    assert m.consume_replan_trigger() == 0
    for i in range(12):
        m.on_event(_finish(i, i * 0.3, False))
    n = m.consume_replan_trigger()
    assert n == m.count("page") + m.count("anomaly") > 0
    assert m.consume_replan_trigger() == 0


def test_monitor_tpot_channel_only_when_budgeted():
    assert HealthMonitor(_spec_small()).tpot is None
    m = HealthMonitor(_spec_small(tpot_budget_ms=10.0, min_events=2))
    for i in range(10):
        m.on_event(_ev(i, i * 0.3, name="request.finish",
                       args={"uid": i, "attained": True, "tenant": "c",
                             "tpot_s": 0.5}))  # 500ms >> 10ms budget
    assert any(a.signal == "tpot" for a in m.alerts)


# ------------------------------------------------------------- deployment --
@pytest.fixture(scope="module")
def served_dep():
    from repro.deploy import build
    spec = DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=64,
                        max_experts=8),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False),
        serving=ServingSpec(slots=2, max_len=32, online_train=False),
        health=HealthSpec(min_events=1, cooldown_s=0.0))
    return build(spec)


def test_serve_with_health_reports_and_detaches(served_dep):
    dep = served_dep
    dep.serve(n_requests=3, rate=4.0, max_new=4)
    rep = dep.report()
    assert "health" in rep
    assert rep["health"]["events"] > 0
    # the monitor lives only inside serve(): nothing stays on the bus
    assert not obs.BUS.enabled()


def test_serve_health_false_disables_layer():
    from repro.deploy import build
    spec = DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=64,
                        max_experts=8),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False),
        serving=ServingSpec(slots=2, max_len=32, online_train=False))
    dep = build(spec)
    dep.serve(n_requests=2, rate=4.0, max_new=4, health=False)
    assert dep._health is None
    assert "health" not in dep.report()


def test_replanner_accepts_health_trigger():
    from repro.replan import Replanner
    m = HealthMonitor(_spec_small())
    # trigger="health" without a monitor is a hard error
    with pytest.raises(AssertionError):
        Replanner(object(), None, np.ones((1, 1)), lambda f: None,
                  trigger="health", health=None)
    rp = Replanner(object(), None, np.ones((1, 1)), lambda f: None,
                   trigger="health", health=m)
    assert rp.trigger == "health" and rp.report()["trigger"] == "health"
