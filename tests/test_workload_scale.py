"""Serving-path scale + integration tests for repro.workload (slow).

Controller integration of the scenario harness and the scale bugfixes:
heap intake pops in exactly the old sorted admission order, duplicate
uids are rejected at submit, busy+idle conserves the simulated clock
across idle jumps, ``Deployment.serve(scenario=)`` wires the generator
end-to-end (with a per-deployment uid sequence across repeated calls),
and the 10k-request fleet-scale run (2 models x 2 devices) completes
with the stall-conservation row True and sub-quadratic intake.
"""
import dataclasses
import random

import numpy as np
import pytest

from repro.deploy.spec import SpecError


@pytest.fixture(scope="module")
def setup():
    import jax
    import jax.numpy as jnp
    from benchmarks.bench_e2e_decode import _thresholds
    from repro.common.config import reduced
    from repro.configs import get_config
    from repro.models import transformer as tf
    cfg = reduced(get_config("mixtral_8x7b"), layers=2, d_model=64)
    params = tf.init_model(jax.random.PRNGKey(1), cfg, jnp.float32)
    return cfg, params, _thresholds(cfg, params)


def _make(setup, **kw):
    from repro.core.pipeline import paper_scaled_models
    from repro.serving import ServingController
    cfg, params, thr = setup
    device, link = paper_scaled_models(cfg)
    opts = dict(slots=2, max_len=128, policy="slo", online_train=False,
                offload_opts=dict(device=device, link=link, cache_slots=2))
    opts.update(kw)
    return ServingController(params, cfg, thresholds=thr, **opts)


def _scenario_requests(setup, n=24, seed=5, **tenant_kw):
    from repro.workload import (ArrivalSpec, ScenarioSpec, TenantSpec,
                                generate_requests)
    cfg = setup[0]
    tkw = dict(name="chat", slo_ms=5000.0, max_new_min=2, max_new_max=3)
    tkw.update(tenant_kw)
    spec = ScenarioSpec(
        name="itest", seed=seed, n_requests=n,
        arrival=ArrivalSpec(kind="poisson", rate=2.0),
        tenants=(TenantSpec(**tkw),))
    return spec, generate_requests(spec, cfg.vocab_size)


def test_heap_intake_preserves_sorted_admission_order(setup):
    """Pin: heapq intake pops (arrival_t, uid) exactly like the old
    sort-on-submit + pop(0) path, regardless of submit order."""
    _, reqs = _scenario_requests(setup, n=32)
    ctl = _make(setup)
    shuffled = reqs[:]
    random.Random(7).shuffle(shuffled)
    for r in shuffled:
        ctl.submit(r)
    order = []
    while ctl.pending:
        ctl._ingest(ctl.pending[0][0] + 1e-9)
        while ctl.queue:
            order.append(ctl.queue.pop(0).uid)
    expect = [r.uid for r in
              sorted(reqs, key=lambda r: (r.arrival_t, r.uid))]
    assert order == expect


def test_duplicate_uid_rejected_at_submit(setup):
    from repro.serving import SLORequest
    ctl = _make(setup)
    cfg = setup[0]
    r = SLORequest(3, np.zeros(4, np.int32), max_new_tokens=2,
                   slo_ms=1e6, arrival_t=0.0)
    ctl.submit(r)
    with pytest.raises(ValueError, match="duplicate request uid 3"):
        ctl.submit(SLORequest(3, np.zeros(4, np.int32), max_new_tokens=2,
                              slo_ms=1e6, arrival_t=1.0))
    assert cfg is setup[0]


def test_busy_idle_conserves_clock_across_idle_jumps(setup):
    """Pin for the idle-jump fix: the old path advanced dt + 1e-12 but
    credited only dt to idle_s, drifting busy+idle off the clock by one
    epsilon per idle gap.  Sparse arrivals force many idle jumps."""
    _, reqs = _scenario_requests(setup, n=16, seed=11)
    for i, r in enumerate(reqs):  # stretch gaps: guaranteed idle jumps
        r.arrival_t = i * 7.0
    ctl = _make(setup)
    for r in reqs:
        ctl.submit(r)
    ctl.run()
    clock = ctl.sched.clock
    budget = ctl.stats["busy_s"] + ctl.stats["idle_s"]
    assert clock > 100.0  # the gaps actually dominated the run
    assert abs(clock - budget) < 1e-9 * max(1.0, clock)


def test_deployment_serve_scenario_end_to_end(tmp_path):
    import os
    from repro.deploy import (DeploymentSpec, ModelSpec, RuntimeSpec,
                              ServingSpec, build)
    from repro.workload import ScenarioSpec
    dep = build(DeploymentSpec(
        name="scen",
        model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=64,
                        max_experts=8),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False),
        serving=ServingSpec(slots=2, max_len=128, online_train=False)))
    scen = dataclasses.replace(
        ScenarioSpec.load(os.path.join(
            os.path.dirname(__file__), os.pardir, "examples", "scenarios",
            "flash_crowd.json")),
        n_requests=6)
    dep.serve(scenario=scen)
    books = dep.controller.completed + dep.controller.rejected
    assert len(books) == 6
    assert {r.tenant for r in books} <= {"chat", "code"}
    tr = dep.controller.tenant_report()
    assert set(tr) <= {"chat", "code"}

    # spec path (not just the object) works too, and repeated serve()
    # calls draw fresh uids from the deployment's sequence — no
    # duplicate-uid rejection on the second batch
    p = tmp_path / "scen.json"
    p.write_text(dataclasses.replace(scen, seed=scen.seed + 1).to_json())
    dep.serve(scenario=str(p))
    dep.serve(n_requests=2)  # synthesized path shares the sequence
    books = dep.controller.completed + dep.controller.rejected
    uids = [r.uid for r in books]
    assert len(set(uids)) == len(uids) == 14

    with pytest.raises(SpecError, match="not both"):
        dep.serve(requests=[], scenario=scen)


@pytest.mark.slow
def test_fleetscale_10k_conservation_and_subquadratic_intake():
    """The fleet-scale acceptance: 4 models x 4 devices x 10k scenario
    requests complete (one member replanning live against the fleet
    ledger), with the stall-conservation row True and sub-quadratic
    intake demonstrated (runs the nightly bench suite in-process and
    asserts on its acceptance rows)."""
    from benchmarks import bench_fleetscale
    from repro import obs
    rows: list = []
    collector = obs.MetricsCollector()
    with obs.consumer(collector):
        bench_fleetscale.run(rows)
    byname = {r[0]: r for r in rows}
    for model in bench_fleetscale.MODELS:
        derived = byname[f"fleetscale/model={model}"][2]
        assert f"n={bench_fleetscale.N_PER_MODEL}" in derived, derived
    sub = byname["fleetscale/submit_subquadratic"][2]
    assert sub.startswith("True"), sub
    rp = byname["fleetscale/replan/model=d"][2]
    assert rp.startswith("True"), rp
    reg = collector.registry.snapshot()
    assert reg.get("events_total", 0) > 0
    assert int(reg.get("stall.conservation_violations", 0)) == 0
