"""repro.cluster unit + property tests: deterministic balanced placement
(``plan_cluster`` / ``partition_layer``), per-device link selection, and
the ClusterScheduler dispatch invariants (sticky routing, lockstep
clocks, n=1 trace parity with the plain single-device scheduler).

Property tests run under real ``hypothesis`` when installed, else the
deterministic grid fallback (``tests/_hypothesis_compat.py``)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (ClusterEngine, ClusterPlan, ClusterScheduler,
                           LinkSelector, partition_layer, plan_cluster,
                           uniform_cluster_plan)
from repro.common.config import reduced
from repro.configs import get_config
from repro.core.offload import LinkModel, build_expert_store
from repro.runtime import ExpertScheduler, ResidencyManager, TransferEngine
from repro.store import floor_bytes, plan_store

from tests._hypothesis_compat import given, settings, st


# ---------------------------------------------------------------- helpers --
def _cfg(max_experts=8):
    return reduced(get_config("mixtral_8x7b"), layers=4, d_model=128,
                   max_experts=max_experts)


def _freqs(cfg, seed):
    rng = np.random.default_rng(seed)
    f = rng.random((cfg.num_layers, cfg.num_experts)) ** 2
    return f / f.sum(axis=1, keepdims=True)


def _store(e=4, d=16, f=32, seed=0):
    rng = np.random.default_rng(seed)
    moe = {
        "we_gate": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_up": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_down": jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32) * 0.1,
    }
    thr = np.full((e,), 0.5, np.float32)
    return build_expert_store(moe, thr, bits=2, group=16)


def _flat_plan(n, E, replicate=0):
    """One-MoE-layer placement-only plan: expert e homes on device e%n,
    the first ``replicate`` experts home everywhere."""
    device_of = {(0, e): (tuple(range(n)) if e < replicate else (e % n,))
                 for e in range(E)}
    return ClusterPlan(n_devices=n, device_of=device_of,
                       pinned_per_device=[[] for _ in range(n)],
                       slots_per_layer=0, slab_bytes=0, num_slabs=[0] * n,
                       replicate=replicate)


def _cluster(store, n, *, slots=3, num_buffers=2, replicate=0):
    plan = _flat_plan(n, store.num_experts, replicate)
    engines = ClusterEngine(LinkModel(), n_devices=n,
                            num_buffers=num_buffers, chunk_channels=8)
    residency = [[ResidencyManager(slots)] for _ in range(n)]
    sched = ClusterScheduler(plan, [store], residency, engines, lookahead=2)
    return sched, residency, engines


# -------------------------------------------------------------- placement --
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       n_devices=st.integers(min_value=1, max_value=5))
def test_partition_frequency_balanced(seed, n_devices):
    """Greedy LPT bound: device frequency loads differ by at most one
    expert's frequency, and every expert has exactly one home."""
    rng = np.random.default_rng(seed)
    freq = rng.random(8) ** 2
    homes = partition_layer(freq, n_devices)
    assert all(len(h) == 1 for h in homes)
    load = np.zeros(n_devices)
    for e, (d,) in enumerate(homes):
        load[d] += freq[e]
    assert load.max() - load.min() <= freq.max() + 1e-12


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       n_devices=st.sampled_from([1, 2, 3, 4]),
       replicate=st.sampled_from([0, 1, 2]))
def test_plan_cluster_deterministic_and_well_formed(seed, n_devices,
                                                    replicate):
    """Same inputs -> identical plan; pins live on their home devices,
    per-device footprints respect the budget, replicated experts home
    everywhere."""
    cfg = _cfg()
    freqs = _freqs(cfg, seed)
    vram_gb = 1.3 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    kw = dict(n_devices=n_devices, vram_gb_per_device=vram_gb,
              host_gb=0.01, ladder=("int2",), replicate=replicate)
    a = plan_cluster(cfg, freqs, **kw)
    b = plan_cluster(cfg, freqs, **kw)
    assert a.device_of == b.device_of
    assert a.pinned_per_device == b.pinned_per_device
    assert a.store_plan.formats == b.store_plan.formats
    assert a.slots_per_layer == b.slots_per_layer
    assert a.num_slabs == b.num_slabs

    moe = [li for li in range(cfg.num_layers)]
    for li in moe:
        for e in range(cfg.num_experts):
            homes = a.devices_of(li, e)
            assert len(homes) >= 1
            assert len(set(homes)) == len(homes)
        hot = sorted(range(cfg.num_experts),
                     key=lambda e: (-freqs[li, e], e))[:replicate]
        for e in hot:
            assert a.devices_of(li, e) == tuple(range(n_devices))
    for d in range(n_devices):
        for k in a.pinned_per_device[d]:
            assert d in a.device_of[k]
        assert a.footprint_bytes(d) <= a.vram_budget_per_device


def test_plan_cluster_n1_matches_plan_store():
    """With one device the cluster planner must reproduce plan_store's
    greedy spend exactly (formats, pins, slots, arena)."""
    cfg = _cfg()
    for seed in (0, 3, 9):
        freqs = _freqs(cfg, seed)
        for mult in (1.05, 1.4):
            vram_gb = mult * floor_bytes(cfg, ("int2",)) / 2 ** 30
            cp = plan_cluster(cfg, freqs, n_devices=1,
                              vram_gb_per_device=vram_gb, host_gb=0.01,
                              ladder=("int2",))
            sp = plan_store(cfg, freqs, vram_gb=vram_gb, host_gb=0.01,
                            ladder=("int2",))
            assert cp.store_plan.formats == sp.formats
            assert cp.pinned_per_device[0] == sp.pinned
            assert cp.slots_per_layer == sp.slots_per_layer
            assert cp.num_slabs[0] == sp.num_slabs


def test_pinned_set_balanced_across_devices():
    """Equal budgets + balanced partition keep per-device pinned counts
    within 2 of each other (fixed representative seeds)."""
    cfg = _cfg()
    for seed in (0, 1, 2, 7):
        freqs = _freqs(cfg, seed)
        vram_gb = 1.25 * floor_bytes(cfg, ("int2",)) / 2 ** 30
        for n in (2, 4):
            plan = plan_cluster(cfg, freqs, n_devices=n,
                                vram_gb_per_device=vram_gb, host_gb=0.01,
                                ladder=("int2",))
            counts = [len(p) for p in plan.pinned_per_device]
            assert max(counts) - min(counts) <= 2, (seed, n, counts)


def test_plan_cluster_infeasible_budget_raises():
    from repro.store import PlanError
    cfg = _cfg()
    with pytest.raises(PlanError):
        plan_cluster(cfg, _freqs(cfg, 0), n_devices=2,
                     vram_gb_per_device=1e-6, host_gb=0.01)


def test_uniform_plan_round_robin_without_freqs():
    cfg = _cfg(max_experts=4)
    plan = uniform_cluster_plan(cfg, 2)
    for (li, e), homes in plan.device_of.items():
        assert homes == (e % 2,)  # uniform freqs degrade to round-robin


# ------------------------------------------------------------------ links --
def test_link_selector_prefers_least_loaded_link():
    engines = ClusterEngine(LinkModel(), n_devices=3, chunk_channels=8)
    engines[0]._link_free = 5.0
    engines[1]._link_free = 1.0
    engines[2]._link_free = 3.0
    sel = LinkSelector(engines)
    assert sel.pick((0, 1, 2), now=0.0) == 1
    assert sel.pick((0, 2), now=0.0) == 2
    # ties break to the lowest device id; `now` floors idle links
    engines[1]._link_free = 0.0
    engines[2]._link_free = 0.0
    assert sel.pick((2, 1), now=2.0) == 1
    assert sel.replica_choices == 3


def test_cluster_engine_shared_record_log():
    store = _store()
    engines = ClusterEngine(LinkModel(), n_devices=2, chunk_channels=8)
    engines[0].issue(store, (0, 0), 0, np.arange(8), 0.0)
    engines[1].issue(store, (0, 1), 1, np.arange(8), 0.0)
    assert [r.device for r in engines.records] == [0, 1]
    assert engines.busy_seconds() == pytest.approx(
        engines.device_busy_seconds(0) + engines.device_busy_seconds(1))
    # independent links: both transfers start at t=0, genuinely parallel
    assert all(r.start_t == 0.0 for r in engines.records)


# --------------------------------------------------------------- dispatch --
def _trace(records):
    return [(r.key, r.kind, round(r.enqueue_t, 12), round(r.start_t, 12),
             round(r.complete_t, 12), r.nbytes, r.chunks) for r in records]


def _drive(sched, store, seed, n_ops=40):
    """The same random op trace the runtime property suite uses."""
    rng = np.random.default_rng(seed)
    f = store.d_ff
    for _ in range(n_ops):
        op = rng.integers(0, 5)
        e = int(rng.integers(0, store.num_experts))
        idx = np.sort(rng.choice(f, size=int(rng.integers(1, f // 2)),
                                 replace=False))
        if op == 0:
            sched.enqueue_prefetch(0, e, idx, float(rng.random()),
                                   depth=int(rng.integers(1, 3)))
        elif op == 1:
            sched.pump()
        elif op == 2:
            sched.advance(float(rng.random()) * 1e-3)
        elif op == 3:
            payload, miss = sched.demand_async(0, e, lambda i=idx: i)
            sched.wait_for(0, e, was_miss=miss)
        else:
            truth = rng.choice(store.num_experts,
                               size=int(rng.integers(1, 3)), replace=False)
            sched.reconcile(0, truth.tolist())


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_single_device_cluster_trace_identical(seed):
    """n_devices=1 dispatch is a transparent shim: the same op trace
    produces the identical transfer timeline and stats as the plain
    ExpertScheduler."""
    store = _store(seed=1)
    plain_res = [ResidencyManager(3)]
    plain_eng = TransferEngine(LinkModel(), num_buffers=2, chunk_channels=8)
    plain = ExpertScheduler([store], plain_res, plain_eng, lookahead=2)
    clustered, _, engines = _cluster(_store(seed=1), 1)
    _drive(plain, store, seed)
    _drive(clustered, store, seed)
    assert _trace(plain_eng.records) == _trace(engines.records)
    assert dataclasses.asdict(plain.stats) == \
        dataclasses.asdict(clustered.stats)
    assert plain.clock == clustered.clock


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       n_devices=st.sampled_from([2, 3, 4]))
def test_cluster_clocks_stay_lockstep(seed, n_devices):
    store = _store(seed=2)
    sched, _, _ = _cluster(store, n_devices)
    rng = np.random.default_rng(seed)
    for _ in range(25):
        _drive(sched, store, int(rng.integers(0, 10 ** 9)), n_ops=2)
        clocks = [s.clock for s in sched.devs]
        assert max(clocks) - min(clocks) <= 1e-9, clocks


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_transfers_land_on_home_devices(seed):
    """Un-replicated experts only ever transfer over their home device's
    link, and only that device's residency holds them."""
    store = _store(seed=3)
    n = 2
    sched, residency, engines = _cluster(store, n)
    _drive(sched, store, seed, n_ops=50)
    for r in engines.records:
        key = r.key[0] if (isinstance(r.key, tuple) and
                           isinstance(r.key[0], tuple)) else r.key
        _, e = key
        assert r.device == e % n, (r.key, r.device)
    for d in range(n):
        for (li, e) in residency[d][0].keys():
            assert e % n == d


def test_replicated_expert_routes_to_least_loaded_link():
    """A replicated expert's cold fetch goes over the idler link; once
    staged, later demands stick to the device that holds it."""
    store = _store()
    sched, residency, engines = _cluster(store, 2, replicate=1)
    # saturate device 0's link (expert 2 homes on device 0)
    p, m = sched.demand_async(0, 2, lambda: np.arange(16))
    assert engines.records[-1].device == 0
    # expert 0 is replicated: with device 0 busy it must fetch on dev 1
    p, m = sched.demand_async(0, 0, lambda: np.arange(8))
    assert engines.records[-1].device == 1
    assert (0, 0) in residency[1][0]
    sched.wait_for(0, 0, was_miss=m)
    # sticky: a repeat demand is a hit on device 1, no new transfer
    n_rec = len(engines.records)
    p, m2 = sched.demand_async(0, 0, lambda: np.arange(8))
    assert not m2 and len(engines.records) == n_rec


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       n_devices=st.sampled_from([2, 3]))
def test_cluster_demand_accounting_conserved(seed, n_devices):
    """Merged stats: every waited demand lands in exactly one bucket,
    summed across devices."""
    store = _store(seed=6)
    sched, _, _ = _cluster(store, n_devices, slots=store.num_experts)
    rng = np.random.default_rng(seed)
    f = store.d_ff
    n_waits = 0
    for _ in range(25):
        e = int(rng.integers(0, store.num_experts))
        if rng.random() < 0.5:
            sched.enqueue_prefetch(0, e, np.arange(f // 4),
                                   float(rng.random()))
            sched.pump()
        else:
            idx = np.arange(int(rng.integers(1, f)))
            payload, miss = sched.demand_async(0, e, lambda i=idx: i)
            sched.wait_for(0, e, was_miss=miss)
            n_waits += 1
        sched.advance(float(rng.random()) * 1e-3)
    s = sched.stats
    assert (s.demand_hits + s.residual_waits + s.demand_reuse +
            s.demand_fetches) == n_waits
    assert 0.0 <= sched.prefetch_recall() <= 1.0
    assert 0.0 <= sched.prefetch_precision() <= 1.0


def test_cluster_reconcile_cancels_on_every_device():
    store = _store()
    sched, _, engines = _cluster(store, 2, num_buffers=1)
    # one queued (never issued) prefetch per device
    for e in range(4):
        sched.enqueue_prefetch(0, e, np.arange(4), 0.5 + 0.1 * e)
    queued = sum(len(s._queued) for s in sched.devs)
    assert queued >= 2  # both devices have backlog
    cancelled = sched.reconcile(0, [])
    assert cancelled == queued
    assert all(not s._queued for s in sched.devs)


def test_cluster_demand_union_covers_need_across_devices():
    store = _store()
    sched, _, _ = _cluster(store, 2, slots=store.num_experts)
    for e in range(store.num_experts):
        need = np.sort(np.unique(np.arange(e, store.d_ff, 3)))
        (idx, gate, down), miss = sched.demand_union(0, e, need)
        sched.wait_for(0, e, was_miss=miss)
        assert np.all(np.isin(need, idx))
        assert gate.shape[0] == idx.shape[0] == down.shape[0]
    # grow one union: the top-up happens on the expert's own device
    (idx, _, _), m = sched.demand_union(0, 1, np.arange(store.d_ff))
    sched.wait_for(0, 1, was_miss=m)
    assert np.all(np.isin(np.arange(store.d_ff), idx))
    assert sched.stats.demand_topups >= 1
