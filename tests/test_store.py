"""Tiered expert store: formats, tiers (disk/host/device pool), planner."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.common.config import reduced
from repro.configs import get_config
from repro.core import hqq
from repro.runtime.residency import ResidencyManager, payload_nbytes
from repro.store import (DevicePool, DiskModel, DiskTier, HostTier,
                         PlanError, dense_residency_bytes, floor_bytes,
                         get_format, plan_store, tier_key)
from repro.store import formats as F


# --------------------------------------------------------------- formats ---
def test_format_registry_lookup():
    assert get_format("int2").up_bits == 2
    assert get_format("fp16").keep_ratio == 1.0
    with pytest.raises(KeyError):
        get_format("int37")


def test_format_bytes_ladder_monotone():
    d, f = 256, 512
    hosts = [F.host_bytes(get_format(n), d, f) for n in F.LADDER]
    vrams = [F.expert_vram_bytes(get_format(n), d, f) for n in F.LADDER]
    assert hosts == sorted(hosts), hosts  # lean -> rich grows
    assert vrams == sorted(vrams), vrams


def test_draft_half_of_full_slice():
    d, n = 256, 100
    full = F.slice_bytes(d, n, "full")
    draft = F.slice_bytes(d, n, "draft")
    assert full == n * 2 * d * 2
    assert 0.45 * full < draft < 0.55 * full


def test_qtensor_fp16_metadata_byte_accounting():
    """Satellite pin: scale/zero stored fp16; nbytes is exactly
    packed + 2 * group-count * cols * 2 bytes (dequant still f32)."""
    import jax
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 64)) * 0.05
    qt = hqq.quantize(w, bits=2, group=64)
    assert qt.scale.dtype == np.float16 and qt.zero.dtype == np.float16
    g = 128 // 64
    expected = (g * (64 // 4) * 64  # packed uint8, 4 codes/byte
                + 2 * g * 1 * 64 * 2)  # scale + zero at 2 bytes
    assert qt.nbytes == expected, (qt.nbytes, expected)
    wr = hqq.dequantize(qt, np.float32)
    assert float(np.abs(np.asarray(wr) - np.asarray(w)).max()) < 0.1


# ------------------------------------------------------------ device pool --
def test_pool_alloc_free_roundtrip():
    pool = DevicePool(slab_bytes=1024, num_slabs=4)
    a = pool.try_alloc(1000)
    b = pool.try_alloc(2048)  # span of 2
    assert len(a.slabs) == 1 and len(b.slabs) == 2
    assert pool.free_slabs == 1
    pool.free(a)
    pool.free(b)
    assert pool.free_slabs == 4
    pool.check_invariants()


def test_pool_exhaustion_returns_none():
    pool = DevicePool(slab_bytes=1024, num_slabs=2)
    a = pool.try_alloc(2048)
    assert pool.try_alloc(1) is None
    assert pool.stats.failures == 1
    pool.free(a)
    assert pool.try_alloc(1) is not None


def test_pool_overflow_discarded_on_free():
    pool = DevicePool(slab_bytes=64, num_slabs=1)
    a = pool.try_alloc(10)
    o = pool.alloc_overflow(10)
    assert o.slabs[0] >= pool.num_slabs
    pool.free(o)
    assert pool.free_slabs == 0  # overflow slab did NOT join the arena
    pool.free(a)
    assert pool.free_slabs == 1
    pool.check_invariants()


@given(st.lists(st.tuples(st.booleans(), st.integers(1, 3000)),
                min_size=1000, max_size=1400),
       st.integers(2, 8))
@settings(max_examples=5, deadline=None)
def test_pool_zero_fragmentation_growth_1000_cycles(ops, num_slabs):
    """Acceptance pin: >= 1000 alloc/free cycles; the arena never grows,
    free+used always partitions it, and no slab is double-owned."""
    pool = DevicePool(slab_bytes=1024, num_slabs=num_slabs)
    live = []
    arena0 = pool.arena_bytes
    for is_alloc, nbytes in ops:
        if is_alloc or not live:
            span = pool.try_alloc(nbytes)
            if span is None:  # arena full: caller evicts -> free oldest
                if live:
                    pool.free(live.pop(0))
                span = pool.try_alloc(nbytes)
            if span is not None:
                live.append(span)
        else:
            pool.free(live.pop(0))
        assert pool.arena_bytes == arena0  # zero growth, every step
        pool.check_invariants()
        owned = [s for sp in live for s in sp.slabs]
        assert len(owned) == len(set(owned))
    assert pool.stats.allocs >= 1
    assert pool.fragmentation_bytes(live) <= len(live) * 1024


# ------------------------------------------------------------- host tier ---
def _mini_disk(tmp_path, n=6, nbytes=100):
    recs = {f"L0.E{i}": {"x": np.full(nbytes // 8, i, np.float64)}
            for i in range(n)}
    return DiskTier.build(tmp_path / "shards", recs), recs


def test_host_tier_lru_eviction_under_byte_budget(tmp_path):
    disk, _ = _mini_disk(tmp_path)
    host = HostTier(capacity_bytes=250, disk=disk)
    for i in range(4):
        host.admit(f"L0.E{i}", {"x": i}, 100)
    assert len(host) == 2 and host.bytes_in_use == 200
    assert "L0.E3" in host and "L0.E2" in host  # LRU kept the newest
    assert host.stats.evictions == 2


def test_host_miss_refills_from_disk(tmp_path):
    disk, recs = _mini_disk(tmp_path)
    host = HostTier(capacity_bytes=10 ** 6, disk=disk)
    rec, disk_s = host.fetch("L0.E3")
    np.testing.assert_array_equal(rec["x"], recs["L0.E3"]["x"])
    assert disk_s > 0 and host.stats.misses == 1
    _, disk_s2 = host.fetch("L0.E3")
    assert disk_s2 == 0.0 and host.stats.hits == 1


def test_disk_tier_lazy_single_record(tmp_path):
    disk, recs = _mini_disk(tmp_path)
    rec, t = disk.load("L0.E2")
    np.testing.assert_array_equal(rec["x"], recs["L0.E2"]["x"])
    assert t > 0
    # laziness: exactly one record decoded, far less than the whole file
    assert disk.reader.records_decoded == 1
    total = sum(disk.reader.nbytes(k) for k in disk.reader.keys())
    assert disk.reader.bytes_read < total


def test_disk_tier_index_built_once_per_reader(tmp_path):
    """The shard's offset index is decoded lazily and exactly once: a
    loop of per-expert fetches (the cluster prefill path) reuses it
    instead of re-scanning the header, and telemetry proves it."""
    disk, _ = _mini_disk(tmp_path)
    assert disk.reader.index_builds == 0  # opening never scans the header
    for i in range(6):
        disk.load(f"L0.E{i}")
    for i in range(6):  # repeat fetches reuse the same index
        disk.load(f"L0.E{i}")
        assert f"L0.E{i}" in disk
    assert disk.reader.index_builds == 1
    assert disk.stats.index_builds == 1
    assert disk.stats.reads == 12


def test_disk_model_bandwidth_and_seek():
    m = DiskModel(read_bw=1e9, seek_us=100.0)
    assert m.read_time(1e9) == pytest.approx(1.0 + 1e-4)
    assert m.read_time(0) == 0.0


# --------------------------------------------------------------- planner ---
def _cfg_freqs():
    cfg = reduced(get_config("mixtral_8x7b"), layers=2, d_model=128)
    rng = np.random.default_rng(0)
    freqs = rng.dirichlet(np.ones(cfg.num_experts),
                          size=cfg.num_layers).astype(np.float64)
    return cfg, freqs


def test_planner_respects_budget():
    cfg, freqs = _cfg_freqs()
    dense = dense_residency_bytes(cfg)
    for frac in (0.45, 0.6, 0.8, 1.0):
        plan = plan_store(cfg, freqs, vram_gb=frac * dense / 2 ** 30)
        assert plan.footprint_bytes() <= plan.vram_budget
        assert plan.slots_per_layer >= 1
        assert len(plan.formats) == cfg.num_layers * cfg.num_experts


def test_planner_richer_with_bigger_budget():
    cfg, freqs = _cfg_freqs()
    dense = dense_residency_bytes(cfg)

    def wealth(plan):
        rung = {n: i for i, n in enumerate(F.LADDER)}
        return (sum(rung[n] for n in plan.formats.values()),
                len(plan.pinned), plan.slots_per_layer)

    w_small = wealth(plan_store(cfg, freqs, vram_gb=0.5 * dense / 2 ** 30))
    w_big = wealth(plan_store(cfg, freqs, vram_gb=1.0 * dense / 2 ** 30))
    assert sum(w_big) > sum(w_small)
    assert all(b >= s for b, s in zip(w_big, w_small))


def test_planner_rejects_infeasible_budget():
    cfg, freqs = _cfg_freqs()
    with pytest.raises(PlanError):
        plan_store(cfg, freqs, vram_gb=1e-6)
    # floor itself is feasible
    plan = plan_store(cfg, freqs,
                      vram_gb=floor_bytes(cfg) * 1.001 / 2 ** 30)
    assert plan.slots_per_layer == 1 and not plan.pinned


def test_planner_pins_hottest():
    cfg, freqs = _cfg_freqs()
    dense = dense_residency_bytes(cfg)
    plan = plan_store(cfg, freqs, vram_gb=dense / 2 ** 30)
    assert plan.pinned, "a dense-sized budget must afford pins"
    for (li, e) in plan.pinned:
        assert plan.formats[(li, e)] == F.LADDER[-1]
        # every pinned expert is at least as hot as any unpinned one in
        # its layer
        unpinned = [freqs[li, j] for j in range(cfg.num_experts)
                    if (li, j) not in plan.pinned]
        if unpinned:
            assert freqs[li, e] >= max(unpinned) - 1e-12


def test_planner_ladder_restriction():
    cfg, freqs = _cfg_freqs()
    dense = dense_residency_bytes(cfg)
    plan = plan_store(cfg, freqs, vram_gb=dense / 2 ** 30,
                      ladder=("int2",))
    assert set(plan.formats.values()) == {"int2"}


# --------------------------------------------- residency × pool coupling ---
def _payload(n, d=8):
    idx = np.arange(n)
    return (idx, np.zeros((n, d), np.float16), np.zeros((n, d), np.float16))


def test_residency_put_allocates_and_eviction_frees():
    pool = DevicePool(slab_bytes=payload_nbytes(_payload(4)), num_slabs=2)
    res = ResidencyManager(2, pool=pool)
    res.put("a", _payload(4))
    res.put("b", _payload(4))
    assert pool.free_slabs == 0
    res.put("c", _payload(4))  # evicts LRU "a", reusing its slab
    assert pool.free_slabs == 0 and "a" not in res
    res.drop("b")
    assert pool.free_slabs == 1
    pool.check_invariants()


def test_residency_arena_pressure_evicts_before_capacity():
    """Slab exhaustion, not just slot count, forces eviction."""
    one = payload_nbytes(_payload(4))
    pool = DevicePool(slab_bytes=one, num_slabs=2)
    res = ResidencyManager(10, pool=pool)  # slots ample, arena tight
    res.put("a", _payload(4))
    res.put("b", _payload(4))
    res.put("c", _payload(4))  # arena full -> policy evicts "a"
    assert "a" not in res and "c" in res
    assert len(res) == 2
    pool.check_invariants()


def test_residency_update_payload_resizes_span():
    one = payload_nbytes(_payload(4))
    pool = DevicePool(slab_bytes=one, num_slabs=3)
    res = ResidencyManager(3, pool=pool)
    res.put("a", _payload(4))
    assert pool.free_slabs == 2
    res.update_payload("a", _payload(8))  # twice the bytes -> 2 slabs
    assert pool.free_slabs == 1
    res.update_payload("a", _payload(4))
    assert pool.free_slabs == 2
    pool.check_invariants()


def test_residency_pinned_overflow_keeps_arena_fixed():
    one = payload_nbytes(_payload(4))
    pool = DevicePool(slab_bytes=one, num_slabs=1)
    res = ResidencyManager(3, pool=pool, pinned=["a", "b"])
    res.put("a", _payload(4))
    res.put("b", _payload(4))  # everything pinned: overflow span
    assert pool.stats.overflow_allocs == 1
    res.drop("b")
    assert pool.free_slabs == 0  # overflow slab discarded
    res.drop("a")
    assert pool.free_slabs == 1
    pool.check_invariants()


# ------------------------------------------------------------ tiered store -
def test_tiered_store_serves_kept_subset(tmp_path):
    from repro.core.pipeline import _unstack_layers
    from repro.models import transformer as tf
    import jax
    import jax.numpy as jnp

    cfg, freqs = _cfg_freqs()
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    layers = _unstack_layers(params, cfg)
    thr = np.full((cfg.num_layers, cfg.num_experts), 0.2, np.float32)
    plan = plan_store(cfg, freqs, vram_gb=0.55 *
                      dense_residency_bytes(cfg) / 2 ** 30, max_pinned=0)
    from repro.store import build_layer_stores
    stores, host = build_layer_stores(layers, thr, plan,
                                      tmp_path / "store", freqs=freqs)
    li = 0
    store = stores[li]
    lean = [e for e in range(cfg.num_experts)
            if store.fmts[e].keep_ratio < 1.0]
    assert lean, "budget should leave some experts in a lean format"
    e = lean[0]
    idx = np.arange(cfg.moe_d_ff)
    served, gate, down, info = store.fetch_slice(e, idx)
    np.testing.assert_array_equal(served, store.available_channels(e))
    assert gate.shape == (len(served), cfg.d_model)
    # values match the original weights for the served channels
    np.testing.assert_allclose(
        np.asarray(gate, np.float32),
        np.asarray(layers[li]["moe"]["we_gate"][e], np.float32).T[served],
        atol=2e-3)
    # draft fetch: half the bytes, approximately equal values
    served_d, gate_d, _, info_d = store.fetch_slice(e, idx,
                                                    precision="draft")
    np.testing.assert_array_equal(served_d, served)
    assert info_d.nbytes < 0.6 * info.nbytes
    err = np.abs(np.asarray(gate_d, np.float32) -
                 np.asarray(gate, np.float32)).max()
    assert err < 0.02, err


def test_refine_adopted_for_full_keep_format(tmp_path):
    """Regression: when the served idx is the SAME ndarray as the request
    (keep_ratio 1.0 fast path), the applied refine must still replace the
    draft payload that compute sees."""
    from repro.core.pipeline import _unstack_layers
    from repro.models import transformer as tf
    from repro.runtime import ExpertScheduler, ResidencyManager, \
        TransferEngine
    from repro.store import build_layer_stores
    import jax
    import jax.numpy as jnp

    cfg, freqs = _cfg_freqs()
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    layers = _unstack_layers(params, cfg)
    thr = np.full((cfg.num_layers, cfg.num_experts), 0.2, np.float32)
    plan = plan_store(cfg, freqs, vram_gb=1.0, ladder=("fp16",),
                      max_pinned=0)
    stores, _ = build_layer_stores(layers, thr, plan, tmp_path / "s",
                                   freqs=freqs)
    res = [ResidencyManager(4) if s is not None else None for s in stores]
    sched = ExpertScheduler(stores, res, TransferEngine())
    e = 0
    idx = np.arange(cfg.moe_d_ff)
    payload, miss = sched.demand_async(0, e, lambda: idx)
    assert miss and sched.stats.draft_fetches == 1
    assert payload[0] is idx  # the aliasing precondition of the bug
    sched.advance(10.0)  # refine transfer completes
    sched.wait_for(0, e)
    cur = sched.staged_payload(0, e)
    assert sched.stats.refines_applied == 1
    assert cur is not payload  # the tuple was swapped...
    _, gate_full, _, _ = stores[0].fetch_slice(e, idx)
    np.testing.assert_array_equal(np.asarray(cur[1]),
                                  np.asarray(gate_full))  # ...to full fp16


def test_tiered_store_disk_stage_reported(tmp_path):
    from repro.core.pipeline import _unstack_layers
    from repro.models import transformer as tf
    import jax
    import jax.numpy as jnp

    cfg, freqs = _cfg_freqs()
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    layers = _unstack_layers(params, cfg)
    thr = np.full((cfg.num_layers, cfg.num_experts), 0.2, np.float32)
    plan = plan_store(cfg, freqs, vram_gb=0.6 *
                      dense_residency_bytes(cfg) / 2 ** 30,
                      host_gb=1e-7)  # host tier can hold ~nothing
    plan.host_budget = 2 * F.host_bytes(get_format("fp16"), cfg.d_model,
                                        cfg.moe_d_ff)
    from repro.store import build_layer_stores
    stores, host = build_layer_stores(layers, thr, plan,
                                      tmp_path / "store", freqs=freqs)
    store = stores[0]
    idx = np.arange(0, cfg.moe_d_ff, 3)
    # force a cold key: fetch an expert the warm pass could not admit
    cold = [e for e in range(cfg.num_experts)
            if tier_key(0, e) not in host]
    assert cold, "tiny host budget must leave cold experts"
    _, _, _, info = store.fetch_slice(cold[0], idx)
    assert info.disk_s > 0.0
    _, _, _, info2 = store.fetch_slice(cold[0], idx)
    assert info2.disk_s == 0.0  # now host-resident
