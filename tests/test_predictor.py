"""FloE dual predictors: trainability, recall, and the similarity premise."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predictor
from repro.models import moe as moe_lib


def test_inter_predictor_learns_linear_routing():
    """If routing is a linear function of h, the predictor should recover it
    far above chance."""
    key = jax.random.PRNGKey(0)
    t_, d, e, k = 512, 32, 8, 2
    h = jax.random.normal(key, (t_, d))
    w_true = jax.random.normal(jax.random.PRNGKey(1), (d, e))
    true_ids = jax.lax.top_k(h @ w_true, k)[1]
    targets = jax.nn.one_hot(true_ids, e).sum(1)
    params = predictor.init_inter_predictor(jax.random.PRNGKey(2), d, e, hidden=32)
    params = predictor.train_inter_predictor(params, h, targets, steps=300)
    pred = predictor.inter_predict_topk(params, h, k)
    rec = float(predictor.recall_at_k(pred, true_ids))
    assert rec > 0.8, rec  # chance would be k/e = 0.25


def test_inter_predictor_cross_layer():
    """Predict layer i+1 routing from layer i hidden states when the two are
    highly similar (the paper's actual setting)."""
    key = jax.random.PRNGKey(3)
    t_, d, e, k = 512, 32, 8, 2
    h_i = jax.random.normal(key, (t_, d))
    h_next = h_i + 0.2 * jax.random.normal(jax.random.PRNGKey(4), (t_, d))
    w_router = jax.random.normal(jax.random.PRNGKey(5), (d, e))
    true_ids = jax.lax.top_k(h_next @ w_router, k)[1]
    targets = jax.nn.one_hot(true_ids, e).sum(1)
    params = predictor.init_inter_predictor(jax.random.PRNGKey(6), d, e, hidden=64)
    params = predictor.train_inter_predictor(params, h_i, targets, steps=300)
    rec = float(predictor.recall_at_k(
        predictor.inter_predict_topk(params, h_i, k), true_ids))
    assert rec > 0.6, rec


def test_intra_predictor_recall_under_similarity():
    """Reuse-based mask prediction: cosine-similar hidden states give high
    channel recall (paper reports ~0.95 at >0.95 similarity)."""
    key = jax.random.PRNGKey(7)
    t_, d, f = 64, 64, 512
    h_next = jax.random.normal(key, (t_, d))
    h_prev = h_next + 0.1 * jax.random.normal(jax.random.PRNGKey(8), (t_, d))
    sim = float(predictor.cosine_similarity(h_prev, h_next))
    assert sim > 0.95
    w_up = jax.random.normal(jax.random.PRNGKey(9), (d, f)) * 0.1
    v_true = h_next @ w_up
    t = jnp.quantile(jnp.abs(v_true), 0.8)
    true_mask = jnp.abs(v_true) >= t
    pred_mask = predictor.intra_predict_mask(h_prev, w_up, t)
    prec, rec = predictor.mask_precision_recall(pred_mask, true_mask)
    assert float(rec) > 0.75, float(rec)
    assert float(prec) > 0.75, float(prec)


def test_intra_predictor_exact_when_identical():
    h = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 64))
    t = jnp.quantile(jnp.abs(h @ w), 0.7)
    pred = predictor.intra_predict_mask(h, w, t)
    true = jnp.abs(h @ w) >= t
    prec, rec = predictor.mask_precision_recall(pred, true)
    assert float(prec) == 1.0 and float(rec) == 1.0


def test_cosine_similarity_bounds():
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    assert abs(float(predictor.cosine_similarity(a, a)) - 1.0) < 1e-6
    assert float(predictor.cosine_similarity(a, -a)) < -0.99


# ------------------------------------------------- confidence calibrator ----
def test_calibrator_empty_bucket_is_identity():
    """Before any reconciliation sample lands, calibration must be a
    no-op: precision reads 1.0, scale reads 1.0, and a confidence passes
    through unchanged (clamped to [0, 1])."""
    cal = predictor.ConfidenceCalibrator()
    assert cal.samples == 0
    assert cal.precision == 1.0
    assert cal.scale == 1.0
    for c in (0.0, 0.3, 1.0):
        assert cal(c) == c
    assert cal(1.7) == 1.0  # clamp, not amplify


def test_calibrator_all_wrong_demotes_to_floor():
    """A predictor that is confidently wrong every time must be demoted,
    but only down to the floor — the floor keeps speculative traffic
    sortable instead of collapsing every priority to exactly zero."""
    cal = predictor.ConfidenceCalibrator(beta=0.9, floor=0.05)
    for _ in range(500):
        cal.update(0.9, False)
    assert cal.precision < 1e-3
    assert cal.scale == cal.floor
    assert cal(0.8) == 0.8 * cal.floor
    # and the demotion never crosses below the floor with more evidence
    for _ in range(500):
        cal.update(0.99, False)
    assert cal.scale == cal.floor


def test_calibrator_deterministic_priorities():
    """Identical update streams must calibrate identically — prefetch
    priorities derived through the calibrator are part of the
    reproducible timeline, so two replicas fed the same reconciliation
    history must sort speculative traffic in exactly the same order."""
    import numpy as np

    rng = np.random.default_rng(0)
    stream = [(float(c), bool(h)) for c, h in
              zip(rng.random(256), rng.random(256) < 0.5)]
    a = predictor.ConfidenceCalibrator(beta=0.95)
    b = predictor.ConfidenceCalibrator(beta=0.95)
    for c, h in stream:
        a.update(c, h)
        b.update(c, h)
    assert a.scale == b.scale and a.precision == b.precision
    probes = rng.random(32)
    assert [a(p) for p in probes] == [b(p) for p in probes]
    # an overconfident stream demotes strictly (scale < 1), monotonically
    # preserving the order of calibrated priorities
    assert a.scale < 1.0
    lo, hi = a(0.2), a(0.9)
    assert lo < hi
