"""Training substrate: loss decreases, optimizer, checkpoint round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.common.config import TrainConfig, reduced
from repro.configs import get_config
from repro.data import SyntheticLM, make_batches
from repro.launch.train import train_loop
from repro.optim import adamw_init, adamw_update, cosine_schedule


def test_loss_decreases_small_model(tmp_path):
    cfg = reduced(get_config("smollm_135m"), layers=2, d_model=128)
    tc = TrainConfig(learning_rate=2e-3, total_steps=60, warmup_steps=5)
    _, _, hist = train_loop(cfg, tc, batch=8, seq=64, steps=60, log_every=59)
    assert hist[-1][1] < hist[0][1] - 0.05, hist


def test_moe_training_decreases_loss():
    cfg = reduced(get_config("mixtral_8x7b"), layers=2, d_model=128)
    tc = TrainConfig(learning_rate=2e-3, total_steps=60, warmup_steps=5)
    _, _, hist = train_loop(cfg, tc, batch=8, seq=64, steps=60, log_every=59)
    assert hist[-1][1] < hist[0][1] - 0.05, hist


def test_grad_accumulation_matches_full_batch():
    cfg = reduced(get_config("smollm_135m"), layers=2, d_model=64)
    from repro.launch.train import build_train_step
    from repro.models import transformer as tf
    tc = TrainConfig(total_steps=10)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = adamw_init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                          cfg.vocab_size)}
    full, _, _ = build_train_step(cfg, tc, None, donate=False)
    micro, _, _ = build_train_step(cfg, tc, None, microbatch=4, donate=False)
    p1, _, m1 = full(params, opt, batch)
    p2, _, m2 = micro(params, opt, batch)
    # losses match exactly; grads may differ slightly in reduction order
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_cosine_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(jnp.asarray(s), tc)) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]  # warmup
    assert lrs[2] > lrs[3] > lrs[4]  # decay
    assert lrs[4] >= 0.1 * 1e-3 * 0.99  # floor


def test_checkpoint_roundtrip(tmp_path):
    from repro.models import transformer as tf
    cfg = reduced(get_config("smollm_135m"), layers=2, d_model=64)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    path = tmp_path / "ck.msgpack.zst"
    n = save_checkpoint(path, params)
    assert n > 0
    back = load_checkpoint(path)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert a.shape == b.shape
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_synthetic_data_deterministic():
    s1 = SyntheticLM(128, seed=3).stream(100, seed=5)
    s2 = SyntheticLM(128, seed=3).stream(100, seed=5)
    np.testing.assert_array_equal(s1, s2)
    b = next(make_batches(SyntheticLM(128, seed=3), 4, 16, 1))
    assert b["tokens"].shape == (4, 17)
