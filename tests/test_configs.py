"""Assigned-architecture configs match the assignment sheet exactly."""
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config

SPEC = {
    # id: (layers, d_model, heads, kv, d_ff, vocab)
    "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
    "mamba2_780m": (48, 1536, None, None, 0, 50280),
    "starcoder2_7b": (32, 4608, 36, 4, 18432, 49152),
    "glm4_9b": (40, 4096, 32, 2, 13696, 151552),
    "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
    "phi35_moe": (32, 4096, 32, 8, 6400, 32064),
    "llama4_maverick": (48, 5120, 40, 8, 8192, 202048),
    "mistral_large": (88, 12288, 96, 8, 28672, 32768),
    "internvl2_76b": (80, 8192, 64, 8, 28672, 128256),
    "smollm_135m": (30, 576, 9, 3, 1536, 49152),
}


@pytest.mark.parametrize("aid", list(SPEC))
def test_exact_spec(aid):
    c = get_config(aid)
    layers, d, h, kv, ff, v = SPEC[aid]
    assert c.num_layers == layers
    assert c.d_model == d
    if h is not None:
        assert c.num_heads == h
        assert c.num_kv_heads == kv
    assert c.d_ff == ff
    assert c.vocab_size == v
    assert c.source, "every config must cite its source"


def test_moe_specs():
    phi = get_config("phi35_moe")
    assert phi.num_experts == 16 and phi.num_experts_per_tok == 2
    l4 = get_config("llama4_maverick")
    assert l4.num_experts == 128 and l4.num_experts_per_tok == 1
    mx = get_config("mixtral_8x7b")
    assert mx.num_experts == 8 and mx.num_experts_per_tok == 2


def test_ssm_specs():
    m = get_config("mamba2_780m")
    assert m.ssm_state == 128 and m.d_inner == 3072
    z = get_config("zamba2_7b")
    assert z.ssm_state == 64


def test_segments_cover_all_layers():
    for aid, cfg in all_configs().items():
        n = sum(len(pat) * reps for pat, reps in cfg.segments())
        assert n == cfg.num_layers, (aid, n, cfg.num_layers)


def test_zamba_has_shared_blocks():
    z = get_config("zamba2_7b")
    kinds = [k for pat, reps in z.segments() for k in pat]
    assert "shared" in kinds and "mamba" in kinds


def test_llama4_interleave():
    l4 = get_config("llama4_maverick")
    (pat, reps), = [s for s in l4.segments() if "moe" in s[0]]
    assert pat == ("dense", "moe") and reps == 24


def test_param_counts_rough():
    """Analytic parameter totals near the advertised sizes."""
    approx = {
        "mamba2_780m": 0.78e9,
        "starcoder2_7b": 7e9,
        "glm4_9b": 9e9,
        "mistral_large": 123e9,
        "smollm_135m": 135e6,
        "phi35_moe": 42e9,
    }
    for aid, want in approx.items():
        got = get_config(aid).param_count()
        assert 0.5 * want < got < 1.7 * want, (aid, got, want)
