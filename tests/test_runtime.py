"""repro.runtime: scheduler ordering, cancellation, double buffering,
residency policies, and end-to-end parity with the synchronous pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import reduced
from repro.configs import get_config
from repro.core import sparsify
from repro.core.cache import ExpertCache
from repro.core.offload import LinkModel, build_expert_store
from repro.core.pipeline import (FloEPipeline, _unstack_layers,
                                 paper_scaled_models)
from repro.models import transformer as tf
from repro.runtime import (ExpertScheduler, ResidencyManager, TransferEngine,
                           coalesce_runs)


# ------------------------------------------------------------- fixtures ---
def _store(e=4, d=32, f=64, seed=0):
    rng = np.random.default_rng(seed)
    moe = {
        "we_gate": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_up": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_down": jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32) * 0.1,
    }
    thr = np.full((e,), 0.5, np.float32)
    return build_expert_store(moe, thr, bits=2, group=32)


def _sched(store, *, slots=4, num_buffers=2, lookahead=2, policy="lru",
           cancel_stale=True, link=None):
    res = [ResidencyManager(slots, policy=policy)]
    eng = TransferEngine(link or LinkModel(), num_buffers=num_buffers)
    return ExpertScheduler([store], res, eng, lookahead=lookahead,
                           cancel_stale=cancel_stale), res[0], eng


@pytest.fixture(scope="module")
def pipeline_setup():
    cfg = reduced(get_config("mixtral_8x7b"), layers=4, d_model=128)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    layers = _unstack_layers(params, cfg)
    xcal = jax.random.normal(jax.random.PRNGKey(9), (64, cfg.d_model))
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    return cfg, params, thr


# ------------------------------------------------- scheduler: ordering ----
def test_priority_order_under_conflict():
    """With one staging buffer, queued prefetches must reach the link in
    confidence order, not submission order."""
    store = _store()
    sched, res, eng = _sched(store, num_buffers=1)
    idx = np.arange(10)
    sched.enqueue_prefetch(0, 0, idx, confidence=0.2)
    sched.enqueue_prefetch(0, 1, idx, confidence=0.9)
    sched.enqueue_prefetch(0, 2, idx, confidence=0.5)
    sched.pump()  # buffer=1: only the highest-priority request issues
    assert eng.records[0].key == (0, 1)
    # as buffers free, the rest must follow in confidence order
    sched.advance(10.0)
    sched.advance(10.0)
    assert [r.key for r in eng.records] == [(0, 1), (0, 2), (0, 0)]


def test_depth_discount_demotes_deep_lookahead():
    store = _store()
    sched, _, eng = _sched(store, num_buffers=1)
    sched.enqueue_prefetch(0, 0, np.arange(4), confidence=0.9)  # occupies
    sched.pump()
    sched.enqueue_prefetch(0, 1, np.arange(4), confidence=0.8, depth=3)
    sched.enqueue_prefetch(0, 2, np.arange(4), confidence=0.5, depth=1)
    sched.advance(10.0)
    sched.advance(10.0)
    keys = [r.key for r in eng.records]
    # 0.5 at depth 1 outranks 0.8 * 0.5^2 = 0.2 at depth 3
    assert keys.index((0, 2)) < keys.index((0, 1))


def test_repredicted_request_promotes_priority():
    store = _store()
    sched, _, eng = _sched(store, num_buffers=1)
    sched.enqueue_prefetch(0, 0, np.arange(4), confidence=0.9)  # occupies
    sched.pump()
    sched.enqueue_prefetch(0, 1, np.arange(4), confidence=0.3, depth=2)
    sched.enqueue_prefetch(0, 2, np.arange(4), confidence=0.4, depth=1)
    # fresher, nearer prediction for expert 1 overtakes expert 2
    sched.enqueue_prefetch(0, 1, np.arange(4), confidence=0.9, depth=1)
    sched.advance(10.0)
    sched.advance(10.0)
    keys = [r.key for r in eng.records]
    assert keys.index((0, 1)) < keys.index((0, 2))
    assert sched.stats.prefetch_enqueued == 3  # re-prediction is not new


# --------------------------------------------- scheduler: cancellation ----
def test_cancel_queued_prefetch_on_router_disagreement():
    store = _store()
    sched, res, eng = _sched(store, num_buffers=1)
    sched.enqueue_prefetch(0, 0, np.arange(8), confidence=0.9)
    sched.pump()
    sched.enqueue_prefetch(0, 1, np.arange(8), confidence=0.5)  # queued
    assert (0, 1) not in res  # never staged
    cancelled = sched.reconcile(0, true_experts=[0, 2])
    assert cancelled == 1
    assert sched.stats.prefetch_cancelled == 1
    sched.advance(100.0)
    assert (0, 1) not in res  # cancelled request never reaches the link
    assert all(r.key != (0, 1) for r in eng.records)


def test_inflight_stale_prefetch_is_demoted_not_cancelled():
    store = _store()
    sched, res, eng = _sched(store, num_buffers=2)
    sched.enqueue_prefetch(0, 0, np.arange(8), confidence=0.9)
    sched.pump()  # on the link already
    sched.reconcile(0, true_experts=[1])
    assert sched.stats.prefetch_demoted == 1
    assert sched.stats.prefetch_cancelled == 0
    assert (0, 0) in res  # bytes were committed; the slice still lands
    assert eng.wasted_bytes() > 0


def test_cancel_stale_disabled():
    store = _store()
    sched, _, _ = _sched(store, num_buffers=1, cancel_stale=False)
    sched.enqueue_prefetch(0, 0, np.arange(8), confidence=0.9)
    sched.pump()
    sched.enqueue_prefetch(0, 1, np.arange(8), confidence=0.5)
    assert sched.reconcile(0, true_experts=[0]) == 0
    assert sched.stats.prefetch_cancelled == 0


# ------------------------------------------------ transfer: double buffer -
def test_double_buffer_slot_reuse():
    """Two buffers: transfers 1+2 stage concurrently (serialized only by
    the link); transfer 3 waits for a buffer and reuses the freed slot."""
    store = _store()
    link = LinkModel()
    eng = TransferEngine(link, num_buffers=2)
    idx = np.arange(40)
    _, r1 = eng.issue(store, "a", 0, idx, now=0.0)
    _, r2 = eng.issue(store, "b", 1, idx, now=0.0)
    assert r2.start_t >= r1.complete_t  # serial link
    assert eng.active_count(0.0) == 2
    assert not eng.has_capacity(0.0)
    _, r3 = eng.issue(store, "c", 2, idx, now=0.0)
    # third transfer cannot start before a buffer frees
    assert r3.start_t >= min(r1.complete_t, r2.complete_t)
    done = eng.poll(r1.complete_t)
    assert any(r.key == "a" for r in done)
    assert eng.active_count(r3.complete_t + 1e-12) == 0


def test_demand_preempts_speculative_traffic():
    """A demand issued mid-prefetch enters the link after the current
    chunk, not after the whole speculative backlog."""
    store = _store()
    eng = TransferEngine(LinkModel(), num_buffers=2, chunk_channels=4)
    _, p1 = eng.issue(store, "p1", 0, np.arange(32), now=0.0)
    _, p2 = eng.issue(store, "p2", 1, np.arange(32), now=0.0)
    backlog_end = p2.complete_t
    _, d = eng.issue(store, "d", 2, np.arange(32), now=0.0, kind="demand")
    chunk = p1.duration / p1.chunks
    assert d.start_t <= chunk + 1e-12
    assert d.complete_t < backlog_end  # jumped the queue
    # preempted transfers resume after the demand
    assert p1.complete_t > d.start_t


def test_chunk_coalescing_adjacent_runs():
    assert coalesce_runs(np.array([0, 1, 2, 7, 8, 20])) == \
        [(0, 3), (7, 2), (20, 1)]
    assert coalesce_runs(np.array([], np.int64)) == []
    store = _store()
    eng = TransferEngine(LinkModel(), chunk_channels=50)
    _, contig = eng.issue(store, "x", 0, np.arange(60), now=0.0)
    assert contig.strategy == "direct"  # one adjacent run, no packing
    assert contig.chunks <= 2
    scattered = np.arange(0, 64, 13)
    _, scat = eng.issue(store, "y", 1, scattered, now=0.0)
    assert scat.strategy == "packed"  # 5 tiny runs pack into one chunk


def test_transfer_telemetry():
    store = _store()
    eng = TransferEngine(LinkModel())
    eng.issue(store, "a", 0, np.arange(16), now=0.0)
    eng.issue(store, "b", 1, np.arange(16), now=0.0, kind="demand")
    s = eng.summary()
    assert s["transfers"] == 2
    assert s["bytes"] == 2 * 16 * 2 * store.d_model * 2
    assert s["busy_s"] > 0
    assert eng.demote("a") and not eng.demote("a")  # counted once
    assert eng.wasted_bytes() == s["bytes"] // 2


# --------------------------------------------------- residency policies ---
def test_lru_policy_matches_expert_cache():
    """The runtime's LRU must reproduce ExpertCache access-for-access."""
    rng = np.random.default_rng(3)
    old = ExpertCache(3)
    new = ResidencyManager(3, policy="lru")
    for key in rng.integers(0, 8, 200).tolist():
        o = old.get(key)
        n = new.get(key)
        assert (o is None) == (n is None), key
        if o is None:
            old.put(key, key)
            new.put(key, key)
        assert old.keys() == new.keys()
    assert old.stats.hits == new.stats.hits
    assert old.stats.misses == new.stats.misses
    assert old.stats.evictions == new.stats.evictions


def test_lfu_policy_keeps_hot_expert():
    r = ResidencyManager(2, policy="lfu")
    r.put("hot", 1)
    for _ in range(5):
        r.get("hot")
    r.put("cold", 2)
    r.put("new", 3)  # evicts cold (1 use beats 0)
    assert "hot" in r and "new" in r and "cold" not in r


def test_weighted_policy_prefers_confident_prefetch():
    r = ResidencyManager(2, policy="weighted")
    r.put("sure", 1, score=0.9, prefetch=True)
    r.put("maybe", 2, score=0.1, prefetch=True)
    r.put("x", 3, score=0.5, prefetch=True)  # evicts "maybe"
    assert "sure" in r and "x" in r and "maybe" not in r


def test_pinned_experts_never_evicted():
    r = ResidencyManager(2, policy="lru", pinned=["shared"])
    r.put("shared", 0)
    r.put("a", 1)
    r.put("b", 2)
    r.put("c", 3)
    assert "shared" in r
    assert len(r) == 2


def test_residency_stats_reset():
    r = ResidencyManager(2)
    r.put("a", 1, prefetch=True)
    r.get("a")
    r.get("zzz")
    assert r.stats.hits == 1 and r.stats.misses == 1
    assert r.stats.prefetch_hits == 1
    r.get("a")
    assert r.stats.prefetch_hits == 1  # consumed once per prefetch
    r.reset_stats()
    assert r.stats.hits == r.stats.misses == r.stats.prefetch_hits == 0


def test_expert_cache_no_phantom_prefetch_hit_after_eviction():
    c = ExpertCache(1)
    c.put("a", 1, prefetch=True)
    c.put("b", 2)  # evicts the unconsumed prefetch
    c.put("a", 3)  # plain re-insert
    c.get("a")
    assert c.stats.prefetch_hits == 0
    c.stats.reset()
    assert c.stats.hits == 0 and c.stats.evictions == 0


# ----------------------------------------------------- e2e: parity --------
def test_runtime_decode_bitwise_matches_sync(pipeline_setup):
    """Scheduler-driven decode must be bitwise-identical to the
    synchronous path when residency matches (LRU, lookahead=1, ample
    staging, no cancellation): same payloads, same jax ops, only the
    timing model differs."""
    cfg, params, thr = pipeline_setup
    device, link = paper_scaled_models(cfg)

    def outputs(**kw):
        pipe = FloEPipeline(params, cfg, thresholds=thr, device=device,
                            link=link, mode="floe",
                            cache_slots=cfg.num_experts, **kw)
        outs = []
        for i in range(3):
            h = jax.random.normal(jax.random.PRNGKey(1 + i),
                                  (2, cfg.d_model), jnp.float32)
            out, _ = pipe.decode_token(h)
            outs.append(np.asarray(out))
        return outs

    sync = outputs()
    runtime = outputs(use_runtime=True, lookahead=1, cancel_stale=False,
                      cross_token=False, num_buffers=8)
    for a, b in zip(sync, runtime):
        np.testing.assert_array_equal(a, b)


def test_runtime_decode_reduces_stall(pipeline_setup):
    """On a correlated token stream the event-driven scheduler (lookahead,
    cross-token speculation, demand/compute overlap) must cut modeled
    stall per token by >= 30% vs the synchronous path — the bench's
    acceptance bar, pinned here."""
    cfg, params, thr = pipeline_setup
    device, link = paper_scaled_models(cfg)

    def h_stream(steps, batch, alpha=0.95):
        key = jax.random.PRNGKey(0)
        h = jax.random.normal(key, (batch, cfg.d_model), jnp.float32)
        out = [h]
        for _ in range(steps - 1):
            key, sub = jax.random.split(key)
            n = jax.random.normal(sub, (batch, cfg.d_model), jnp.float32)
            h = alpha * h + (1 - alpha ** 2) ** 0.5 * n
            out.append(h)
        return out

    hs = h_stream(12, 2)

    def stall(**kw):
        pipe = FloEPipeline(params, cfg, thresholds=thr, device=device,
                            link=link, mode="floe", cache_slots=2, **kw)
        for h in hs:
            pipe.decode_token(h)
        return sum(m.stall_s for m in pipe.metrics) / len(pipe.metrics)

    s_sync = stall()
    s_rt = stall(use_runtime=True, lookahead=2)
    assert s_rt < 0.7 * s_sync, (s_sync, s_rt)


# ----------------------------------------------------- serving: offload ---
def test_serving_offloaded_batched_mode(pipeline_setup):
    from repro.serving import Request, ServingEngine
    cfg, params, thr = pipeline_setup
    device, link = paper_scaled_models(cfg)
    rng = np.random.default_rng(0)
    p1 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)

    eng = ServingEngine(params, cfg, batch_size=2, max_len=64,
                        offload_thresholds=thr,
                        offload_opts=dict(device=device, link=link,
                                          cache_slots=4))
    eng.submit(Request(0, p1, max_new_tokens=4))
    eng.submit(Request(1, p2, max_new_tokens=4))
    done = eng.run()
    assert len(done) == 2
    assert all(len(r.output) == 4 for r in done)
    s = eng.floe.sched.stats
    assert s.prefetch_issued > 0  # scheduler actually drove the decode
    assert eng.stats["compute_s"] > 0
    assert eng.modeled_stall_per_token() >= 0.0


def test_serving_offloaded_shares_experts_across_batch(pipeline_setup):
    """Two requests with the SAME prompt route identically: the batched
    demand path must fetch each (layer, expert) once, not once per
    request."""
    from repro.serving import Request, ServingEngine
    cfg, params, thr = pipeline_setup
    device, link = paper_scaled_models(cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)

    def demand_fetches(n_reqs):
        eng = ServingEngine(params, cfg, batch_size=2, max_len=64,
                            offload_thresholds=thr,
                            offload_opts=dict(device=device, link=link,
                                              cache_slots=4))
        for uid in range(n_reqs):
            eng.submit(Request(uid, prompt, max_new_tokens=4))
        eng.run()
        return eng.floe.sched.stats.demand_fetches

    assert demand_fetches(2) == demand_fetches(1)


def test_serving_offloaded_deterministic(pipeline_setup):
    from repro.serving import Request, ServingEngine
    cfg, params, thr = pipeline_setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    outs = []
    for _ in range(2):
        eng = ServingEngine(params, cfg, batch_size=1, max_len=64,
                            offload_thresholds=thr)
        eng.submit(Request(0, prompt, max_new_tokens=4))
        outs.append(eng.run()[0].output)
    assert outs[0] == outs[1]
