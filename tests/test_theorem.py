"""Numeric validation of the paper's Appendix A (Theorem A.2 machinery).

We re-derive the closed forms and check them against Monte-Carlo, then
verify the key inequality F(eta) < G(eta, p) (Lemma A.9) on a grid — the
analytic backbone of L_down <= L_up < L_gate.
"""
import math

import numpy as np
import pytest


# --- tiny self-contained normal utilities (no scipy in this container) -----
def phi(x):
    return math.exp(-x * x / 2.0) / math.sqrt(2.0 * math.pi)


def Phi(x):
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def Phi_inv(q, lo=-10.0, hi=10.0):
    for _ in range(80):  # bisection is plenty here
        mid = (lo + hi) / 2.0
        if Phi(mid) < q:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2.0


def F(eta):
    """Lemma A.4: E[S̄_t(a)^2]/E[a^2] for a ~ N(0,1)."""
    z = Phi_inv(1.0 - eta / 2.0)
    return 1.0 - eta - 2.0 * z * phi(z)


def q_eta(eta, p):
    """Lemma A.5/A.7 threshold (normalized by c; p = lambda*c)."""
    return math.asinh((1.0 - eta) / 2.0 * math.exp(p)) / p


def G(eta, p):
    """Lemma A.9 normalized truncated second moment for the shifted
    exponential."""
    q = q_eta(eta, p)
    denom = 2.0 / p ** 2 - 2.0 / p + 1.0
    t1 = math.exp(p * (q - 1.0)) * (2.0 / p ** 2 - 2.0 * q / p + q * q) / denom
    t2 = math.exp(-p * (1.0 + q)) * (2.0 / p ** 2 + 2.0 * q / p + q * q) / denom
    return t1 - t2


def test_lemma_a4_matches_monte_carlo():
    rng = np.random.default_rng(0)
    a = rng.normal(size=2_000_000)
    for eta in (0.1, 0.3, 0.5):
        t = np.quantile(np.abs(a), 1.0 - eta)
        mc = np.mean(np.where(np.abs(a) < t, a, 0.0) ** 2)
        assert abs(mc - F(eta)) < 5e-3, (eta, mc, F(eta))


def test_lemma_a5_matches_monte_carlo():
    rng = np.random.default_rng(1)
    lam, c = 11.0, 0.28  # the paper's SiLU fit (p = lam*c = 3.08)
    p = lam * c
    x = rng.exponential(1.0 / lam, size=2_000_000)
    a = x - c
    for eta in (0.1, 0.3, 0.5):
        t = np.quantile(np.abs(a), 1.0 - eta)
        mc = np.mean(np.where(np.abs(a) < t, a, 0.0) ** 2)
        closed = G(eta, p) * np.mean(a ** 2)
        # closed form uses the exact quantile; allow MC tolerance
        assert abs(mc - closed) / max(closed, 1e-9) < 0.05, (eta, mc, closed)


def test_lemma_a9_inequality_grid():
    """F(eta) < G(eta, p) for p >= 2, eta in [e^-4, 0.5]."""
    for p in (2.0, 3.08, 5.0, 10.0):
        for eta in np.linspace(math.exp(-4), 0.5, 25):
            assert F(eta) < G(eta, p), (p, eta, F(eta), G(eta, p))


def test_inequality_fails_when_assumption_violated():
    """Sanity: for small p (assumption lam*c >= 2 violated) the gap can
    shrink — the theorem's condition is not vacuous."""
    gaps_ok = [G(0.3, p) - F(0.3) for p in (2.0, 5.0, 10.0)]
    gap_bad = G(0.3, 0.3) - F(0.3)
    assert min(gaps_ok) > gap_bad
