"""Distribution correctness on fake multi-device meshes.

jax locks the device count at first init, so these run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

WORKER = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.common.config import reduced
from repro.configs import get_config
from repro.models import moe, transformer as tf
from repro.launch.train import make_dist

if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
else:
    mesh = jax.make_mesh((2, 4), ("data", "model"))
dist = moe.Dist(mesh=mesh, batch_axes=("data",), batch_sharded=True)

# --- sharded MoE == local oracle (fwd + grads) ---
cfg = reduced(get_config("mixtral_8x7b"), layers=2, d_model=128)
params = moe.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)
out_s, aux_s = jax.jit(lambda p, x: moe.moe_forward(p, x, cfg, dist))(params, x)
out_l, aux_l = moe.moe_forward(params, x, cfg)
assert float(jnp.abs(out_s - out_l).max()) < 1e-4, "sharded forward mismatch"

g_s = jax.jit(jax.grad(lambda p: (moe.moe_forward(p, x, cfg, dist)[0]**2).mean()))(params)
g_l = jax.grad(lambda p: (moe.moe_forward(p, x, cfg)[0]**2).mean())(params)
for a, b in zip(jax.tree.leaves(g_s), jax.tree.leaves(g_l)):
    assert float(jnp.abs(a - b).max()) < 1e-4, "sharded grad mismatch"

# --- full model loss under mesh == local ---
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                      cfg.vocab_size)}
mp = tf.init_model(jax.random.PRNGKey(3), cfg, jnp.float32)
l_s = jax.jit(lambda p, b: tf.loss_fn(p, b, cfg, dist)[0])(mp, batch)
l_l = tf.loss_fn(mp, batch, cfg)[0]
assert abs(float(l_s) - float(l_l)) < 2e-3, (float(l_s), float(l_l))

# --- decode under mesh (batch sharded) ---
state = tf.init_decode_state(cfg, 4, 32, jnp.float32)
tok = jnp.ones((4, 1), jnp.int32)
lg_s, _ = jax.jit(lambda p, t, s: tf.decode_step(p, t, s, cfg, dist))(mp, tok, state)
lg_l, _ = tf.decode_step(mp, tok, state, cfg)
assert float(jnp.abs(lg_s - lg_l).max()) < 1e-3

# --- batch=1 decode (unsharded batch) ---
dist1 = moe.Dist(mesh=mesh, batch_axes=("data",), batch_sharded=False)
state1 = tf.init_decode_state(cfg, 1, 32, jnp.float32)
lg1, _ = jax.jit(lambda p, t, s: tf.decode_step(p, t, s, cfg, dist1))(mp, tok[:1], state1)
lgl, _ = tf.decode_step(mp, tok[:1], state1, cfg)
assert float(jnp.abs(lg1 - lgl).max()) < 1e-3
print("DISTRIBUTED-OK")
"""


@pytest.mark.slow
def test_sharded_model_matches_local():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", WORKER], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "DISTRIBUTED-OK" in r.stdout, r.stdout + "\n" + r.stderr
