"""End-to-end cluster acceptance pins: n_devices=1 decode is bitwise-
identical to the plain single-device runtime path, two devices at the
same per-device VRAM strictly cut stall/token, the serving controller
batch-decodes over the cluster, and the serve.py CLI wires --devices."""
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import plan_cluster, uniform_cluster_plan
from repro.common.config import reduced
from repro.configs import get_config
from repro.core import sparsify
from repro.core.offload import LinkModel
from repro.core.pipeline import (FloEPipeline, _unstack_layers,
                                 paper_scaled_models)
from repro.models import transformer as tf
from repro.store import floor_bytes, measure_frequencies


def _setup(max_experts):
    cfg = reduced(get_config("mixtral_8x7b"), layers=4, d_model=128,
                  max_experts=max_experts)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    layers = _unstack_layers(params, cfg)
    xcal = jax.random.normal(jax.random.PRNGKey(9), (64, cfg.d_model))
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    freqs = measure_frequencies(layers, cfg)
    return cfg, params, thr, freqs


@pytest.fixture(scope="module")
def small_moe():
    return _setup(max_experts=4)


@pytest.fixture(scope="module")
def eight_expert_moe():
    return _setup(max_experts=8)


def _h_stream(cfg, steps, batch, alpha=0.6):
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (batch, cfg.d_model), jnp.float32)
    out = [h]
    for _ in range(steps - 1):
        key, sub = jax.random.split(key)
        n = jax.random.normal(sub, (batch, cfg.d_model), jnp.float32)
        h = alpha * h + (1.0 - alpha ** 2) ** 0.5 * n
        out.append(h)
    return out


# ------------------------------------------------------- n=1 parity pin ---
def test_cluster_n1_decode_bitwise_matches_runtime(small_moe):
    """Acceptance pin: the n_devices=1 cluster shim is transparent —
    bitwise-identical outputs AND identical measured stall/transfer
    timeline vs the plain ``use_runtime=True`` path."""
    cfg, params, thr, freqs = small_moe
    device, link = paper_scaled_models(cfg)

    def decode(**kw):
        pipe = FloEPipeline(params, cfg, thresholds=thr, device=device,
                            link=link, mode="floe",
                            cache_slots=cfg.num_experts, use_runtime=True,
                            lookahead=2, **kw)
        outs = []
        for h in _h_stream(cfg, 4, 2):
            out, _ = pipe.decode_token(h)
            outs.append(np.asarray(out))
        return outs, pipe

    plain_out, plain = decode()
    clus_out, clus = decode(
        cluster_plan=uniform_cluster_plan(cfg, 1, freqs=freqs))
    for a, b in zip(plain_out, clus_out):
        np.testing.assert_array_equal(a, b)
    # the timeline is identical too, not just the math
    assert len(plain.engine.records) == len(clus.engine.records)
    for pm, cm in zip(plain.metrics, clus.metrics):
        assert pm.stall_s == cm.stall_s
        assert pm.prefetch_s == cm.prefetch_s
    assert plain.sched.clock == clus.sched.clock


# ----------------------------------------------- multi-device stall win ---
def test_two_devices_cut_stall_at_fixed_per_device_vram(eight_expert_moe):
    """Parallel links + aggregate residency: at the SAME per-device VRAM
    budget and residency configuration, 2 devices must at least halve
    the single-device stall/token (bench_cluster tracks the full
    1->2->4 curve; this pins the first step)."""
    cfg, params, thr, freqs = eight_expert_moe
    device, link0 = paper_scaled_models(cfg)
    link = LinkModel(peak_bw=link0.peak_bw / 4, launch_us=link0.launch_us,
                     pack_bw=link0.pack_bw / 4)
    vram_gb = 1.05 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    hs = _h_stream(cfg, 4, 8)

    def stall(n):
        plan = plan_cluster(cfg, freqs, n_devices=n,
                            vram_gb_per_device=vram_gb, host_gb=0.0005,
                            ladder=("int2",), max_pinned_per_device=0,
                            max_slots=1)
        pipe = FloEPipeline(params, cfg, thresholds=thr, device=device,
                            link=link, mode="floe", use_runtime=True,
                            cluster_plan=plan,
                            store_dir=tempfile.mkdtemp(prefix="clu-e2e-"),
                            store_freqs=freqs)
        for h in hs:
            pipe.decode_token(h)
        for pool in pipe.device_pools:
            pool.check_invariants()
        return sum(m.stall_s for m in pipe.metrics) / len(pipe.metrics)

    s1, s2 = stall(1), stall(2)
    assert s2 < 0.5 * s1, (s1, s2)


# ------------------------------------------------ controller over cluster -
def test_controller_batched_decode_over_cluster(small_moe):
    """The serving control plane (union demands, swap-in/out) runs over
    the cluster dispatcher: per-expert demands split across device
    links, clocks stay lockstep, every request completes."""
    from repro.serving import ServingController, SLORequest
    cfg, params, thr, freqs = small_moe
    device, link = paper_scaled_models(cfg)
    plan = uniform_cluster_plan(cfg, 2, freqs=freqs, replicate=1)
    ctl = ServingController(
        params, cfg, thresholds=thr, slots=2, max_len=64,
        online_train=False,
        offload_opts=dict(device=device, link=link, cache_slots=4,
                          cluster_plan=plan))
    for i in range(3):
        ctl.submit(SLORequest(i, np.arange(4, dtype=np.int32),
                              max_new_tokens=3, slo_ms=60_000.0,
                              arrival_t=0.05 * i))
    ctl.run()
    assert len(ctl.completed) == 3
    assert all(len(r.output) == 3 for r in ctl.completed)
    clocks = [s.clock for s in ctl.sched.devs]
    assert max(clocks) - min(clocks) <= 1e-9
    rep = ctl.report()
    assert rep["devices"] == 2
    assert 0.0 <= rep["agg_link_utilization"] <= 1.0
    # transfers actually used more than one link
    devices_used = {r.device for r in ctl.pipe.engine.records}
    assert devices_used == {0, 1}


def test_controller_cluster_n1_matches_single_device(small_moe):
    """Controller tokens are bitwise-identical between the plain runtime
    and the n_devices=1 cluster (the shim changes nothing end to end)."""
    from repro.serving import ServingController, SLORequest

    cfg, params, thr, freqs = small_moe
    device, link = paper_scaled_models(cfg)

    def run(**extra):
        ctl = ServingController(
            params, cfg, thresholds=thr, slots=2, max_len=64,
            online_train=False,
            offload_opts=dict(device=device, link=link, cache_slots=4,
                              **extra))
        for i in range(2):
            ctl.submit(SLORequest(i, np.arange(4, dtype=np.int32),
                                  max_new_tokens=3, slo_ms=60_000.0,
                                  arrival_t=0.05 * i))
        ctl.run()
        return {r.uid: r.output for r in ctl.completed}, ctl.sched.clock

    base, t_base = run()
    clus, t_clus = run(cluster_plan=uniform_cluster_plan(cfg, 1,
                                                         freqs=freqs))
    assert base == clus
    assert t_base == t_clus


# ----------------------------------------------------------------- CLI ----
def test_serve_cli_devices(monkeypatch, capsys):
    """`launch/serve.py --devices 2 --vram-gb B` plans the cluster and
    decodes through it, reporting per-device placement + link telemetry."""
    from repro.launch import serve
    monkeypatch.setattr(sys, "argv", [
        "serve.py", "--arch", "mixtral-8x7b", "--reduced", "--mode", "floe",
        "--layers", "2", "--d_model", "128", "--max_new", "4",
        "--devices", "2", "--replicate", "1",
        "--vram-gb", "0.0012", "--host-gb", "0.05"])
    serve.main()
    out = capsys.readouterr().out
    assert "cluster plan:" in out
    assert "dev0:" in out and "dev1:" in out
    assert "mode=floe:" in out and "tok/s" in out
    assert "agg_link_util=" in out
