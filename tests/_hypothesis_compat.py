"""Optional-`hypothesis` shim for the property tests.

When `hypothesis` is installed the real `given`/`settings`/`strategies`
are re-exported unchanged.  When it is missing (the container image does
not bake it in), a small deterministic fallback runs each property test
over a fixed grid of representative samples drawn from the declared
strategies, so tier-1 stays green with reduced (but nonzero) coverage.

Only the strategy combinators this repo actually uses are implemented:
``sampled_from``, ``floats``, ``integers``, ``booleans``, ``tuples``,
``lists``.
"""
from __future__ import annotations

import functools
import inspect
import itertools

try:  # pragma: no cover - exercised implicitly when hypothesis exists
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _MAX_CASES = 12

    class _Strategy:
        """A fixed list of deterministic samples."""

        def __init__(self, samples):
            self.samples = list(samples)

    class _FallbackStrategies:
        @staticmethod
        def sampled_from(values):
            return _Strategy(values)

        @staticmethod
        def floats(min_value, max_value, **_):
            mid = 0.5 * (min_value + max_value)
            return _Strategy([min_value, mid, max_value])

        @staticmethod
        def integers(min_value, max_value, **_):
            mid = (min_value + max_value) // 2
            vals = [min_value, mid, max_value]
            return _Strategy(sorted(set(vals)))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def tuples(*elems):
            combos = itertools.product(*(e.samples or [0] for e in elems))
            return _Strategy(list(itertools.islice(combos, 8)))

        @staticmethod
        def lists(elem, min_size=0, max_size=10, **_):
            base = elem.samples or [0]
            out = []
            if min_size > 0:
                out.append([base[0]] * min_size)
            else:
                out.append(base[:1])
            n = max(min_size, min(max_size, 2 * len(base) + 1))
            out.append([base[i % len(base)] for i in range(n)])
            rev = list(reversed(base))
            out.append([rev[i % len(rev)] for i in range(max(min_size, 1))])
            return _Strategy(out)

    st = _FallbackStrategies()

    def settings(*_, **__):  # noqa: D401 - decorator factory, config ignored
        """No-op stand-in for hypothesis.settings."""
        def deco(fn):
            return fn
        return deco

    def given(*pos_strategies, **kw_strategies):
        """Run the test over an even subsample of the strategy grid."""
        def deco(fn):
            params = [p for p in inspect.signature(fn).parameters]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                names = list(params[len(args):])
                pos_named = dict(zip(names, pos_strategies))
                strategies = {**pos_named, **kw_strategies}
                keys = list(strategies)
                grids = [strategies[k].samples for k in keys]
                cases = list(itertools.product(*grids))
                if len(cases) > _MAX_CASES:
                    step = len(cases) / _MAX_CASES
                    cases = [cases[int(i * step)] for i in range(_MAX_CASES)]
                for case in cases:
                    fn(*args, **dict(zip(keys, case)), **kwargs)

            # pytest must not see the strategy params as fixtures
            wrapper.__signature__ = inspect.Signature([])
            return wrapper
        return deco
