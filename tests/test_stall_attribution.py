"""Stall attribution — every stalled second gets a root cause.

Two layers of coverage:

* a property test: over random decode schedules the attributed total is
  BITWISE equal to ``SchedulerStats.stall_s`` (both accumulate the same
  floats in the same order) and the per-cause segments sum back to the
  total within float-associativity tolerance,
* one unit test per cause class with a hand-built scenario, driving
  :meth:`StallAttribution.attribute` (segmentation) and, for the causes
  the scheduler infers from context (eviction, predictor miss,
  progressive drafts), the real scheduler.
"""
import numpy as np
import jax.numpy as jnp

from repro.core.offload import LinkModel, build_expert_store
from repro.obs import CAUSES, StallAttribution
from repro.runtime import (ExpertScheduler, ResidencyManager, TransferEngine,
                           TransferRecord)

from tests._hypothesis_compat import given, settings, st


def _store(e=4, d=16, f=32, seed=0):
    rng = np.random.default_rng(seed)
    moe = {
        "we_gate": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_up": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_down": jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32) * 0.1,
    }
    thr = np.full((e,), 0.5, np.float32)
    return build_expert_store(moe, thr, bits=2, group=16)


def _sched(store, *, slots=3, policy="lru"):
    res = [ResidencyManager(slots, policy=policy)]
    eng = TransferEngine(LinkModel(), num_buffers=2, chunk_channels=8)
    return ExpertScheduler([store], res, eng, lookahead=2), res[0], eng


def _rec(*, start_t=0.0, complete_t=1.0, demoted=False, disk_s=0.0,
         h2d_s=None, kind="demand") -> TransferRecord:
    dur = complete_t - start_t
    return TransferRecord(
        key=(0, 0), kind=kind, nbytes=1024, chunks=1, strategy="packed",
        enqueue_t=start_t, start_t=start_t, complete_t=complete_t,
        demoted=demoted, disk_s=disk_s,
        h2d_s=dur if h2d_s is None else h2d_s)


# ------------------------------------------------------------ conservation --
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_attribution_conserves_stall_seconds(seed):
    """Random schedule: attribution.total_s == stats.stall_s bitwise,
    and the cause segments sum back to the total."""
    store = _store(seed=1)
    sched, _, _ = _sched(store)
    rng = np.random.default_rng(seed)
    f = store.d_ff
    for _ in range(40):
        op = rng.integers(0, 5)
        e = int(rng.integers(0, store.num_experts))
        idx = np.sort(rng.choice(f, size=int(rng.integers(1, f // 2)),
                                 replace=False))
        if op == 0:
            sched.enqueue_prefetch(0, e, idx, float(rng.random()),
                                   depth=int(rng.integers(1, 3)))
        elif op == 1:
            sched.pump()
        elif op == 2:
            sched.advance(float(rng.random()) * 1e-3)
        elif op == 3:
            payload, miss = sched.demand_async(0, e, lambda i=idx: i)
            sched.wait_for(0, e, was_miss=miss)
        else:
            (sl, _, _), miss = sched.demand_union(0, e, idx)
            sched.wait_for(0, e, was_miss=miss)
    attr = sched.attribution
    assert attr.total_s == sched.stats.stall_s  # bitwise, not approx
    assert attr.check_conservation(sched.stats.stall_s)
    assert abs(attr.attributed_s() - attr.total_s) <= \
        1e-9 * max(1.0, attr.total_s)
    assert set(attr.causes) <= set(CAUSES)


def test_attribution_reset_with_stats():
    store = _store()
    sched, _, _ = _sched(store)
    payload, miss = sched.demand_async(0, 0, lambda: np.arange(8))
    sched.wait_for(0, 0, was_miss=miss)
    sched.reset_stats()
    assert sched.attribution.total_s == 0.0
    assert sched.attribution.events == 0
    assert sched.attribution.check_conservation(sched.stats.stall_s)


def test_merge_preserves_conservation():
    a, b = StallAttribution(), StallAttribution()
    a.attribute(0.25, 0.0, cause="predictor_miss")
    b.attribute(0.5, 0.0, record=_rec(start_t=0.2, complete_t=0.5))
    m = a.merge(b)
    assert m.total_s == a.total_s + b.total_s
    assert m.events == 2
    assert m.check_conservation(0.75)


# -------------------------------------------------- one test per cause -----
def test_cause_predictor_miss():
    """Cold demand, link idle: the whole stall is the predictor's fault."""
    segs = StallAttribution().attribute(
        0.3, 0.0, record=_rec(start_t=0.0, complete_t=0.3))
    assert segs == {"predictor_miss": 0.3}


def test_cause_speculative_demotion():
    """Demand against a transfer demoted mid-flight: demotion, not a
    cold miss."""
    segs = StallAttribution().attribute(
        0.3, 0.0, record=_rec(start_t=0.0, complete_t=0.3, demoted=True))
    assert segs == {"speculative_demotion": 0.3}


def test_cause_eviction_of_future_hit():
    """Explicit context (scheduler saw the key evicted) wins over record
    inference."""
    segs = StallAttribution().attribute(
        0.3, 0.0, record=_rec(start_t=0.0, complete_t=0.3),
        cause="eviction")
    assert segs == {"eviction": 0.3}


def test_cause_link_contention():
    """Transfer queued behind a busy link: the queued wait is contention,
    only the on-link remainder is the primary cause."""
    segs = StallAttribution().attribute(
        0.5, 0.0, record=_rec(start_t=0.2, complete_t=0.5))
    assert abs(segs["link_contention"] - 0.2) < 1e-12
    assert abs(segs["predictor_miss"] - 0.3) < 1e-12


def test_cause_disk_tier_miss():
    """Pipelined disk→host stage: duration beyond the pure h2d time is
    the disk tier's share."""
    segs = StallAttribution().attribute(
        0.5, 0.0,
        record=_rec(start_t=0.0, complete_t=0.5, disk_s=0.3, h2d_s=0.2))
    assert abs(segs["disk_tier_miss"] - 0.3) < 1e-12
    assert abs(segs["predictor_miss"] - 0.2) < 1e-12


def test_cause_draft_residual():
    """Progressive-precision residual fetch: explicit draft context."""
    segs = StallAttribution().attribute(
        0.3, 0.0, record=_rec(start_t=0.0, complete_t=0.3),
        cause="draft_residual")
    assert segs == {"draft_residual": 0.3}


def test_cause_prefetch_late():
    """Waiting on an in-flight prefetch that simply hasn't landed yet."""
    segs = StallAttribution().attribute(
        0.3, 0.0, record=_rec(start_t=0.0, complete_t=0.3, kind="prefetch"),
        origin_prefetch=True)
    assert segs == {"prefetch_late": 0.3}


def test_zero_stall_attributes_nothing():
    attr = StallAttribution()
    segs = attr.attribute(0.0, 1.0, record=_rec())
    assert segs == {}
    assert attr.total_s == 0.0 and attr.events == 1
    assert attr.attributed_s() == 0.0


# -------------------------------------------------- scheduler integration --
def test_scheduler_attributes_eviction():
    """Evict a resident expert under capacity pressure, then demand it:
    the stall lands on the eviction cause."""
    store = _store()
    sched, res, _ = _sched(store, slots=1)
    payload, miss = sched.demand_async(0, 0, lambda: np.arange(8))
    sched.wait_for(0, 0, was_miss=miss)
    # force 0 out by demanding another expert into the single slot
    payload, miss = sched.demand_async(0, 1, lambda: np.arange(8))
    sched.wait_for(0, 1, was_miss=miss)
    assert res.was_evicted((0, 0))
    before = sched.attribution.causes.get("eviction", 0.0)
    payload, miss = sched.demand_async(0, 0, lambda: np.arange(8))
    sched.wait_for(0, 0, was_miss=miss)
    assert sched.attribution.causes.get("eviction", 0.0) > before
    assert sched.attribution.check_conservation(sched.stats.stall_s)


def test_scheduler_attributes_predictor_miss():
    """A cold demand with no history is a predictor miss."""
    store = _store()
    sched, _, _ = _sched(store)
    payload, miss = sched.demand_async(0, 2, lambda: np.arange(8))
    sched.wait_for(0, 2, was_miss=miss)
    assert miss
    assert sched.attribution.causes.get("predictor_miss", 0.0) > 0.0
    assert sched.attribution.check_conservation(sched.stats.stall_s)
