"""repro.replan — drift detection, plan diffing, live migration.

Property tests run through ``tests._hypothesis_compat`` (real
hypothesis when installed, a deterministic grid otherwise):

* the drift detector NEVER triggers under stationary traffic (live
  window sampled exactly from the reference) and ALWAYS triggers under
  a phase swap (live mass disjoint from the reference support);
* ``diff`` is a pure function of its two plans — byte-identical deltas
  on repeated calls, ``diff(plan, plan)`` empty (idempotence, an
  acceptance criterion), frees-before-claims step order;
* migration never perturbs serving: identical token streams with a
  migration executing mid-serve vs none (the decode-parity acceptance
  criterion);
* ``arena_overcommit`` surfaces all-pinned residency growth instead of
  letting migration churn hit it silently.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.common.config import reduced
from repro.configs import get_config
from repro.replan import (DriftDetector, MigrationDelta, MigrationExecutor,
                          MigrationStep, diff, freqs_to_array)
from repro.replan.diff import OPS
from repro.store import floor_bytes, plan_store
from tests._hypothesis_compat import given, settings, st

SCENARIO = os.path.join(os.path.dirname(__file__), os.pardir, "examples",
                        "scenarios", "drift_rotate.json")


def _cfg():
    return reduced(get_config("mixtral_8x7b"), layers=4, d_model=64,
                   max_experts=8)


# ---------------------------------------------------------------- drift ---


def test_freqs_to_array_normalizes_and_keeps_zero_rows():
    arr = freqs_to_array({(0, 1): 3, (0, 3): 1, (2, 0): 8}, 3, 4)
    assert arr.shape == (3, 4)
    np.testing.assert_allclose(arr[0], [0, 0.75, 0, 0.25])
    assert arr[1].sum() == 0.0  # no evidence stays zero, not uniform
    np.testing.assert_allclose(arr[2], [1, 0, 0, 0])
    # out-of-range keys are ignored, not crashes
    assert freqs_to_array({(9, 9): 5}, 2, 2).sum() == 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=8),
       st.integers(min_value=4, max_value=64))
def test_drift_stationary_never_triggers(num_experts, window):
    """Live counts exactly proportional to the reference: TV distance is
    0 forever, so no observation may trigger however long it runs."""
    ref = np.tile(np.arange(1.0, num_experts + 1.0), (2, 1))
    det = DriftDetector(ref, window=window, threshold=0.05, cooldown_s=0.0)
    freqs: dict = {}
    for step in range(1, 6):
        for li in range(2):
            for e in range(num_experts):
                freqs[(li, e)] = step * (e + 1) * window
        r = det.observe(freqs, float(step))
        assert not r.triggered
        assert r.distance < 1e-9
        assert r.armed


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=4, max_value=8),
       st.integers(min_value=4, max_value=32))
def test_drift_phase_swap_always_triggers(num_experts, window):
    """Live mass entirely on experts the reference never used: TV
    distance is exactly 1, so a full window must trigger."""
    half = num_experts // 2
    ref = np.zeros((2, num_experts))
    ref[:, :half] = 1.0 / half
    det = DriftDetector(ref, window=window, threshold=0.5, cooldown_s=0.0)
    freqs = {(li, e): 2 * window
             for li in range(2) for e in range(half, num_experts)}
    r = det.observe(freqs, 1.0)
    assert r.triggered
    assert r.distance == pytest.approx(1.0)
    assert not r.armed  # a trigger disarms until hysteresis or rearm


def test_drift_hysteresis_and_rearm_cycle():
    num_experts = 4
    ref = np.zeros((1, num_experts))
    ref[0, :2] = 0.5
    det = DriftDetector(ref, window=4, threshold=0.5, cooldown_s=0.0,
                        hysteresis=0.5)
    swapped = {(0, 2): 4, (0, 3): 4}
    assert det.observe(swapped, 1.0).triggered
    # disarmed: the same drifted window cannot re-trigger
    r = det.observe(swapped, 2.0)
    assert not r.triggered and not r.armed
    # the burst decays on its own (window restarts, counts match ref):
    # distance falls under hysteresis*threshold and the detector re-arms
    det.snapshot(swapped)
    calm = {(0, 0): 10 + 4, (0, 1): 10 + 4, (0, 2): 4, (0, 3): 4}
    det.snapshot({(0, 0): 10, (0, 1): 10, (0, 2): 4, (0, 3): 4})
    r = det.observe(calm, 3.0)
    assert not r.triggered and r.armed and r.distance < 1e-9
    # armed again: a fresh swap triggers a second time
    det.snapshot(calm)
    swapped2 = {k: v + 8 for k, v in calm.items() if k[1] >= 2}
    assert det.observe({**calm, **swapped2}, 4.0).triggered
    assert det.triggers == 2


def test_drift_window_gate():
    """No trigger before `window` demand events, however drifted."""
    ref = np.array([[1.0, 0.0]])
    det = DriftDetector(ref, window=8, threshold=0.1, cooldown_s=0.0)
    assert not det.observe({(0, 1): 7}, 1.0).triggered  # 7 < window
    assert det.observe({(0, 1): 8}, 2.0).triggered


# ----------------------------------------------------------------- diff ---


def _plans(seed: int):
    cfg = _cfg()
    rng = np.random.default_rng(seed)
    f1 = rng.random((cfg.num_layers, cfg.num_experts))
    f1 /= f1.sum(axis=1, keepdims=True)
    f2 = np.roll(f1, 2, axis=1)
    vram = 1.3 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    mk = lambda f: plan_store(cfg, f, vram_gb=vram, host_gb=0.05,
                              ladder=("int2",), progressive=False)
    return mk(f1), mk(f2)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=5))
def test_diff_deterministic_and_idempotent(seed):
    a, b = _plans(seed)
    d1, d2 = diff(a, b), diff(a, b)
    assert d1 == d2  # frozen dataclasses: byte-identical steps
    assert diff(a, a).empty and diff(b, b).empty
    # frees-before-claims: op groups appear in fixed OPS order
    order = [OPS.index(s.op) for s in d1.steps]
    assert order == sorted(order)


def test_diff_cluster_idempotent_and_rehome():
    from repro.cluster import plan_cluster
    cfg = _cfg()
    rng = np.random.default_rng(3)
    f1 = rng.random((cfg.num_layers, cfg.num_experts))
    f1 /= f1.sum(axis=1, keepdims=True)
    vram = 1.2 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    a = plan_cluster(cfg, f1, n_devices=2, vram_gb_per_device=vram,
                     host_gb=0.05, ladder=("int2",))
    assert diff(a, a).empty
    b = plan_cluster(cfg, np.roll(f1, 3, axis=1), n_devices=2,
                     vram_gb_per_device=vram, host_gb=0.05,
                     ladder=("int2",))
    d = diff(a, b)
    assert diff(a, b) == d
    for s in d.steps:
        if s.op == "rehome":
            assert s.src_device >= 0 and s.device != s.src_device
    # plans at different device counts cannot be diffed
    c4 = plan_cluster(cfg, f1, n_devices=4, vram_gb_per_device=vram,
                      host_gb=0.05, ladder=("int2",))
    with pytest.raises(ValueError):
        diff(a, c4)


def test_migration_step_rejects_unknown_op():
    with pytest.raises(ValueError):
        MigrationStep(op="teleport", key=(0, 0))
    d = MigrationDelta(steps=(MigrationStep(op="pin", key=(0, 1)),))
    assert len(d) == 1 and d.count("pin") == 1 and not d.empty
    assert "pin=1" in d.summary()


# ------------------------------------------------------------ residency ---


def test_arena_overcommit_counter_and_event():
    from repro import obs
    from repro.runtime.residency import ResidencyManager
    res = ResidencyManager(capacity=1, pinned=[("a",)])
    collector = obs.MetricsCollector()
    with obs.consumer(collector):
        res.put(("a",), (np.zeros(4, np.float32),))
        assert res.stats.arena_overcommit == 0
        # capacity full and everything resident is pinned: the insert
        # must land (migration correctness) but NEVER silently
        res.put(("b",), (np.zeros(4, np.float32),))
    assert ("b",) in res and len(res) == 2  # grew past capacity
    assert res.stats.arena_overcommit == 1
    reg = collector.registry.snapshot()
    assert int(reg.get("events_total", 0)) >= 1
    res.stats.reset()
    assert res.stats.arena_overcommit == 0


# ----------------------------------------------------------------- spec ---


def _full_spec(**kw):
    from repro.deploy import (DeploymentSpec, ModelSpec, ReplanSpec,
                              ResourceSpec, RuntimeSpec, ServingSpec)
    cfg = _cfg()
    vram = 1.2 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    base = dict(
        model=ModelSpec(arch="mixtral-8x7b", layers=4, d_model=64,
                        max_experts=8),
        resources=ResourceSpec(vram_gb=vram, host_gb=0.05,
                               ladder=("int2",), progressive=False),
        runtime=RuntimeSpec(use_runtime=True, prefetch=False),
        serving=ServingSpec(slots=2, max_len=64, policy="slo",
                            online_train=False),
        replan=ReplanSpec())
    base.update(kw)
    return DeploymentSpec(**base)


def test_replan_spec_json_roundtrip():
    from repro.deploy import DeploymentSpec, ReplanSpec
    spec = _full_spec(replan=ReplanSpec(window=32, threshold=0.3,
                                        cooldown_s=1.5, check_every=4,
                                        bandwidth_share=0.4))
    again = DeploymentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert again == spec
    assert again.replan.window == 32
    # a spec without the section round-trips to None
    bare = _full_spec(replan=None)
    assert DeploymentSpec.from_dict(bare.to_dict()).replan is None


def test_replan_spec_validation_errors():
    from repro.deploy import ReplanSpec, SpecError
    for kw, field in [
        (dict(window=0), "replan.window"),
        (dict(threshold=0.0), "replan.threshold"),
        (dict(threshold=1.5), "replan.threshold"),
        (dict(hysteresis=1.5), "replan.hysteresis"),
        (dict(cooldown_s=-1.0), "replan.cooldown_s"),
        (dict(check_every=0), "replan.check_every"),
        (dict(bandwidth_share=0.0), "replan.bandwidth_share"),
    ]:
        with pytest.raises(SpecError) as ei:
            _full_spec(replan=ReplanSpec(**kw))
        assert ei.value.field == field
    # replan needs a serving control plane and a tiered store
    with pytest.raises(SpecError) as ei:
        _full_spec(serving=None)
    assert ei.value.field == "replan.enabled"


# ------------------------------------------------- executor + end-to-end --


def _tiny_dep(**spec_kw):
    from repro.deploy import build
    return build(_full_spec(replan=None, **spec_kw))


def test_executor_applies_pins_and_supersedes():
    dep = _tiny_dep()
    sched = dep.pipeline.sched
    pinned = sorted(dep.plan.pinned)
    moe = [li for li, st_ in enumerate(sched.stores) if st_ is not None]
    unpinned = [(li, e) for li in moe for e in range(8)
                if (li, e) not in dep.plan.pinned]
    ex = MigrationExecutor(sched, bandwidth_share=1.0)
    d1 = MigrationDelta(steps=tuple(
        [MigrationStep(op="unpin", key=k) for k in pinned[:2]]
        + [MigrationStep(op="pin", key=k) for k in unpinned[:3]]))
    ex.begin(d1, sched.clock)
    # bookkeeping is eager: pins/unpins land before any bytes move
    for k in unpinned[:3]:
        assert k in sched.residency[k[0]].pinned
    for k in pinned[:2]:
        assert k not in sched.residency[k[0]].pinned
    assert ex.stats.begun == 1 and ex.active
    ex.poll(sched.clock)  # warm-ups issue from the queue under poll()
    assert ex.stats.transfers >= 1
    staged = [k for k in unpinned[:3] if k in sched.residency[k[0]]]
    assert staged  # at least one warm-up staged into residency
    # a newer re-plan supersedes: queue dropped, in-flight demoted
    d2 = MigrationDelta(steps=tuple(
        MigrationStep(op="pin", key=k) for k in unpinned[3:6]))
    ex.begin(d2, sched.clock)
    assert ex.stats.begun == 2 and ex.stats.superseded == 1
    # migrate transfers ride the engine timeline under a distinct kind
    kinds = {r.kind for r in sched.engine.records}
    assert "migrate" in kinds


def test_migration_decode_parity_serving():
    """Acceptance: identical serving outputs, migration on vs off."""
    from repro.serving.controller import SLORequest
    rng = np.random.default_rng(5)
    cfg = _cfg()
    prompts = [rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
               for _ in range(3)]
    outs = {}
    for arm in ("off", "on"):
        dep = _tiny_dep()
        ctl = dep.controller
        ex = None
        if arm == "on":
            sched = ctl.pipe.sched
            pinned = set(dep.plan.pinned)
            moe = [li for li, st_ in enumerate(sched.stores)
                   if st_ is not None]
            steps = tuple(
                [MigrationStep(op="unpin", key=k) for k in sorted(pinned)]
                + [MigrationStep(op="pin", key=(li, e))
                   for li in moe for e in range(cfg.num_experts)
                   if (li, e) not in pinned][:6])
            ex = MigrationExecutor(sched, bandwidth_share=1.0)
            ex.begin(MigrationDelta(steps=steps), ctl.sched.clock)
        for i, p in enumerate(prompts):
            ctl.submit(SLORequest(uid=i, prompt=p, max_new_tokens=6,
                                  slo_ms=1e6))
        while ctl.step():
            if ex is not None:
                ex.poll(ctl.sched.clock)
        ctl._retire(ctl.sched.clock)
        outs[arm] = {r.uid: list(r.output) for r in ctl.completed}
    assert len(outs["off"]) == 3
    assert outs["off"] == outs["on"]


def test_replan_end_to_end_under_drift():
    """Serving the committed drift scenario with aggressive knobs must
    re-plan at least once, and the loop's telemetry must surface in the
    deployment report."""
    from repro.deploy import ReplanSpec
    from repro.workload import ScenarioSpec
    scen = dataclasses.replace(ScenarioSpec.load(SCENARIO), n_requests=12)
    dep = _tiny_dep()
    dep.serve(scenario=scen,
              replan=ReplanSpec(window=8, threshold=0.1, cooldown_s=0.0,
                                check_every=2, bandwidth_share=1.0))
    rep = dep.report()["replan"]
    assert rep["replans"] >= 1
    assert rep["drift_triggers"] >= rep["replans"]
    assert rep["checks"] >= 1
    # serve(replan=False) turns the loop off for that call
    dep2 = _tiny_dep()
    dep2.serve(scenario=dataclasses.replace(scen, seed=99), replan=False)
    assert "replan" not in dep2.report()


def test_fleet_replan_ledger():
    """Re-plans move the admission ledger atomically; a footprint the
    headroom cannot absorb is denied with a typed AdmissionError."""
    from repro.cluster import plan_cluster
    from repro.deploy import AdmissionError, build_fleet
    cfg = _cfg()
    vram = 1.1 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    specs = [_full_spec(replan=None, name=n) for n in "ab"]
    specs = [dataclasses.replace(s, name=n) for s, n in zip(specs, "ab")]
    fleet = build_fleet(specs, vram_gb_per_device=2.3 * vram,
                        host_gb=0.05)
    dep = fleet["a"].deployment
    assert dep._replan_ledger is not None
    committed = list(fleet.committed)
    dep._replan_ledger(fleet["a"].plan)  # same footprint: no-op recommit
    assert fleet.committed == committed
    # a re-plan at ~2x the budget cannot fit the leftover headroom
    rng = np.random.default_rng(0)
    f = rng.random((cfg.num_layers, cfg.num_experts))
    f /= f.sum(axis=1, keepdims=True)
    big = plan_cluster(cfg, f, n_devices=1, vram_gb_per_device=2 * vram,
                       host_gb=0.05, ladder=("int2",))
    with pytest.raises(AdmissionError) as ei:
        dep._replan_ledger(big)
    assert ei.value.field == "fleet.a"
    assert fleet.committed == committed  # denied: ledger untouched
