"""Expert cache (LRU) and offload engine (compact layout + cost model)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hqq
from repro.core.cache import ExpertCache
from repro.core.offload import ExpertStore, LinkModel, build_expert_store


# ----------------------------------------------------------------- cache ---
def test_lru_eviction_order():
    c = ExpertCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1  # refresh a
    c.put("c", 3)  # evicts b (least recent)
    assert "b" not in c and "a" in c and "c" in c
    assert c.stats.evictions == 1


def test_cache_stats():
    c = ExpertCache(4)
    c.put("x", 0, prefetch=True)
    assert c.get("x") == 0
    assert c.get("y") is None
    s = c.stats
    assert s.hits == 1 and s.misses == 1 and s.prefetch_hits == 1
    assert s.hit_rate == 0.5


@given(st.lists(st.integers(0, 9), min_size=1, max_size=60),
       st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_lru_capacity_invariant(accesses, cap):
    c = ExpertCache(cap)
    for a in accesses:
        if c.get(a) is None:
            c.put(a, a)
    assert len(c) <= cap
    # most recent access must be resident
    assert accesses[-1] in c


# --------------------------------------------------------------- offload ---
def _store(e=3, d=64, f=128):
    rng = np.random.default_rng(0)
    moe = {
        "we_gate": rng.normal(size=(e, d, f)).astype(np.float32) * 0.1,
        "we_up": rng.normal(size=(e, d, f)).astype(np.float32) * 0.1,
        "we_down": rng.normal(size=(e, f, d)).astype(np.float32) * 0.1,
    }
    moe_j = {k: jnp.asarray(v) for k, v in moe.items()}
    thr = np.full((e,), 0.5, np.float32)
    return moe, build_expert_store(moe_j, thr, bits=2, group=64)


def test_compact_layout_roundtrip():
    moe, store = _store()
    idx = np.array([3, 17, 90])
    gate_cols, down_rows = store.fetch_sparse(1, idx)
    np.testing.assert_allclose(np.asarray(gate_cols),
                               moe["we_gate"][1][:, idx].T, atol=1e-3)
    np.testing.assert_allclose(np.asarray(down_rows),
                               moe["we_down"][1][idx, :], atol=1e-3)


def test_fetch_dense_layout():
    moe, store = _store()
    wg, wu, wd = store.fetch_dense(2)
    np.testing.assert_allclose(np.asarray(wg), moe["we_gate"][2], atol=1e-3)
    np.testing.assert_allclose(np.asarray(wd), moe["we_down"][2], atol=1e-3)
    # up is INT2-dequantized: same shape, correlated
    assert wu.shape == moe["we_up"][2].shape


def test_transfer_accounting():
    _, store = _store()
    store.fetch_sparse(0, np.arange(10))
    log = store.log
    assert log.transfers == 1
    assert log.bytes_moved == 10 * 2 * 64 * 2  # records are f16
    assert log.modeled_seconds > 0


def test_compressed_smaller_than_dense():
    _, store = _store()
    assert store.compressed_expert_bytes(0.2) < store.dense_expert_bytes() / 3


# ------------------------------------------------------------ link model ---
def test_link_chunk_tradeoff_u_shape():
    """Few huge chunks and many tiny chunks are both worse than a middle
    ground once packing overlap is considered (paper Fig. 7)."""
    link = LinkModel()
    total = 20 * 1024 * 1024
    times = {n: link.transfer_time(total, n) for n in (1, 8, 64, 4096)}
    assert times[4096] > times[64]  # launch-overhead-bound
    assert times[1] > times[8] or times[1] > times[64]  # packing-bound


def test_pinned_faster_than_pageable():
    link = LinkModel()
    assert link.transfer_time(1 << 20, 4, pinned=True) < \
        link.transfer_time(1 << 20, 4, pinned=False)


def test_effective_bw_saturates():
    link = LinkModel()
    bw = link.effective_bw(100 << 20, 50)
    assert 0.5 * link.peak_bw < bw <= link.peak_bw
