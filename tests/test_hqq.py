"""HQQ quantization: round-trip quality, packing, and properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hqq


def _w(key=0, m=128, n=64, scale=0.05):
    return jax.random.normal(jax.random.PRNGKey(key), (m, n)) * scale


def test_error_monotone_in_bits():
    w = _w()
    errs = [hqq.rel_error(w, hqq.quantize(w, bits=b, group=64))
            for b in (8, 4, 3, 2, 1)]
    assert all(a < b for a, b in zip(errs, errs[1:])), errs


def test_int8_is_accurate():
    w = _w()
    assert hqq.rel_error(w, hqq.quantize(w, bits=8, group=64)) < 0.01


def test_half_quadratic_beats_naive_rounding():
    w = _w(3)
    for bits in (2, 1):
        opt = hqq.rel_error(w, hqq.quantize(w, bits=bits, group=64, iters=20))
        naive = hqq.rel_error(w, hqq.quantize(w, bits=bits, group=64, iters=0))
        assert opt <= naive + 1e-6, (bits, opt, naive)


@given(bits=st.sampled_from([1, 2, 4, 8]),
       g=st.sampled_from([32, 64]),
       rows=st.sampled_from([64, 128, 192]),
       cols=st.sampled_from([8, 128]))
@settings(max_examples=12, deadline=None)
def test_pack_unpack_roundtrip(bits, g, rows, cols):
    key = jax.random.PRNGKey(rows * cols + bits)
    codes = jax.random.randint(key, (rows // g, g, cols), 0, 2 ** bits
                               ).astype(jnp.uint8)
    packed = hqq._pack(codes, bits) if bits < 8 else codes
    un = hqq._unpack(packed, bits, g) if bits < 8 else packed
    np.testing.assert_array_equal(np.asarray(un), np.asarray(codes))


def test_dequant_within_one_scale_step():
    """|W - dequant| <= scale per element (INT4, after optimization)."""
    w = _w(5)
    qt = hqq.quantize(w, bits=4, group=64)
    wr = hqq.dequantize(qt, jnp.float32)
    scale = np.repeat(np.asarray(qt.scale), 64, axis=1).reshape(w.shape)
    assert np.all(np.abs(np.asarray(w) - np.asarray(wr)) <= scale * 1.01)


def test_expert_stack_vmap_consistency():
    we = jax.random.normal(jax.random.PRNGKey(1), (3, 128, 64)) * 0.05
    qte = hqq.quantize_per_expert(we, bits=2, group=64)
    for e in range(3):
        ref = hqq.dequantize(hqq.quantize(we[e], bits=2, group=64), jnp.float32)
        got = hqq.dequantize_expert(qte, e, jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)


def test_compression_ratio_int2():
    w = _w(m=256, n=256)
    qt = hqq.quantize(w, bits=2, group=64)
    # 2 bits + scale/zero overhead vs 16-bit dense
    assert 4.0 < hqq.compression_ratio(w, qt) < 8.0


def test_quantize_rejects_bad_group():
    with pytest.raises(AssertionError):
        hqq.quantize(_w(m=100), bits=2, group=64)
