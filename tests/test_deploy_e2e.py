"""Deploy acceptance pins.

* ``build(spec)`` decode is BITWISE-identical to the equivalent
  kwargs-constructed ``FloEPipeline`` / ``ServingController`` (same
  clocks too) — the one-build-path guarantee.
* A two-model ``build_fleet`` over ONE shared HostTier/DiskTier
  completes; footprint-aware admission rejects a model whose plan
  cannot fit with a typed :class:`AdmissionError`; suspending an idle
  model evicts its pinned set and frees ledger headroom.
* The serve CLI drives everything from a spec file.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import (FloEPipeline, _unstack_layers,
                                 paper_scaled_models)
from repro.deploy import (AdmissionError, DeploymentSpec, ModelSpec,
                          ResourceSpec, RuntimeSpec, ServingSpec,
                          SpecError, build, build_fleet)
from repro.deploy.builder import calibrate_thresholds
from repro.models import transformer as tf
from repro.store import floor_bytes


@pytest.fixture(scope="module")
def small_moe():
    spec = DeploymentSpec(model=ModelSpec(arch="mixtral-8x7b", layers=4,
                                          d_model=128))
    cfg = spec.resolve_config()
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    thr = calibrate_thresholds(_unstack_layers(params, cfg), cfg)
    return spec, cfg, params, thr


def _fleet_spec(name, seed, vram_gb, **res):
    return DeploymentSpec(
        name=name,
        model=ModelSpec(arch="mixtral-8x7b", layers=4, d_model=128,
                        max_experts=8, seed=seed),
        resources=ResourceSpec(vram_gb=vram_gb, host_gb=0.001,
                               ladder=("int2",), **res),
        runtime=RuntimeSpec(use_runtime=True))


# --------------------------------------------------- spec == kwargs parity --
def test_build_matches_kwargs_pipeline_bitwise(small_moe):
    """Acceptance pin: spec-built decode == kwargs-built decode, bitwise,
    with identical measured clocks."""
    spec, cfg, params, thr = small_moe
    dep = build(spec, params=params, thresholds=thr)
    device, link = paper_scaled_models(cfg)
    pipe = FloEPipeline(params, cfg, thresholds=thr, device=device,
                        link=link, mode="floe", use_runtime=True,
                        cache_slots=4, lookahead=2)
    hs = dep.h_stream(4, batch=2)
    for h in hs:
        a, _ = dep.pipeline.decode_token(h)
        b, _ = pipe.decode_token(h)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert dep.pipeline.sched.clock == pipe.sched.clock
    for ma, mb in zip(dep.pipeline.metrics, pipe.metrics):
        assert ma.stall_s == mb.stall_s
        assert ma.prefetch_s == mb.prefetch_s


def test_build_matches_kwargs_pipeline_tiered(small_moe):
    """Same pin through the tiered store: spec-planned formats/pins and
    a hand-run plan_store produce identical decode + timeline."""
    from repro.store import measure_frequencies, plan_store
    spec, cfg, params, thr = small_moe
    vram_gb = 1.2 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    tiered = DeploymentSpec(
        model=spec.model,
        resources=ResourceSpec(vram_gb=vram_gb, host_gb=0.05,
                               ladder=("int2",)),
        runtime=RuntimeSpec(use_runtime=True))
    dep = build(tiered, params=params, thresholds=thr)
    device, link = paper_scaled_models(cfg)
    layers = _unstack_layers(params, cfg)
    freqs = measure_frequencies(layers, cfg)
    plan = plan_store(cfg, freqs, vram_gb=vram_gb, host_gb=0.05,
                      ladder=("int2",))
    pipe = FloEPipeline(params, cfg, thresholds=thr, device=device,
                        link=link, mode="floe", use_runtime=True,
                        store_plan=plan, store_freqs=freqs)
    for h in dep.h_stream(3):
        a, _ = dep.pipeline.decode_token(h)
        b, _ = pipe.decode_token(h)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert dep.pipeline.sched.clock == pipe.sched.clock
    assert dep.plan.formats == plan.formats
    assert dep.plan.pinned == plan.pinned


def test_build_matches_kwargs_controller(small_moe):
    """Spec-built controller tokens/clock == kwargs-built controller."""
    from repro.serving import ServingController, SLORequest
    spec, cfg, params, thr = small_moe

    def submit_all(ctl):
        for i in range(3):
            ctl.submit(SLORequest(i, np.arange(4, dtype=np.int32),
                                  max_new_tokens=3, slo_ms=60_000.0,
                                  arrival_t=0.05 * i))
        ctl.run()
        return {r.uid: r.output for r in ctl.completed}, ctl.sched.clock

    served = DeploymentSpec(
        model=spec.model,
        runtime=RuntimeSpec(use_runtime=True),
        serving=ServingSpec(slots=2, max_len=64, online_train=False))
    dep = build(served, params=params, thresholds=thr)
    out_spec, t_spec = submit_all(dep.controller)

    device, link = paper_scaled_models(cfg)
    ctl = ServingController(params, cfg, thresholds=thr, slots=2,
                            max_len=64, online_train=False,
                            offload_opts=dict(device=device, link=link,
                                              cache_slots=4))
    out_kw, t_kw = submit_all(ctl)
    assert out_spec == out_kw
    assert t_spec == t_kw


# --------------------------------------------------------------- the fleet --
def test_fleet_two_models_share_tiers_and_reject_oversize():
    """Acceptance pin: a two-model fleet over ONE shared HostTier/DiskTier
    completes decode for both models, and admission rejects (typed
    error) a third model whose plan cannot fit."""
    probe = _fleet_spec("probe", 0, 1.0)
    vg = 1.2 * floor_bytes(probe.resolve_config(), ("int2",)) / 2 ** 30
    sa, sb = _fleet_spec("a", 0, vg), _fleet_spec("b", 1, vg)
    fleet = build_fleet([sa, sb], vram_gb_per_device=2.5 * vg,
                        host_gb=0.002)
    assert list(fleet.members) == ["a", "b"]
    # ONE shared substrate under both models
    pa = fleet["a"].deployment.pipeline
    pb = fleet["b"].deployment.pipeline
    assert pa.host_tier is pb.host_tier
    assert pa.host_tier.disk is pb.host_tier.disk
    assert pa.engine is pb.engine
    # but DISJOINT per-device arenas
    assert pa.device_pools[0] is not pb.device_pools[0]

    ma = fleet.generate("a", tokens=2, batch=2)
    mb = fleet.generate("b", tokens=2, batch=2)
    assert len(ma) == len(mb) == 2
    # clocks stay lockstep across models (shared link timelines)
    assert pa.sched.clock == pb.sched.clock
    rep = fleet.report()
    assert rep["host_bytes_in_use"] <= rep["host_capacity_bytes"]
    # each model's records are scoped by its prefix in the shared tier
    assert rep["models"]["a"]["host_resident_bytes"] > 0
    assert rep["models"]["b"]["host_resident_bytes"] > 0

    # a third identical model cannot fit the remaining footprint
    with pytest.raises(AdmissionError) as ei:
        build_fleet([sa, sb, _fleet_spec("c", 2, vg)],
                    vram_gb_per_device=2.5 * vg, host_gb=0.01)
    assert ei.value.field == "fleet.c"
    assert "footprint" in str(ei.value)


def test_fleet_host_share_admission():
    """Admission is host-aware too: two models whose host shares exceed
    the shared tier's capacity are rejected at the host check."""
    probe = _fleet_spec("probe", 0, 1.0)
    vg = 1.2 * floor_bytes(probe.resolve_config(), ("int2",)) / 2 ** 30
    with pytest.raises(AdmissionError) as ei:
        build_fleet([_fleet_spec("a", 0, vg), _fleet_spec("b", 1, vg)],
                    vram_gb_per_device=2.5 * vg, host_gb=0.0005)
    assert "host share" in str(ei.value)


def test_fleet_suspend_evicts_pinned_and_frees_headroom():
    """Idle-model pinned-set eviction: suspend() drops the pinned staged
    slices (arena slabs return to the pool), the ledger credits the
    bytes back, and resume() re-stages and decodes correctly."""
    probe = _fleet_spec("probe", 0, 1.0)
    # leave pinning ON (default plan spend) so there is a pinned set
    vg = 1.5 * floor_bytes(probe.resolve_config(), ("int2",)) / 2 ** 30
    sa, sb = _fleet_spec("a", 0, vg), _fleet_spec("b", 1, vg)
    fleet = build_fleet([sa, sb], vram_gb_per_device=2.6 * vg,
                        host_gb=0.002)
    m = fleet["a"]
    assert sum(len(p) for p in m.plan.pinned_per_device) > 0
    pipe = m.deployment.pipeline
    free_before = pipe.device_pools[0].free_slabs
    committed_before = fleet.committed[0]

    freed = fleet.suspend("a")
    assert freed > 0
    assert pipe.device_pools[0].free_slabs > free_before
    assert fleet.committed[0] == committed_before - freed
    assert not fleet["a"].active
    with pytest.raises(SpecError):
        fleet.generate("a", tokens=1)
    # the other model keeps serving while "a" is idle
    fleet.generate("b", tokens=1)

    fleet.resume("a")
    assert fleet.committed[0] == committed_before
    # pinned entries are staged again and decode works
    for d, pins in enumerate(m.plan.pinned_per_device):
        for (li, e) in pins:
            assert (li, e) in pipe.cluster_residency[d][li]
    fleet.generate("a", tokens=1)
    for pool in pipe.device_pools:
        pool.check_invariants()


def test_fleet_spec_errors():
    probe = _fleet_spec("probe", 0, 1.0)
    vg = 1.2 * floor_bytes(probe.resolve_config(), ("int2",)) / 2 ** 30
    flat = DeploymentSpec(name="flat",
                          model=ModelSpec(arch="mixtral-8x7b", layers=4,
                                          d_model=128, max_experts=8),
                          runtime=RuntimeSpec(use_runtime=True))
    with pytest.raises(SpecError):  # fleet members need a tiered store
        build_fleet([flat], vram_gb_per_device=1.0, host_gb=0.01)
    with pytest.raises(SpecError):  # duplicate labels
        build_fleet([_fleet_spec("a", 0, vg), _fleet_spec("a", 1, vg)],
                    vram_gb_per_device=2.5 * vg, host_gb=0.01)


def test_fleet_two_devices_links_shared():
    """A 2-device fleet: both models' traffic lands on the SAME two
    per-device link timelines (one ClusterEngine), clocks lockstep."""
    probe = _fleet_spec("probe", 0, 1.0, devices=2)
    vg = 1.2 * floor_bytes(probe.resolve_config(), ("int2",)) / 2 ** 30
    fleet = build_fleet(
        [_fleet_spec("a", 0, vg, devices=2, replicate=1),
         _fleet_spec("b", 1, vg, devices=2, replicate=1)],
        vram_gb_per_device=2.5 * vg, host_gb=0.002)
    fleet.generate("a", tokens=2, batch=4)
    fleet.generate("b", tokens=2, batch=4)
    eng = fleet.engine
    assert {r.device for r in eng.records} == {0, 1}
    clocks = [s.clock for m in fleet.members.values()
              for s in m.deployment.pipeline.sched.devs]
    assert max(clocks) - min(clocks) <= 1e-9


# ----------------------------------------------------------------- the CLI --
def test_serve_cli_from_spec_file(tmp_path, monkeypatch, capsys):
    """`serve.py --spec deploy.json` drives the whole build from a file."""
    from repro.launch import serve
    spec = DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=128),
        resources=ResourceSpec(vram_gb=0.0012, host_gb=0.05),
        runtime=RuntimeSpec(use_runtime=True))
    path = tmp_path / "deploy.json"
    path.write_text(spec.to_json())
    monkeypatch.setattr(sys, "argv",
                        ["serve.py", "--spec", str(path), "--max_new", "4"])
    serve.main()
    out = capsys.readouterr().out
    assert "store plan:" in out
    assert "mode=floe:" in out and "tok/s" in out


def test_serve_cli_dump_spec(monkeypatch, capsys):
    from repro.launch import serve
    monkeypatch.setattr(sys, "argv", [
        "serve.py", "--arch", "mixtral-8x7b", "--reduced", "--mode", "floe",
        "--layers", "2", "--vram-gb", "0.0012", "--dump-spec"])
    serve.main()
    out = capsys.readouterr().out
    spec = DeploymentSpec.from_json(out)
    assert spec.resources.vram_gb == 0.0012
    assert spec.runtime.use_runtime
