"""repro.workload: scenario validation, determinism, traffic shapes.

Fast (no jax model): everything here runs on the generator itself —
spec validation matrix and JSON round-trip, replay byte-determinism of
saved traces, statistical pins on the diurnal/burst arrival envelopes
(via the `_hypothesis_compat` property shim), session-affinity prefix
reuse, drift monotonicity, and central uid allocation.
"""
import json
import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.deploy.spec import SpecError
from repro.workload import (ArrivalSpec, BurstSpec, DriftSpec, ScenarioSpec,
                            TenantSpec, WorkloadError, generate_requests,
                            load_trace, rotation_offset, save_trace,
                            tenant_token_probs, trace_str)
from repro.workload.generate import _peak_rate, instantaneous_rate

VOCAB = 128


def _spec(**kw):
    base = dict(name="t", seed=5, n_requests=20)
    base.update(kw)
    return ScenarioSpec(**base)


# ------------------------------------------------------------- validation --
@pytest.mark.parametrize("kw,field", [
    (dict(name=""), "scenario.name"),
    (dict(seed=-1), "scenario.seed"),
    (dict(n_requests=0), "scenario.n_requests"),
    (dict(duration_s=0.0), "scenario.duration_s"),
    (dict(arrival=ArrivalSpec(kind="weekly")), "arrival.kind"),
    (dict(arrival=ArrivalSpec(rate=0.0)), "arrival.rate"),
    (dict(arrival=ArrivalSpec(kind="diurnal", amplitude=1.0)),
     "arrival.amplitude"),
    (dict(arrival=ArrivalSpec(kind="diurnal", period_s=0.0)),
     "arrival.period_s"),
    (dict(arrival=ArrivalSpec(bursts=(BurstSpec(multiplier=0.0),))),
     "arrival.bursts[0].multiplier"),
    (dict(arrival=ArrivalSpec(bursts=(BurstSpec(duration_s=0.0),))),
     "arrival.bursts[0].duration_s"),
    (dict(arrival=ArrivalSpec(bursts=(BurstSpec(start_t=-1.0),))),
     "arrival.bursts[0].start_t"),
    (dict(tenants=()), "tenants"),
    (dict(tenants=(TenantSpec(name=""),)), "tenants[0].name"),
    (dict(tenants=(TenantSpec(), TenantSpec())), "tenants[1].name"),
    (dict(tenants=(TenantSpec(weight=0.0),)), "tenants[0].weight"),
    (dict(tenants=(TenantSpec(slo_ms=0.0),)), "tenants[0].slo_ms"),
    (dict(tenants=(TenantSpec(prompt_len_min=0),)),
     "tenants[0].prompt_len_min"),
    (dict(tenants=(TenantSpec(prompt_len_max=4),)),
     "tenants[0].prompt_len_max"),
    (dict(tenants=(TenantSpec(max_new_max=2),)), "tenants[0].max_new_max"),
    (dict(tenants=(TenantSpec(temperature=-0.1),)),
     "tenants[0].temperature"),
    (dict(tenants=(TenantSpec(session_len=0),)), "tenants[0].session_len"),
    (dict(tenants=(TenantSpec(think_time_s=-1.0),)),
     "tenants[0].think_time_s"),
    (dict(tenants=(TenantSpec(router_bias=-0.5),)),
     "tenants[0].router_bias"),
    (dict(tenants=(TenantSpec(bias_seed=-1),)), "tenants[0].bias_seed"),
    (dict(drift=DriftSpec(kind="sideways")), "drift.kind"),
    (dict(drift=DriftSpec(kind="rotate", strength=0.0)), "drift.strength"),
    (dict(drift=DriftSpec(kind="rotate", strength=1.5)), "drift.strength"),
    (dict(drift=DriftSpec(kind="rotate", period_s=0.0)), "drift.period_s"),
    (dict(drift=DriftSpec(kind="phase", at_t=-1.0)), "drift.at_t"),
])
def test_validation_matrix(kw, field):
    with pytest.raises(SpecError) as e:
        _spec(**kw)
    assert e.value.field == field


def test_valid_spec_constructs():
    s = _spec(
        arrival=ArrivalSpec(kind="diurnal", rate=2.0, amplitude=0.5,
                            bursts=(BurstSpec(),)),
        tenants=(TenantSpec(name="a"), TenantSpec(name="b", bias_seed=1)),
        drift=DriftSpec(kind="rotate"))
    assert s.n_requests == 20


def test_json_round_trip_exact():
    s = _spec(
        duration_s=90.0,
        arrival=ArrivalSpec(kind="diurnal", rate=1.5, period_s=45.0,
                            amplitude=0.25, phase=0.1,
                            bursts=(BurstSpec(start_t=3.0, duration_s=2.0,
                                              multiplier=6.0),)),
        tenants=(TenantSpec(name="chat", weight=2.5, session_len=3),
                 TenantSpec(name="code", bias_seed=9, router_bias=0.3)),
        drift=DriftSpec(kind="phase", at_t=40.0))
    assert ScenarioSpec.from_json(s.to_json()) == s
    # and the rendering itself is stable (sorted keys, fixed indent)
    assert s.to_json() == ScenarioSpec.from_json(s.to_json()).to_json()


def test_from_dict_rejects_unknown_fields():
    d = _spec().to_dict()
    d["extra"] = 1
    with pytest.raises(SpecError):
        ScenarioSpec.from_dict(d)
    d2 = _spec().to_dict()
    d2["arrival"]["surge"] = 2.0
    with pytest.raises(SpecError):
        ScenarioSpec.from_dict(d2)


def test_generate_rejects_tiny_vocab():
    with pytest.raises(WorkloadError):
        generate_requests(_spec(), vocab_size=1)


# ----------------------------------------------------------- determinism --
def test_generation_deterministic_and_sorted():
    s = _spec(arrival=ArrivalSpec(kind="diurnal", rate=3.0, amplitude=0.4),
              tenants=(TenantSpec(name="a", session_len=2),
                       TenantSpec(name="b", bias_seed=3)))
    a = generate_requests(s, VOCAB)
    b = generate_requests(s, VOCAB)
    assert trace_str(s, a) == trace_str(s, b)
    assert len(a) == s.n_requests
    assert all(x.arrival_t <= y.arrival_t for x, y in zip(a, a[1:]))
    # different seed -> different stream
    c = generate_requests(_spec(seed=6), VOCAB)
    assert trace_str(s, a) != trace_str(_spec(seed=6), c)


def test_uid_allocation_central_and_unique():
    s = _spec(n_requests=50, tenants=(TenantSpec(session_len=4),))
    a = generate_requests(s, VOCAB)
    assert [r.uid for r in a] == list(range(50))
    b = generate_requests(s, VOCAB, uid_base=len(a))
    uids = [r.uid for r in a] + [r.uid for r in b]
    assert len(set(uids)) == len(uids) == 100


def test_trace_replay_byte_deterministic(tmp_path):
    s = _spec(tenants=(TenantSpec(name="chat", session_len=3),
                       TenantSpec(name="code", bias_seed=2)))
    reqs = generate_requests(s, VOCAB)
    p1, p2 = tmp_path / "t1.json", tmp_path / "t2.json"
    save_trace(p1, s, reqs)
    spec2, reqs2 = load_trace(p1)
    save_trace(p2, spec2, reqs2)
    assert p1.read_bytes() == p2.read_bytes()
    assert spec2 == s
    for r, r2 in zip(reqs, reqs2):
        assert (r.uid, r.tenant, r.arrival_t, r.slo_ms, r.max_new_tokens,
                r.temperature) == (r2.uid, r2.tenant, r2.arrival_t,
                                   r2.slo_ms, r2.max_new_tokens,
                                   r2.temperature)
        assert np.array_equal(r.prompt, r2.prompt)


def test_committed_example_scenarios_load_and_generate():
    import os
    d = os.path.join(os.path.dirname(__file__), os.pardir,
                     "examples", "scenarios")
    names = sorted(os.listdir(d))
    assert {"diurnal_mix.json", "flash_crowd.json",
            "drift_rotate.json"} <= set(names)
    for fname in names:
        spec = ScenarioSpec.load(os.path.join(d, fname))
        reqs = generate_requests(spec, VOCAB)
        assert len(reqs) == spec.n_requests
        # committed artifacts are in canonical rendering already
        with open(os.path.join(d, fname)) as f:
            assert f.read() == spec.to_json()


# ------------------------------------------------------- arrival envelope --
@settings(max_examples=12, deadline=None)
@given(rate=st.floats(min_value=0.5, max_value=8.0),
       amplitude=st.floats(min_value=0.0, max_value=0.9))
def test_diurnal_rate_envelope(rate, amplitude):
    s = _spec(arrival=ArrivalSpec(kind="diurnal", rate=rate,
                                  period_s=50.0, amplitude=amplitude))
    peak = _peak_rate(s)
    for t in np.linspace(0.0, 150.0, 61):
        r = instantaneous_rate(s, float(t))
        assert 0.0 < r <= peak + 1e-12
        expect = rate * (1.0 + amplitude * math.sin(2 * math.pi * t / 50.0))
        assert r == pytest.approx(expect)


def test_diurnal_arrivals_concentrate_at_rate_peaks():
    # period 100s, phase 0: rate peaks in (0, 50), troughs in (50, 100).
    s = _spec(seed=2, n_requests=400, duration_s=100.0,
              arrival=ArrivalSpec(kind="diurnal", rate=8.0,
                                  period_s=100.0, amplitude=0.9))
    reqs = generate_requests(s, VOCAB)
    first = sum(1 for r in reqs if r.arrival_t % 100.0 < 50.0)
    second = len(reqs) - first
    assert first > 1.5 * second, (first, second)


def test_burst_multiplies_local_arrival_density():
    base = _spec(seed=3, n_requests=600, duration_s=60.0,
                 arrival=ArrivalSpec(kind="poisson", rate=5.0))
    burst = _spec(seed=3, n_requests=600, duration_s=60.0,
                  arrival=ArrivalSpec(kind="poisson", rate=5.0,
                                      bursts=(BurstSpec(start_t=20.0,
                                                        duration_s=10.0,
                                                        multiplier=6.0),)))
    def in_window(reqs):
        return sum(1 for r in reqs if 20.0 <= r.arrival_t < 30.0)
    n_base = in_window(generate_requests(base, VOCAB))
    n_burst = in_window(generate_requests(burst, VOCAB))
    assert n_burst > 3 * max(n_base, 1), (n_burst, n_base)


# --------------------------------------------------------------- tenants --
def test_tenant_mix_follows_weights():
    s = _spec(seed=4, n_requests=300,
              tenants=(TenantSpec(name="heavy", weight=4.0),
                       TenantSpec(name="light", weight=1.0, bias_seed=1)))
    reqs = generate_requests(s, VOCAB)
    heavy = sum(1 for r in reqs if r.tenant == "heavy")
    assert 0.65 < heavy / len(reqs) < 0.95
    # every request carries its tenant's SLO / length envelope
    for r in reqs:
        assert r.tenant in ("heavy", "light")
        assert 8 <= len(r.prompt) <= 16
        assert 4 <= r.max_new_tokens <= 8


def test_session_affinity_shares_prefix():
    s = _spec(seed=9, n_requests=60,
              tenants=(TenantSpec(name="chat", session_len=4,
                                  think_time_s=0.2),))
    reqs = generate_requests(s, VOCAB)
    # group by identical leading prompt_len_min tokens: sessions of >1
    # request MUST exist and share the prefix
    pref = {}
    for r in reqs:
        pref.setdefault(tuple(r.prompt[:8]), []).append(r)
    multi = [g for g in pref.values() if len(g) > 1]
    assert multi, "no multi-request sessions generated"
    for g in multi:
        # think-time gaps: later requests in the session arrive later
        ts = sorted(r.arrival_t for r in g)
        assert ts == [r.arrival_t for r in sorted(g,
                                                  key=lambda r: r.arrival_t)]
        p0 = tuple(g[0].prompt[:8])
        assert all(tuple(r.prompt[:8]) == p0 for r in g)


def test_tenant_bias_separates_token_distributions():
    s = _spec(tenants=(TenantSpec(name="a", bias_seed=0),
                       TenantSpec(name="b", bias_seed=1)))
    pa = tenant_token_probs(s, s.tenants[0], VOCAB, 0.0)
    pb = tenant_token_probs(s, s.tenants[1], VOCAB, 0.0)
    assert pa.shape == pb.shape == (VOCAB,)
    assert pa.sum() == pytest.approx(1.0) and pb.sum() == pytest.approx(1.0)
    # same Zipf shape, different permutation -> same sorted weights,
    # different placement
    assert np.allclose(np.sort(pa), np.sort(pb))
    assert not np.allclose(pa, pb)


# ----------------------------------------------------------------- drift --
def test_rotation_offset_monotone_and_zero_without_drift():
    s = _spec(drift=DriftSpec(kind="rotate", period_s=25.0, strength=0.5))
    offs = [rotation_offset(s, t, VOCAB) for t in np.linspace(0, 200, 81)]
    assert offs == sorted(offs)
    assert offs[0] == 0 and offs[-1] > 0
    s0 = _spec()
    assert all(rotation_offset(s0, t, VOCAB) == 0 for t in (0.0, 50.0))


def test_rotate_drift_moves_distribution_gradually():
    s = _spec(drift=DriftSpec(kind="rotate", period_s=50.0, strength=0.5),
              tenants=(TenantSpec(name="a", router_bias=1.5),))
    p0 = tenant_token_probs(s, s.tenants[0], VOCAB, 0.0)
    p_mid = tenant_token_probs(s, s.tenants[0], VOCAB, 60.0)
    p_far = tenant_token_probs(s, s.tenants[0], VOCAB, 140.0)
    tv_mid = 0.5 * np.abs(p0 - p_mid).sum()
    tv_far = 0.5 * np.abs(p0 - p_far).sum()
    assert 0.0 < tv_mid
    assert np.allclose(np.sort(p0), np.sort(p_mid))  # shape preserved


def test_phase_drift_is_abrupt():
    s = _spec(drift=DriftSpec(kind="phase", at_t=30.0),
              tenants=(TenantSpec(name="a"),))
    before = tenant_token_probs(s, s.tenants[0], VOCAB, 29.9)
    before2 = tenant_token_probs(s, s.tenants[0], VOCAB, 0.0)
    after = tenant_token_probs(s, s.tenants[0], VOCAB, 30.0)
    after2 = tenant_token_probs(s, s.tenants[0], VOCAB, 200.0)
    assert np.allclose(before, before2)   # static before the switch
    assert np.allclose(after, after2)     # static after the switch
    assert not np.allclose(before, after)  # the switch itself
