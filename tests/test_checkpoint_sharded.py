"""Checkpoint extensions: QTensor pytree-node round-trip + sharded layout."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (ShardReader, ShardWriter, load_checkpoint,
                              save_checkpoint, save_sharded)
from repro.core import hqq


def _qt(key=0, m=128, n=64, bits=2):
    w = jax.random.normal(jax.random.PRNGKey(key), (m, n)) * 0.05
    return w, hqq.quantize(w, bits=bits, group=64)


# ------------------------------------------------- QTensor pytree nodes ----
def test_qtensor_roundtrip_flat_checkpoint(tmp_path):
    w, qt = _qt()
    p = tmp_path / "qt.ckpt"
    assert save_checkpoint(p, {"up_q": qt}) > 0
    back = load_checkpoint(p)["up_q"]
    assert isinstance(back, hqq.QTensor)
    # sub-byte packed codes survive bit-exactly
    np.testing.assert_array_equal(np.asarray(qt.packed), back.packed)
    np.testing.assert_array_equal(np.asarray(qt.scale), back.scale)
    assert back.scale.dtype == np.float16
    # frozen-dataclass aux data keeps python types (ints + tuple shape)
    assert back.bits == 2 and back.group == 64
    assert back.shape == (128, 64) and isinstance(back.shape, tuple)
    assert isinstance(back.shape[0], int)
    # dequantization equivalence
    np.testing.assert_array_equal(
        np.asarray(hqq.dequantize(qt, jnp.float32)),
        np.asarray(hqq.dequantize(back, jnp.float32)))


def test_qtensor_nested_in_params_tree(tmp_path):
    _, qt = _qt(1)
    tree = {"layer0": {"moe": {"up_q": qt,
                               "router": np.ones((4, 2), np.float32)},
                       "names": ("a", 3)},
            "stack": [qt, {"t": np.arange(3)}]}
    p = tmp_path / "nested.ckpt"
    save_checkpoint(p, tree)
    back = load_checkpoint(p)
    assert isinstance(back["layer0"]["moe"]["up_q"], hqq.QTensor)
    assert isinstance(back["stack"][0], hqq.QTensor)
    np.testing.assert_array_equal(np.asarray(qt.zero),
                                  back["stack"][0].zero)
    np.testing.assert_array_equal(back["stack"][1]["t"], np.arange(3))


def test_qtensor_per_expert_stack_roundtrip(tmp_path):
    """The shape actually checkpointed: vmapped (E, ...) QTensor stacks."""
    we = jax.random.normal(jax.random.PRNGKey(2), (3, 128, 64)) * 0.05
    qte = hqq.quantize_per_expert(we, bits=4, group=64)
    p = tmp_path / "stack.ckpt"
    save_checkpoint(p, {"up_q": qte})
    back = load_checkpoint(p)["up_q"]
    for e in range(3):
        np.testing.assert_array_equal(
            np.asarray(hqq.dequantize_expert(qte, e, jnp.float32)),
            np.asarray(hqq.dequantize_expert(back, e, jnp.float32)))


# ------------------------------------------------------- sharded layout ----
def test_sharded_roundtrip_and_lazy_index(tmp_path):
    recs = {}
    for i in range(8):
        _, qt = _qt(10 + i, m=64, n=32)
        recs[f"L0.E{i}"] = {"up_q": qt, "idx": np.arange(i + 1)}
    total = save_sharded(tmp_path / "sh", recs)
    assert total > 0
    r = ShardReader(tmp_path / "sh")
    assert set(r.keys()) == set(recs)
    # single-record load: one decode, a fraction of the file's bytes
    one = r.load("L0.E5")
    assert r.records_decoded == 1
    assert r.bytes_read == r.nbytes("L0.E5")
    assert r.bytes_read < sum(r.nbytes(k) for k in r.keys())
    np.testing.assert_array_equal(one["idx"], np.arange(6))
    assert isinstance(one["up_q"], hqq.QTensor)
    np.testing.assert_array_equal(np.asarray(recs["L0.E5"]["up_q"].packed),
                                  one["up_q"].packed)


def test_shard_writer_rejects_duplicate_keys(tmp_path):
    import pytest
    with ShardWriter(tmp_path / "sh") as w:
        w.add("k", {"x": np.ones(2)})
        with pytest.raises(AssertionError):
            w.add("k", {"x": np.zeros(2)})


def test_sharded_bf16_leaves(tmp_path):
    x = jnp.asarray(np.linspace(-2, 2, 32), jnp.bfloat16)
    save_sharded(tmp_path / "sh", {"a": {"w": x}})
    back = ShardReader(tmp_path / "sh").load("a")["w"]
    assert str(back.dtype) == "bfloat16"
    np.testing.assert_array_equal(np.asarray(x, np.float32),
                                  np.asarray(back, np.float32))
