"""End-to-end tiered store: planner-driven decode, progressive precision,
the footprint↔stall tradeoff, and the serve.py CLI (acceptance pins)."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import reduced
from repro.configs import get_config
from repro.core import sparsify
from repro.core.pipeline import FloEPipeline, _unstack_layers, \
    paper_scaled_models
from repro.models import transformer as tf
from repro.store import (dense_residency_bytes, floor_bytes,
                         measure_frequencies, plan_store)


@pytest.fixture(scope="module")
def small_moe():
    cfg = reduced(get_config("mixtral_8x7b"), layers=2, d_model=128)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    layers = _unstack_layers(params, cfg)
    xcal = jax.random.normal(jax.random.PRNGKey(9), (96, cfg.d_model)) * 0.5
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    freqs = measure_frequencies(layers, cfg)
    return cfg, params, thr, freqs


def _decode(cfg, params, thr, freqs, plan, tmp, *, tokens=5):
    device, link = paper_scaled_models(cfg)
    pipe = FloEPipeline(params, cfg, thresholds=thr, use_runtime=True,
                        store_plan=plan, store_dir=str(tmp),
                        store_freqs=freqs, device=device, link=link)
    outs = []
    for i in range(tokens):
        h = jax.random.normal(jax.random.PRNGKey(100 + i),
                              (1, cfg.d_model), jnp.float32) * 0.3
        out, _ = pipe.decode_token(h)
        outs.append(np.asarray(out))
    return pipe, outs


def test_planned_decode_below_dense_footprint(small_moe, tmp_path):
    """Acceptance pin: a budget well below dense residency plans and runs
    a full decode through the tiered store."""
    cfg, params, thr, freqs = small_moe
    dense = dense_residency_bytes(cfg)
    plan = plan_store(cfg, freqs, vram_gb=0.55 * dense / 2 ** 30,
                      host_gb=0.05)
    assert plan.footprint_bytes() < 0.55 * dense
    pipe, outs = _decode(cfg, params, thr, freqs, plan, tmp_path / "s")
    assert all(np.all(np.isfinite(o)) for o in outs)
    assert pipe.sched.stats.demand_fetches + pipe.sched.stats.demand_hits > 0
    # quality knob: a lean budget approximates, a rich budget converges
    ref = FloEPipeline(params, cfg, thresholds=thr, mode="resident")
    h = jax.random.normal(jax.random.PRNGKey(100), (1, cfg.d_model),
                          jnp.float32) * 0.3
    out_ref, _ = ref.decode_token(h)

    def rel(o):
        return float(np.linalg.norm(o - np.asarray(out_ref)) /
                     (np.linalg.norm(np.asarray(out_ref)) + 1e-9))

    rich_plan = plan_store(cfg, freqs, vram_gb=0.95 * dense / 2 ** 30,
                           host_gb=0.05, max_pinned=0)
    _, outs_rich = _decode(cfg, params, thr, freqs, rich_plan,
                           tmp_path / "rich", tokens=1)
    assert rel(outs_rich[0]) < rel(outs[0]) < 1.2, \
        (rel(outs_rich[0]), rel(outs[0]))
    # device pool: arena intact after the full decode
    pipe.device_pool.check_invariants()
    assert pipe.device_pool.stats.allocs > 0


def test_pinned_experts_stay_resident(small_moe, tmp_path):
    cfg, params, thr, freqs = small_moe
    dense = dense_residency_bytes(cfg)
    plan = plan_store(cfg, freqs, vram_gb=dense / 2 ** 30, host_gb=0.05)
    assert plan.pinned
    pipe, _ = _decode(cfg, params, thr, freqs, plan, tmp_path / "s")
    for (li, e) in plan.pinned:
        ent = pipe.residency[li].peek((li, e))
        assert ent is not None, f"pinned ({li},{e}) was evicted"
        assert ent.ready_t == 0.0


def test_progressive_reduces_demand_stall(small_moe, tmp_path):
    """Acceptance pin: draft-then-refine beats single-shot full-format on
    demand stall at an identical plan."""
    cfg, params, thr, freqs = small_moe
    dense = dense_residency_bytes(cfg)
    gb = 0.5 * dense / 2 ** 30
    single = plan_store(cfg, freqs, vram_gb=gb, host_gb=0.05,
                        progressive=False, max_pinned=0)
    prog = plan_store(cfg, freqs, vram_gb=gb, host_gb=0.05,
                      progressive=True, max_pinned=0)
    pipe_s, _ = _decode(cfg, params, thr, freqs, single, tmp_path / "a")
    pipe_p, _ = _decode(cfg, params, thr, freqs, prog, tmp_path / "b")
    stall_s = sum(m.stall_s for m in pipe_s.metrics)
    stall_p = sum(m.stall_s for m in pipe_p.metrics)
    assert pipe_p.sched.stats.draft_fetches > 0
    assert pipe_s.sched.stats.draft_fetches == 0
    assert stall_p < stall_s, (stall_p, stall_s)


def test_footprint_stall_tradeoff_monotone(small_moe, tmp_path):
    """Acceptance pin: more VRAM -> never more stall (quality constant)."""
    cfg, params, thr, freqs = small_moe
    floor = floor_bytes(cfg, ("int2",))
    points = []
    for i, mult in enumerate((1.001, 1.4, 1.9)):
        plan = plan_store(cfg, freqs, vram_gb=mult * floor / 2 ** 30,
                          host_gb=0.05, ladder=("int2",))
        pipe, _ = _decode(cfg, params, thr, freqs, plan,
                          tmp_path / f"m{i}")
        points.append((plan.footprint_bytes(),
                       sum(m.stall_s for m in pipe.metrics)))
    for (fp0, st0), (fp1, st1) in zip(points, points[1:]):
        assert fp1 >= fp0
        assert st1 <= st0 * 1.001 + 1e-12, points


def test_disk_tier_exercised_under_tiny_host_budget(small_moe, tmp_path):
    cfg, params, thr, freqs = small_moe
    dense = dense_residency_bytes(cfg)
    plan = plan_store(cfg, freqs, vram_gb=0.6 * dense / 2 ** 30,
                      host_gb=3e-5)
    pipe, outs = _decode(cfg, params, thr, freqs, plan, tmp_path / "s")
    assert pipe.host_tier.disk.stats.reads > 0
    assert pipe.engine.summary()["disk_s"] > 0.0
    assert all(np.all(np.isfinite(o)) for o in outs)


def test_controller_over_tiered_store(small_moe, tmp_path):
    """The serving control plane decodes through the planned store."""
    from repro.serving import ServingController, SLORequest
    cfg, params, thr, freqs = small_moe
    device, link = paper_scaled_models(cfg)
    dense = dense_residency_bytes(cfg)
    plan = plan_store(cfg, freqs, vram_gb=0.55 * dense / 2 ** 30,
                      host_gb=0.05)
    ctl = ServingController(
        params, cfg, thresholds=thr, slots=2, max_len=64,
        online_train=False,
        offload_opts=dict(device=device, link=link, store_plan=plan,
                          store_dir=str(tmp_path / "s"),
                          store_freqs=freqs))
    for i in range(3):
        ctl.submit(SLORequest(i, np.arange(4, dtype=np.int32),
                              max_new_tokens=3, slo_ms=10_000.0,
                              arrival_t=0.1 * i))
    ctl.run()
    assert len(ctl.completed) == 3
    assert all(len(r.output) == 3 for r in ctl.completed)
    ctl.pipe.device_pool.check_invariants()


def test_serve_cli_vram_budget(small_moe, monkeypatch, capsys):
    """Acceptance pin: `launch/serve.py --vram-gb B` with B below the
    dense-residency footprint plans and runs a full decode."""
    from repro.launch import serve
    cfg, *_ = small_moe
    dense_gb = dense_residency_bytes(cfg) / 2 ** 30
    budget = 0.6 * dense_gb
    monkeypatch.setattr(sys, "argv", [
        "serve.py", "--arch", "mixtral-8x7b", "--reduced", "--mode", "floe",
        "--layers", "2", "--d_model", "128", "--max_new", "4",
        "--vram-gb", f"{budget:.6f}", "--host-gb", "0.05"])
    serve.main()
    out = capsys.readouterr().out
    assert "store plan:" in out
    assert "mode=floe:" in out and "tok/s" in out
    assert "store: demand_fetches=" in out
    # the plan honored the sub-dense budget
    line = [ln for ln in out.splitlines() if "store plan:" in ln][0]
    assert "footprint=" in line
