"""Golden-trace regression pin for the runtime scheduler.

One fixed-seed scenario — prefetches at mixed confidence/depth, clock
advances, demand fetches, reconcile cancellation/demotion, union demands
with top-ups — is run with a ``repro.obs`` Tracer attached, and the
UNIFIED EVENT STREAM (every ``transfer.start``/``transfer.complete``/
``demand.stall``/``residency.evict``/... the subsystems emit, plus the
final stats) is compared against ``tests/data/golden_trace.json``.

Pinning the bus output rather than raw engine records means the pin
covers both the timing model AND the instrumentation: a refactor that
shifts any event time, drops an emit site, or changes an attribution
segment must regenerate the file deliberately (run with
``GOLDEN_REGEN=1``) and justify the diff in review, instead of drifting
silently.
"""
import json
import os
import pathlib

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.offload import LinkModel, build_expert_store
from repro.runtime import ExpertScheduler, ResidencyManager, TransferEngine

GOLDEN = pathlib.Path(__file__).parent / "data" / "golden_trace.json"
_ROUND = 12  # decimal places: arithmetic is deterministic, repr is not


def _scenario(tracer=None):
    rng = np.random.default_rng(1234)
    e, d, f = 6, 16, 32
    moe = {
        "we_gate": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_up": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_down": jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32) * 0.1,
    }
    store = build_expert_store(moe, np.full((e,), 0.5, np.float32),
                               bits=2, group=16)
    res = [ResidencyManager(3, policy="weighted")]
    eng = TransferEngine(LinkModel(), num_buffers=2, chunk_channels=8)
    sched = ExpertScheduler([store], res, eng, lookahead=2,
                            depth_discount=0.5)

    consumers = [tracer] if tracer is not None else []
    # a fresh bus per run: event seq numbers restart at 0, so two runs
    # in one process produce identical streams
    with obs.use_bus(obs.EventBus()), obs.consumer(*consumers):
        # mixed-confidence speculation, one deep
        sched.enqueue_prefetch(0, 0, np.arange(12), 0.9, depth=1)
        sched.enqueue_prefetch(0, 1, np.arange(4, 20), 0.4, depth=1)
        sched.enqueue_prefetch(0, 2, np.arange(8), 0.8, depth=3)
        sched.pump()
        sched.advance(2e-4)

        # a straggler prediction that never reaches the link...
        sched.enqueue_prefetch(0, 4, np.arange(24), 0.3, depth=2)
        # ...true router: cancels queued 4, keeps 0/1; demand 3 (cold miss)
        sched.reconcile(0, [0, 1, 3])
        payload, miss = sched.demand_async(0, 3, lambda: np.arange(0, 32, 3))
        sched.wait_for(0, 3, was_miss=miss)

        # union demands: full hit on 0, top-up on 1, promoted-then-demand
        (idx0, _, _), m0 = sched.demand_union(0, 0, np.arange(6))
        sched.wait_for(0, 0, was_miss=m0)
        (idx1, _, _), m1 = sched.demand_union(0, 1, np.arange(0, 24))
        sched.wait_for(0, 1, was_miss=m1)
        sched.advance(5e-4)

        # second round: re-speculate, demote in flight
        sched.enqueue_prefetch(0, 2, np.arange(16), 0.7, depth=1)
        sched.pump()
        sched.reconcile(0, [0])
        sched.advance(1.0)
        # flush transfer.complete spans for anything still on the link
        eng.drain_events()
    return sched, eng


def _round(v):
    if isinstance(v, float):
        return round(v, _ROUND)
    if isinstance(v, dict):
        return {k: _round(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_round(x) for x in v]
    return v


def _trace():
    tracer = obs.Tracer()
    sched, eng = _scenario(tracer)
    events = []
    for ev in tracer.events:
        events.append({
            "seq": ev.seq,
            "t": round(ev.t, _ROUND),
            "name": ev.name,
            "cat": ev.cat,
            "dur": round(ev.dur, _ROUND),
            "device": ev.device,
            "args": _round(ev.args or {}),
        })
    s = sched.stats
    stats = {k: (round(v, _ROUND) if isinstance(v, float) else v)
             for k, v in vars(s).items()}
    return {"events": events, "stats": stats,
            "attribution": _round(sched.attribution.snapshot()),
            "clock": round(sched.clock, _ROUND)}


def test_golden_trace_event_for_event():
    got = _trace()
    if os.environ.get("GOLDEN_REGEN") or not GOLDEN.exists():
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(got, indent=1) + "\n")
    want = json.loads(GOLDEN.read_text())
    assert len(got["events"]) == len(want["events"]), \
        "event count changed — regenerate deliberately (GOLDEN_REGEN=1)"
    for i, (g, w) in enumerate(zip(got["events"], want["events"])):
        assert g == w, (f"event {i} drifted:\n got {g}\nwant {w}\n"
                        f"(GOLDEN_REGEN=1 to accept)")
    assert got["stats"] == want["stats"]
    assert got["attribution"] == want["attribution"]
    assert got["clock"] == want["clock"]


def test_golden_trace_is_deterministic():
    """The scenario itself must be bit-stable run-to-run, otherwise the
    golden pin would flake rather than catch drift."""
    assert _trace() == _trace()


def test_tracer_export_is_byte_identical():
    """Two identical simulated runs render byte-identical Perfetto JSON
    (sorted keys, sub-ns-rounded timestamps, seq-ordered events)."""
    t1, t2 = obs.Tracer(), obs.Tracer()
    _scenario(t1)
    _scenario(t2)
    assert t1.export_str() == t2.export_str()
    assert len(t1) > 0


def test_observation_does_not_perturb_the_run():
    """Tracing is observation-only: the timeline with a consumer
    attached is bitwise the timeline without one."""
    sched_on, eng_on = _scenario(obs.Tracer())
    sched_off, eng_off = _scenario(None)
    on = [(r.key, r.kind, r.start_t, r.complete_t, r.demoted)
          for r in eng_on.records]
    off = [(r.key, r.kind, r.start_t, r.complete_t, r.demoted)
           for r in eng_off.records]
    assert on == off
    assert vars(sched_on.stats) == vars(sched_off.stats)
    assert sched_on.clock == sched_off.clock


def test_golden_trace_covers_new_paths():
    """The pinned scenario must exercise cancellation, demotion, top-up,
    demand traffic, AND the emit sites — so drift in any of those paths
    trips the pin."""
    tracer = obs.Tracer()
    sched, eng = _scenario(tracer)
    s = sched.stats
    assert s.prefetch_cancelled >= 1
    assert s.prefetch_demoted >= 1
    assert s.demand_topups >= 1
    assert s.demand_fetches >= 1
    assert any(r.kind == "demand" for r in eng.records)
    assert any(r.demoted for r in eng.records)
    names = {ev.name for ev in tracer.events}
    assert "transfer.start" in names
    assert "transfer.complete" in names
    assert "demand.stall" in names
    # attribution conservation holds on the pinned scenario exactly
    assert sched.attribution.check_conservation(s.stall_s)
