"""repro.spec_exec — shadow formats/pricing, the divergence predictor,
shadow-bank fidelity, and the SpeculationSpec control-plane contract.

The end-to-end safety pins (off-is-noop and rollback-bitwise against a
never-speculated serve, stall/token win) live in
``benchmarks/bench_speculate.py``; the event-stream contract lives in
``tests/test_obs.py``.  This module covers the pieces in isolation.
"""
import dataclasses

import numpy as np
import pytest

from repro.common.config import reduced
from repro.configs import get_config
from repro.cluster import plan_cluster
from repro.store import (SHADOW_FORMATS, floor_bytes, get_shadow_format,
                         plan_store, shadow_bytes)
from repro.spec_exec import (DivergencePredictor, ShadowBank,
                             build_shadow_bank)


def _cfg(layers=2, d_model=64):
    return reduced(get_config("mixtral_8x7b"), layers=layers,
                   d_model=d_model, max_experts=8)


def _freqs(cfg, seed=0):
    rng = np.random.default_rng(seed)
    f = rng.random((cfg.num_layers, cfg.num_experts)) ** 2
    return f / f.sum(axis=1, keepdims=True)


# ------------------------------------------------------ formats + pricing --
def test_shadow_formats_registered_and_priced():
    assert set(SHADOW_FORMATS) == {"draft-int8", "shadow-int2"}
    f8 = get_shadow_format("draft-int8")
    f2 = get_shadow_format("shadow-int2")
    assert f8.bits == 8 and f2.bits == 2
    # int2 shadows cost strictly less device memory than int8 ones
    assert shadow_bytes(f2, 64, 256) < shadow_bytes(f8, 64, 256)
    with pytest.raises(KeyError):
        get_shadow_format("fp64-shadow")


def test_planner_shadows_axis_prices_explicitly():
    """``plan_store(shadows=...)`` funds shadows from the same budget as
    pins/upgrades: they appear in the breakdown, the spend stays within
    budget, and a shadow-free plan at the same budget is unchanged by
    the axis existing (``shadows=None`` keeps the legacy plan)."""
    cfg = _cfg(layers=4)
    freqs = _freqs(cfg, 3)
    gb = 1.4 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    base = plan_store(cfg, freqs, vram_gb=gb, host_gb=0.05,
                      ladder=("int2",), progressive=False)
    shadowed = plan_store(cfg, freqs, vram_gb=gb, host_gb=0.05,
                          ladder=("int2",), progressive=False,
                          shadows="draft-int8")
    assert base.shadows == {} and "shadows" not in base.breakdown
    assert len(shadowed.shadows) > 0
    assert all(name == "draft-int8" for name in shadowed.shadows.values())
    fmt = get_shadow_format("draft-int8")
    cost = len(shadowed.shadows) * shadow_bytes(fmt, cfg.d_model,
                                                cfg.moe_d_ff)
    assert shadowed.breakdown["shadows"] == cost
    # pinned experts never miss, so they are never shadowed
    assert not set(shadowed.shadows) & set(shadowed.pinned)
    assert sum(shadowed.breakdown.values()) <= gb * 2 ** 30
    # shadows COMPETE: funding them can only shrink the other stages
    assert len(shadowed.pinned) <= len(base.pinned)


def test_planner_shadows_stay_within_budget_and_saturate():
    """At any budget the shadowed plan's footprint stays within budget
    (shadows spend leftover after pins, so their count is NOT monotone
    in the budget); at a generous budget every non-pinned expert is
    shadowed."""
    cfg = _cfg(layers=4)
    freqs = _freqs(cfg, 1)
    floor = floor_bytes(cfg, ("int2",)) / 2 ** 30
    for m in (1.02, 1.3, 2.0):
        plan = plan_store(cfg, freqs, vram_gb=m * floor, host_gb=0.05,
                          ladder=("int2",), progressive=False,
                          shadows="shadow-int2")
        assert plan.footprint_bytes() <= m * floor * 2 ** 30
        assert not set(plan.shadows) & set(plan.pinned)
    # generous: shadows + pins tile every MoE expert exactly
    n_moe = sum(1 for li in range(cfg.num_layers)
                for _ in range(cfg.num_experts)
                if (li, 0) in plan.formats)
    assert len(plan.shadows) + len(plan.pinned) == n_moe


def test_cluster_planner_single_device_parity_with_shadows():
    """n_devices=1 + shadows must reproduce plan_store's spend exactly,
    shadows included (same greedy, same order, same prices)."""
    cfg = _cfg(layers=4)
    freqs = _freqs(cfg, 5)
    gb = 1.4 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    cp = plan_cluster(cfg, freqs, n_devices=1, vram_gb_per_device=gb,
                      host_gb=0.01, ladder=("int2",), progressive=False,
                      shadows="draft-int8")
    sp = plan_store(cfg, freqs, vram_gb=gb, host_gb=0.01,
                    ladder=("int2",), progressive=False,
                    shadows="draft-int8")
    assert cp.store_plan.shadows == sp.shadows
    assert cp.store_plan.formats == sp.formats
    assert cp.pinned_per_device[0] == sp.pinned


# -------------------------------------------------------------- the bank --
def _layers(cfg, seed=0):
    rng = np.random.default_rng(seed)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    return [{"moe": {
        "we_gate": rng.normal(size=(e, d, f)).astype(np.float32) * 0.1,
        "we_up": rng.normal(size=(e, d, f)).astype(np.float32) * 0.1,
        "we_down": rng.normal(size=(e, f, d)).astype(np.float32) * 0.1,
    }} for _ in range(cfg.num_layers)]


def test_shadow_bank_matches_plan_and_geometry():
    cfg = _cfg()
    freqs = _freqs(cfg, 2)
    gb = 2.0 * floor_bytes(cfg, ("int2",)) / 2 ** 30
    plan = plan_store(cfg, freqs, vram_gb=gb, host_gb=0.05,
                      ladder=("int2",), progressive=False,
                      shadows="draft-int8")
    layers = _layers(cfg)
    bank = build_shadow_bank(layers, plan)
    assert len(bank) == len(plan.shadows) > 0
    fmt = get_shadow_format("draft-int8")
    kept = max(1, int(round(cfg.d_ff * fmt.keep_ratio)))
    for (li, e) in plan.shadows:
        assert bank.has(li, e)
        idx, gate_cols, down_rows = bank.get(li, e)
        assert idx.shape == (kept,)
        assert np.all(np.diff(idx) > 0)  # sorted unique channel subset
        assert gate_cols.shape == (kept, cfg.d_model)
        assert down_rows.shape == (kept, cfg.d_model)
    assert bank.get(10 ** 6, 0) is None and not bank.has(10 ** 6, 0)


def test_shadow_codec_fidelity_orders_by_bits():
    """The int8 shadow reconstructs its kept records strictly better
    than the int2 shadow of the same expert (both bounded)."""
    cfg = _cfg()
    layers = _layers(cfg, 7)
    errs = {}
    for name in ("draft-int8", "shadow-int2"):
        plan = plan_store(_cfg(), _freqs(cfg, 2),
                          vram_gb=2.0 * floor_bytes(cfg, ("int2",)) / 2 ** 30,
                          host_gb=0.05, ladder=("int2",), progressive=False,
                          shadows=name)
        (li, e) = sorted(plan.shadows)[0]
        idx, gate_cols, _ = bank_entry = build_shadow_bank(
            layers, plan).get(li, e)
        ref = np.asarray(layers[li]["moe"]["we_gate"][e],
                         np.float32).T[idx]
        rel = (np.linalg.norm(np.asarray(gate_cols, np.float32) - ref)
               / np.linalg.norm(ref))
        errs[name] = rel
    assert errs["draft-int8"] < errs["shadow-int2"] < 1.0
    assert errs["draft-int8"] < 0.05


# --------------------------------------------------- divergence predictor --
def test_divergence_predictor_cold_start_optimistic():
    p = DivergencePredictor(min_samples=2)
    assert p.predicted(0, 0) == 0.0
    assert p.gate(0, 0, 1e-9)  # no evidence -> speculate


def test_divergence_predictor_learns_per_expert():
    p = DivergencePredictor(beta=0.5, min_samples=2)
    for _ in range(8):
        p.update(0, 0, 0.5)   # bad expert
        p.update(0, 1, 0.001)  # good expert
    assert not p.gate(0, 0, 0.05)
    assert p.gate(0, 1, 0.05)
    # an unseen expert falls back to the GLOBAL EMA (which is poisoned
    # by the bad expert here, so the gate declines)
    assert p.predicted(1, 7) > 0.0
    snap = p.snapshot()
    assert snap["samples"] == 16 and "0/0" in snap["experts"]


def test_divergence_predictor_is_deterministic():
    a, b = DivergencePredictor(), DivergencePredictor()
    rng = np.random.default_rng(0)
    for _ in range(64):
        li, e, d = int(rng.integers(2)), int(rng.integers(8)), \
            float(rng.random())
        a.update(li, e, d)
        b.update(li, e, d)
    assert a.snapshot() == b.snapshot()


# ------------------------------------------------------------ spec plane --
def test_speculation_spec_validation():
    from repro.deploy import (DeploymentSpec, ModelSpec, ResourceSpec,
                              ServingSpec, SpecError, SpeculationSpec)

    def dspec(sp, vram_gb=1.0, serving=ServingSpec()):
        return DeploymentSpec(
            model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=64,
                            max_experts=8),
            resources=ResourceSpec(vram_gb=vram_gb),
            serving=serving, speculation=sp)

    dspec(SpeculationSpec())  # valid
    with pytest.raises(SpecError, match="shadow_format"):
        dspec(SpeculationSpec(shadow_format="fp64-shadow"))
    with pytest.raises(SpecError, match="max_divergence"):
        dspec(SpeculationSpec(max_divergence=0.0))
    with pytest.raises(SpecError, match="beta"):
        dspec(SpeculationSpec(beta=1.0))
    with pytest.raises(SpecError, match="min_samples"):
        dspec(SpeculationSpec(min_samples=0))
    with pytest.raises(SpecError, match="vram_gb"):
        dspec(SpeculationSpec(), vram_gb=0.0)
    with pytest.raises(SpecError, match="serving"):
        dspec(SpeculationSpec(), serving=None)
    # disabled sections skip the cross-field requirements
    dspec(SpeculationSpec(enabled=False), serving=None)


def test_speculation_spec_json_round_trip():
    from repro.deploy import (DeploymentSpec, ModelSpec, ResourceSpec,
                              ServingSpec, SpeculationSpec)
    spec = DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", layers=2, d_model=64,
                        max_experts=8),
        resources=ResourceSpec(vram_gb=1.0),
        serving=ServingSpec(),
        speculation=SpeculationSpec(shadow_format="shadow-int2",
                                    max_divergence=0.1, beta=0.8,
                                    min_samples=4))
    assert DeploymentSpec.from_json(spec.to_json()) == spec
    # None section stays absent from the JSON and survives the trip
    bare = dataclasses.replace(spec, speculation=None)
    assert "speculation" not in bare.to_dict()
    assert DeploymentSpec.from_json(bare.to_json()) == bare


def test_serve_time_speculation_contract():
    """One built deployment exercises every serve-time resolution path:
    a shadow-format switch is refused (the bank is priced and built at
    plan time), ``speculate=False`` detaches cleanly, the default
    attaches the executor, and stripping the section refuses
    ``speculate=True`` (shadows cannot appear from nothing)."""
    from repro.deploy import (DeploymentSpec, ModelSpec, ResourceSpec,
                              RuntimeSpec, ServingSpec, SpecError,
                              SpeculationSpec, build)
    spec = DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", reduced=True, layers=2,
                        d_model=64, max_experts=8, vocab=128),
        resources=ResourceSpec(vram_gb=0.22, host_gb=2.0,
                               ladder=("int2",), progressive=False),
        runtime=RuntimeSpec(mode="floe", use_runtime=True),
        serving=ServingSpec(slots=2, policy="slo", online_train=False),
        speculation=SpeculationSpec())
    dep = build(spec)
    assert len(dep.plan.shadows) > 0

    with pytest.raises(SpecError, match="shadow_format"):
        dep.serve(n_requests=1, max_new=2,
                  speculate=SpeculationSpec(shadow_format="shadow-int2"))

    dep.serve(n_requests=2, max_new=2, seed=1, speculate=False)
    assert dep.controller.speculator is None

    dep.serve(n_requests=2, max_new=2, seed=2)
    assert dep.controller.speculator is dep._speculator
    rep = dep.report()
    assert "speculation" in rep
    assert rep["speculation"]["spec_served"] >= 0
    for k in ("spec_served", "spec_accepts", "spec_rollbacks",
              "spec_declined"):
        assert k in rep["serving"]

    # a deployment whose spec never had the section cannot speculate:
    # the planner priced no shadows at build time
    dep.spec = dataclasses.replace(dep.spec, speculation=None)
    dep._speculator = None
    with pytest.raises(SpecError, match="speculation"):
        dep.serve(n_requests=1, max_new=2, speculate=True)
