# NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
# benches must see the real single device; only launch/dryrun.py forces 512.
import jax

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
