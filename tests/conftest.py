# NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
# benches must see the real single device; only launch/dryrun.py forces 512.
import jax
import pytest

jax.config.update("jax_enable_x64", False)

# Heavy end-to-end modules (minutes of decode / training per module).
# Everything still runs under the ROADMAP tier-1 command — the marker only
# enables `-m "not slow"` for a quick dev loop.
_SLOW_MODULES = {
    "test_cluster_e2e", "test_controller", "test_deploy_e2e",
    "test_pipeline", "test_runtime", "test_serving", "test_smoke_archs",
    "test_store_e2e", "test_system", "test_train_ckpt",
    "test_workload_scale",
}


def pytest_collection_modifyitems(config, items):
    for item in items:
        item.add_marker(pytest.mark.tier1)
        if item.module.__name__.rpartition(".")[2] in _SLOW_MODULES:
            item.add_marker(pytest.mark.slow)
