"""Serving controller conformance: SLO admission/rejection, mid-stream
swap-in bitwise parity, incremental union masks, union-demand coverage,
and online-predictor behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import reduced
from repro.configs import get_config
from repro.core import predictor, sparsify
from repro.core.pipeline import _unstack_layers, paper_scaled_models
from repro.models import transformer as tf
from repro.serving import ServingController, SLORequest, UnionDemandTracker


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"), layers=2, d_model=64)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    layers = _unstack_layers(params, cfg)
    xcal = jax.random.normal(jax.random.PRNGKey(9), (64, cfg.d_model)) * 0.5
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    device, link = paper_scaled_models(cfg)
    return cfg, params, thr, device, link


def _make(setup, **kw):
    cfg, params, thr, device, link = setup
    opts = dict(slots=2, max_len=64, policy="slo", online_train=False,
                offload_opts=dict(device=device, link=link, cache_slots=4))
    opts.update(kw)
    return ServingController(params, cfg, thresholds=thr, **opts)


def _req(uid, cfg, seed, max_new=4, slo_ms=1e6, arrival_t=0.0, temp=0.0):
    rng = np.random.default_rng(seed)
    return SLORequest(uid, rng.integers(0, cfg.vocab_size, 5).astype(
        np.int32), max_new_tokens=max_new, slo_ms=slo_ms,
        arrival_t=arrival_t, temperature=temp)


# ------------------------------------------------------------- admission ---
def test_generous_slo_admitted_and_attained(setup):
    cfg = setup[0]
    ctl = _make(setup)
    ctl.submit(_req(0, cfg, 1, slo_ms=1e7))
    done = ctl.run()
    assert len(done) == 1 and done[0].attained
    assert not ctl.rejected
    assert done[0].ttft is not None and done[0].ttft > 0
    assert len(done[0].output) == 4


def test_infeasible_slo_rejected_after_telemetry(setup):
    """Once step telemetry exists, a request whose deadline cannot be met
    even if admitted immediately is rejected, not queued to die."""
    cfg = setup[0]
    ctl = _make(setup)
    ctl.submit(_req(0, cfg, 1, max_new=6, slo_ms=1e7, arrival_t=0.0))
    # arrives mid-decode with a deadline already in the past
    ctl.submit(_req(1, cfg, 2, max_new=6, slo_ms=1e-3, arrival_t=0.05))
    done = ctl.run()
    assert [r.uid for r in done] == [0]
    assert len(ctl.rejected) == 1 and ctl.rejected[0].uid == 1
    assert ctl.stats["rejections"] == 1
    assert ctl.slo_attainment() == 0.5  # rejected counts against


def test_no_rejection_before_any_telemetry(setup):
    """The very first request bootstraps optimistically (no estimate yet
    to reject on), even with a hopeless SLO."""
    cfg = setup[0]
    ctl = _make(setup)
    ctl.submit(_req(0, cfg, 1, max_new=2, slo_ms=1e-6))
    done = ctl.run()
    assert len(done) == 1 and not ctl.rejected
    assert not done[0].attained  # ...it still misses the deadline


def test_slo_attainment_denominator_counts_everyone(setup):
    cfg = setup[0]
    ctl = _make(setup)
    assert ctl.slo_attainment() == 1.0  # vacuous
    ctl.submit(_req(0, cfg, 1, slo_ms=1e7))
    ctl.submit(_req(1, cfg, 2, slo_ms=1e7, arrival_t=0.01))
    ctl.run()
    assert ctl.slo_attainment() == 1.0


# ---------------------------------------------------- continuous batching --
def test_swap_in_mid_stream_bitwise_vs_solo(setup):
    """A request that joins a busy batch mid-stream must produce exactly
    the tokens it would produce decoding alone: expert transfers are
    shared, expert COMPUTE is per-row with own masks, and union-demand
    top-ups guarantee coverage regardless of cache history."""
    cfg = setup[0]
    batch = _make(setup)
    batch.submit(_req(0, cfg, 3, max_new=8))
    batch.submit(_req(1, cfg, 4, max_new=4, arrival_t=0.4))
    done = {r.uid: r.output for r in batch.run()}
    assert batch.stats["swaps_in"] == 2

    for uid, seed, mn in ((0, 3, 8), (1, 4, 4)):
        solo = _make(setup)
        solo.submit(_req(uid, cfg, seed, max_new=mn))
        assert solo.run()[0].output == done[uid], uid


def test_swap_in_bitwise_with_temperature(setup):
    """Per-request keyed sampling keeps stochastic decoding independent
    of batch composition too."""
    cfg = setup[0]
    batch = _make(setup)
    batch.submit(_req(0, cfg, 5, max_new=6, temp=0.9))
    batch.submit(_req(1, cfg, 6, max_new=3, temp=0.9, arrival_t=0.3))
    done = {r.uid: r.output for r in batch.run()}
    solo = _make(setup)
    solo.submit(_req(1, cfg, 6, max_new=3, temp=0.9))
    assert solo.run()[0].output == done[1]


def test_finished_request_frees_slot_for_queued(setup):
    """slots=2, 3 requests: the third must start before the longest
    finishes (continuous batching), not after the whole batch."""
    cfg = setup[0]
    ctl = _make(setup)
    ctl.submit(_req(0, cfg, 7, max_new=8))
    ctl.submit(_req(1, cfg, 8, max_new=2, arrival_t=0.01))
    ctl.submit(_req(2, cfg, 9, max_new=2, arrival_t=0.02))
    done = {r.uid: r for r in ctl.run()}
    assert len(done) == 3
    assert done[2].first_token_t < done[0].finish_t
    assert ctl.stats["swaps_in"] == 3


def test_static_policy_runs_batch_to_completion(setup):
    """The baseline: a queued request waits for the WHOLE running batch
    even when a batch mate finished long ago."""
    cfg = setup[0]
    ctl = _make(setup, policy="static")
    ctl.submit(_req(0, cfg, 7, max_new=8))
    ctl.submit(_req(1, cfg, 8, max_new=2, arrival_t=0.01))
    ctl.submit(_req(2, cfg, 9, max_new=2, arrival_t=0.02))
    done = {r.uid: r for r in ctl.run()}
    assert len(done) == 3
    assert done[2].first_token_t > done[0].finish_t  # waited for batch
    assert ctl.stats["preemptions"] == 0 and not ctl.rejected


def test_preemption_under_deadline_pressure(setup):
    """slots=1: a tight-deadline arrival preempts the slack running
    request; the victim resumes and still matches its solo output."""
    cfg = setup[0]
    ctl = _make(setup, slots=1, max_preemptions=2)
    ctl.submit(_req(0, cfg, 3, max_new=10, slo_ms=1e7))
    # feasible-if-admitted-now, infeasible-if-it-waits deadline
    tight = _req(1, cfg, 4, max_new=2, slo_ms=250.0, arrival_t=0.2)
    ctl.submit(tight)
    done = {r.uid: r for r in ctl.run()}
    assert ctl.stats["preemptions"] >= 1
    assert done[0].preemptions >= 1
    assert done[1].attained

    solo = _make(setup, slots=1)
    solo.submit(_req(0, cfg, 3, max_new=10, slo_ms=1e7))
    assert solo.run()[0].output == done[0].output  # resume is exact


# ----------------------------------------------------- incremental unions --
def test_incremental_union_mask_matches_scratch_recompute():
    rng = np.random.default_rng(0)
    tr = UnionDemandTracker(32)
    for step in range(120):
        rid = int(rng.integers(0, 6))
        if rng.random() < 0.3:
            tr.remove(rid)
        else:
            masks = {(int(rng.integers(0, 3)), int(rng.integers(0, 8))):
                     rng.random(32) < 0.3
                     for _ in range(int(rng.integers(1, 4)))}
            conf = {k: (float(rng.random()), int(rng.integers(1, 3)))
                    for k in masks}
            tr.set_contribution(rid, masks, conf)
        ref = tr.rebuild()
        assert set(tr.keys()) == set(ref.keys())
        for key in tr.keys():
            np.testing.assert_array_equal(tr.union(key), ref[key])


def test_tracker_zero_mask_contribution_lifecycle():
    """A contributor whose mask is all-False must still hold the key
    alive and be removable without corrupting the counters."""
    tr = UnionDemandTracker(4)
    tr.set_contribution(1, {(0, 0): np.zeros(4, bool)}, {(0, 0): (0.1, 1)})
    tr.set_contribution(2, {(0, 0): np.ones(4, bool)}, {(0, 0): (0.2, 1)})
    tr.remove(2)  # counts hit zero while rid 1 still contributes
    assert (0, 0) in tr.keys()
    tr.remove(1)
    assert tr.keys() == []


def test_tracker_swap_out_only_removes_own_contribution():
    tr = UnionDemandTracker(4)
    a = np.array([True, False, True, False])
    b = np.array([False, False, True, True])
    tr.set_contribution(1, {(0, 5): a}, {(0, 5): (0.9, 1)})
    tr.set_contribution(2, {(0, 5): b}, {(0, 5): (0.4, 2)})
    np.testing.assert_array_equal(tr.union((0, 5)), a | b)
    tr.remove(1)
    np.testing.assert_array_equal(tr.union((0, 5)), b)
    assert tr.confidence((0, 5)) == (0.4, 2)


# ------------------------------------------------------- online predictor --
def test_online_predictor_monotonically_improves_recall():
    """Synthetic router: truth is top-k of a fixed linear map the reuse
    base knows only noisily.  Online rounds of residual training must
    improve held-out recall monotonically (within tolerance) and end
    strictly above the fallback."""
    rng = np.random.default_rng(0)
    d, e, k = 32, 8, 2
    w_true = rng.normal(size=(d, e)).astype(np.float32)
    w_base = (0.55 * w_true +
              0.8 * rng.normal(size=(d, e)).astype(np.float32))

    def batch(n, seed):
        r = np.random.default_rng(seed)
        h = r.normal(size=(n, d)).astype(np.float32)
        logits = h @ w_true
        tgt = np.zeros((n, e), np.float32)
        top = np.argsort(-logits, axis=1)[:, :k]
        np.put_along_axis(tgt, top, 1.0, axis=1)
        return h, h @ w_base, tgt

    h_ev, b_ev, t_ev = batch(256, 999)
    rec = ServingController._recall_at_k
    r_fallback = rec(b_ev, t_ev, k)

    probe = predictor.init_inter_predictor(jax.random.PRNGKey(0), d, e)
    recalls = []
    for rnd in range(4):
        h, b, t = batch(128, rnd)
        probe = predictor.train_inter_predictor(
            probe, jnp.asarray(h), jnp.asarray(t), steps=150,
            base_logits=jnp.asarray(b))
        lg = np.asarray(predictor.residual_inter_logits(
            probe, jnp.asarray(h_ev), jnp.asarray(b_ev)))
        recalls.append(rec(lg, t_ev, k))
    for a, b2 in zip(recalls, recalls[1:]):
        assert b2 >= a - 0.02, recalls  # monotone within tolerance
    assert recalls[-1] > r_fallback + 0.05, (recalls, r_fallback)


def test_gated_adoption_rejects_useless_probe(setup):
    """When the reuse base is already perfect on the buffered rows, the
    validation gate must keep the fallback (no probe adopted)."""
    ctl = _make(setup, online_train=True, min_train_rows=16,
                train_window=32, train_steps=30)
    rng = np.random.default_rng(1)
    d, e = ctl.cfg.d_model, ctl.cfg.num_experts
    h = rng.normal(size=(48, d)).astype(np.float32)
    logits = rng.normal(size=(48, e)).astype(np.float32) * 5
    tgt = np.zeros((48, e), np.float32)
    top = np.argsort(-logits, axis=1)[:, :2]
    np.put_along_axis(tgt, top, 1.0, axis=1)
    ctl._train_buf_ct[0] = [(h, logits, tgt)]  # base IS the truth
    ctl._fit_bank(ctl._train_buf_ct, ctl.inter_ct)
    assert 0 not in ctl.inter_ct


def test_gated_adoption_accepts_useful_probe(setup):
    """When the base is noise and the mapping is learnable, the probe
    must clear the gate and be adopted."""
    ctl = _make(setup, online_train=True, min_train_rows=16,
                train_window=64, train_steps=400)
    rng = np.random.default_rng(2)
    d, e = ctl.cfg.d_model, ctl.cfg.num_experts
    w = rng.normal(size=(d, e)).astype(np.float32)
    h = rng.normal(size=(96, d)).astype(np.float32)
    truth_logits = h @ w
    tgt = np.zeros((96, e), np.float32)
    top = np.argsort(-truth_logits, axis=1)[:, :2]
    np.put_along_axis(tgt, top, 1.0, axis=1)
    base = np.zeros_like(truth_logits)  # uninformative fallback
    ctl._train_buf_ct[0] = [(h, base, tgt)]
    ctl._fit_bank(ctl._train_buf_ct, ctl.inter_ct)
    assert 0 in ctl.inter_ct


def test_calibrator_demotes_overconfident_predictor():
    c = predictor.ConfidenceCalibrator()
    for _ in range(200):
        c.update(0.9, False)
        c.update(0.9, True)  # realized precision 0.5, claimed 0.9
    assert 0.4 < c.scale < 0.7
    assert c(0.9) < 0.9  # demoted
    assert c(0.0) == 0.0


def test_calibrator_never_boosts_past_claimed():
    c = predictor.ConfidenceCalibrator()
    for _ in range(50):
        c.update(0.1, True)  # underconfident: realized 1.0, claimed 0.1
    assert c.scale == 1.0  # capped: demotion-only
    assert c(0.4) == pytest.approx(0.4)


def test_multi_hot_and_residual_logits():
    mh = np.asarray(predictor.multi_hot(np.array([[0, 2], [2, 2]]), 4))
    np.testing.assert_array_equal(mh, [[1, 0, 1, 0], [0, 0, 1, 0]])
    probe = predictor.init_inter_predictor(jax.random.PRNGKey(0), 8, 4)
    h = jnp.ones((3, 8))
    base = jnp.ones((3, 4)) * 2.0
    np.testing.assert_allclose(
        np.asarray(predictor.residual_inter_logits(probe, h, base)),
        2.0 + np.asarray(predictor.inter_logits(probe, h)), rtol=1e-6)


# --------------------------------------------------------- union demands ---
def test_union_demand_coverage_means_full_coverage_metrics(setup):
    """Top-up fetches guarantee coverage 1.0 on every decode step — the
    FloE approximation can only lose channels to prediction, never to
    cache staleness."""
    cfg = setup[0]
    ctl = _make(setup, offload_opts=dict(device=setup[3], link=setup[4],
                                         cache_slots=2))
    ctl.submit(_req(0, cfg, 10, max_new=5))
    ctl.submit(_req(1, cfg, 11, max_new=5, arrival_t=0.01))
    ctl.run()
    assert ctl.metrics, "no decode steps recorded"
    assert all(m.coverage == 1.0 for m in ctl.metrics)


def test_report_contains_control_plane_fields(setup):
    cfg = setup[0]
    ctl = _make(setup)
    ctl.submit(_req(0, cfg, 1, max_new=3))
    ctl.run()
    rep = ctl.report()
    for key in ("slo_attainment", "ttft_ms_mean", "tpot_ms_mean",
                "preemptions", "tokens_per_s", "prefetch_recall",
                "prediction_recall", "demand_topups", "train_rounds"):
        assert key in rep, key
    assert rep["completed"] == 1
    assert rep["tokens_per_s"] > 0
