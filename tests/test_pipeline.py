"""FloE on-the-fly pipeline: modes, prefetch hit rate, modeled latency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import reduced
from repro.configs import get_config
from repro.core import sparsify
from repro.core.pipeline import FloEPipeline, _unstack_layers
from repro.models import transformer as tf


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral_8x7b"), layers=4, d_model=128)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    layers = _unstack_layers(params, cfg)
    xcal = jax.random.normal(jax.random.PRNGKey(9), (64, cfg.d_model))
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    return cfg, params, thr


def _run(cfg, params, thr, mode, steps=3, slots=8, vary_input=False,
         batch=2, **kw):
    from repro.core.pipeline import paper_scaled_models
    device, link = paper_scaled_models(cfg)
    pipe = FloEPipeline(params, cfg, thresholds=thr, cache_slots=slots,
                        mode=mode, device=device, link=link, **kw)
    for i in range(steps):
        h = jax.random.normal(jax.random.PRNGKey(1 + (i if vary_input else 0)),
                              (batch, cfg.d_model), jnp.float32)
        out, m = pipe.decode_token(h)
    return pipe, out, m


def test_unstack_layer_count(setup):
    cfg, params, _ = setup
    assert len(_unstack_layers(params, cfg)) == cfg.num_layers


def test_floe_faster_than_naive_offload(setup):
    cfg, params, thr = setup
    pipe_f, _, _ = _run(cfg, params, thr, "floe")
    pipe_n, _, _ = _run(cfg, params, thr, "naive")
    assert pipe_f.tokens_per_second() > 2 * pipe_n.tokens_per_second()


def test_floe_on_the_fly_criterion(setup):
    """Paper Fig. 6/8: FloE reaches >=91% of the fully-resident baseline and
    can slightly surpass it (the sparse kernel computes less than dense).
    On-the-fly means at least ~80% of resident speed."""
    cfg, params, thr = setup
    pipe_r, _, _ = _run(cfg, params, thr, "resident")
    pipe_f, _, _ = _run(cfg, params, thr, "floe")
    ratio = pipe_f.tokens_per_second() / pipe_r.tokens_per_second()
    assert ratio > 0.8, ratio


def test_prefetch_hides_transfer(setup):
    """After the first (cold) token, prediction+prefetch should serve decode
    from the cache: warm-step stalls collapse vs the cold step."""
    cfg, params, thr = setup
    pipe, _, m = _run(cfg, params, thr, "floe", steps=4)
    cold = pipe.metrics[0].stall_s
    warm = sum(x.stall_s for x in pipe.metrics[1:])
    assert warm <= cold * 0.25 + 1e-12, (cold, warm)
    assert pipe.metrics[-1].stall_s == 0.0


def test_no_prefetch_stalls_more(setup):
    """With a cache too small to hold the working set and varying inputs,
    prediction+prefetch overlaps the traffic that otherwise stalls."""
    cfg, params, thr = setup
    # single-batch (the paper's regime): per-layer working set = top-k = 2
    # experts, matching the 2-slot cache; inputs vary per token.
    kw = dict(steps=5, slots=2, vary_input=True, batch=1)
    pipe_p, _, _ = _run(cfg, params, thr, "floe", prefetch=True, **kw)
    pipe_0, _, _ = _run(cfg, params, thr, "floe", prefetch=False, **kw)
    stall_p = sum(x.stall_s for x in pipe_p.metrics[1:])
    stall_0 = sum(x.stall_s for x in pipe_0.metrics[1:])
    assert stall_0 > stall_p, (stall_0, stall_p)


def test_floe_output_tracks_resident(setup):
    """Sparsity+INT2 approximation error is bounded (random weights are the
    worst case; trained models do much better — see benchmarks)."""
    cfg, params, thr = setup
    _, out_r, _ = _run(cfg, params, thr, "resident")
    _, out_f, _ = _run(cfg, params, thr, "floe")
    rel = float(jnp.linalg.norm(out_f - out_r) / jnp.linalg.norm(out_r))
    assert rel < 0.8, rel


def test_coverage_high_with_warm_cache(setup):
    cfg, params, thr = setup
    pipe, _, m = _run(cfg, params, thr, "floe", steps=4)
    assert m.coverage > 0.8
    assert m.expert_hits > 0
