"""Deliverable (f): per-architecture REDUCED smoke tests — instantiate a
reduced variant of the same family (2 layers, d_model<=512, <=4 experts) and
run one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.common.config import TrainConfig, reduced
from repro.configs import ARCH_IDS, get_config
from repro.launch.train import build_train_step
from repro.models import transformer as tf
from repro.optim import adamw_init


def _batch(cfg, b=2, s=32, key=jax.random.PRNGKey(7)):
    if cfg.frontend == "audio":
        return {
            "embeddings": jax.random.normal(key, (b, s, cfg.d_model), jnp.float32),
            "targets": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        }
    if cfg.frontend == "vision":
        ni = min(cfg.frontend_tokens or 4, 8)
        return {
            "tokens": jax.random.randint(key, (b, s - ni), 0, cfg.vocab_size),
            "vision_embeds": jax.random.normal(key, (b, ni, cfg.d_model), jnp.float32),
        }
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_reduced_forward_and_train_step(aid):
    cfg = reduced(get_config(aid))
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    batch = _batch(cfg)
    b = batch[next(iter(batch))].shape[0]

    logits, aux = tf.forward(params, batch, cfg)
    s_total = 32
    assert logits.shape == (b, s_total, cfg.vocab_size), logits.shape
    assert bool(jnp.isfinite(logits).all()), f"{aid}: NaN/inf logits"

    step_fn, _, _ = build_train_step(cfg, TrainConfig(total_steps=2), None,
                                     donate=False)
    opt = adamw_init(params)
    params2, opt2, metrics = step_fn(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"])), aid
    # params actually changed
    moved = any(
        bool(jnp.any(a != b)) for a, b in
        zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, f"{aid}: train step did not update params"


@pytest.mark.parametrize("aid", [a for a in ARCH_IDS
                                 if get_config(a).causal])
def test_reduced_decode_step(aid):
    cfg = reduced(get_config(aid))
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    b = 2
    state = tf.init_decode_state(cfg, b, 64, jnp.float32)
    tok = jnp.ones((b, 1), jnp.int32)
    logits, state2 = tf.decode_step(params, tok, state, cfg)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    logits3, _ = tf.decode_step(params, tok, state2, cfg)
    assert bool(jnp.isfinite(logits3).all())
