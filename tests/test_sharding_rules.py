"""Sharding rules: every config gets a consistent, divisibility-safe spec."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.common import sharding as shd
from repro.common.config import SINGLE_POD, MULTI_POD, reduced
from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as tf


def _spec_tree(cfg, mesh_cfg):
    shapes = jax.eval_shape(
        lambda k: tf.init_model(k, cfg, jnp.bfloat16), jax.random.PRNGKey(0))
    return shapes, shd.shard_params_spec(shapes, mesh_cfg.axes,
                                         mesh_cfg.shape, cfg)


@pytest.mark.parametrize("aid", ARCH_IDS)
@pytest.mark.parametrize("mesh_cfg", [SINGLE_POD, MULTI_POD],
                         ids=["single", "multi"])
def test_specs_divide_shapes(aid, mesh_cfg):
    cfg = get_config(aid)
    sizes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    shapes, specs = _spec_tree(cfg, mesh_cfg)

    def check(path, leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= sizes[a]
            assert dim % n == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        check, shapes, specs,
        is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("aid", ["starcoder2_7b", "llama4_maverick",
                                 "smollm_135m"])
def test_odd_heads_use_seq_sharding(aid):
    cfg = get_config(aid)
    assert shd.attn_mode(cfg, 16) == "seq"


def test_divisible_archs_use_head_sharding():
    for aid in ("hubert_xlarge", "zamba2_7b", "glm4_9b", "phi35_moe",
                "mistral_large", "internvl2_76b"):
        assert shd.attn_mode(get_config(aid), 16) == "head", aid


def test_moe_experts_shard_over_model():
    cfg = get_config("llama4_maverick")
    shapes, specs = _spec_tree(cfg, SINGLE_POD)
    # find the we_gate spec: (seg scan, experts, embed, ffn)
    found = []
    def visit(path, spec):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "we_gate":
            found.append(spec)
    jax.tree_util.tree_map_with_path(visit, specs,
                                     is_leaf=lambda x: isinstance(x, P))
    assert found and all("model" in [a for a in s if a] for s in found)


def test_divisibility_report():
    issues = shd.check_divisibility(get_config("glm4_9b"), SINGLE_POD)
    assert any("kv heads" in i for i in issues)  # kv=2 < 16 documented
    issues = shd.check_divisibility(get_config("mamba2_780m"), SINGLE_POD)
    assert any("vocab" in i for i in issues)  # 50280 % 16 != 0
