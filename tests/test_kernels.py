"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hqq, sparsify
from repro.kernels import ops, ref
from repro.kernels.sparse_gemv import sparse_gemv, sparse_gemv_compact


def _setup(key, b, d, f, dtype, sparsity=0.8):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    x = jax.random.normal(ks[0], (b, d), dtype)
    wg = (jax.random.normal(ks[1], (d, f)) * 0.05).astype(dtype)
    wd = (jax.random.normal(ks[2], (f, d)) * 0.05).astype(dtype)
    v = jax.random.normal(ks[3], (b, f), jnp.float32)
    t = jnp.quantile(jnp.abs(v), sparsity)
    v = jnp.where(jnp.abs(v) >= t, v, 0.0)
    ba = sparsify.block_union_mask(v != 0, 128).any(0).astype(jnp.int32)
    return x, v, wg, wd, ba


@pytest.mark.parametrize("b", [1, 4])
@pytest.mark.parametrize("d,f", [(128, 256), (256, 512), (384, 1152)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_gemv_sweep(b, d, f, dtype):
    x, v, wg, wd, ba = _setup(b * d + f, b, d, f, dtype)
    y = sparse_gemv(x, v.astype(dtype), wg, wd, ba)
    yr = ref.sparse_gemv_ref(x, v.astype(dtype), wg, wd, ba, 128)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("pattern", ["all", "none", "alternating", "first"])
def test_sparse_gemv_compact_patterns(pattern):
    b, d, f = 2, 128, 512
    x, v, wg, wd, _ = _setup(11, b, d, f, jnp.float32, sparsity=0.5)
    nblk = f // 128
    ba = {
        "all": jnp.ones(nblk, jnp.int32),
        "none": jnp.zeros(nblk, jnp.int32),
        "alternating": jnp.arange(nblk, dtype=jnp.int32) % 2,
        "first": jnp.zeros(nblk, jnp.int32).at[0].set(1),
    }[pattern]
    v_m = v * jnp.repeat(ba.astype(bool), 128)[None]
    y = sparse_gemv_compact(x, v_m, wg, wd, ba)
    yr = ref.sparse_gemv_ref(x, v_m, wg, wd, ba, 128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


@given(bits=st.sampled_from([2, 4, 8]),
       d=st.sampled_from([128, 256]),
       f=st.sampled_from([128, 384]),
       b=st.sampled_from([1, 3]))
@settings(max_examples=10, deadline=None)
def test_quant_gemv_sweep(bits, d, f, b):
    w = jax.random.normal(jax.random.PRNGKey(d + f), (d, f)) * 0.05
    qt = hqq.quantize(w, bits=bits, group=64)
    x = jax.random.normal(jax.random.PRNGKey(b), (b, d), jnp.float32)
    v = ops.quant_gemv(x, qt)
    vr = ref.quant_gemv_ref(x, qt.packed, qt.scale, qt.zero, bits, 64)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr),
                               rtol=1e-5, atol=1e-5)


def test_fused_floe_expert_gemv():
    b, d, f = 2, 256, 512
    w = jax.random.normal(jax.random.PRNGKey(0), (d, f)) * 0.05
    qt = hqq.quantize(w, bits=2, group=64)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, d), jnp.float32)
    wg = jax.random.normal(jax.random.PRNGKey(2), (d, f)) * 0.05
    wd = jax.random.normal(jax.random.PRNGKey(3), (f, d)) * 0.05
    v = x @ hqq.dequantize(qt, jnp.float32)
    t = jnp.quantile(jnp.abs(v), 0.8)
    for compact in (True, False):
        y = ops.floe_expert_gemv(x, qt, wg, wd, t, compact=compact)
        yr = ops.floe_expert_gemv_ref(x, qt, wg, wd, t)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-5, atol=1e-5)


def test_inactive_blocks_contribute_nothing():
    """The kernel must produce EXACT zeros for inactive blocks (it skips
    them), matching the sparse semantics."""
    b, d, f = 1, 128, 256
    x, v, wg, wd, _ = _setup(3, b, d, f, jnp.float32)
    ba = jnp.array([1, 0], jnp.int32)
    y_skip = sparse_gemv(x, v, wg, wd, ba)
    # oracle computed with the second block's v zeroed
    v2 = v.at[:, 128:].set(0.0)
    yr = ref.sparse_gemv_ref(x, v2, wg, wd, jnp.array([1, 1], jnp.int32), 128)
    np.testing.assert_allclose(np.asarray(y_skip), np.asarray(yr),
                               rtol=1e-5, atol=1e-6)
