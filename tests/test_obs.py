"""repro.obs — bus semantics, Perfetto export validity, metrics
determinism, and the bounded-transfer-telemetry refactor.

Covers the observability acceptance criteria directly:

* with no consumer attached the bus is disabled and emitting is a no-op
  (the zero-overhead guard emit sites rely on),
* the exported trace is valid Chrome/Perfetto trace-event JSON (every
  record carries ``name``/``ph``/``pid``/``tid``; spans carry ``dur``,
  instants carry ``s``; metadata names processes and threads),
* export is byte-deterministic across identical runs,
* :class:`TransferAggregates` maintained incrementally at append /
  demote / preemption time equal a recomputation over the full record
  log (the rolling-aggregate refactor of the unbounded-telemetry fix),
* :class:`RecordLog` stays bounded while ``total``/``since`` keep
  absolute positions.
"""
import json

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.core.offload import LinkModel, build_expert_store
from repro.runtime import (ExpertScheduler, RecordLog, ResidencyManager,
                           TransferEngine, TransferRecord)


def _store(e=4, d=16, f=32, seed=0):
    rng = np.random.default_rng(seed)
    moe = {
        "we_gate": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_up": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_down": jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32) * 0.1,
    }
    thr = np.full((e,), 0.5, np.float32)
    return build_expert_store(moe, thr, bits=2, group=16)


def _drive(seed=7, n_ops=60, tracer=None, ring_maxlen=None):
    """Random but reproducible schedule with optional consumers."""
    store = _store(seed=1)
    res = [ResidencyManager(3, policy="weighted")]
    eng = TransferEngine(LinkModel(), num_buffers=2, chunk_channels=8)
    if ring_maxlen is not None:  # observation-only: a tiny record ring
        eng.records = RecordLog(maxlen=ring_maxlen)
    sched = ExpertScheduler([store], res, eng, lookahead=2)
    rng = np.random.default_rng(seed)
    f = store.d_ff
    consumers = [tracer] if tracer is not None else []
    with obs.use_bus(obs.EventBus()), obs.consumer(*consumers):
        for _ in range(n_ops):
            op = rng.integers(0, 5)
            e = int(rng.integers(0, store.num_experts))
            idx = np.sort(rng.choice(f, size=int(rng.integers(1, f // 2)),
                                     replace=False))
            if op == 0:
                sched.enqueue_prefetch(0, e, idx, float(rng.random()),
                                       depth=int(rng.integers(1, 3)))
            elif op == 1:
                sched.pump()
            elif op == 2:
                sched.advance(float(rng.random()) * 1e-3)
            elif op == 3:
                payload, miss = sched.demand_async(0, e, lambda i=idx: i)
                sched.wait_for(0, e, was_miss=miss)
            else:
                sched.reconcile(0, [int(x) for x in
                                    rng.choice(store.num_experts, size=2,
                                               replace=False)])
        sched.advance(1.0)
        eng.drain_events()
    return sched, eng


# ------------------------------------------------------------------- bus ---
def test_bus_disabled_without_consumers():
    with obs.use_bus(obs.EventBus()) as bus:
        assert not obs.enabled()
        obs.emit("anything", 0.0)  # no consumer: silently dropped
        seen = []
        with obs.consumer(obs.subscribe(lambda ev: seen.append(ev))) as c:
            assert obs.enabled()
            obs.emit("ping", 1.5, cat="test", args={"x": 1})
        assert not obs.enabled()
        assert [e.name for e in seen] == ["ping"]
        assert seen[0].t == 1.5 and seen[0].args == {"x": 1}
        assert bus.consumers == []


def test_scope_stamps_model():
    seen = []
    with obs.use_bus(obs.EventBus()):
        with obs.consumer(obs.subscribe(lambda ev: seen.append(ev))):
            obs.emit("a", 0.0)
            with obs.scope("llama"):
                obs.emit("b", 0.0)
                with obs.scope("qwen"):
                    obs.emit("c", 0.0)
            obs.emit("d", 0.0)
    assert [e.model for e in seen] == ["", "llama", "qwen", ""]
    assert [e.seq for e in seen] == [0, 1, 2, 3]


# ---------------------------------------------------------------- tracer ---
def test_trace_export_is_valid_trace_event_json(tmp_path):
    tracer = obs.Tracer()
    _drive(tracer=tracer)
    path = tmp_path / "trace.json"
    n = tracer.export(path)
    assert n == len(tracer.events) > 0
    doc = json.loads(path.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs
    phases = set()
    for rec in evs:
        assert {"name", "ph", "pid", "tid"} <= set(rec)
        phases.add(rec["ph"])
        if rec["ph"] == "X":
            assert rec["dur"] >= 0 and "ts" in rec
        elif rec["ph"] == "i":
            assert rec["s"] == "t" and "ts" in rec
        else:
            assert rec["ph"] == "M"
            assert rec["name"] in ("process_name", "thread_name")
    assert {"M", "X", "i"} <= phases
    # every (pid, tid) that carries events is named by metadata
    named = {(r["pid"], r["tid"]) for r in evs
             if r["ph"] == "M" and r["name"] == "thread_name"}
    used = {(r["pid"], r["tid"]) for r in evs if r["ph"] != "M"}
    assert used <= named


def test_trace_export_byte_deterministic():
    t1, t2 = obs.Tracer(), obs.Tracer()
    _drive(seed=11, tracer=t1)
    _drive(seed=11, tracer=t2)
    assert len(t1) > 0
    assert t1.export_str() == t2.export_str()


def test_observation_only_no_timeline_change():
    s_on, e_on = _drive(seed=13, tracer=obs.Tracer())
    s_off, e_off = _drive(seed=13, tracer=None)
    assert vars(s_on.stats) == vars(s_off.stats)
    assert s_on.clock == s_off.clock
    assert [(r.key, r.start_t, r.complete_t) for r in e_on.records] == \
           [(r.key, r.start_t, r.complete_t) for r in e_off.records]


# --------------------------------------------------------------- metrics ---
def test_metrics_snapshot_deterministic_and_sorted():
    c1, c2 = obs.MetricsCollector(), obs.MetricsCollector()
    _drive(seed=17, tracer=c1)
    _drive(seed=17, tracer=c2)
    s1, s2 = c1.registry.snapshot(), c2.registry.snapshot()
    assert s1 == s2
    assert list(s1) == sorted(s1)
    assert s1["events_total"] > 0
    assert s1.get("stall.conservation_violations", 0) == 0


def test_histogram_percentiles_nearest_rank():
    h = obs.Histogram()
    for v in [5.0, 1.0, 3.0, 2.0, 4.0]:
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5 and s["sum"] == 15.0
    assert s["p50"] == 3.0 and s["p99"] == 5.0 and s["max"] == 5.0
    assert obs.Histogram().summary()["count"] == 0


def test_scheduler_metrics_fold():
    sched, _ = _drive(seed=19)
    reg = obs.scheduler_metrics(obs.MetricsRegistry(), sched)
    snap = reg.snapshot()
    assert snap["sched.demand_fetches"] == sched.stats.demand_fetches
    assert snap["stall.conservation_ok"] == 1.0
    assert any(k.startswith("experts.freq.") for k in snap)
    assert abs(snap["stall.attributed_s"] -
               sched.attribution.attributed_s()) < 1e-12


# --------------------------------- bounded telemetry (rolling aggregates) --
def _agg_from_log(records):
    """Recompute the rolling aggregates from the raw record log."""
    agg = {"transfers": 0, "bytes": 0, "busy_s": 0.0, "demoted": 0,
           "wasted_bytes": 0, "disk_s": 0.0}
    for r in records:
        agg["transfers"] += 1
        agg["bytes"] += r.nbytes
        agg["busy_s"] += r.duration
        agg["disk_s"] += r.disk_s
        if r.demoted:
            agg["demoted"] += 1
            agg["wasted_bytes"] += r.nbytes
    return agg


def test_aggregates_equal_full_log():
    """Incremental aggregates (append/demote/preemption deltas) must
    equal a recomputation over the full record log — the invariant the
    unbounded-list fix rests on."""
    _, eng = _drive(seed=23)
    assert eng.records.dropped == 0  # full log still in the ring
    want = _agg_from_log(eng.records)
    assert eng.agg.transfers == want["transfers"]
    assert eng.agg.bytes == want["bytes"]
    assert eng.agg.demoted == want["demoted"]
    assert eng.agg.wasted_bytes == want["wasted_bytes"]
    assert abs(eng.agg.busy_s - want["busy_s"]) <= \
        1e-9 * max(1.0, want["busy_s"])
    assert abs(eng.agg.disk_s - want["disk_s"]) <= 1e-9
    assert abs(eng.busy_seconds() - want["busy_s"]) <= \
        1e-9 * max(1.0, want["busy_s"])


def test_record_log_stays_bounded():
    log = RecordLog(maxlen=8)
    recs = [TransferRecord(key=(0, i), kind="prefetch", nbytes=1, chunks=1,
                           strategy="packed", enqueue_t=0.0, start_t=0.0,
                           complete_t=1.0) for i in range(20)]
    for r in recs:
        log.append(r)
    assert len(log) == 8
    assert log.total == 20
    assert log.dropped == 12
    assert [r.seq for r in log] == list(range(12, 20))
    assert [r.seq for r in log.since(15)] == [15, 16, 17, 18, 19]
    assert log[-1].seq == 19


def test_summary_matches_aggregates():
    _, eng = _drive(seed=29)
    s = eng.summary()
    assert s["transfers"] == eng.agg.transfers
    assert s["bytes"] == eng.agg.bytes
    assert s["demoted"] == eng.agg.demoted
    assert s["wasted_bytes"] == eng.agg.wasted_bytes


# ------------------------------------------------- bounded histograms --
def test_histogram_exact_below_bound():
    from repro.obs.metrics import Histogram
    bounded = Histogram(bound=100, seed=3)
    exact = Histogram()
    for i in range(100):
        v = float((i * 37) % 100)
        bounded.observe(v)
        exact.observe(v)
    assert bounded.summary() == exact.summary()
    assert bounded.values == exact.values


def test_histogram_reservoir_stats_exact_above_bound():
    from repro.obs.metrics import Histogram
    h = Histogram(bound=64, seed=9)
    vals = [float((i * 7919) % 1000) for i in range(5000)]
    for v in vals:
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5000
    assert s["sum"] == sum(vals)
    assert s["max"] == max(vals)
    assert len(h.values) == 64  # memory stays at the bound
    # quantiles come from the reservoir but stay in range
    assert min(vals) <= s["p50"] <= max(vals)


def test_histogram_reservoir_deterministic():
    from repro.obs.metrics import MetricsRegistry
    def fill(reg):
        for i in range(3000):
            reg.histogram("x.latency").observe(float((i * 13) % 500))
        return reg.snapshot()
    a = fill(MetricsRegistry(hist_bound=128, seed=42))
    b = fill(MetricsRegistry(hist_bound=128, seed=42))
    assert a == b
    # a different registry seed reseeds the reservoir (quantiles may
    # move) but never the exact running stats
    c = fill(MetricsRegistry(hist_bound=128, seed=43))
    for k in ("x.latency.count", "x.latency.sum", "x.latency.mean",
              "x.latency.max"):
        assert a[k] == c[k]


def test_registry_default_bound_engages_only_at_scale():
    from repro.obs.metrics import DEFAULT_HIST_BOUND, MetricsRegistry
    reg = MetricsRegistry()
    h = reg.histogram("small")
    assert h.bound == DEFAULT_HIST_BOUND
    for i in range(200):  # well below the bound: exact mode
        h.observe(float(i))
    assert h.values == [float(i) for i in range(200)]
    assert reg.snapshot()["small.p50"] == 99.0


# ------------------------------------------------ ring wraparound edges --
def _rec(i):
    return TransferRecord(key=(0, i), kind="prefetch", nbytes=1, chunks=1,
                          strategy="packed", enqueue_t=0.0, start_t=0.0,
                          complete_t=1.0)


def test_record_log_since_after_wraparound():
    log = RecordLog(maxlen=4)
    for i in range(10):
        log.append(_rec(i))
    assert log.dropped == 6
    # a seq that has aged out returns only what the ring still holds
    assert [r.seq for r in log.since(0)] == [6, 7, 8, 9]
    assert [r.seq for r in log.since(6)] == [6, 7, 8, 9]
    # the wrap boundary itself
    assert [r.seq for r in log.since(9)] == [9]
    # a future seq is empty, not an error
    assert log.since(10) == []
    assert log.since(999) == []


def test_record_log_since_without_wraparound_matches_slicing():
    log = RecordLog(maxlen=64)
    for i in range(10):
        log.append(_rec(i))
    assert log.dropped == 0
    for s in range(12):
        assert [r.seq for r in log.since(s)] == list(range(s, 10))


from tests._hypothesis_compat import given, settings, st  # noqa: E402


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=40, max_value=90))
def test_aggregates_survive_wraparound_under_preemption(seed, n_ops):
    """Demand preemption mutates IN-FLIGHT records (``_preempt_schedule``
    pushes prefetch completion out and applies ``busy_s`` deltas through
    the ``inflight`` references) — the rolling aggregates must therefore
    be identical whether the mutated record is still in the ring or has
    already wrapped out of a tiny one."""
    _, big = _drive(seed=seed, n_ops=n_ops)
    _, small = _drive(seed=seed, n_ops=n_ops, ring_maxlen=4)
    assert big.records.dropped == 0  # default ring: full ground truth
    assert small.records.dropped == max(0, small.records.total - 4)
    want = _agg_from_log(big.records)
    for eng in (big, small):
        assert eng.agg.transfers == want["transfers"]
        assert eng.agg.bytes == want["bytes"]
        assert eng.agg.demoted == want["demoted"]
        assert eng.agg.wasted_bytes == want["wasted_bytes"]
        assert abs(eng.agg.busy_s - want["busy_s"]) <= \
            1e-9 * max(1.0, want["busy_s"])
        assert abs(eng.agg.disk_s - want["disk_s"]) <= 1e-9
    assert len(small.records) <= 4
    assert small.records.total == big.records.total


def test_aggregates_after_actual_wraparound():
    """Pinned companion to the property test: this drive is KNOWN to
    wrap the tiny ring, so the preemption-past-the-boundary path is
    exercised every run, not only when the grid lands on it."""
    _, big = _drive(seed=23, n_ops=120)
    _, small = _drive(seed=23, n_ops=120, ring_maxlen=4)
    assert small.records.dropped > 0
    assert small.agg.transfers == big.agg.transfers
    assert small.agg.bytes == big.agg.bytes
    assert abs(small.agg.busy_s - big.agg.busy_s) <= \
        1e-9 * max(1.0, big.agg.busy_s)


# ----------------------------------------------------- tracer span cap --
def test_tracer_rejects_bad_cap():
    import pytest
    with pytest.raises(ValueError):
        obs.Tracer(max_export=0)


def test_tracer_cap_keeps_most_recent_and_stamps_metadata(tmp_path,
                                                          capsys):
    capped = obs.Tracer(max_export=10)
    full = obs.Tracer()
    _drive(seed=31, tracer=capped)
    _drive(seed=31, tracer=full)
    assert len(capped) == len(full) > 10  # buffering is unbounded
    doc = capped.to_chrome()
    assert doc["metadata"] == {"dropped_events": len(full) - 10,
                               "total_events": len(full),
                               "max_export": 10}
    body = [r for r in doc["traceEvents"] if r["ph"] != "M"]
    tail = [r for r in full.to_chrome()["traceEvents"]
            if r["ph"] != "M"][-10:]
    assert body == tail  # the most recent events win
    n = capped.export(tmp_path / "t.json")
    assert n == 10
    assert "dropped" in capsys.readouterr().err


def test_tracer_uncapped_export_unchanged(tmp_path, capsys):
    tracer = obs.Tracer()
    _drive(seed=31, tracer=tracer)
    n = tracer.export(tmp_path / "t.json")
    assert n == len(tracer)
    assert tracer.dropped_last_export == 0
    doc = json.loads((tmp_path / "t.json").read_text())
    assert "metadata" not in doc  # only truncated exports are stamped
    assert capsys.readouterr().err == ""


# ------------------------------------------------- reservoir stamping --
def test_snapshot_stamps_reservoir_flag_past_bound():
    from repro.obs.metrics import MetricsRegistry
    reg = MetricsRegistry(hist_bound=32, seed=1)
    for i in range(31):
        reg.histogram("lat").observe(float(i))
    assert "lat.reservoir" not in reg.snapshot()  # exact mode: no stamp
    for i in range(100):
        reg.histogram("lat").observe(float(i))
    snap = reg.snapshot()
    assert snap["lat.reservoir"] is True
    assert snap["lat.count"] == 131  # running stats stay exact


# ------------------------------------------------ speculation event stream --
def _spec_dep(max_divergence=0.5):
    from repro.deploy import (DeploymentSpec, ModelSpec, ResourceSpec,
                              RuntimeSpec, ServingSpec, SpeculationSpec,
                              build)
    spec = DeploymentSpec(
        model=ModelSpec(arch="mixtral-8x7b", reduced=True, layers=2,
                        d_model=64, max_experts=8, vocab=128),
        resources=ResourceSpec(vram_gb=0.22, host_gb=2.0, ladder=("int2",),
                               progressive=False),
        runtime=RuntimeSpec(mode="floe", use_runtime=True),
        serving=ServingSpec(slots=2, policy="slo", online_train=False),
        speculation=SpeculationSpec(max_divergence=max_divergence))
    return build(spec)


def test_speculation_event_stream_well_formed():
    """The speculative executor's event stream must be audit-grade:
    every ``spec.serve`` carries its layer/expert/stall_avoided_s, every
    verification emits ``spec.divergence`` followed by exactly one
    verdict (``spec.accept`` | ``spec.rollback``) for the same expert,
    the verdict counts reconcile with the executor's own report, and
    the ``speculative_fallback`` stall cause still conserves bitwise."""
    events = []

    class Sink:
        def on_event(self, ev):
            if ev.name.startswith("spec."):
                events.append(ev)

    collector = obs.MetricsCollector()
    dep = _spec_dep()
    with obs.consumer(Sink(), collector):
        dep.serve(n_requests=6, rate=4.0, max_new=6, seed=3)

    serves = [e for e in events if e.name == "spec.serve"]
    divs = [e for e in events if e.name == "spec.divergence"]
    verdicts = [e for e in events
                if e.name in ("spec.accept", "spec.rollback")]
    rep = dep._speculator.report()
    assert rep["spec_served"] > 0, "scenario produced no speculation"
    assert len(serves) == rep["spec_served"]
    assert len(verdicts) == rep["spec_accepts"] + rep["spec_rollbacks"]
    assert len(divs) == len(verdicts)

    for ev in serves:
        assert ev.cat == "spec"
        assert set(ev.args) >= {"layer", "expert", "stall_avoided_s",
                                "rows"}
        assert ev.args["stall_avoided_s"] > 0.0
    # each divergence is followed by its verdict for the SAME expert
    pending = {}
    for ev in events:
        key = (ev.args.get("layer"), ev.args.get("expert"))
        if ev.name == "spec.divergence":
            assert key not in pending
            pending[key] = float(ev.args["divergence"])
        elif ev.name in ("spec.accept", "spec.rollback"):
            div = pending.pop(key)
            limit = dep.spec.speculation.max_divergence
            assert (div <= limit) == (ev.name == "spec.accept")
    assert not pending, "divergence emitted without a verdict"

    # metrics collector mirrors the stream
    snap = collector.registry.snapshot()
    assert snap.get("spec.serve", 0) == rep["spec_served"]
    assert snap.get("spec.accept", 0) == rep["spec_accepts"]
    assert snap.get("spec.rollback", 0) == rep["spec_rollbacks"]
    assert snap.get("spec.divergence.count", 0) == len(divs)

    # stall conservation survives the new cause bitwise
    sched = dep.pipeline.sched
    assert sched.attribution.check_conservation(sched.stats.stall_s)
    causes = sched.attribution.snapshot()["causes"]
    assert "speculative_fallback" in causes


def test_speculation_off_emits_no_spec_events():
    """``serve(speculate=False)`` on a speculation-capable deployment
    must leave the event stream spec-free — off is a noop."""
    events = []

    class Sink:
        def on_event(self, ev):
            events.append(ev.name)

    dep = _spec_dep()
    with obs.consumer(Sink()):
        dep.serve(n_requests=4, rate=4.0, max_new=4, seed=5,
                  speculate=False)
    assert dep.controller.speculator is None
    assert not [n for n in events if n.startswith("spec.")]
