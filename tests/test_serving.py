"""Batched serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import reduced
from repro.configs import get_config
from repro.models import transformer as tf
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("smollm_135m"), layers=2, d_model=64)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, params


def test_greedy_generation_deterministic(model):
    cfg, params = model
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    outs = []
    for _ in range(2):
        eng = ServingEngine(params, cfg, batch_size=2, max_len=64)
        eng.submit(Request(0, prompt, max_new_tokens=6))
        done = eng.run()
        outs.append(done[0].output)
    assert outs[0] == outs[1]
    assert len(outs[0]) == 6


def test_batched_matches_single(model):
    """A request's output must not depend on its batch neighbors."""
    cfg, params = model
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    eng = ServingEngine(params, cfg, batch_size=2, max_len=64)
    eng.submit(Request(0, p1, max_new_tokens=5))
    eng.submit(Request(1, p2, max_new_tokens=5))
    both = {r.uid: r.output for r in eng.run()}

    solo = ServingEngine(params, cfg, batch_size=2, max_len=64)
    solo.submit(Request(0, p1, max_new_tokens=5))
    alone = solo.run()[0].output
    assert both[0] == alone


def test_length_bucketing(model):
    cfg, params = model
    rng = np.random.default_rng(2)
    eng = ServingEngine(params, cfg, batch_size=4, max_len=64)
    for i, ln in enumerate([5, 9, 5, 9, 5]):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, ln).astype(np.int32),
                           max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in done)
    assert eng.tokens_per_second() > 0


def test_tokens_per_second_definition_excludes_queue_wait():
    """Pin the corrected throughput definition: the offloaded path divides
    by modeled SERVICE time (compute + stall) only — queue-wait /
    admission delay must not deflate the figure.  The resident path keeps
    wall-clock (its wall time is the service time)."""
    eng = ServingEngine.__new__(ServingEngine)  # no model needed
    eng.stats = {"tokens": 100, "steps": 0, "wall_s": 50.0,
                 "stall_s": 2.0, "compute_s": 3.0, "queue_wait_s": 45.0}
    eng.floe = None
    assert eng.tokens_per_second() == pytest.approx(100 / 50.0)
    eng.floe = object()  # offloaded mode marker
    assert eng.tokens_per_second() == pytest.approx(100 / 5.0)
    assert eng.modeled_stall_per_token() == pytest.approx(0.02)


def test_queue_wait_accounted_separately(model):
    """More requests than batch slots: later batches' admission delay
    lands in queue_wait_s, not in the throughput denominator."""
    cfg, params = model
    rng = np.random.default_rng(4)
    eng = ServingEngine(params, cfg, batch_size=1, max_len=64)
    for i in range(3):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 8).astype(
            np.int32), max_new_tokens=2))
    eng.run()
    assert eng.stats["queue_wait_s"] > 0.0  # batches 2/3 waited
    assert eng.tokens_per_second() > 0


def test_greedy_matches_forward_argmax(model):
    """First generated token == argmax of the forward pass at the last
    prompt position."""
    cfg, params = model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    logits, _ = tf.forward(params, {"tokens": jnp.asarray(prompt[None])}, cfg)
    want = int(jnp.argmax(logits[0, -1]))
    eng = ServingEngine(params, cfg, batch_size=1, max_len=64)
    eng.submit(Request(0, prompt, max_new_tokens=1))
    got = eng.run()[0].output[0]
    assert got == want
