"""Spec-layer conformance: typed validation + lossless JSON round-trip.

Every invalid ``DeploymentSpec`` field combination raises a
:class:`~repro.deploy.SpecError` that NAMES the offending field (the
acceptance bar for replacing the old deep-in-constructor asserts), and
``spec == from_json(to_json(spec))`` holds for representative specs
including the committed example file.
"""
from pathlib import Path

import pytest

from repro.deploy import (DeploymentSpec, ModelSpec, ResourceSpec,
                          RuntimeSpec, ServingSpec, SpecError)

REPO = Path(__file__).resolve().parents[1]


def _spec(**kw):
    base = dict(model=ModelSpec(arch="mixtral-8x7b", layers=2,
                                d_model=128))
    base.update(kw)
    return DeploymentSpec(**base)


# ------------------------------------------------------------- validation --
@pytest.mark.parametrize("field,kw", [
    # vram below the feasibility floor
    ("resources.vram_gb", dict(resources=ResourceSpec(vram_gb=1e-6))),
    # negative vram
    ("resources.vram_gb", dict(resources=ResourceSpec(vram_gb=-1.0))),
    # tiered store without the runtime scheduler
    ("resources.vram_gb", dict(resources=ResourceSpec(vram_gb=1.0),
                               runtime=RuntimeSpec(use_runtime=False))),
    # devices < 1
    ("resources.devices", dict(resources=ResourceSpec(devices=0))),
    # cluster without the runtime scheduler
    ("resources.devices", dict(resources=ResourceSpec(devices=2),
                               runtime=RuntimeSpec(use_runtime=False))),
    # replicate >= num_experts (reduced mixtral has 4)
    ("resources.replicate", dict(resources=ResourceSpec(replicate=4))),
    ("resources.replicate", dict(resources=ResourceSpec(replicate=-1))),
    # tiered store without host budget
    ("resources.host_gb", dict(resources=ResourceSpec(vram_gb=1.0,
                                                      host_gb=0.0))),
    # unknown ladder format
    ("resources.ladder", dict(resources=ResourceSpec(
        vram_gb=1.0, ladder=("int3",)))),
    # unknown runtime mode / residency policy, bad knobs
    ("runtime.mode", dict(runtime=RuntimeSpec(mode="turbo"))),
    ("runtime.residency_policy",
     dict(runtime=RuntimeSpec(residency_policy="mru"))),
    ("runtime.lookahead", dict(runtime=RuntimeSpec(lookahead=0))),
    ("runtime.num_buffers", dict(runtime=RuntimeSpec(num_buffers=0))),
    ("runtime.cache_slots", dict(runtime=RuntimeSpec(cache_slots=0))),
    # serving: slo <= 0, unknown policy, slots < 1
    ("serving.slo_ms", dict(serving=ServingSpec(slo_ms=0.0))),
    ("serving.slo_ms", dict(serving=ServingSpec(slo_ms=-5.0))),
    ("serving.policy", dict(serving=ServingSpec(policy="fifo"))),
    ("serving.slots", dict(serving=ServingSpec(slots=0))),
    ("serving.max_len", dict(serving=ServingSpec(max_len=0))),
    ("serving.max_preemptions",
     dict(serving=ServingSpec(max_preemptions=-1))),
    # serving needs the runtime scheduler
    ("runtime.use_runtime", dict(serving=ServingSpec(),
                                 runtime=RuntimeSpec(use_runtime=False))),
    # model floors
    ("model.layers", dict(model=ModelSpec(layers=0))),
    ("model.d_model", dict(model=ModelSpec(d_model=4))),
    ("model.max_experts", dict(model=ModelSpec(max_experts=-1))),
    ("model.train_steps", dict(model=ModelSpec(train_steps=-1))),
])
def test_invalid_spec_raises_typed_error_naming_field(field, kw):
    with pytest.raises(SpecError) as ei:
        _spec(**kw)
    assert ei.value.field == field, (ei.value.field, field)
    assert field in str(ei.value)


def test_unknown_arch_names_field():
    with pytest.raises(SpecError) as ei:
        _spec(model=ModelSpec(arch="gpt-17-nano"))
    assert ei.value.field == "model.arch"


def test_spec_error_is_value_error():
    # callers that caught ValueError from the old asserts keep working
    with pytest.raises(ValueError):
        _spec(resources=ResourceSpec(devices=0))


def test_serving_requires_moe_model():
    with pytest.raises(SpecError) as ei:
        DeploymentSpec(model=ModelSpec(arch="starcoder2-7b", layers=2,
                                       d_model=128),
                       serving=ServingSpec())
    assert ei.value.field == "serving.policy"


# --------------------------------------------------------- JSON round-trip --
@pytest.mark.parametrize("spec", [
    DeploymentSpec(),
    _spec(),
    _spec(resources=ResourceSpec(vram_gb=1.0, host_gb=0.5, devices=2,
                                 replicate=1, ladder=("int2", "int4"),
                                 max_slots=3, max_pinned=2,
                                 progressive=False),
          runtime=RuntimeSpec(lookahead=3, residency_policy="weighted",
                              batched_demand=True, cross_token=False),
          serving=ServingSpec(slots=2, slo_ms=2500.0, policy="static",
                              online_train=False),
          name="round-trip"),
])
def test_json_round_trip_is_lossless(spec):
    assert DeploymentSpec.from_json(spec.to_json()) == spec
    # and a second trip is a fixed point
    j = spec.to_json()
    assert DeploymentSpec.from_json(j).to_json() == j


def test_ladder_survives_as_tuple():
    spec = _spec(resources=ResourceSpec(vram_gb=1.0,
                                        ladder=("int2",)))
    back = DeploymentSpec.from_json(spec.to_json())
    assert back.resources.ladder == ("int2",)
    assert isinstance(back.resources.ladder, tuple)


def test_from_json_rejects_unknown_fields():
    with pytest.raises(SpecError) as ei:
        DeploymentSpec.from_json(
            '{"runtime": {"mode": "floe", "warp_speed": true}}')
    assert "warp_speed" in str(ei.value)


def test_from_json_rejects_unknown_sections():
    """A typo'd SECTION name must not load as all-defaults."""
    with pytest.raises(SpecError) as ei:
        DeploymentSpec.from_json('{"runtimes": {"mode": "floe"}}')
    assert ei.value.field == "runtimes"


def test_from_json_explicit_null_serving_means_no_serving():
    spec = DeploymentSpec.from_json('{"serving": null}')
    assert spec.serving is None


def test_from_json_rejects_non_object():
    with pytest.raises(SpecError):
        DeploymentSpec.from_json("[1, 2]")
    with pytest.raises(SpecError):
        DeploymentSpec.from_json("not json at all {")


def test_committed_example_spec_is_valid_and_round_trips():
    """examples/deploy_mixtral_11gb.json — the paper's headline config
    (full Mixtral-8x7B under an 11 GiB budget) as a committed spec."""
    text = (REPO / "examples" / "deploy_mixtral_11gb.json").read_text()
    spec = DeploymentSpec.from_json(text)
    assert spec.model.arch == "mixtral-8x7b" and not spec.model.reduced
    assert spec.resources.vram_gb == 11.0
    assert spec.serving is not None
    assert DeploymentSpec.from_json(spec.to_json()) == spec
    # 11 GiB sits between the feasibility floor and dense residency
    from repro.store import dense_residency_bytes, floor_bytes
    cfg = spec.resolve_config()
    assert floor_bytes(cfg) < 11 * 2 ** 30 < dense_residency_bytes(cfg)


# ------------------------------------------------------------ kwargs shims --
def test_pipeline_kwargs_build_a_runtime_spec():
    """The legacy kwargs surface is a thin shim: FloEPipeline normalizes
    its runtime kwargs into one typed RuntimeSpec."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.common.config import reduced
    from repro.configs import get_config
    from repro.core.pipeline import FloEPipeline
    from repro.models import transformer as tf

    cfg = reduced(get_config("mixtral-8x7b"), layers=2, d_model=128)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    pipe = FloEPipeline(params, cfg, thresholds=thr, mode="floe",
                        use_runtime=True, lookahead=3,
                        residency_policy="lfu", cache_slots=6)
    assert pipe.runtime_spec == RuntimeSpec(
        mode="floe", use_runtime=True, lookahead=3,
        residency_policy="lfu", cache_slots=6)
    assert pipe.sched.lookahead == 3


def test_controller_kwargs_build_a_serving_spec():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.common.config import reduced
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.serving import ServingController

    cfg = reduced(get_config("mixtral-8x7b"), layers=2, d_model=128)
    params = tf.init_model(jax.random.PRNGKey(0), cfg, jnp.float32)
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    ctl = ServingController(params, cfg, thresholds=thr, slots=3,
                            policy="static", online_train=False,
                            max_preemptions=1)
    assert ctl.serving_spec == ServingSpec(slots=3, policy="static",
                                           online_train=False,
                                           max_preemptions=1)
    with pytest.raises(SpecError) as ei:
        ServingController(params, cfg, thresholds=thr, policy="bogus")
    assert ei.value.field == "serving.policy"
