"""The trip-weighted HLO analyzer that powers the roofline terms."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (collective_summary, dot_flops_total,
                                       hbm_bytes_estimate, parse_hlo,
                                       _shape_bytes)

SYNTH = """
%body.1 (arg.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%y), channel_id=1, to_apply=%add.2
}
%cond.1 (arg.2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(7)
  %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main.42 (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %t = (s32[], f32[8,16]) tuple(%a)
  %wh = (s32[], f32[8,16]) while(%t), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert _shape_bytes("bf16[128]") == 256
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8


def test_trip_weighted_collectives_synthetic():
    s = collective_summary(SYNTH)
    assert s["all-reduce"]["count"] == 7  # one op x 7 trips
    assert s["all-reduce"]["bytes"] == 7 * 8 * 16 * 4


def test_trip_weighted_dot_flops_synthetic():
    # dot: 2 * (8*16) * 16 = 4096 flops x 7 trips
    assert dot_flops_total(SYNTH) == 7 * 2 * 8 * 16 * 16


def test_against_real_compiled_module():
    """End-to-end: a scanned matmul must count flops x trip count."""
    L, B, D = 5, 4, 32

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), ()
        return jax.lax.scan(body, x, ws)[0].sum()

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32)).compile().as_text()
    flops = dot_flops_total(txt)
    expect = L * 2 * B * D * D
    assert abs(flops - expect) / expect < 0.05, (flops, expect)
    assert hbm_bytes_estimate(txt) > L * B * D * 4  # at least the activations


def test_single_device_module_has_no_collectives():
    txt = jax.jit(lambda x: (x @ x).sum()).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile().as_text()
    assert collective_summary(txt) in ({}, {k: v for k, v in ()})
