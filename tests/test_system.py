"""End-to-end behaviour: train a small MoE → compress with FloE → serve
offloaded → outputs remain usable and the pipeline beats naive offload."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import TrainConfig, reduced
from repro.configs import get_config
from repro.core import sparsify
from repro.core.pipeline import FloEPipeline, _unstack_layers
from repro.launch.train import train_loop
from repro.models import transformer as tf


@pytest.fixture(scope="module")
def trained_moe():
    cfg = reduced(get_config("mixtral_8x7b"), layers=2, d_model=128)
    tc = TrainConfig(learning_rate=2e-3, total_steps=80, warmup_steps=8)
    params, _, hist = train_loop(cfg, tc, batch=8, seq=64, steps=80,
                                 log_every=79)
    assert hist[-1][1] < hist[0][1]
    return cfg, params


def _calibrate(cfg, params, n=128):
    layers = _unstack_layers(params, cfg)
    xcal = jax.random.normal(jax.random.PRNGKey(9), (n, cfg.d_model)) * 0.5
    thr = np.zeros((cfg.num_layers, cfg.num_experts), np.float32)
    for li, layer in enumerate(layers):
        if "moe" not in layer:
            continue
        for e in range(cfg.num_experts):
            u = xcal @ layer["moe"]["we_up"][e]
            thr[li, e] = float(sparsify.threshold_from_samples(
                jnp.abs(u), cfg.floe.sparsity))
    return thr


def test_end_to_end_floe_on_trained_model(trained_moe):
    cfg, params = trained_moe
    thr = _calibrate(cfg, params)
    h = jax.random.normal(jax.random.PRNGKey(4), (1, cfg.d_model),
                          jnp.float32) * 0.3

    results = {}
    for mode in ("resident", "naive", "floe"):
        pipe = FloEPipeline(params, cfg, thresholds=thr, cache_slots=8,
                            mode=mode)
        for _ in range(3):
            out, m = pipe.decode_token(h)
        results[mode] = (pipe.tokens_per_second(), out)

    tps_r, out_r = results["resident"]
    tps_n, out_n = results["naive"]
    tps_f, out_f = results["floe"]
    # headline structure of Fig. 6: resident > floe >> naive
    assert tps_f > 2 * tps_n, (tps_f, tps_n)
    assert tps_r >= tps_f
    # trained model: FloE output stays close to the resident reference
    rel = float(jnp.linalg.norm(out_f - out_r) / jnp.linalg.norm(out_r))
    assert rel < 0.6, rel


def test_generation_quality_survives_training(trained_moe):
    """Trained model emits plausible continuations (loss dropped, logits
    concentrated)."""
    cfg, params = trained_moe
    toks = jnp.ones((1, 16), jnp.int32)
    logits, _ = tf.forward(params, {"tokens": toks}, cfg)
    probs = jax.nn.softmax(logits[0, -1])
    assert float(probs.max()) > 2.0 / cfg.vocab_size
