"""Property-based conformance suite for the runtime (scheduler, transfer
engine, residency) — random demand/prefetch traces must uphold the
runtime's core invariants:

  * a transfer never completes before it was issued (and never starts
    before it was enqueued),
  * residency never exceeds its capacity (pins can only hold it AT
    capacity, never grow it past the pinned count),
  * demand preemption never starves speculative traffic — every issued
    transfer still completes,
  * a pinned expert can never be evicted,
  * ``demand_union`` always returns a slice covering the requested
    channels (sorted, unique),
  * the scheduler clock is monotone and demand accounting is conserved.

Runs under real ``hypothesis`` when installed; otherwise the
deterministic grid fallback in ``tests/_hypothesis_compat.py``.
"""
import numpy as np
import pytest

from repro.core.offload import LinkModel, build_expert_store
from repro.runtime import ExpertScheduler, ResidencyManager, TransferEngine

from tests._hypothesis_compat import given, settings, st

import jax.numpy as jnp


def _store(e=4, d=16, f=32, seed=0):
    rng = np.random.default_rng(seed)
    moe = {
        "we_gate": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_up": jnp.asarray(rng.normal(size=(e, d, f)), jnp.float32) * 0.1,
        "we_down": jnp.asarray(rng.normal(size=(e, f, d)), jnp.float32) * 0.1,
    }
    thr = np.full((e,), 0.5, np.float32)
    return build_expert_store(moe, thr, bits=2, group=16)


def _sched(store, *, slots=3, num_buffers=2, policy="lru", pinned=()):
    res = [ResidencyManager(slots, policy=policy, pinned=pinned)]
    eng = TransferEngine(LinkModel(), num_buffers=num_buffers,
                         chunk_channels=8)
    sched = ExpertScheduler([store], res, eng, lookahead=2)
    return sched, res[0], eng


def _drive(sched, store, seed, n_ops=40):
    """Random but reproducible op trace over the scheduler."""
    rng = np.random.default_rng(seed)
    f = store.d_ff
    for _ in range(n_ops):
        op = rng.integers(0, 5)
        e = int(rng.integers(0, store.num_experts))
        idx = np.sort(rng.choice(f, size=int(rng.integers(1, f // 2)),
                                 replace=False))
        if op == 0:
            sched.enqueue_prefetch(0, e, idx, float(rng.random()),
                                   depth=int(rng.integers(1, 3)))
        elif op == 1:
            sched.pump()
        elif op == 2:
            sched.advance(float(rng.random()) * 1e-3)
        elif op == 3:
            payload, miss = sched.demand_async(0, e, lambda i=idx: i)
            sched.wait_for(0, e, was_miss=miss)
        else:
            truth = rng.choice(store.num_experts,
                               size=int(rng.integers(1, 3)), replace=False)
            sched.reconcile(0, truth.tolist())


# ---------------------------------------------------------- transfer time --
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_transfer_never_completes_before_issue(seed):
    store = _store(seed=1)
    sched, _, eng = _sched(store)
    _drive(sched, store, seed)
    for rec in eng.records:
        assert rec.start_t >= rec.enqueue_t - 1e-12, rec
        assert rec.complete_t >= rec.start_t - 1e-12, rec
        assert rec.complete_t > rec.enqueue_t - 1e-12, rec


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_scheduler_clock_monotone(seed):
    store = _store(seed=2)
    sched, _, _ = _sched(store)
    rng = np.random.default_rng(seed)
    last = sched.clock
    for _ in range(30):
        _drive(sched, store, int(rng.integers(0, 10 ** 9)), n_ops=1)
        assert sched.clock >= last - 1e-15
        last = sched.clock
    assert sched.stats.stall_s >= 0.0


# ----------------------------------------------------------- no starvation -
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       n_demands=st.integers(min_value=1, max_value=6))
def test_demand_preemption_never_starves(seed, n_demands):
    """Speculative transfers pushed back by demand preemption still
    complete: after enough clock, nothing stays in flight forever."""
    store = _store(seed=3)
    sched, res, eng = _sched(store, slots=store.num_experts,
                             num_buffers=4)
    rng = np.random.default_rng(seed)
    f = store.d_ff
    for e in range(store.num_experts):
        sched.enqueue_prefetch(0, e, np.arange(f // 2), 0.5 + 0.1 * e)
    sched.pump()
    for _ in range(n_demands):
        e = int(rng.integers(0, store.num_experts))
        idx = np.arange(int(rng.integers(1, f)))
        payload, miss = sched.demand_async(0, e, lambda i=idx: i)
        sched.wait_for(0, e, was_miss=miss)
    sched.advance(1e6)  # plenty of modeled time
    assert eng.active_count(sched.clock) == 0
    assert not eng.inflight
    for rec in eng.records:
        assert np.isfinite(rec.complete_t)
        assert rec.complete_t >= rec.start_t - 1e-12


# ---------------------------------------------------------- residency caps -
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       capacity=st.integers(min_value=1, max_value=5))
def test_residency_never_exceeds_capacity(seed, capacity):
    rng = np.random.default_rng(seed)
    for policy in ("lru", "lfu", "weighted"):
        r = ResidencyManager(capacity, policy=policy)
        for _ in range(60):
            key = int(rng.integers(0, 10))
            if rng.random() < 0.6:
                r.put(key, key, score=float(rng.random()),
                      prefetch=bool(rng.integers(0, 2)))
            else:
                r.get(key)
            assert len(r) <= capacity


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_residency_with_pins_bounded_by_pinned_count(seed):
    """Pins can push residency past capacity only by the pinned entries
    themselves plus the single unpinned insert that found every victim
    candidate pinned — never unboundedly."""
    rng = np.random.default_rng(seed)
    pinned = list(range(4))
    r = ResidencyManager(2, policy="lru", pinned=pinned)
    for _ in range(40):
        key = int(rng.integers(0, 8))
        r.put(key, key)
        n_pinned = sum(k in r.pinned for k in r.keys())
        assert len(r) <= max(2, n_pinned + 1)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       capacity=st.integers(min_value=1, max_value=4))
def test_pinned_expert_eviction_impossible(seed, capacity):
    rng = np.random.default_rng(seed)
    for policy in ("lru", "lfu", "weighted"):
        r = ResidencyManager(capacity, policy=policy, pinned=["keep"])
        r.put("keep", 0)
        for _ in range(50):
            op = rng.integers(0, 3)
            key = int(rng.integers(0, 12))
            if op == 0:
                r.put(key, key, score=float(rng.random()))
            elif op == 1:
                r.get(key)
            else:
                r.get("keep")
            assert "keep" in r, policy


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_scheduler_trace_respects_residency_capacity(seed):
    store = _store(seed=4)
    sched, res, _ = _sched(store, slots=2)
    _drive(sched, store, seed, n_ops=50)
    assert len(res) <= 2


# ------------------------------------------------------------ demand_union -
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_demand_union_always_covers_need(seed):
    """After any history, a union demand's payload covers the requested
    channels with a sorted unique index set."""
    store = _store(seed=5)
    sched, res, _ = _sched(store, slots=store.num_experts)
    rng = np.random.default_rng(seed)
    f = store.d_ff
    _drive(sched, store, seed, n_ops=15)
    for _ in range(8):
        e = int(rng.integers(0, store.num_experts))
        need = np.sort(rng.choice(f, size=int(rng.integers(1, f)),
                                  replace=False))
        (idx, gate, down), miss = sched.demand_union(0, e, need)
        sched.wait_for(0, e, was_miss=miss)
        assert np.all(np.isin(need, idx))
        assert np.all(np.diff(idx) > 0)  # sorted, unique
        assert gate.shape[0] == idx.shape[0] == down.shape[0]


def test_reconcile_with_inflight_topup_does_not_crash():
    """Regression: top-up transfers live under compound inflight keys
    ((layer, expert), 'topup', seq); reconcile must not try to unpack
    them as (layer, expert) while one is still on the link."""
    store = _store(seed=7)
    sched, _, eng = _sched(store, slots=store.num_experts)
    payload, miss = sched.demand_async(0, 0, lambda: np.arange(4))
    sched.wait_for(0, 0, was_miss=miss)
    (idx, _, _), m = sched.demand_union(0, 0, np.arange(12))  # top-up
    assert any(isinstance(k, tuple) and len(k) == 3
               for k in eng.inflight), "scenario must leave a top-up live"
    cancelled = sched.reconcile(0, [0])  # must not raise
    assert cancelled == 0
    sched.wait_for(0, 0, was_miss=m)
    assert np.all(np.isin(np.arange(12), idx))


# ------------------------------------------------------ stats conservation -
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_demand_accounting_conserved(seed):
    """Every waited demand lands in exactly one bucket."""
    store = _store(seed=6)
    sched, _, _ = _sched(store, slots=store.num_experts)
    rng = np.random.default_rng(seed)
    f = store.d_ff
    n_waits = 0
    for _ in range(25):
        if rng.random() < 0.5:
            e = int(rng.integers(0, store.num_experts))
            sched.enqueue_prefetch(0, e, np.arange(f // 4),
                                   float(rng.random()))
            sched.pump()
        else:
            e = int(rng.integers(0, store.num_experts))
            idx = np.arange(int(rng.integers(1, f)))
            payload, miss = sched.demand_async(0, e, lambda i=idx: i)
            sched.wait_for(0, e, was_miss=miss)
            n_waits += 1
        sched.advance(float(rng.random()) * 1e-3)
    s = sched.stats
    assert (s.demand_hits + s.residual_waits + s.demand_reuse +
            s.demand_fetches) == n_waits
    assert 0.0 <= sched.prefetch_recall() <= 1.0
    assert 0.0 <= sched.prefetch_precision() <= 1.0


# --------------------------------------------- incremental union tracker ---
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       n_ops=st.integers(min_value=1, max_value=60))
def test_union_tracker_incremental_matches_rebuild(seed, n_ops):
    from repro.serving import UnionDemandTracker
    rng = np.random.default_rng(seed)
    f = 16
    tr = UnionDemandTracker(f)
    for _ in range(n_ops):
        rid = int(rng.integers(0, 5))
        if rng.random() < 0.25:
            tr.remove(rid)
        else:
            masks, conf = {}, {}
            for _ in range(int(rng.integers(0, 4))):
                key = (int(rng.integers(0, 2)), int(rng.integers(0, 4)))
                masks[key] = rng.random(f) < rng.random()
                conf[key] = (float(rng.random()), int(rng.integers(1, 3)))
            tr.set_contribution(rid, masks, conf)
        ref = tr.rebuild()
        assert set(tr.keys()) == set(ref.keys())
        for key in tr.keys():
            np.testing.assert_array_equal(tr.union(key), ref[key])


def test_union_tracker_counts_are_exact():
    """Counters equal the number of contributing requests per channel."""
    from repro.serving import UnionDemandTracker
    tr = UnionDemandTracker(4)
    m = np.array([True, True, False, False])
    tr.set_contribution(1, {(0, 0): m}, {(0, 0): (0.5, 1)})
    tr.set_contribution(2, {(0, 0): np.array([True, False, True, False])},
                        {(0, 0): (0.9, 2)})
    np.testing.assert_array_equal(tr._counts[(0, 0)],
                                  np.array([2, 1, 1, 0]))
    assert tr.confidence((0, 0)) == (0.9, 1)
    tr.remove(1)
    np.testing.assert_array_equal(tr.union((0, 0)),
                                  np.array([True, False, True, False]))
    tr.remove(2)
    assert tr.keys() == []


# ------------------------------------------------------------ stats surface -
def test_stats_reset_zeroes_every_public_field(  # noqa: D103
        ):
    """``SchedulerStats.reset()`` must zero EVERY public field — a field
    added without riding the ``dataclasses.fields`` loop (as the four
    ``spec_*`` speculation counters do) would survive a reset and leak
    one serve window's counts into the next report."""
    import dataclasses

    store = _store(seed=2)
    sched, _, _ = _sched(store)
    _drive(sched, store, 11)
    # guarantee at least one counter and the float accumulator moved
    idx = np.arange(store.d_ff // 2)
    payload, miss = sched.demand_async(0, 0, lambda: idx)
    sched.wait_for(0, 0, was_miss=miss)
    sched.stats.spec_served += 1  # the executor's counters ride along
    st_ = sched.stats
    assert any(getattr(st_, f.name) for f in dataclasses.fields(st_))
    st_.reset()
    for name, val in vars(st_).items():
        if name.startswith("_"):
            continue
        assert val == type(val)(), (name, val)
        assert type(val) in (int, float), (name, type(val))


def test_stats_report_surface_covers_every_field():
    """Every ``SchedulerStats`` field must appear in the metrics report
    as ``sched.<name>``, and every stall cause (including the
    speculation-era ``speculative_fallback``) as ``stall.cause.<c>_s``
    — the reporting surface may never silently lag the stats block."""
    import dataclasses

    from repro.obs import CAUSES, MetricsRegistry, scheduler_metrics

    store = _store(seed=3)
    sched, _, _ = _sched(store)
    _drive(sched, store, 5)
    snap = scheduler_metrics(MetricsRegistry(), sched).snapshot()
    for f in dataclasses.fields(sched.stats):
        assert f"sched.{f.name}" in snap, f.name
    for cause in CAUSES:
        assert f"stall.cause.{cause}_s" in snap, cause
